//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a realistic multi-user
//! workload through the request-lifecycle API over a real worker pool —
//! EDF batcher -> dispatch policy -> per-worker engines/sessions ->
//! query-aware decode — with the lifecycle features the monolithic
//! `serve_trace` loop could not express:
//!
//!   * tokens stream incrementally as `ServeEvent::Token`s;
//!   * `--workers N` decodes on N engine workers, each owning a slice of
//!     the KV budget (`--dispatch` picks round-robin / least-loaded /
//!     session-affinity), and `--threads N` steps them on real OS
//!     threads per decode round (per-worker utilization lands in the
//!     report);
//!   * one request is cancelled mid-stream and its KV pages provably
//!     return to its worker's pool (summed `bytes_in_use` drops at the
//!     cancel point);
//!   * `--deadline-ms D` puts an SLO on every 4th request — EDF admission
//!     pulls them forward, and the frontend sheds/aborts the ones that
//!     miss it anyway;
//!   * `--arrival poisson|gamma` switches from trace replay to the live
//!     open-loop generator (`--arrival-shape steady|ramp|burst|diurnal`).
//!
//!     cargo run --release --example serve_multiuser -- \
//!         --requests 64 --policy tinyserve --budget 256 --batch 4 \
//!         --workers 2 --dispatch least-loaded --cancel-after 3 \
//!         --deadline-ms 0

use anyhow::Result;

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::{
    DispatchKind, Frontend, Lifecycle, ServeEvent, ServeOptions, WorkerPool,
};
use tinyserve::plugins::{EntropyEarlyExit, Pipeline, RepetitionGuard};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::cli::Args;
use tinyserve::workload::{
    generate_trace, ArrivalProcess, LoadShape, OpenLoopConfig, OpenLoopGen,
    TraceConfig,
};

fn main() -> Result<()> {
    let args = Args::parse();
    let policy_arg = args.str_or("policy", "tinyserve");
    let policy = match PolicyKind::parse(&policy_arg) {
        Some(p) => p,
        None => {
            eprintln!(
                "unknown --policy '{policy_arg}'; valid: {}",
                PolicyKind::names().join("|")
            );
            std::process::exit(2);
        }
    };
    let dispatch_arg = args.str_or("dispatch", "least-loaded");
    let dispatch = match DispatchKind::parse(&dispatch_arg) {
        Some(d) => d,
        None => {
            eprintln!(
                "unknown --dispatch '{dispatch_arg}'; valid: {}",
                DispatchKind::names().join("|")
            );
            std::process::exit(2);
        }
    };
    let cfg = ServingConfig {
        model: args.str_or("model", "tiny-trained"),
        policy,
        budget: args.usize_or("budget", 256),
        max_batch: args.usize_or("batch", 4),
        kv_budget_mb: args.f64_opt("kv-budget-mb"),
        ..Default::default()
    };
    let workers = args.usize_or("workers", 2);
    let n_requests = args.usize_or("requests", 64);
    let seed = args.usize_or("seed", 42) as u64;
    let interarrival_ms = args.f64_or("interarrival-ms", 50.0);
    let session_prob = args.f64_or("session-prob", 0.35);
    let n_sessions = args.usize_or("sessions", 8);
    let arrival = args.str_or("arrival", "trace");
    let deadline_ms = args.f64_or("deadline-ms", 0.0);

    println!(
        "== multi-user serving: {n_requests} requests, model {}, policy {}, \
         budget {}, {workers} workers ({}), arrival {arrival} ==",
        cfg.model,
        policy.name(),
        cfg.budget,
        dispatch.name(),
    );
    let manifest = Manifest::load(&tinyserve::artifacts_dir())?;
    let pool = WorkerPool::build(&manifest, &cfg, workers, dispatch)?;
    pool.warmup()?;

    let opts = ServeOptions {
        collect_traces: true,
        seed,
        threads: args.usize_or("threads", 1),
        ..Default::default()
    };
    let mut plugins = Pipeline::new();
    plugins.push(Box::new(EntropyEarlyExit::new(0.05, 3, 4)));
    plugins.push(Box::new(RepetitionGuard { max_run: 16 }));
    let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);

    // pick a session-free, deadline-free request to cancel after
    // `cancel_after` streamed tokens (session-free so every one of its
    // pages is exclusively owned and the byte drop is unambiguous;
    // deadline-free so expiry cannot race the cancellation). Only the
    // trace mode knows its requests upfront; open-loop runs skip the demo.
    let cancel_after = args.usize_or("cancel-after", 3).max(1);
    let mut victim: Option<u64> = None;
    if arrival == "trace" {
        let mut trace = generate_trace(&TraceConfig {
            n_requests,
            mean_interarrival_s: interarrival_ms / 1e3,
            prompt_chars: (200, 600),
            new_tokens: (10, 30),
            session_reuse_prob: session_prob,
            n_sessions,
            seed,
        });
        // optional SLO: every 4th request must finish within --deadline-ms
        if deadline_ms > 0.0 {
            for req in trace.iter_mut().filter(|r| r.id % 4 == 0) {
                req.deadline_ms = Some(deadline_ms);
            }
        }
        victim = trace
            .iter()
            .find(|r| {
                r.session.is_none()
                    && r.deadline_ms.is_none()
                    && r.max_new_tokens > cancel_after + 2
            })
            .map(|r| r.id);
        for req in trace {
            fe.submit(req);
        }
    } else {
        let process = ArrivalProcess::parse(&arrival).unwrap_or_else(|| {
            eprintln!(
                "unknown --arrival '{arrival}'; valid: trace|{}",
                ArrivalProcess::names().join("|")
            );
            std::process::exit(2);
        });
        let shape_arg = args.str_or("arrival-shape", "burst");
        let shape = LoadShape::parse(&shape_arg).unwrap_or_else(|| {
            eprintln!(
                "unknown --arrival-shape '{shape_arg}'; valid: {}",
                LoadShape::names().join("|")
            );
            std::process::exit(2);
        });
        fe.set_source(Box::new(OpenLoopGen::new(OpenLoopConfig {
            n_requests,
            rate_rps: 1e3 / interarrival_ms.max(1e-6),
            process,
            shape,
            prompt_chars: (200, 600),
            new_tokens: (10, 30),
            session_reuse_prob: session_prob,
            n_sessions,
            deadline_ms: if deadline_ms > 0.0 { Some(deadline_ms) } else { None },
            deadline_every: 4,
            tier_interactive: 0.0,
            tier_background: 0.0,
            seed,
        })));
    }

    // pump the event loop, cancelling the victim mid-stream
    let t0 = std::time::Instant::now();
    let mut victim_tokens = 0usize;
    let mut cancel_bytes: Option<(usize, usize)> = None;
    while fe.has_work() {
        for ev in fe.step()? {
            match ev {
                ServeEvent::Token { id, .. } if Some(id) == victim => {
                    victim_tokens += 1;
                    if victim_tokens == cancel_after {
                        let before = fe.kv_bytes_in_use();
                        assert!(fe.cancel(id), "victim cancellable mid-stream");
                        let after = fe.kv_bytes_in_use();
                        assert!(
                            after < before,
                            "cancellation must return KV pages to its worker's \
                             pool ({after} !< {before})"
                        );
                        cancel_bytes = Some((before, after));
                    }
                }
                ServeEvent::DeadlineExpired { id, t } => {
                    println!("request {id} missed its deadline at {t:.2} s");
                }
                _ => {}
            }
        }
    }
    if let Some(id) = victim {
        match cancel_bytes {
            Some((before, after)) => {
                assert_eq!(fe.state_of(id), Some(Lifecycle::Cancelled));
                println!(
                    "cancelled request {id} after {victim_tokens} tokens: KV bytes \
                     {before} -> {after} ({} freed)",
                    before - after
                );
            }
            // only reachable with a large --cancel-after: a plugin (e.g.
            // entropy early-exit) can finish the victim first
            None => println!(
                "request {id} finished before the --cancel-after {cancel_after} \
                 trigger; rerun with a smaller value to see mid-stream \
                 cancellation"
            ),
        }
    }
    let (r, pool) = fe.into_parts();
    let real = t0.elapsed().as_secs_f64();
    let mut m = r.metrics;

    let mut t = Table::new("serve_multiuser report", &["metric", "value"]);
    let mut rows: Vec<(String, String)> = vec![
        ("requests completed".into(), format!("{}", m.total_requests)),
        ("cancelled".into(), format!("{}", m.total_cancelled)),
        ("deadline expired".into(), format!("{}", m.total_expired)),
        ("virtual wall clock".into(), format!("{:.2} s", r.wall_s)),
        ("real compute time".into(), format!("{real:.2} s")),
        ("worker busy (sum)".into(), format!("{:.0} %", r.busy_frac * 100.0)),
        ("throughput".into(), format!("{:.1} tok/s", m.throughput_tps())),
        ("request rate".into(), format!("{:.2} req/s", m.requests_per_sec())),
        ("decode latency".into(), format!("{:.2} ms/token", m.ms_per_token())),
        ("e2e latency p50".into(), format!("{:.0} ms", m.request_e2e.p50() * 1e3)),
        ("e2e latency p99".into(), format!("{:.0} ms", m.request_e2e.p99() * 1e3)),
        ("ttft p50".into(), format!("{:.0} ms", m.request_ttft.p50() * 1e3)),
        ("ttft p99".into(), format!("{:.0} ms", m.request_ttft.p99() * 1e3)),
        ("kv page hit rate".into(), format!("{:.1} %", m.hit_rate.mean() * 100.0)),
        ("exact-match accuracy".into(), format!("{:.1} %", r.accuracy * 100.0)),
        ("char accuracy".into(), format!("{:.1} %", r.char_accuracy * 100.0)),
        (
            "session reuse rate".into(),
            format!("{:.0} %", r.session_stats.reuse_rate() * 100.0),
        ),
        (
            "reused prefix tokens".into(),
            format!("{}", r.session_stats.reused_tokens),
        ),
        ("session migrations".into(), format!("{}", r.session_stats.migrations)),
        ("batcher max queue".into(), format!("{}", r.batcher_stats.max_queue_depth)),
        ("edf queue jumps".into(), format!("{}", r.batcher_stats.edf_jumps)),
        ("deferred admissions".into(), format!("{}", r.batcher_stats.deferred)),
    ];
    for (w, ws) in r.worker_stats.iter().enumerate() {
        rows.push((
            format!("worker {w}"),
            format!(
                "admitted {}  finished {}  tokens {}  steps {}  util {:.0}%  \
                 kv peak {:.2} MB",
                ws.admitted,
                ws.finished,
                ws.new_tokens,
                ws.steps,
                ws.utilization(r.wall_s) * 100.0,
                ws.kv_bytes_peak as f64 / 1e6
            ),
        ));
        assert_eq!(
            pool.engine(w).pool.pages_in_use(),
            0,
            "worker {w} leaked pages after the run"
        );
    }
    for (k, v) in rows {
        t.row(vec![k, v]);
    }
    t.emit(&tinyserve::results_dir(), "serve_multiuser");

    println!("\nper-task accuracy:");
    for (task, acc, n) in &r.per_task {
        println!("  {task:10} {:.0}%  (n={n})", acc * 100.0);
    }
    Ok(())
}
