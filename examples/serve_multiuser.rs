//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a realistic multi-user
//! Poisson workload through the request-lifecycle API — router ->
//! continuous batcher -> session store -> query-aware engine -> PJRT
//! executables — with the lifecycle features the monolithic `serve_trace`
//! loop could not express:
//!
//!   * tokens stream incrementally as `ServeEvent::Token`s;
//!   * one request is cancelled mid-stream and its KV pages provably
//!     return to the pool (`bytes_in_use` drops at the cancel point);
//!   * `--deadline-ms D` puts an SLO on every 4th request, and the
//!     frontend sheds/aborts the ones that miss it.
//!
//!     cargo run --release --example serve_multiuser -- \
//!         --requests 64 --policy tinyserve --budget 256 --batch 4 \
//!         --cancel-after 3 --deadline-ms 0

use anyhow::Result;

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::{Frontend, Lifecycle, ServeEvent, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::plugins::{EntropyEarlyExit, Pipeline, RepetitionGuard};
use tinyserve::report::Table;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::cli::Args;
use tinyserve::workload::{generate_trace, TraceConfig};

fn main() -> Result<()> {
    let args = Args::parse();
    let policy_arg = args.str_or("policy", "tinyserve");
    let policy = match PolicyKind::parse(&policy_arg) {
        Some(p) => p,
        None => {
            eprintln!(
                "unknown --policy '{policy_arg}'; valid: {}",
                PolicyKind::names().join("|")
            );
            std::process::exit(2);
        }
    };
    let cfg = ServingConfig {
        model: args.str_or("model", "tiny-trained"),
        policy,
        budget: args.usize_or("budget", 256),
        max_batch: args.usize_or("batch", 4),
        ..Default::default()
    };
    let trace_cfg = TraceConfig {
        n_requests: args.usize_or("requests", 64),
        mean_interarrival_s: args.f64_or("interarrival-ms", 50.0) / 1e3,
        prompt_chars: (200, 600),
        new_tokens: (10, 30),
        session_reuse_prob: args.f64_or("session-prob", 0.35),
        n_sessions: args.usize_or("sessions", 8),
        seed: args.usize_or("seed", 42) as u64,
    };

    println!(
        "== multi-user serving: {} requests, model {}, policy {}, budget {} ==",
        trace_cfg.n_requests, cfg.model, policy.name(), cfg.budget
    );
    let mut engine = Engine::new(&tinyserve::artifacts_dir(), cfg)?;
    engine.warmup()?;
    let mut trace = generate_trace(&trace_cfg);

    // optional SLO: every 4th request must finish within --deadline-ms
    let deadline_ms = args.f64_or("deadline-ms", 0.0);
    if deadline_ms > 0.0 {
        for req in trace.iter_mut().filter(|r| r.id % 4 == 0) {
            req.deadline_ms = Some(deadline_ms);
        }
    }
    // pick a session-free, deadline-free request to cancel after
    // `cancel_after` streamed tokens (session-free so every one of its
    // pages is exclusively owned and the byte drop is unambiguous;
    // deadline-free so expiry cannot race the cancellation)
    let cancel_after = args.usize_or("cancel-after", 3).max(1);
    let victim: Option<u64> = trace
        .iter()
        .find(|r| {
            r.session.is_none()
                && r.deadline_ms.is_none()
                && r.max_new_tokens > cancel_after + 2
        })
        .map(|r| r.id);

    let opts = ServeOptions {
        n_workers: args.usize_or("workers", 4),
        collect_traces: true,
        ..Default::default()
    };
    let mut plugins = Pipeline::new();
    plugins.push(Box::new(EntropyEarlyExit::new(0.05, 3, 4)));
    plugins.push(Box::new(RepetitionGuard { max_run: 16 }));

    let t0 = std::time::Instant::now();
    let mut fe = Frontend::builder().options(opts).build(&mut engine, &mut plugins);
    for req in trace {
        fe.submit(req);
    }

    // pump the event loop, cancelling the victim mid-stream
    let mut victim_tokens = 0usize;
    let mut cancel_bytes: Option<(usize, usize)> = None;
    while fe.has_work() {
        for ev in fe.step()? {
            match ev {
                ServeEvent::Token { id, .. } if Some(id) == victim => {
                    victim_tokens += 1;
                    if victim_tokens == cancel_after {
                        let before =
                            fe.engine().store.bytes_in_use(&fe.engine().pool);
                        assert!(fe.cancel(id), "victim cancellable mid-stream");
                        let after =
                            fe.engine().store.bytes_in_use(&fe.engine().pool);
                        assert!(
                            after < before,
                            "cancellation must return KV pages to the pool \
                             ({after} !< {before})"
                        );
                        cancel_bytes = Some((before, after));
                    }
                }
                ServeEvent::DeadlineExpired { id, t } => {
                    println!("request {id} missed its deadline at {t:.2} s");
                }
                _ => {}
            }
        }
    }
    if let Some(id) = victim {
        match cancel_bytes {
            Some((before, after)) => {
                assert_eq!(fe.state_of(id), Some(Lifecycle::Cancelled));
                println!(
                    "cancelled request {id} after {victim_tokens} tokens: KV bytes \
                     {before} -> {after} ({} freed)",
                    before - after
                );
            }
            // only reachable with a large --cancel-after: a plugin (e.g.
            // entropy early-exit) can finish the victim first
            None => println!(
                "request {id} finished before the --cancel-after {cancel_after} \
                 trigger; rerun with a smaller value to see mid-stream \
                 cancellation"
            ),
        }
    }
    let r = fe.into_report();
    let real = t0.elapsed().as_secs_f64();
    let mut m = r.metrics;

    let mut t = Table::new("serve_multiuser report", &["metric", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("requests completed", format!("{}", m.total_requests)),
        ("cancelled", format!("{}", m.total_cancelled)),
        ("deadline expired", format!("{}", m.total_expired)),
        ("virtual wall clock", format!("{:.2} s", r.wall_s)),
        ("real compute time", format!("{real:.2} s")),
        ("engine busy", format!("{:.0} %", r.busy_frac * 100.0)),
        ("throughput", format!("{:.1} tok/s", m.throughput_tps())),
        ("request rate", format!("{:.2} req/s", m.requests_per_sec())),
        ("decode latency", format!("{:.2} ms/token", m.ms_per_token())),
        ("e2e latency p50", format!("{:.0} ms", m.request_e2e.p50() * 1e3)),
        ("e2e latency p99", format!("{:.0} ms", m.request_e2e.p99() * 1e3)),
        ("ttft p50", format!("{:.0} ms", m.request_ttft.p50() * 1e3)),
        ("ttft p99", format!("{:.0} ms", m.request_ttft.p99() * 1e3)),
        ("kv page hit rate", format!("{:.1} %", m.hit_rate.mean() * 100.0)),
        ("exact-match accuracy", format!("{:.1} %", r.accuracy * 100.0)),
        ("char accuracy", format!("{:.1} %", r.char_accuracy * 100.0)),
        ("session reuse rate", format!("{:.0} %", r.session_stats.reuse_rate() * 100.0)),
        ("reused prefix tokens", format!("{}", r.session_stats.reused_tokens)),
        ("session migrations", format!("{}", r.session_stats.migrations)),
        ("batcher max queue", format!("{}", r.batcher_stats.max_queue_depth)),
        ("peak KV pages", format!("{}", engine.pool.peak_pages)),
    ];
    for (k, v) in rows {
        t.row(vec![k.into(), v]);
    }
    t.emit(&tinyserve::results_dir(), "serve_multiuser");

    println!("\nper-task accuracy:");
    for (task, acc, n) in &r.per_task {
        println!("  {task:10} {:.0}%  (n={n})", acc * 100.0);
    }
    Ok(())
}
