//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a realistic multi-user
//! Poisson workload against the trained tiny model through the full stack —
//! router -> continuous batcher -> session store -> query-aware engine ->
//! PJRT executables — and report latency percentiles, throughput and
//! exact-match accuracy.
//!
//!     cargo run --release --example serve_multiuser -- \
//!         --requests 64 --policy tinyserve --budget 256 --batch 4

use anyhow::Result;

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::{serve_trace, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::plugins::{EntropyEarlyExit, Pipeline, RepetitionGuard};
use tinyserve::report::Table;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::cli::Args;
use tinyserve::workload::{generate_trace, TraceConfig};

fn main() -> Result<()> {
    let args = Args::parse();
    let policy = PolicyKind::parse(&args.str_or("policy", "tinyserve"))
        .expect("bad --policy");
    let cfg = ServingConfig {
        model: args.str_or("model", "tiny-trained"),
        policy,
        budget: args.usize_or("budget", 256),
        max_batch: args.usize_or("batch", 4),
        ..Default::default()
    };
    let trace_cfg = TraceConfig {
        n_requests: args.usize_or("requests", 64),
        mean_interarrival_s: args.f64_or("interarrival-ms", 50.0) / 1e3,
        prompt_chars: (200, 600),
        new_tokens: (10, 30),
        session_reuse_prob: args.f64_or("session-prob", 0.35),
        n_sessions: args.usize_or("sessions", 8),
        seed: args.usize_or("seed", 42) as u64,
    };

    println!(
        "== multi-user serving: {} requests, model {}, policy {}, budget {} ==",
        trace_cfg.n_requests, cfg.model, policy.name(), cfg.budget
    );
    let mut engine = Engine::new(&tinyserve::artifacts_dir(), cfg)?;
    engine.warmup()?;
    let trace = generate_trace(&trace_cfg);
    let opts = ServeOptions {
        n_workers: args.usize_or("workers", 4),
        collect_traces: true,
        ..Default::default()
    };
    let mut plugins = Pipeline::new();
    plugins.push(Box::new(EntropyEarlyExit::new(0.05, 3, 4)));
    plugins.push(Box::new(RepetitionGuard { max_run: 16 }));

    let t0 = std::time::Instant::now();
    let r = serve_trace(&mut engine, &trace, &opts, &mut plugins)?;
    let real = t0.elapsed().as_secs_f64();
    let mut m = r.metrics;

    let mut t = Table::new("serve_multiuser report", &["metric", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("requests completed", format!("{}", m.total_requests)),
        ("virtual wall clock", format!("{:.2} s", r.wall_s)),
        ("real compute time", format!("{real:.2} s")),
        ("engine busy", format!("{:.0} %", r.busy_frac * 100.0)),
        ("throughput", format!("{:.1} tok/s", m.throughput_tps())),
        ("request rate", format!("{:.2} req/s", m.requests_per_sec())),
        ("decode latency", format!("{:.2} ms/token", m.ms_per_token())),
        ("e2e latency p50", format!("{:.0} ms", m.request_e2e.p50() * 1e3)),
        ("e2e latency p99", format!("{:.0} ms", m.request_e2e.p99() * 1e3)),
        ("ttft p50", format!("{:.0} ms", m.request_ttft.p50() * 1e3)),
        ("kv page hit rate", format!("{:.1} %", m.hit_rate.mean() * 100.0)),
        ("exact-match accuracy", format!("{:.1} %", r.accuracy * 100.0)),
        ("char accuracy", format!("{:.1} %", r.char_accuracy * 100.0)),
        ("session reuse rate", format!("{:.0} %", r.session_stats.reuse_rate() * 100.0)),
        ("reused prefix tokens", format!("{}", r.session_stats.reused_tokens)),
        ("session migrations", format!("{}", r.session_stats.migrations)),
        ("batcher max queue", format!("{}", r.batcher_stats.max_queue_depth)),
        ("peak KV pages", format!("{}", engine.pool.peak_pages)),
    ];
    for (k, v) in rows {
        t.row(vec![k.into(), v]);
    }
    t.emit(&tinyserve::results_dir(), "serve_multiuser");

    println!("\nper-task accuracy:");
    for (task, acc, n) in &r.per_task {
        println!("  {task:10} {:.0}%  (n={n})", acc * 100.0);
    }
    Ok(())
}
