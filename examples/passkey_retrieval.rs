//! Passkey retrieval under cache pressure: sweep the needle position and
//! KV budget, compare selection policies. This is the experiment that
//! motivates query-aware selection (paper Fig. 1): StreamingLLM loses the
//! needle once it leaves the window, TinyServe retrieves it from anywhere.
//!
//!     cargo run --release --example passkey_retrieval -- --n 8

use anyhow::Result;

use tinyserve::config::ServingConfig;
use tinyserve::engine::{Engine, Sampling};
use tinyserve::metrics::StepMetrics;
use tinyserve::report::Table;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::cli::Args;
use tinyserve::util::rng::Rng;
use tinyserve::workload::tasks;

/// Passkey at a controlled depth: 0.0 = start of context, 1.0 = end.
fn doc_at_depth(rng: &mut Rng, total_chars: usize, depth: f64) -> tasks::Doc {
    let base = tasks::passkey_doc(rng, total_chars);
    // passkey_doc puts the needle at the start; re-embed it at `depth`
    let needle_end = base.prompt.find(". Remember it. ").unwrap() + 15;
    let needle = &base.prompt[..needle_end];
    let rest = &base.prompt[needle_end..];
    let tail_q = "What is the pass key? Answer: ";
    let body = &rest[..rest.len() - tail_q.len()];
    let cut = ((body.len() as f64) * depth) as usize;
    tasks::Doc {
        prompt: format!("{}{}{}{}", &body[..cut], needle, &body[cut..], tail_q),
        answer: base.answer,
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let n = args.usize_or("n", 6);
    let chars = args.usize_or("chars", 800);
    let budget = args.usize_or("budget", 256);
    let model = args.str_or("model", "tiny-trained");

    let policies = [
        PolicyKind::FullCache,
        PolicyKind::StreamingLlm,
        PolicyKind::TinyServe,
        PolicyKind::Oracle,
    ];
    let depths = [0.0, 0.25, 0.5, 0.75];

    let mut t = Table::new(
        &format!("passkey retrieval: needle depth x policy (budget {budget}, ~{chars} chars)"),
        &["depth", "policy", "exact %", "char %", "ms/tok"],
    );
    for &depth in &depths {
        for &policy in &policies {
            let b = if policy == PolicyKind::FullCache { 4096 } else { budget };
            let cfg = ServingConfig {
                model: model.clone(),
                policy,
                budget: b,
                max_batch: 1,
                ..Default::default()
            };
            let mut engine = Engine::new(&tinyserve::artifacts_dir(), cfg)?;
            let mut task_rng = Rng::new(1234);
            let mut rng = Rng::new(5);
            let mut exact = 0usize;
            let mut chacc = 0.0;
            let mut ms = 0.0;
            let mut steps = 0usize;
            for _ in 0..n {
                let doc = doc_at_depth(&mut task_rng, chars, depth);
                let mut seq = engine.new_sequence_with_policy(policy);
                seq.tokens = tasks::encode_prompt(&doc.prompt);
                seq.max_new_tokens = doc.answer.len() + 3;
                let mut m = StepMetrics::default();
                engine.prefill(&mut seq, &mut m)?;
                while !seq.finished {
                    let mut m = StepMetrics::default();
                    let mut batch = [&mut seq];
                    engine.decode_step(&mut batch, Sampling::Greedy, &mut rng, &mut m)?;
                    ms += m.step_seconds * 1e3;
                    steps += 1;
                }
                let gen = tasks::decode_ids(seq.generated_tokens());
                exact += tasks::answer_matches(&doc, &gen) as usize;
                chacc += tasks::answer_char_accuracy(&doc, &gen);
                engine.release(&mut seq);
            }
            t.row(vec![
                format!("{depth:.2}"),
                policy.name().into(),
                format!("{:.0}", exact as f64 / n as f64 * 100.0),
                format!("{:.0}", chacc / n as f64 * 100.0),
                format!("{:.2}", ms / steps.max(1) as f64),
            ]);
        }
    }
    t.emit(&tinyserve::results_dir(), "passkey_retrieval");
    Ok(())
}
