//! Closed-loop TCP client for the network serving front door
//! (docs/network_serving.md): N concurrent connections, each holding one
//! request in flight, thinking between completions, retrying on typed
//! `retry` backpressure and reporting typed `overload` sheds.
//!
//! Two modes:
//!
//!   * `--addr HOST:PORT` — drive an external `tinyserve serve --listen`
//!     server (the real engine path).
//!   * no `--addr` — self-serve: bind an in-process server over the
//!     deterministic `MockBackend` on an ephemeral loopback port and drive
//!     it. Runs everywhere (no artifacts); with `--conns 1` the server's
//!     virtual clock makes the whole exchange seed-deterministic, and
//!     `--trace-out FILE` dumps the server-side connection/request trace
//!     for byte-diffing across runs (the CI loopback smoke job does
//!     exactly this, twice, and diffs).
//!
//!     cargo run --release --example serve_client -- \
//!         --conns 1 --requests 8 --seed 7 --trace-out /tmp/net1.jsonl
//!
//! Backpressure demo: shrink the server with --max-conns / --queue-depth /
//! --shed-policy shed and raise --conns to watch typed sheds instead of
//! unbounded queueing.

use anyhow::Result;

use tinyserve::report::Table;
use tinyserve::server::shed::{AdmissionConfig, ShedPolicy};
use tinyserve::server::{MockBackend, Server, ServerConfig};
use tinyserve::util::cli::Args;
use tinyserve::workload::{run_closed_loop, ClientConfig, SloTier};

fn main() -> Result<()> {
    let args = Args::parse();
    let tier = match args.get("tier") {
        None => None,
        Some(t) => match SloTier::parse(t) {
            Some(t) => Some(t),
            None => {
                eprintln!(
                    "unknown --tier '{t}'; valid: {}",
                    SloTier::names().join("|")
                );
                std::process::exit(2);
            }
        },
    };
    let mut client = ClientConfig {
        addr: args.str_or("addr", ""),
        conns: args.usize_or("conns", 2),
        requests_per_conn: args.usize_or("requests", 4),
        prompt_chars: args.usize_or("prompt-chars", 400),
        max_new_tokens: args.usize_or("max-new", 16),
        think_ms: args.f64_or("think-ms", 0.0),
        seed: args.usize_or("seed", 42) as u64,
        deadline_ms: args.f64_opt("deadline-ms"),
        tier,
        max_retries: args.usize_or("max-retries", 8),
    };

    // self-serve: spin up a MockBackend server on an ephemeral port
    let mut self_serve = None;
    if client.addr.is_empty() {
        let policy_arg = args.str_or("shed-policy", "defer");
        let policy = ShedPolicy::parse(&policy_arg).unwrap_or_else(|| {
            eprintln!(
                "unknown --shed-policy '{policy_arg}'; valid: {}",
                ShedPolicy::names().join("|")
            );
            std::process::exit(2);
        });
        let cfg = ServerConfig {
            exit_when_idle: true,
            admission: AdmissionConfig {
                max_conns: args.usize_or("max-conns", 64),
                queue_depth: args.usize_or("queue-depth", 256),
                policy,
                ..AdmissionConfig::default()
            },
            ..ServerConfig::default()
        };
        let server = Server::bind(cfg)?;
        client.addr = server.local_addr()?.to_string();
        println!("self-serving MockBackend on {}", client.addr);
        self_serve = Some(std::thread::spawn(move || {
            let mut backend = MockBackend::new();
            let stats = server.run(&mut backend);
            (stats, backend)
        }));
    }

    let t0 = std::time::Instant::now();
    let stats = run_closed_loop(&client)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new("serve_client report", &["metric", "value"]);
    for (k, v) in [
        ("connections", client.conns.to_string()),
        ("submitted", stats.submitted.to_string()),
        ("finished", stats.finished.to_string()),
        ("cancelled", stats.cancelled.to_string()),
        ("expired", stats.expired.to_string()),
        ("retried (deferred)", stats.retried.to_string()),
        ("overloaded (shed)", stats.overloaded.to_string()),
        ("conns shed", stats.conns_shed.to_string()),
        ("tokens streamed", stats.tokens.to_string()),
        ("wall time", format!("{wall:.3} s")),
    ] {
        t.row(vec![k.to_string(), v]);
    }
    t.emit(&tinyserve::results_dir(), "serve_client");

    if let Some(handle) = self_serve {
        let (server_stats, backend) = handle.join().expect("server thread");
        let server_stats = server_stats?;
        println!(
            "server: accepted {} closed {} submits {} deferred {} shed {}+{}",
            server_stats.accepted,
            server_stats.closed,
            server_stats.submitted,
            server_stats.shed.submits_deferred,
            server_stats.shed.conns_shed,
            server_stats.shed.submits_shed,
        );
        assert_eq!(
            backend.kv_bytes_in_use(),
            0,
            "server leaked KV bytes after a clean drain"
        );
        if let Some(path) = args.get("trace-out") {
            // conn lifecycle spans, then the full event-signature stream:
            // with --conns 1 both are pure functions of the seed, so two
            // runs of this example must write byte-identical files
            let mut lines = backend.trace.clone();
            lines.extend(backend.event_log.iter().cloned());
            std::fs::write(path, lines.join("\n") + "\n")?;
            println!("server trace ({} lines) -> {path}", lines.len());
        }
    }
    Ok(())
}
