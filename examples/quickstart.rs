//! Quickstart: load a model and stream a generation through the
//! request-lifecycle serving API — submit a request, pump the event loop,
//! and watch tokens surface one by one as typed `ServeEvent`s.
//!
//! Run after `make artifacts && cargo build --release`:
//!     cargo run --release --example quickstart

use anyhow::Result;

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::{Frontend, Lifecycle, ServeEvent, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::plugins::Pipeline;
use tinyserve::util::rng::Rng;
use tinyserve::workload::{tasks, Request};

fn main() -> Result<()> {
    // 1. serving configuration: paper defaults (S=16, query-aware policy)
    let cfg = ServingConfig {
        model: "tiny-trained".into(),
        budget: 256, // attention token budget per step
        ..Default::default()
    };
    println!(
        "model={} policy={} page_size={} budget={}",
        cfg.model,
        cfg.policy.name(),
        cfg.page_size,
        cfg.budget
    );

    // 2. engine = PJRT runtime + paged KV pool + policy machinery
    let mut engine = Engine::new(&tinyserve::artifacts_dir(), cfg)?;
    engine.warmup()?; // compile decode executables up front

    // 3. build a retrieval prompt with a known answer
    let mut task_rng = Rng::new(7);
    let doc = tasks::make_doc(&mut task_rng, tasks::Task::Passkey, 400);
    println!("\nprompt tail: ...{:?}", &doc.prompt[doc.prompt.len() - 60..]);
    println!("expected answer: {:?}\n", doc.answer);

    // 4. frontend = virtual clock + batcher + router + sessions over the
    //    engine; submit returns immediately with a handle
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder()
        .options(ServeOptions::default())
        .build(&mut engine, &mut plugins);
    let handle = fe.submit(Request {
        id: 0,
        arrival_s: 0.0,
        prompt: tasks::encode_prompt(&doc.prompt),
        max_new_tokens: 8,
        session: None,
        task: None,
        answer: Some(doc.answer.clone()),
        deadline_ms: None,
        tier: Default::default(),
    });

    // 5. pump the event loop: each step yields typed events, and tokens
    //    stream incrementally instead of arriving as one final report
    let mut generated = String::new();
    while fe.has_work() {
        for ev in fe.step()? {
            match ev {
                ServeEvent::Admitted { id, t } => {
                    println!("[{t:7.3}s] request {id} admitted, prefilling");
                }
                ServeEvent::Token { id, tok, t } => {
                    let piece = tasks::decode_ids(&[tok]);
                    generated.push_str(&piece);
                    println!(
                        "[{t:7.3}s] request {id} token {tok:>4} {piece:?}  \
                         ({} KV pages resident)",
                        fe.engine().pool.pages_in_use()
                    );
                }
                ServeEvent::Finished(rec) => {
                    println!(
                        "[{:7.3}s] request {} finished: {} new tokens, \
                         ttft {:.1} ms, e2e {:.1} ms",
                        rec.e2e_seconds,
                        rec.id,
                        rec.new_tokens,
                        rec.ttft_seconds * 1e3,
                        rec.e2e_seconds * 1e3
                    );
                }
                other => println!("event: {other:?}"),
            }
        }
    }
    assert_eq!(fe.state_of(handle.id), Some(Lifecycle::Finished));
    let report = fe.into_report();

    println!("\ngenerated: {generated:?}");
    println!(
        "exact match: {}",
        if tasks::answer_matches(&doc, &generated) { "YES" } else { "no" }
    );
    println!(
        "throughput {:.1} tok/s over {:.2} s virtual ({:.1} ms/token decode)",
        report.metrics.throughput_tps(),
        report.wall_s,
        report.metrics.ms_per_token()
    );

    // 6. runtime counters (the instrumentation layer)
    let s = engine.rt.stats();
    println!(
        "\nruntime: {} executions, {:.1} MB h2d, {:.1} MB d2h, {:.1} ms exec",
        s.executions,
        s.h2d_bytes as f64 / 1e6,
        s.d2h_bytes as f64 / 1e6,
        s.exec_seconds * 1e3
    );
    Ok(())
}
