//! Quickstart: load a model, prefill a prompt, stream a greedy generation,
//! and print the per-step serving metrics the paper's instrumentation
//! exposes (selected pages, gather bytes, attention entropy, KV hit rate).
//!
//! Run after `make artifacts && cargo build --release`:
//!     cargo run --release --example quickstart

use anyhow::Result;

use tinyserve::config::ServingConfig;
use tinyserve::engine::{Engine, Sampling};
use tinyserve::metrics::StepMetrics;
use tinyserve::util::rng::Rng;
use tinyserve::workload::tasks;

fn main() -> Result<()> {
    // 1. serving configuration: paper defaults (S=16, query-aware policy)
    let cfg = ServingConfig {
        model: "tiny-trained".into(),
        budget: 256, // attention token budget per step
        ..Default::default()
    };
    println!(
        "model={} policy={} page_size={} budget={}",
        cfg.model,
        cfg.policy.name(),
        cfg.page_size,
        cfg.budget
    );

    // 2. engine = PJRT runtime + paged KV pool + policy machinery
    let mut engine = Engine::new(&tinyserve::artifacts_dir(), cfg)?;
    engine.warmup()?; // compile decode executables up front

    // 3. build a retrieval prompt with a known answer
    let mut task_rng = Rng::new(7);
    let doc = tasks::make_doc(&mut task_rng, tasks::Task::Passkey, 400);
    println!("\nprompt tail: ...{:?}", &doc.prompt[doc.prompt.len() - 60..]);
    println!("expected answer: {:?}\n", doc.answer);

    let mut seq = engine.new_sequence();
    seq.tokens = tasks::encode_prompt(&doc.prompt);
    seq.max_new_tokens = 8;

    // 4. prefill (chunked artifact path), then decode token by token
    let mut m = StepMetrics::default();
    engine.prefill(&mut seq, &mut m)?;
    println!(
        "prefill: {} tokens, {} pages, {:.1} ms",
        seq.cache.pos,
        seq.cache.n_pages(),
        m.step_seconds * 1e3
    );

    let mut rng = Rng::new(42);
    while !seq.finished {
        let mut m = StepMetrics::default();
        let out = {
            let mut batch = [&mut seq];
            engine.decode_step(&mut batch, Sampling::Greedy, &mut rng, &mut m)?
        };
        let tok = out[0].token;
        println!(
            "step {:2}  token {:>4} {:?}  {:5.1} ms  pages {:2}/{:2}  hit {:4.0}%  \
             gather {:6.1} KB  entropy {:.2}",
            seq.generated,
            tok,
            tasks::decode_ids(&[tok]),
            m.step_seconds * 1e3,
            m.pages_selected / engine.n_layer,
            seq.cache.n_pages(),
            m.hit_rate() * 100.0,
            m.gather_bytes as f64 / 1e3,
            m.entropy,
        );
    }

    let generated = tasks::decode_ids(seq.generated_tokens());
    println!("\ngenerated: {generated:?}");
    println!(
        "exact match: {}",
        if tasks::answer_matches(&doc, &generated) { "YES" } else { "no" }
    );
    engine.release(&mut seq);

    // 5. runtime counters (the instrumentation layer)
    let s = engine.rt.stats();
    println!(
        "\nruntime: {} executions, {:.1} MB h2d, {:.1} MB d2h, {:.1} ms exec",
        s.executions,
        s.h2d_bytes as f64 / 1e6,
        s.d2h_bytes as f64 / 1e6,
        s.exec_seconds * 1e3
    );
    Ok(())
}
