//! Regenerate the remaining paper figures' DATA (F1, F4, F6, F7), the
//! §3.6 memory-model curves, and a quick Table 9 eviction sweep; tables
//! T1-T9 + F3/F5 live in `benches/` (run `cargo bench`, or `make bench`).
//! CSVs land in results/.
//!
//!     cargo run --release --example paper_tables            # all figures
//!     cargo run --release --example paper_tables -- f7      # one figure
//!     cargo run --release --example paper_tables -- t9      # budget sweep

use anyhow::Result;

use tinyserve::config::{KvDtype, ServingConfig};
use tinyserve::engine::{Engine, Sampling};
use tinyserve::harness::{measure_decode, scale};
use tinyserve::hwmodel::HwModel;
use tinyserve::metrics::StepMetrics;
use tinyserve::report::{Series, Table};
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::{PolicyKind, SelectCtx};
use tinyserve::util::cli::Args;
use tinyserve::util::rng::Rng;

const MODEL: &str = "tiny-trained";

/// F1 — motivation heatmap data: page relevance scores for a set of
/// consecutive decode-step queries (shows the selected set shifting).
fn fig1(manifest: &Manifest) -> Result<()> {
    let cfg = ServingConfig {
        model: MODEL.into(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 1,
        ..Default::default()
    };
    let mut engine = Engine::from_manifest(manifest, cfg)?;
    let mut rng = Rng::new(3);
    let mut seq = engine.new_sequence();
    engine.synthetic_fill(&mut seq, 511, &mut rng);
    seq.tokens.push(1);
    seq.max_new_tokens = usize::MAX / 2;

    let n_pages = seq.cache.n_pages();
    let mut t = Table::new(
        "Figure 1: per-step page scores (query-dependence of relevance)",
        &["step", "page", "score", "selected"],
    );
    for step in 0..scale(12) {
        // run one step; afterwards recompute layer-0 scores for the trace
        let mut m = StepMetrics::default();
        {
            let mut b = [&mut seq];
            engine.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m)?;
        }
        // score pages with a probe query derived from the step (the engine
        // consumed the real q; we reuse metadata + a fresh probe to expose
        // the score structure)
        let d = engine.d_kv;
        let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut policy = tinyserve::sparsity::make_policy(PolicyKind::TinyServe);
        let ctx = SelectCtx {
            layer: 0,
            n_layers: engine.n_layer,
            q: &q,
            pool: &engine.pool,
            seq: &seq.cache,
            budget_pages: 16,
            sink_pages: 1,
            recent_pages: 2,
            last_entropy: f32::NAN,
        };
        let mut sel = Vec::new();
        policy.select_into(&ctx, &mut sel);
        for p in 0..n_pages.min(seq.cache.n_pages()) {
            let score = tinyserve::sparsity::score_page(
                &q,
                engine.pool.meta(seq.cache.pages[p].id, 0),
            );
            t.row(vec![
                format!("{step}"),
                format!("{p}"),
                format!("{score:.3}"),
                format!("{}", sel.contains(&p) as u8),
            ]);
        }
    }
    engine.release(&mut seq);
    t.emit(&tinyserve::results_dir(), "fig1_query_scores");
    Ok(())
}

/// F4 — radar data: normalized accuracy / latency / throughput / hit rate
/// per policy (reads table4 results if present, else measures quickly).
fn fig4(manifest: &Manifest) -> Result<()> {
    let mut t = Table::new(
        "Figure 4: radar axes per policy (tiny-trained)",
        &["policy", "ms/tok", "tok/s", "KV hit %", "gather MB/step"],
    );
    for &policy in PolicyKind::all() {
        let budget = if policy == PolicyKind::FullCache { 4096 } else { 256 };
        match measure_decode(
            manifest, MODEL, policy, 1024, budget, 1, scale(12), KvDtype::F32,
        ) {
            Ok(r) => {
                t.row(vec![
                    policy.name().into(),
                    format!("{:.2}", r.ms_per_token),
                    format!("{:.1}", r.tokens_per_s),
                    format!("{:.1}", r.hit_rate * 100.0),
                    format!("{:.2}", r.gather_bytes_per_step / 1e6),
                ]);
            }
            Err(e) => eprintln!("skip {policy:?}: {e}"),
        }
    }
    t.emit(&tinyserve::results_dir(), "fig4_radar");
    Ok(())
}

/// F6/F7 — KV reuse + bandwidth traces over decode steps per strategy.
fn fig67(manifest: &Manifest) -> Result<()> {
    let steps = scale(48);
    let policies = [
        PolicyKind::FullCache,
        PolicyKind::StreamingLlm,
        PolicyKind::TinyServe,
    ];
    let mut hit = Series::new("Figure 6: KV page reuse over decode steps", "step");
    let mut bw = Series::new(
        "Figure 7: gather traffic per decode step (HBM analogue)",
        "step",
    );
    hit.x = (0..steps).map(|i| i as f64).collect();
    bw.x = hit.x.clone();
    for &p in &policies {
        let budget = if p == PolicyKind::FullCache { 4096 } else { 256 };
        let r = measure_decode(manifest, MODEL, p, 2048, budget, 1, steps, KvDtype::F32)?;
        hit.columns.push((p.name().to_string(), r.trace_hit.clone()));
        bw.columns.push((
            p.name().to_string(),
            r.trace_bytes.iter().map(|b| b / 1e6).collect(),
        ));
        println!(
            "{}: mean gather {:.2} MB/step, hit {:.0}%",
            p.name(),
            r.gather_bytes_per_step / 1e6,
            r.hit_rate * 100.0
        );
    }
    hit.emit(&tinyserve::results_dir(), "fig6_kv_reuse");
    bw.emit(&tinyserve::results_dir(), "fig7_bandwidth");
    Ok(())
}

/// T9 (quick variant) — memory-budgeted page store: residency hit rate
/// and accuracy at 50% of the unbounded KV peak per eviction policy,
/// with the disk spill tier enabled (spill budget = peak). The full
/// three-tier budget sweep lives in `benches/table9_eviction.rs`; this
/// entry registers the table with the one-command figure regeneration
/// flow.
fn table9(manifest: &Manifest) -> Result<()> {
    use tinyserve::harness::{measure_eviction, EvictionCase};
    use tinyserve::kvcache::EvictionPolicyKind;
    let base_case = EvictionCase {
        n_cases: scale(6),
        prompt_chars: 500,
        budget_tokens: 256,
        seed: 11,
        ..Default::default()
    };
    let base = measure_eviction(manifest, MODEL, &base_case)?;
    let budget = base.bytes_peak_unbounded / 2;
    let mut t = Table::new(
        &format!(
            "Table 9 (quick): eviction policies at 50% of {:.2} MB peak \
             (disk spill on)",
            base.bytes_peak_unbounded as f64 / 1e6
        ),
        &[
            "policy",
            "resid hit %",
            "demote/tok",
            "acc %",
            "Δacc pp",
            "viol",
            "faults",
        ],
    );
    for &kind in EvictionPolicyKind::all() {
        let case = EvictionCase {
            eviction: kind,
            budget_bytes: Some(budget),
            spill_budget_bytes: Some(base.bytes_peak_unbounded.max(1)),
            readahead_pages: 2,
            ..base_case.clone()
        };
        match measure_eviction(manifest, MODEL, &case) {
            Ok(r) => {
                t.row(vec![
                    kind.name().to_string(),
                    format!("{:.1}", r.residency_hit_rate * 100.0),
                    format!("{:.3}", r.demotions_per_token),
                    format!("{:.1}", r.accuracy * 100.0),
                    format!("{:+.1}", (r.accuracy - base.accuracy) * 100.0),
                    format!("{}", r.violations),
                    format!("{}", r.disk_faults),
                ]);
            }
            Err(e) => eprintln!("skip {}: {e}", kind.name()),
        }
    }
    t.emit(&tinyserve::results_dir(), "table9_eviction_quick");
    Ok(())
}

/// §3.6 memory model curves: memory fraction vs page size and the optimal
/// S* = sqrt(L/K) prediction.
fn memmodel() -> Result<()> {
    let mut s = Series::new("§3.6 memory fraction vs page size (L=32K, K=0.3P)", "S");
    let l = 32768usize;
    let sizes = [4usize, 8, 16, 32, 64, 128];
    s.x = sizes.iter().map(|&x| x as f64).collect();
    for rho in [0.2, 0.35, 0.6] {
        let col: Vec<f64> = sizes
            .iter()
            .map(|&sz| {
                let k = (0.3 * (l / sz) as f64) as usize;
                HwModel::memory_fraction(l, sz, k, rho)
            })
            .collect();
        s.columns.push((format!("rho={rho}"), col));
    }
    s.emit(&tinyserve::results_dir(), "memmodel_fraction");
    println!(
        "optimal S* for L=32K, K=614: {:.1}",
        HwModel::optimal_page_size(32768, 614)
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let manifest = Manifest::load(&tinyserve::artifacts_dir())?;
    let which = args.subcommand().unwrap_or("all");
    if matches!(which, "all" | "f1") {
        fig1(&manifest)?;
    }
    if matches!(which, "all" | "f4") {
        fig4(&manifest)?;
    }
    if matches!(which, "all" | "f6" | "f7") {
        fig67(&manifest)?;
    }
    if matches!(which, "all" | "t9") {
        table9(&manifest)?;
    }
    if matches!(which, "all" | "mem") {
        memmodel()?;
    }
    Ok(())
}
