//! Table 9 — three-tier budget sweep of the memory-budgeted page store:
//! KV byte budget at {25, 50, 75, 100}% of the unbounded peak, across the
//! four eviction policies (LRU, CLOCK, query-aware-cold, SIEVE), each
//! with the disk spill tier off and on (spill budget = unbounded peak,
//! score-driven readahead of 2 pages). Reports residency hit rate,
//! demotions per generated token, exact-match accuracy delta against the
//! unbounded baseline, and the spill tier's out/fault/readahead traffic —
//! the enforced-invariant version of the paper's ">2x KV memory savings"
//! claim, extended below q8.
//!
//! Alongside the human table this emits `results/BENCH_table9.json`, a
//! schema-versioned perf record CI uploads as an artifact so the bench
//! trajectory is tracked across PRs.

use tinyserve::harness::{measure_eviction, scale, EvictionCase};
use tinyserve::kvcache::EvictionPolicyKind;
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::util::json::Json;

const MODEL: &str = "tiny-trained";
const BUDGET_TOKENS: usize = 256;
const PROMPT_CHARS: usize = 600;
const SEED: u64 = 11;

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n_cases = scale(10);
    let base_case = EvictionCase {
        n_cases,
        prompt_chars: PROMPT_CHARS,
        budget_tokens: BUDGET_TOKENS,
        seed: SEED,
        ..Default::default()
    };
    let base = measure_eviction(&manifest, MODEL, &base_case).expect("unbounded baseline");
    let peak = base.bytes_peak_unbounded;
    println!(
        "unbounded: peak {:.2} MB, accuracy {:.1}%",
        peak as f64 / 1e6,
        base.accuracy * 100.0
    );

    let mut t = Table::new(
        &format!(
            "Table 9: three-tier eviction sweep ({MODEL}, budgets vs {:.2} MB \
             unbounded peak; spill budget = peak, readahead 2)",
            peak as f64 / 1e6
        ),
        &[
            "policy",
            "budget %",
            "spill",
            "resid hit %",
            "demote/tok",
            "acc %",
            "Δacc pp",
            "max MB",
            "viol",
            "spill-out MB",
            "faults",
            "ra hits",
            "disk pk",
        ],
    );
    for frac in [0.25f64, 0.5, 0.75, 1.0] {
        let budget = (peak as f64 * frac) as usize;
        for &kind in EvictionPolicyKind::all() {
            for spill_on in [false, true] {
                let case = EvictionCase {
                    eviction: kind,
                    budget_bytes: Some(budget),
                    spill_budget_bytes: spill_on.then_some(peak.max(1)),
                    readahead_pages: if spill_on { 2 } else { 0 },
                    ..base_case.clone()
                };
                match measure_eviction(&manifest, MODEL, &case) {
                    Ok(r) => {
                        t.row(vec![
                            kind.name().to_string(),
                            format!("{:.0}", frac * 100.0),
                            if spill_on { "disk" } else { "-" }.to_string(),
                            format!("{:.1}", r.residency_hit_rate * 100.0),
                            format!("{:.3}", r.demotions_per_token),
                            format!("{:.1}", r.accuracy * 100.0),
                            format!("{:+.1}", (r.accuracy - base.accuracy) * 100.0),
                            format!("{:.2}", r.max_bytes_in_use as f64 / 1e6),
                            format!("{}", r.violations),
                            format!("{:.2}", r.spill_out_bytes as f64 / 1e6),
                            format!("{}", r.disk_faults),
                            format!("{}", r.readahead_hits),
                            format!("{}", r.disk_pages_peak),
                        ]);
                    }
                    Err(e) => eprintln!(
                        "skip {}@{:.0}% spill={spill_on}: {e}",
                        kind.name(),
                        frac * 100.0
                    ),
                }
            }
        }
    }
    t.emit(&tinyserve::results_dir(), "table9_eviction");
    t.emit_bench(
        &tinyserve::results_dir(),
        "table9",
        vec![
            ("model", Json::from(MODEL)),
            ("seed", Json::from(SEED as usize)),
            ("n_cases", Json::from(n_cases)),
            ("unbounded_peak_bytes", Json::from(peak)),
            ("baseline_accuracy", Json::from(base.accuracy)),
            ("baseline_run_seconds", Json::from(base.run_seconds)),
        ],
    );
}
