//! Table 9 — memory-budgeted page store sweep: KV byte budget at
//! {25, 50, 75, 100}% of the unbounded peak, across the four eviction
//! policies (LRU, CLOCK, query-aware-cold, SIEVE). Reports residency hit rate,
//! demotions per generated token and exact-match accuracy delta against
//! the unbounded baseline — the enforced-invariant version of the paper's
//! ">2x KV memory savings" claim.

use tinyserve::harness::{measure_eviction, scale};
use tinyserve::kvcache::EvictionPolicyKind;
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;

const MODEL: &str = "tiny-trained";
const BUDGET_TOKENS: usize = 256;
const PROMPT_CHARS: usize = 600;
const SEED: u64 = 11;

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n_cases = scale(10);
    let base = measure_eviction(
        &manifest,
        MODEL,
        EvictionPolicyKind::QueryAware,
        None,
        n_cases,
        PROMPT_CHARS,
        BUDGET_TOKENS,
        SEED,
    )
    .expect("unbounded baseline");
    let peak = base.bytes_peak_unbounded;
    println!(
        "unbounded: peak {:.2} MB, accuracy {:.1}%",
        peak as f64 / 1e6,
        base.accuracy * 100.0
    );

    let mut t = Table::new(
        &format!(
            "Table 9: eviction-policy sweep ({MODEL}, budgets vs {:.2} MB unbounded peak)",
            peak as f64 / 1e6
        ),
        &[
            "policy",
            "budget %",
            "budget MB",
            "resid hit %",
            "demote/tok",
            "acc %",
            "Δacc pp",
            "max MB",
            "viol",
        ],
    );
    for frac in [0.25f64, 0.5, 0.75, 1.0] {
        let budget = (peak as f64 * frac) as usize;
        for &kind in EvictionPolicyKind::all() {
            match measure_eviction(
                &manifest,
                MODEL,
                kind,
                Some(budget),
                n_cases,
                PROMPT_CHARS,
                BUDGET_TOKENS,
                SEED,
            ) {
                Ok(r) => {
                    t.row(vec![
                        kind.name().to_string(),
                        format!("{:.0}", frac * 100.0),
                        format!("{:.2}", budget as f64 / 1e6),
                        format!("{:.1}", r.residency_hit_rate * 100.0),
                        format!("{:.3}", r.demotions_per_token),
                        format!("{:.1}", r.accuracy * 100.0),
                        format!("{:+.1}", (r.accuracy - base.accuracy) * 100.0),
                        format!("{:.2}", r.max_bytes_in_use as f64 / 1e6),
                        format!("{}", r.violations),
                    ]);
                }
                Err(e) => eprintln!("skip {}@{:.0}%: {e}", kind.name(), frac * 100.0),
            }
        }
    }
    t.emit(&tinyserve::results_dir(), "table9_eviction");
}
