//! Table 2 — ablations: page size S, selection ratio K/P, component
//! on/off (query-aware scoring vs recency, bounding-box vs exact oracle),
//! and scale consistency. Measured on the real decode path (345m-sim for
//! efficiency, matching the paper's ablation base).

use tinyserve::config::KvDtype;
use tinyserve::config::ServingConfig;
use tinyserve::engine::{Engine, Sampling};
use tinyserve::harness::scale;
use tinyserve::metrics::StepMetrics;
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::rng::Rng;
use tinyserve::util::stats::Samples;

const MODEL: &str = "gpt2-345m-sim";
const CTX: usize = 2048;

struct Row {
    label: String,
    ms: f64,
    std: f64,
    tok_s: f64,
    hit: f64,
    gather_mb: f64,
}

fn measure(cfg: ServingConfig, policy: PolicyKind, steps: usize) -> anyhow::Result<Row> {
    let manifest = Manifest::load(&tinyserve::artifacts_dir())?;
    let mut e = Engine::from_manifest(&manifest, cfg)?;
    let mut rng = Rng::new(13);
    let mut seq = e.new_sequence_with_policy(policy);
    e.synthetic_fill(&mut seq, CTX - 1, &mut rng);
    seq.tokens.push(1);
    seq.max_new_tokens = usize::MAX / 2;
    for _ in 0..3 {
        let mut m = StepMetrics::default();
        let mut b = [&mut seq];
        e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m)?;
    }
    let mut lat = Samples::new();
    let mut hit = 0.0;
    let mut gb = 0.0;
    for _ in 0..steps {
        let mut m = StepMetrics::default();
        let mut b = [&mut seq];
        e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m)?;
        lat.push(m.step_seconds);
        hit += m.hit_rate();
        gb += m.gather_bytes as f64;
    }
    e.release(&mut seq);
    Ok(Row {
        label: String::new(),
        ms: lat.mean() * 1e3,
        std: lat.std() * 1e3,
        tok_s: 1.0 / lat.mean(),
        hit: hit / steps as f64 * 100.0,
        gather_mb: gb / steps as f64 / 1e6,
    })
}

fn main() {
    let steps = scale(20);
    let mut t = Table::new(
        "Table 2: ablations (gpt2-345m-sim, ctx 2048)",
        &["config", "ms/tok", "±", "tok/s", "KV hit %", "gather MB/step"],
    );
    let base = || ServingConfig {
        model: MODEL.into(),
        budget: 512,
        max_batch: 1,
        ..Default::default()
    };

    // --- component ablation: selection strategy variants ---
    let components: Vec<(String, ServingConfig, PolicyKind)> = vec![
        ("Full TinyServe (bbox query-aware)".into(), base(), PolicyKind::TinyServe),
        ("w/o query-aware (recency only = StreamingLLM)".into(), base(), PolicyKind::StreamingLlm),
        ("exact scoring (Oracle upper bound)".into(), base(), PolicyKind::Oracle),
        ("observed-mass (SnapKV)".into(), base(), PolicyKind::SnapKv),
        ("layer taper (PyramidKV)".into(), base(), PolicyKind::PyramidKv),
        (
            "FullCache baseline".into(),
            ServingConfig { budget: CTX, ..base() },
            PolicyKind::FullCache,
        ),
    ];
    for (label, mut cfg, p) in components {
        cfg.policy = p;
        match measure(cfg, p, steps) {
            Ok(mut r) => {
                r.label = label;
                t.row(vec![
                    r.label.clone(),
                    format!("{:.2}", r.ms),
                    format!("{:.2}", r.std),
                    format!("{:.1}", r.tok_s),
                    format!("{:.1}", r.hit),
                    format!("{:.2}", r.gather_mb),
                ]);
            }
            Err(e) => eprintln!("skip {label}: {e}"),
        }
    }

    // --- page size sweep (S) at fixed budget tokens ---
    for s in [8usize, 16, 32, 64] {
        let cfg = ServingConfig { page_size: s, ..base() };
        if let Ok(r) = measure(cfg, PolicyKind::TinyServe, steps) {
            t.row(vec![
                format!("page size S={s}"),
                format!("{:.2}", r.ms),
                format!("{:.2}", r.std),
                format!("{:.1}", r.tok_s),
                format!("{:.1}", r.hit),
                format!("{:.2}", r.gather_mb),
            ]);
        }
    }

    // --- selection ratio K/P: budget tokens as a fraction of ctx ---
    for (ratio, budget) in [(0.1, 256usize), (0.25, 512), (0.5, 1024), (1.0, 2048)] {
        let cfg = ServingConfig { budget, ..base() };
        if let Ok(r) = measure(cfg, PolicyKind::TinyServe, steps) {
            t.row(vec![
                format!("K/P ratio {ratio} (budget {budget})"),
                format!("{:.2}", r.ms),
                format!("{:.2}", r.std),
                format!("{:.1}", r.tok_s),
                format!("{:.1}", r.hit),
                format!("{:.2}", r.gather_mb),
            ]);
        }
    }

    // --- KV dtype (the FP16/INT8 executor modes) ---
    for dt in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let cfg = ServingConfig { kv_dtype: dt, ..base() };
        if let Ok(r) = measure(cfg, PolicyKind::TinyServe, steps) {
            t.row(vec![
                format!("kv dtype {dt:?}"),
                format!("{:.2}", r.ms),
                format!("{:.2}", r.std),
                format!("{:.1}", r.tok_s),
                format!("{:.1}", r.hit),
                format!("{:.2}", r.gather_mb),
            ]);
        }
    }

    // --- scale consistency (full config across model sizes) ---
    for model in ["tinyllama-125m-sim", "gpt2-345m-sim", "gpt2-774m-sim"] {
        let cfg = ServingConfig {
            model: model.into(),
            budget: 512,
            max_batch: 1,
            ..Default::default()
        };
        if let Ok(r) = measure(cfg, PolicyKind::TinyServe, steps) {
            t.row(vec![
                format!("scale: {model}"),
                format!("{:.2}", r.ms),
                format!("{:.2}", r.std),
                format!("{:.1}", r.tok_s),
                format!("{:.1}", r.hit),
                format!("{:.2}", r.gather_mb),
            ]);
        }
    }

    t.emit(&tinyserve::results_dir(), "table2_ablation");
}
