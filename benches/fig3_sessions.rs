//! Figures 2/3 — session management: cache reuse rate and migration
//! overhead across session counts and reuse probabilities (measured through
//! the real session store + router on the serving loop).

// `serve_trace` is deprecated in favour of the Frontend lifecycle API but
// stays the trace-replay entry point for paper-table benches.
#![allow(deprecated)]

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::{serve_trace, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::harness::scale;
use tinyserve::plugins::Pipeline;
use tinyserve::report::{Series, Table};
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::workload::{generate_trace, TraceConfig};

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n_requests = scale(32);

    // reuse rate + reused tokens vs session-following probability
    let probs = [0.0, 0.25, 0.5, 0.75, 0.95];
    let mut s = Series::new("Figure 3a: session reuse vs follow-up probability", "p_follow");
    s.x = probs.to_vec();
    let mut reuse_col = Vec::new();
    let mut ttft_col = Vec::new();
    let mut mig_col = Vec::new();
    for &p in &probs {
        let cfg = ServingConfig {
            model: "tiny-trained".into(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 4,
            ..Default::default()
        };
        let mut engine = Engine::from_manifest(&manifest, cfg).expect("engine");
        let trace = generate_trace(&TraceConfig {
            n_requests,
            session_reuse_prob: p,
            n_sessions: 6,
            prompt_chars: (200, 400),
            new_tokens: (6, 14),
            ..Default::default()
        });
        let opts = ServeOptions { n_workers: 4, ..Default::default() };
        let mut plugins = Pipeline::new();
        let r = serve_trace(&mut engine, &trace, &opts, &mut plugins).expect("serve");
        let mut m = r.metrics;
        reuse_col.push(r.session_stats.reuse_rate());
        ttft_col.push(m.request_ttft.p50() * 1e3);
        mig_col.push(r.session_stats.migrations as f64);
        println!(
            "p={p}: reuse {:.0}%  reused tokens {}  p50 ttft {:.0} ms  migrations {}",
            r.session_stats.reuse_rate() * 100.0,
            r.session_stats.reused_tokens,
            m.request_ttft.p50() * 1e3,
            r.session_stats.migrations,
        );
    }
    s.columns.push(("reuse_rate".into(), reuse_col));
    s.columns.push(("p50_ttft_ms".into(), ttft_col));
    s.columns.push(("migrations".into(), mig_col));
    s.emit(&tinyserve::results_dir(), "fig3_sessions");

    // migration overhead vs session size (tokens): measured store+restore
    let mut t = Table::new(
        "Figure 3b: snapshot/migration cost vs session size",
        &["session tokens", "snapshot ms", "restore ms", "migrated MB"],
    );
    use tinyserve::kvcache::{PagePool, SeqCache};
    use tinyserve::util::rng::Rng;
    let mut rng = Rng::new(3);
    for tokens in [128usize, 512, 2048, 8192] {
        let mut pool = PagePool::new(4, 128, 16, tinyserve::config::KvDtype::F32);
        let mut seq = SeqCache::new();
        let row: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        for _ in 0..tokens {
            let (page, slot) = seq.slot_for_next(&mut pool);
            for l in 0..4 {
                pool.write_token(page, slot, l, &row, &row);
            }
            seq.commit_token();
        }
        let t0 = std::time::Instant::now();
        let snap = seq.snapshot(&mut pool);
        let snap_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let mut restored = SeqCache::restore(&snap, &mut pool);
        let restore_ms = t1.elapsed().as_secs_f64() * 1e3;
        let bytes = tokens * 128 * 2 * 4 * 4;
        t.row(vec![
            format!("{tokens}"),
            format!("{snap_ms:.3}"),
            format!("{restore_ms:.3}"),
            format!("{:.2}", bytes as f64 / 1e6),
        ]);
        restored.clear(&mut pool);
        let mut snap = snap;
        snap.clear(&mut pool);
        seq.clear(&mut pool);
    }
    t.emit(&tinyserve::results_dir(), "fig3_migration");
}
