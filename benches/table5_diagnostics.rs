//! Table 5 — serving synthetic diagnostics (paper §4.9): repetition,
//! rare-token recall and attention aliasing, per policy, on the trained
//! model. Char-level accuracy gives the paper's 0-100 scale. Two
//! analytics-derived columns ride along: the mean KV page hit rate of the
//! selection loop and the top-k recall of bbox selection against the
//! exact-attention oracle (`--audit-selection` machinery, audited every
//! `AUDIT_EVERY` decode steps).

use tinyserve::harness::{measure_accuracy_audited, scale};
use tinyserve::report::{fmt_pct, Table};
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::json::Json;
use tinyserve::workload::tasks::Task;

const MODEL: &str = "tiny-trained";
const SEED: u64 = 7;
/// oracle-audit cadence in decode steps; short answer decodes still get
/// several audited steps per case
const AUDIT_EVERY: usize = 2;

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n = scale(12);
    let diags = [Task::Repeat, Task::RareToken, Task::Alias];
    let policies = [
        PolicyKind::FullCache,
        PolicyKind::StreamingLlm,
        PolicyKind::SoftPrune,
        PolicyKind::TinyServe,
    ];
    let mut t = Table::new(
        &format!("Table 5: serving diagnostics ({MODEL}, n={n} per cell, char acc %)"),
        &[
            "policy",
            "Repetition",
            "Rare Token",
            "Aliasing",
            "KV hit %",
            "selection recall %",
        ],
    );
    for &policy in &policies {
        let mut cells = vec![policy.name().to_string()];
        let mut hit_sum = 0.0f64;
        let mut hit_n = 0usize;
        let mut recalls: Vec<f64> = Vec::new();
        for &task in &diags {
            match measure_accuracy_audited(
                &manifest,
                MODEL,
                policy,
                task,
                n,
                600,
                256,
                SEED,
                AUDIT_EVERY,
            ) {
                Ok(r) => {
                    cells.push(format!("{:.1}", r.char_acc * 100.0));
                    hit_sum += r.hit_rate;
                    hit_n += 1;
                    recalls.extend(r.selection_recall);
                }
                Err(e) => {
                    eprintln!("skip {:?}/{:?}: {e}", policy, task);
                    cells.push("-".into());
                }
            }
        }
        cells.push(if hit_n > 0 {
            fmt_pct(hit_sum / hit_n as f64)
        } else {
            "-".into()
        });
        cells.push(if recalls.is_empty() {
            "-".into()
        } else {
            fmt_pct(recalls.iter().sum::<f64>() / recalls.len() as f64)
        });
        t.row(cells);
    }
    t.emit(&tinyserve::results_dir(), "table5_diagnostics");
    t.emit_bench(
        &tinyserve::results_dir(),
        "table5",
        vec![
            ("model", Json::from(MODEL)),
            ("seed", Json::from(SEED as usize)),
            ("n_cases", Json::from(n)),
            ("audit_every", Json::from(AUDIT_EVERY)),
        ],
    );
}
