//! Table 5 — serving synthetic diagnostics (paper §4.9): repetition,
//! rare-token recall and attention aliasing, per policy, on the trained
//! model. Char-level accuracy gives the paper's 0-100 scale.

use tinyserve::harness::{measure_accuracy, scale};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::workload::tasks::Task;

const MODEL: &str = "tiny-trained";

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n = scale(12);
    let diags = [Task::Repeat, Task::RareToken, Task::Alias];
    let policies = [
        PolicyKind::FullCache,
        PolicyKind::StreamingLlm,
        PolicyKind::SoftPrune,
        PolicyKind::TinyServe,
    ];
    let mut t = Table::new(
        &format!("Table 5: serving diagnostics ({MODEL}, n={n} per cell, char acc %)"),
        &["policy", "Repetition", "Rare Token", "Aliasing"],
    );
    for &policy in &policies {
        let mut cells = vec![policy.name().to_string()];
        for &task in &diags {
            match measure_accuracy(&manifest, MODEL, policy, task, n, 600, 256, 7) {
                Ok(r) => cells.push(format!("{:.1}", r.char_acc * 100.0)),
                Err(e) => {
                    eprintln!("skip {:?}/{:?}: {e}", policy, task);
                    cells.push("-".into());
                }
            }
        }
        t.row(cells);
    }
    t.emit(&tinyserve::results_dir(), "table5_diagnostics");
}
