//! Table 1 — model-scale comparison: latency / memory / throughput / KV hit
//! for every policy across the scale family (measured on the real decode
//! path), plus A100-projected latency from the calibrated cost model.
//! Accuracy columns come from table4_tasks (trained model); this bench is
//! the efficiency half.

use tinyserve::config::KvDtype;
use tinyserve::harness::{measure_decode, scale};
use tinyserve::hwmodel::{HwModel, Shape};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;

/// (model row, paper ctx, real measured ctx, budget, paper FullCache ms).
/// Measured budget is ~ctx/4 so selection actually prunes (paper K/P=0.3);
/// FullCache always gets the smallest artifact covering ctx.
const ROWS: &[(&str, usize, usize, usize, f64)] = &[
    ("tinyllama-125m-sim", 4096, 2048, 512, 25.1),
    ("gpt2-345m-sim", 8192, 2048, 512, 45.2),
    ("opt-350m-sim", 8192, 8192, 2048, 46.8),
    ("gpt2-774m-sim", 16384, 4096, 2048, 89.2),
    ("llama-1p3b-sim", 32768, 4096, 2048, 156.8),
];

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let steps = scale(24);
    let quick = tinyserve::harness::quick();
    let mut t = Table::new(
        "Table 1 (efficiency): model scale x policy",
        &[
            "model", "policy", "ctx", "budget", "ms/tok", "±", "tok/s",
            "KV hit %", "gather MB/step", "mem GB", "A100 ms/tok",
        ],
    );
    let rows = if quick { &ROWS[..2] } else { ROWS };
    for &(model, paper_ctx, real_ctx, budget, paper_full_ms) in rows {
        let info = manifest.model(model).expect("model");
        // calibrate the cost model on this row's FullCache paper number
        let mut hw = HwModel::a100();
        let shape = |k_pages: usize, ctx: usize| Shape {
            d_model: info.d_model,
            n_layer: info.n_layer,
            n_params: info.n_params,
            ctx,
            page_size: 16,
            k_pages,
            kv_dtype: KvDtype::F16,
            batch: 1,
        };
        hw.calibrate(&shape(paper_ctx / 16, paper_ctx), paper_full_ms);

        for &policy in PolicyKind::all() {
            let ctx = real_ctx.min(info.ctx);
            // FullCache gets the smallest budget that covers ctx (fairness)
            let b = if policy == PolicyKind::FullCache {
                tinyserve::harness::fullcache_budget(info, ctx)
            } else {
                budget.min(*info.budget_variants().last().unwrap())
            };
            match measure_decode(
                &manifest, model, policy, ctx, b, 1, steps, KvDtype::F32,
            ) {
                Ok(r) => {
                    // projection at the paper's operating point: full cache
                    // vs K/P = 0.3 selection at the paper's context
                    let k_pages = if policy == PolicyKind::FullCache {
                        paper_ctx / 16
                    } else {
                        (3 * (paper_ctx / 16)) / 10
                    };
                    let proj = hw.decode_token_ms(&shape(k_pages, paper_ctx));
                    t.row(vec![
                        model.into(),
                        policy.name().into(),
                        format!("{ctx}"),
                        format!("{b}"),
                        format!("{:.2}", r.ms_per_token),
                        format!("{:.2}", r.ms_std),
                        format!("{:.1}", r.tokens_per_s),
                        format!("{:.1}", r.hit_rate * 100.0),
                        format!("{:.2}", r.gather_bytes_per_step / 1e6),
                        format!("{:.2}", r.pool_bytes as f64 / 1e9 + info.n_params as f64 * 4.0 / 1e9),
                        format!("{proj:.1}"),
                    ]);
                }
                Err(e) => eprintln!("skip {model}/{policy:?}: {e}"),
            }
        }
    }
    t.emit(&tinyserve::results_dir(), "table1_model_scale");
}
