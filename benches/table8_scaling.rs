//! Table 8 — multi-GPU throughput scaling (1..8 workers). The box has one
//! core, so absolute scaling comes from the calibrated hardware model fed
//! with the *measured* single-worker service rate; the router/migration
//! logic is exercised for real via virtual workers in the serving loop.

use tinyserve::config::{KvDtype, ServingConfig};
use tinyserve::coordinator::{serve_trace, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::harness::{measure_decode, scale};
use tinyserve::hwmodel::{HwModel, Shape};
use tinyserve::plugins::Pipeline;
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::workload::{generate_trace, TraceConfig};

const MODEL: &str = "gpt2-345m-sim";

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let info = manifest.model(MODEL).expect("model").clone();

    // measured single-engine service rate (batch = largest variant)
    let batch = *info.batch_variants("qkv").last().unwrap();
    let base = measure_decode(
        &manifest,
        MODEL,
        PolicyKind::TinyServe,
        2048,
        2048,
        batch,
        scale(16),
        KvDtype::F32,
    )
    .expect("base measurement");
    println!(
        "measured single-worker rate: {:.1} tok/s (batch {batch})",
        base.tokens_per_s
    );

    let hw = HwModel::a100();
    let shape = Shape {
        d_model: info.d_model,
        n_layer: info.n_layer,
        n_params: info.n_params,
        ctx: 16384,
        page_size: 16,
        k_pages: 128,
        kv_dtype: KvDtype::F16,
        batch,
    };

    let mut t = Table::new(
        &format!("Table 8: multi-GPU scaling ({MODEL}, measured base + hw model)"),
        &["#GPUs", "tok/ms", "speedup", "efficiency %", "router migrations"],
    );
    // efficiency is evaluated at the A100-projected service rate (the CPU
    // base rate is so slow that coordination cost vanishes; the projected
    // rate exposes it, which is what Table 8 reports); the tok/ms column
    // scales the *measured* base by that efficiency.
    let proj_rate = 1e3 / hw.decode_token_ms(&shape) * shape.batch as f64;
    for n in [1usize, 2, 4, 8] {
        let eff = hw.multi_gpu_efficiency(&shape, proj_rate, n);
        let thr = base.tokens_per_s * n as f64 * eff;
        // run the real router with n virtual workers to count migrations
        let cfg = ServingConfig {
            model: "tiny-trained".into(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 4,
            ..Default::default()
        };
        let migrations = Engine::from_manifest(&manifest, cfg)
            .ok()
            .and_then(|mut e| {
                let trace = generate_trace(&TraceConfig {
                    n_requests: scale(24),
                    session_reuse_prob: 0.5,
                    n_sessions: 6,
                    prompt_chars: (100, 250),
                    new_tokens: (4, 10),
                    ..Default::default()
                });
                let opts = ServeOptions { n_workers: n, ..Default::default() };
                let mut plugins = Pipeline::new();
                serve_trace(&mut e, &trace, &opts, &mut plugins).ok()
            })
            .map(|r| r.session_stats.migrations)
            .unwrap_or(0);
        t.row(vec![
            format!("{n}"),
            format!("{:.3}", thr / 1e3),
            format!("{:.2}x", thr / base.tokens_per_s.max(1e-9)),
            format!("{:.1}", eff * 100.0),
            format!("{migrations}"),
        ]);
    }
    t.emit(&tinyserve::results_dir(), "table8_scaling");
}
