//! Table 8 — multi-worker throughput scaling. Two layers:
//!
//! 1. **Real concurrent workers** (the point of this bench since the
//!    `WorkerPool` refactor): the same bursty open-loop arrival mix is
//!    served by pools of 1/2/4 engine workers under deterministic modeled
//!    time, reporting per-worker throughput and the p99 TTFT; at the
//!    largest pool, `least-loaded` dispatch is compared against
//!    `round-robin` — load-adaptive dispatch should hold or beat it on
//!    tail TTFT when bursts pile requests up.
//! 2. **A100 projection** (the pre-pool content): the calibrated hardware
//!    model extrapolates the measured single-worker service rate to the
//!    paper's 1..8-GPU testbed.
//! 3. **Executor dispatch overhead** (artifact-free, runs first): per-round
//!    cost of the scoped spawn/join step phase vs the persistent
//!    channel-fed decode threads, on a no-op round so only the dispatch
//!    machinery is priced. This is the number `--executor persistent`
//!    saves on every decode round.
//! 4. **SLO-class preemption** (Table 8d): a tiered bursty mix on a single
//!    admission slot, served with and without `--preempt` — the
//!    interactive tier's p99 TTFT is the headline, recorded in the
//!    BENCH_table8.json perf context.

use tinyserve::config::{KvDtype, ServingConfig};
use tinyserve::coordinator::pool::{
    execute_round_with, PersistentExecutor, RoundExecutor,
};
use tinyserve::coordinator::{
    BatcherConfig, DispatchKind, Frontend, ServeOptions, ServeReport, TimeModel,
    WorkerPool,
};
use tinyserve::harness::{measure_decode, scale};
use tinyserve::hwmodel::{HwModel, Shape};
use tinyserve::plugins::Pipeline;
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::json::Json;
use tinyserve::workload::{
    ArrivalProcess, LoadShape, OpenLoopConfig, OpenLoopGen, SloTier,
};

const MODEL: &str = "gpt2-345m-sim";
const SERVE_MODEL: &str = "tiny-trained";

fn workload(n_requests: usize) -> OpenLoopConfig {
    // bursty mix: 4x rate spikes for 30% of each period, gamma
    // interarrivals — the regime where dispatch policy moves the tail
    OpenLoopConfig {
        n_requests,
        rate_rps: 40.0,
        process: ArrivalProcess::Gamma { shape: 0.4 },
        shape: LoadShape::Bursts { period_s: 1.0, burst_s: 0.3, factor: 4.0 },
        prompt_chars: (100, 500),
        new_tokens: (4, 12),
        session_reuse_prob: 0.3,
        n_sessions: 6,
        deadline_ms: None,
        deadline_every: 1,
        tier_interactive: 0.0,
        tier_background: 0.0,
        seed: 42,
    }
}

/// Nearest-rank quantile over an unsorted sample (sorts in place).
fn pct(vals: &mut [f64], q: f64) -> f64 {
    if vals.is_empty() {
        return f64::NAN;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals[((vals.len() - 1) as f64 * q).round() as usize]
}

/// One tiered run for Table 8d: a single admission slot plus long
/// background decodes — the regime where a lower-tier sequence starves an
/// interactive arrival past its TTFT target, which is exactly what
/// `--preempt` exists to fix. Background requests decode ~1.8-2.3k tokens
/// (~150-200 ms of modeled slot residency), well past the preemptor's
/// 125 ms interactive starvation gate. Modeled time, so the on/off
/// comparison is exact and seed-reproducible.
fn serve_tiered(
    manifest: &Manifest,
    preempt: bool,
    n_requests: usize,
) -> Option<ServeReport> {
    let cfg = ServingConfig {
        model: SERVE_MODEL.into(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        ..Default::default()
    };
    let pool =
        WorkerPool::build(manifest, &cfg, 1, DispatchKind::LeastLoaded).ok()?;
    let opts = ServeOptions {
        time_model: TimeModel::Modeled,
        batcher: BatcherConfig {
            max_active: 1,
            batch_timeout_s: 0.05,
            prefill_per_round: 1,
        },
        preempt,
        ..Default::default()
    };
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
    fe.set_source(Box::new(OpenLoopGen::new(OpenLoopConfig {
        n_requests,
        rate_rps: 12.0,
        process: ArrivalProcess::Gamma { shape: 0.4 },
        shape: LoadShape::Bursts { period_s: 1.0, burst_s: 0.3, factor: 4.0 },
        prompt_chars: (100, 300),
        new_tokens: (1792, 2304),
        session_reuse_prob: 0.0,
        n_sessions: 1,
        deadline_ms: None,
        deadline_every: 1,
        tier_interactive: 0.3,
        tier_background: 0.5,
        seed: 42,
    })));
    while fe.has_work() {
        fe.step().ok()?;
    }
    Some(fe.into_report())
}

/// One pool run under modeled time. Returns the report plus the *real*
/// wall-clock seconds of the pump loop — modeled time prices the virtual
/// clock deterministically, but the decode work is genuinely executed, so
/// wall time is where `threads > 1` shows up (the event stream does not
/// change; see the determinism contract in docs/serving_api.md).
fn serve_pool(
    manifest: &Manifest,
    workers: usize,
    threads: usize,
    dispatch: DispatchKind,
    n_requests: usize,
) -> Option<(ServeReport, f64)> {
    let cfg = ServingConfig {
        model: SERVE_MODEL.into(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        ..Default::default()
    };
    let pool = WorkerPool::build(manifest, &cfg, workers, dispatch).ok()?;
    let opts = ServeOptions {
        time_model: TimeModel::Modeled,
        threads,
        ..Default::default()
    };
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
    fe.set_source(Box::new(OpenLoopGen::new(workload(n_requests))));
    let t0 = std::time::Instant::now();
    while fe.has_work() {
        fe.step().ok()?;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Some((fe.into_report(), wall_s))
}

/// Mean per-round wall cost (µs) of running `rounds` no-op decode rounds
/// through `exec`, reusing `persistent` when given. The round body is a
/// single multiply per worker, so the measurement is dominated by thread
/// spawn/join (scoped) or channel send + completion wait (persistent).
fn dispatch_overhead_us(
    exec: RoundExecutor,
    persistent: Option<&PersistentExecutor>,
    workers: usize,
    rounds: usize,
) -> f64 {
    let step = |w: usize, x: u64| -> u64 { (w as u64).wrapping_mul(x) };
    let work = || (0..workers).map(|w| (w, w as u64 + 1)).collect::<Vec<_>>();
    for _ in 0..64 {
        execute_round_with(exec, persistent, work(), &step);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        execute_round_with(exec, persistent, work(), &step);
    }
    t0.elapsed().as_secs_f64() / rounds as f64 * 1e6
}

fn main() {
    // ---- executor dispatch overhead (no artifacts needed) ----
    let workers = 4usize;
    let rounds = scale(2_000);
    let scoped_us = dispatch_overhead_us(
        RoundExecutor::Threaded { threads: workers },
        None,
        workers,
        rounds,
    );
    let persistent = PersistentExecutor::new(workers);
    let persistent_us = dispatch_overhead_us(
        RoundExecutor::Persistent { threads: workers },
        Some(&persistent),
        workers,
        rounds,
    );
    let mut te = Table::new(
        &format!(
            "Table 8c: per-round dispatch overhead ({workers} workers, no-op \
             round, {rounds} rounds)"
        ),
        &["executor", "us/round"],
    );
    te.row(vec!["scoped".into(), format!("{scoped_us:.1}")]);
    te.row(vec!["persistent".into(), format!("{persistent_us:.1}")]);
    te.emit(&tinyserve::results_dir(), "table8_executor");
    println!(
        "persistent executor: {persistent_us:.1} us/round vs scoped \
         {scoped_us:.1} us/round ({:.2}x lower dispatch overhead)",
        scoped_us / persistent_us.max(1e-9)
    );

    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let info = manifest.model(MODEL).expect("model").clone();
    let n_requests = scale(48);

    // ---- real pools: workers x threads x dispatch on the bursty mix ----
    // the threads dimension reports *real wall-clock* seconds of the pump
    // loop (modeled virtual time is identical by the determinism
    // contract): threads=N must beat threads=1 on the same 4-worker pool,
    // which is the whole point of the thread-parallel round executor
    let mut t = Table::new(
        &format!(
            "Table 8a: concurrent worker pools ({SERVE_MODEL}, bursty open-loop, \
             modeled time)"
        ),
        &[
            "workers",
            "threads",
            "dispatch",
            "tok/s",
            "tok/s per worker",
            "ttft p50 ms",
            "ttft p99 ms",
            "deferred",
            "wall s",
            "wall speedup",
        ],
    );
    let mut base_tps: Option<f64> = None;
    let mut seq_wall_4w: Option<f64> = None;
    let mut ll_vs_rr: Option<(f64, f64)> = None;
    // recorded from the rows actually run, so the emitted perf-record
    // context can never drift from the sweep list
    let mut threads_dim: Vec<usize> = Vec::new();
    for &(n, threads, dispatch) in &[
        (1usize, 1usize, DispatchKind::LeastLoaded),
        (2, 1, DispatchKind::LeastLoaded),
        (4, 1, DispatchKind::LeastLoaded),
        (4, 4, DispatchKind::LeastLoaded),
        (4, 1, DispatchKind::RoundRobin),
    ] {
        let Some((r, wall_s)) = serve_pool(&manifest, n, threads, dispatch, n_requests)
        else {
            println!("(engine unavailable: skipping real-pool sweep)");
            break;
        };
        let mut m = r.metrics;
        let tps = m.throughput_tps();
        if n == 1 {
            base_tps = Some(tps);
        }
        if !threads_dim.contains(&threads) {
            threads_dim.push(threads);
        }
        let mut wall_speedup = f64::NAN;
        if n == 4 && dispatch == DispatchKind::LeastLoaded {
            match threads {
                1 => seq_wall_4w = Some(wall_s),
                _ => {
                    if let Some(seq) = seq_wall_4w {
                        wall_speedup = seq / wall_s.max(1e-9);
                        println!(
                            "  4 workers, {threads} threads: {wall_speedup:.2}x \
                             real wall-clock over sequential stepping \
                             ({seq:.2}s -> {wall_s:.2}s)"
                        );
                    }
                }
            }
        }
        let p99 = m.request_ttft.p99() * 1e3;
        if n == 4 && threads == 1 {
            match dispatch {
                DispatchKind::LeastLoaded => ll_vs_rr = Some((p99, f64::NAN)),
                DispatchKind::RoundRobin => {
                    if let Some((ll, _)) = ll_vs_rr {
                        ll_vs_rr = Some((ll, p99));
                    }
                }
                _ => {}
            }
        }
        t.row(vec![
            format!("{n}"),
            format!("{threads}"),
            dispatch.name().to_string(),
            format!("{tps:.1}"),
            format!("{:.1}", tps / n as f64),
            format!("{:.0}", m.request_ttft.p50() * 1e3),
            format!("{p99:.0}"),
            format!("{}", r.batcher_stats.deferred),
            format!("{wall_s:.3}"),
            if wall_speedup.is_finite() {
                format!("{wall_speedup:.2}x")
            } else {
                "-".to_string()
            },
        ]);
        if let Some(base) = base_tps {
            if n > 1 && threads == 1 && dispatch == DispatchKind::LeastLoaded {
                println!(
                    "  {n} workers: {:.2}x the 1-worker throughput",
                    tps / base.max(1e-9)
                );
            }
        }
    }
    if let Some((ll, rr)) = ll_vs_rr {
        if rr.is_finite() {
            println!(
                "4-worker p99 TTFT: least-loaded {ll:.0} ms vs round-robin {rr:.0} \
                 ms ({})",
                if ll <= rr { "least-loaded holds the tail" } else { "round-robin won this mix" }
            );
        }
    }
    t.emit(&tinyserve::results_dir(), "table8_scaling");

    // ---- Table 8d: SLO-class preemption on a tiered bursty mix ----
    // same scenario with and without --preempt; the headline number is the
    // interactive tier's p99 TTFT, which preemption must improve
    let tiered_n = scale(10);
    let mut td = Table::new(
        &format!(
            "Table 8d: SLO-class preemption ({SERVE_MODEL}, tiered bursty \
             open-loop, 1 slot, modeled time)"
        ),
        &[
            "preempt",
            "ttft p99 interactive ms",
            "ttft p99 all ms",
            "preemptions",
            "finished",
        ],
    );
    // NaN until both runs complete (engine may be unavailable)
    let mut p99_tiered = [f64::NAN; 2];
    let mut preemptions = 0u64;
    for (slot, &preempt) in [false, true].iter().enumerate() {
        let Some(r) = serve_tiered(&manifest, preempt, tiered_n) else {
            println!("(engine unavailable: skipping preemption sweep)");
            break;
        };
        let mut m = r.metrics;
        let mut inter: Vec<f64> = r
            .requests
            .iter()
            .filter(|rec| rec.tier == SloTier::Interactive)
            .map(|rec| rec.ttft_seconds * 1e3)
            .collect();
        let p99_i = pct(&mut inter, 0.99);
        p99_tiered[slot] = p99_i;
        if preempt {
            preemptions = r.batcher_stats.preempted;
        }
        td.row(vec![
            if preempt { "on" } else { "off" }.to_string(),
            format!("{p99_i:.0}"),
            format!("{:.0}", m.request_ttft.p99() * 1e3),
            format!("{}", r.batcher_stats.preempted),
            format!("{}", m.total_requests),
        ]);
    }
    if p99_tiered.iter().all(|p| p.is_finite()) {
        println!(
            "tiered burst: interactive p99 TTFT {:.0} ms -> {:.0} ms with \
             preemption on ({} preemptions)",
            p99_tiered[0], p99_tiered[1], preemptions
        );
    }
    td.emit(&tinyserve::results_dir(), "table8_preempt");

    t.emit_bench(
        &tinyserve::results_dir(),
        "table8",
        vec![
            ("model", Json::from(SERVE_MODEL)),
            ("n_requests", Json::from(n_requests)),
            (
                "threads_dim",
                Json::Arr(threads_dim.iter().map(|&t| Json::from(t)).collect()),
            ),
            // Table 8c numbers ride along in the perf record so regressions
            // in the persistent executor's per-round win are diffable
            ("dispatch_scoped_us", Json::Num(scoped_us)),
            ("dispatch_persistent_us", Json::Num(persistent_us)),
            // Table 8d: the preemption headline (NaN-free only when the
            // tiered sweep ran; Json::Num serialises NaN as null)
            ("ttft_p99_interactive_preempt_off_ms", Json::Num(p99_tiered[0])),
            ("ttft_p99_interactive_preempt_on_ms", Json::Num(p99_tiered[1])),
            ("preemptions", Json::from(preemptions as usize)),
        ],
    );

    // ---- A100 projection (measured base rate x hwmodel efficiency) ----
    let batch = *info.batch_variants("qkv").last().unwrap();
    let base = match measure_decode(
        &manifest,
        MODEL,
        PolicyKind::TinyServe,
        2048,
        2048,
        batch,
        scale(16),
        KvDtype::F32,
    ) {
        Ok(b) => b,
        Err(e) => {
            println!("(projection skipped: {e})");
            return;
        }
    };
    println!(
        "measured single-worker rate: {:.1} tok/s (batch {batch})",
        base.tokens_per_s
    );
    let hw = HwModel::a100();
    let shape = Shape {
        d_model: info.d_model,
        n_layer: info.n_layer,
        n_params: info.n_params,
        ctx: 16384,
        page_size: 16,
        k_pages: 128,
        kv_dtype: KvDtype::F16,
        batch,
    };
    let mut tp = Table::new(
        &format!("Table 8b: multi-GPU projection ({MODEL}, measured base + hw model)"),
        &["#GPUs", "tok/ms", "speedup", "efficiency %"],
    );
    // efficiency is evaluated at the A100-projected service rate (the CPU
    // base rate is so slow that coordination cost vanishes; the projected
    // rate exposes it, which is what Table 8 reports); the tok/ms column
    // scales the *measured* base by that efficiency.
    let proj_rate = 1e3 / hw.decode_token_ms(&shape) * shape.batch as f64;
    for n in [1usize, 2, 4, 8] {
        let eff = hw.multi_gpu_efficiency(&shape, proj_rate, n);
        let thr = base.tokens_per_s * n as f64 * eff;
        tp.row(vec![
            format!("{n}"),
            format!("{:.3}", thr / 1e3),
            format!("{:.2}x", thr / base.tokens_per_s.max(1e-9)),
            format!("{:.1}", eff * 100.0),
        ]);
    }
    tp.emit(&tinyserve::results_dir(), "table8_projection");
}
