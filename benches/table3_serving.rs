//! Table 3 — multi-user serving comparison: P50/P99 latency, throughput and
//! engine utilization under a Poisson trace, comparing scheduler/policy
//! configurations that emulate the paper's comparator systems:
//!   vLLM-like          paged FullCache + continuous batching
//!   TGI-like           window attention (StreamingLLM) + static batching
//!   TensorRT-LLM-like  greedy fused batching, larger batch, no timeout
//!   TinyServe          query-aware selection + continuous batching

// `serve_trace` is deprecated in favour of the Frontend lifecycle API but
// stays the trace-replay entry point for paper-table benches.
#![allow(deprecated)]

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::batcher::BatcherConfig;
use tinyserve::coordinator::{serve_trace, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::harness::scale;
use tinyserve::plugins::Pipeline;
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::workload::{generate_trace, TraceConfig};

const MODEL: &str = "tiny-trained";

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n_requests = scale(48);
    let trace_cfg = TraceConfig {
        n_requests,
        mean_interarrival_s: 0.05,
        prompt_chars: (150, 500),
        new_tokens: (10, 30),
        session_reuse_prob: 0.3,
        n_sessions: 8,
        seed: 42,
    };
    let trace = generate_trace(&trace_cfg);

    struct Sys {
        name: &'static str,
        policy: PolicyKind,
        budget: usize,
        batch: usize,
        timeout_ms: f64,
        prefill_per_round: usize,
    }
    let systems = [
        Sys { name: "vLLM-like (paged FullCache)", policy: PolicyKind::FullCache,
              budget: 1024, batch: 4, timeout_ms: 50.0, prefill_per_round: 2 },
        Sys { name: "TGI-like (window + static batch)", policy: PolicyKind::StreamingLlm,
              budget: 256, batch: 4, timeout_ms: 100.0, prefill_per_round: 4 },
        Sys { name: "TRT-LLM-like (greedy fused)", policy: PolicyKind::FullCache,
              budget: 1024, batch: 8, timeout_ms: 0.0, prefill_per_round: 4 },
        Sys { name: "TINYSERVE (query-aware)", policy: PolicyKind::TinyServe,
              budget: 256, batch: 4, timeout_ms: 50.0, prefill_per_round: 2 },
    ];

    let mut t = Table::new(
        &format!("Table 3: multi-user serving ({MODEL}, {n_requests} reqs, Poisson 50ms)"),
        &[
            "system", "P50 e2e ms", "P99 e2e ms", "P50 ttft ms", "thr req/s",
            "thr tok/s", "util %", "KV hit %", "acc %",
        ],
    );
    for s in &systems {
        let cfg = ServingConfig {
            model: MODEL.into(),
            policy: s.policy,
            budget: s.budget,
            max_batch: s.batch,
            batch_timeout_ms: s.timeout_ms,
            ..Default::default()
        };
        let mut engine = match Engine::from_manifest(&manifest, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skip {}: {e}", s.name);
                continue;
            }
        };
        engine.warmup().ok();
        let opts = ServeOptions {
            batcher: BatcherConfig {
                max_active: s.batch * 2,
                batch_timeout_s: s.timeout_ms / 1e3,
                prefill_per_round: s.prefill_per_round,
            },
            ..Default::default()
        };
        let mut plugins = Pipeline::new();
        match serve_trace(&mut engine, &trace, &opts, &mut plugins) {
            Ok(r) => {
                let mut m = r.metrics;
                t.row(vec![
                    s.name.into(),
                    format!("{:.0}", m.request_e2e.p50() * 1e3),
                    format!("{:.0}", m.request_e2e.p99() * 1e3),
                    format!("{:.0}", m.request_ttft.p50() * 1e3),
                    format!("{:.2}", m.requests_per_sec()),
                    format!("{:.1}", m.throughput_tps()),
                    format!("{:.1}", r.busy_frac * 100.0),
                    format!("{:.1}", m.hit_rate.mean() * 100.0),
                    format!("{:.1}", r.accuracy * 100.0),
                ]);
            }
            Err(e) => eprintln!("serve {} failed: {e}", s.name),
        }
    }
    t.emit(&tinyserve::results_dir(), "table3_serving");
}
