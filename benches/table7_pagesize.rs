//! Table 7 — KV page size sweep: latency, perplexity and hit rate vs S
//! (paper: S in {4..64}, larger pages are faster to scan but less precise).

use tinyserve::config::ServingConfig;
use tinyserve::harness::{measure_ppl, scale};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;

const MODEL: &str = "tiny-trained";
const CTX: usize = 2048;
const BUDGET: usize = 256;

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let steps = scale(20);
    let n_docs = scale(6);
    let mut t = Table::new(
        &format!("Table 7: page size sweep ({MODEL}, ctx {CTX}, budget {BUDGET})"),
        &["S", "ms/tok", "±", "PPL", "KV hit %", "score ms", "gather MB/step"],
    );
    for s in [4usize, 8, 16, 32, 64] {
        if BUDGET % s != 0 {
            continue;
        }
        let lat = measure_decode_with_pagesize(&manifest, s, steps);
        let ppl = measure_ppl(&manifest, MODEL, PolicyKind::TinyServe, s, BUDGET, n_docs, 500);
        match (lat, ppl) {
            (Ok(r), Ok(p)) => {
                t.row(vec![
                    format!("{s}"),
                    format!("{:.2}", r.ms_per_token),
                    format!("{:.2}", r.ms_std),
                    format!("{p:.3}"),
                    format!("{:.1}", r.hit_rate * 100.0),
                    format!("{:.3}", r.score_ms),
                    format!("{:.2}", r.gather_bytes_per_step / 1e6),
                ]);
            }
            (l, p) => eprintln!("skip S={s}: lat={:?} ppl={:?}", l.is_ok(), p.is_ok()),
        }
    }
    t.emit(&tinyserve::results_dir(), "table7_pagesize");
}

fn measure_decode_with_pagesize(
    manifest: &tinyserve::runtime::Manifest,
    page_size: usize,
    steps: usize,
) -> anyhow::Result<tinyserve::harness::DecodeMeasurement> {
    use tinyserve::engine::{Engine, Sampling};
    use tinyserve::metrics::StepMetrics;
    use tinyserve::util::rng::Rng;
    use tinyserve::util::stats::Samples;
    let cfg = ServingConfig {
        model: MODEL.into(),
        policy: PolicyKind::TinyServe,
        budget: BUDGET,
        page_size,
        max_batch: 1,
        ..Default::default()
    };
    let mut e = Engine::from_manifest(manifest, cfg)?;
    let mut rng = Rng::new(5);
    let mut seq = e.new_sequence();
    e.synthetic_fill(&mut seq, CTX - 1, &mut rng);
    seq.tokens.push(1);
    seq.max_new_tokens = usize::MAX / 2;
    for _ in 0..3 {
        let mut m = StepMetrics::default();
        let mut b = [&mut seq];
        e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m)?;
    }
    let mut lat = Samples::new();
    let mut agg = StepMetrics::default();
    for _ in 0..steps {
        let mut m = StepMetrics::default();
        let mut b = [&mut seq];
        e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m)?;
        lat.push(m.step_seconds);
        agg.gather_bytes += m.gather_bytes;
        agg.pages_selected += m.pages_selected;
        agg.pages_reused += m.pages_reused;
        agg.score_seconds += m.score_seconds;
        agg.step_seconds += m.step_seconds;
    }
    let pool_bytes = e.pool.bytes_in_use();
    e.release(&mut seq);
    Ok(tinyserve::harness::DecodeMeasurement {
        model: MODEL.into(),
        policy: PolicyKind::TinyServe,
        ctx: CTX,
        budget: BUDGET,
        batch: 1,
        ms_per_token: lat.mean() * 1e3,
        ms_std: lat.std() * 1e3,
        tokens_per_s: 1.0 / lat.mean(),
        hit_rate: agg.pages_reused as f64 / agg.pages_selected.max(1) as f64,
        gather_gb_per_s: 0.0,
        gather_bytes_per_step: agg.gather_bytes as f64 / steps as f64,
        score_ms: agg.score_seconds / steps as f64 * 1e3,
        gather_ms: 0.0,
        exec_ms: 0.0,
        pool_bytes,
        trace_bytes: vec![],
        trace_hit: vec![],
    })
}
