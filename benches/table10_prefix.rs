//! Table 10 — cross-request shared prefix cache: a multi-tenant template
//! workload (N tenants x M templates, zipf-popular, paraphrased question
//! tails) served with the prefix cache off vs on, across tenant/template
//! skews. Reports prefill-compute saved (prompt tokens whose prefill was
//! skipped by page adoption), modeled TTFT P50/P99 delta, KV bytes
//! deduplicated, index hit rate and publish/unpublish churn — the serving
//! win behind "query-aware selection makes KV reuse cheap": identical
//! token streams (pinned by the property battery and the serve-level
//! integration test) at a fraction of the prefill compute.
//!
//! Time is `TimeModel::Modeled`, so the TTFT columns are deterministic
//! from the seed and the sharing-on vs sharing-off delta is exactly the
//! skipped prefill priced out of the virtual clock.
//!
//! Alongside the human table this emits `results/BENCH_table10.json`,
//! which CI uploads and guards (the hit rate of the shared-heavy cell
//! must be non-zero).

use tinyserve::harness::{measure_prefix, scale, PrefixCase};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::util::json::Json;

const MODEL: &str = "tiny-trained";
const SEED: u64 = 11;

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n_requests = scale(48);

    // (label, tenants, templates/tenant, template share of traffic)
    let mixes: [(&str, usize, usize, f64); 3] = [
        ("light  2x2 p=0.3", 2, 2, 0.3),
        ("medium 4x2 p=0.6", 4, 2, 0.6),
        ("heavy  8x4 p=0.9", 8, 4, 0.9),
    ];

    let mut t = Table::new(
        &format!(
            "Table 10: shared prefix cache ({MODEL}, {n_requests} reqs/cell, \
             modeled time; off vs on per tenant/template mix)"
        ),
        &[
            "mix",
            "prefix",
            "hit %",
            "skip tok",
            "skip %",
            "dedup MB",
            "pub/unpub",
            "ttft P50 ms",
            "ttft P99 ms",
            "P50 Δ%",
            "viol",
            "acc %",
        ],
    );

    let mut bench_rows: Vec<(String, f64, f64, f64, f64)> = Vec::new();
    for (label, tenants, templates, prob) in mixes {
        let base_case = PrefixCase {
            n_requests,
            n_tenants: tenants,
            templates_per_tenant: templates,
            template_prob: prob,
            prefix_cache_mb: None,
            prefix_min_pages: 1,
            seed: SEED,
        };
        let off = match measure_prefix(&manifest, MODEL, &base_case) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skip {label} (off): {e}");
                continue;
            }
        };
        let on = match measure_prefix(
            &manifest,
            MODEL,
            &PrefixCase { prefix_cache_mb: Some(16.0), ..base_case.clone() },
        ) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skip {label} (on): {e}");
                continue;
            }
        };
        let skip_pct =
            on.tokens_skipped as f64 / on.prompt_tokens.max(1) as f64 * 100.0;
        let p50_delta = (off.ttft_p50_ms - on.ttft_p50_ms)
            / off.ttft_p50_ms.max(1e-9)
            * 100.0;
        for (name, r) in [("off", &off), ("on", &on)] {
            t.row(vec![
                label.to_string(),
                name.to_string(),
                format!("{:.1}", r.hit_rate * 100.0),
                format!("{}", r.tokens_skipped),
                if name == "on" { format!("{skip_pct:.1}") } else { "-".into() },
                format!("{:.2}", r.bytes_deduped as f64 / 1e6),
                format!("{}/{}", r.pages_published, r.pages_unpublished),
                format!("{:.1}", r.ttft_p50_ms),
                format!("{:.1}", r.ttft_p99_ms),
                if name == "on" { format!("{p50_delta:+.1}") } else { "-".into() },
                format!("{}", r.kv_budget_violations),
                format!("{:.1}", r.accuracy * 100.0),
            ]);
        }
        println!(
            "{label}: {skip_pct:.1}% prefill tokens skipped, \
             TTFT P50 {:.1} -> {:.1} ms ({p50_delta:+.1}%), hit rate {:.0}%",
            off.ttft_p50_ms,
            on.ttft_p50_ms,
            on.hit_rate * 100.0
        );
        bench_rows.push((
            label.to_string(),
            on.hit_rate,
            skip_pct,
            p50_delta,
            on.bytes_deduped as f64,
        ));
    }

    t.emit(&tinyserve::results_dir(), "table10_prefix");
    // flat per-mix scalars so the CI guard can assert on them without a
    // JSON-path tool: <mix>_{hit_rate,skip_pct,ttft_p50_delta_pct,dedup_bytes}
    let mut owned: Vec<(String, Json)> = Vec::new();
    for (label, hit, skip, delta, dedup) in &bench_rows {
        let s = label.split_whitespace().next().unwrap_or("mix");
        owned.push((format!("{s}_hit_rate"), Json::from(*hit)));
        owned.push((format!("{s}_skip_pct"), Json::from(*skip)));
        owned.push((format!("{s}_ttft_p50_delta_pct"), Json::from(*delta)));
        owned.push((format!("{s}_dedup_bytes"), Json::from(*dedup)));
    }
    let mut context: Vec<(&str, Json)> = vec![
        ("model", Json::from(MODEL)),
        ("seed", Json::from(SEED as usize)),
        ("n_requests", Json::from(n_requests)),
    ];
    context.extend(owned.iter().map(|(k, v)| (k.as_str(), v.clone())));
    t.emit_bench(&tinyserve::results_dir(), "table10", context);
}
