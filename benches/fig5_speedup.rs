//! Figure 5 — decode speedup vs FullCache across context lengths and
//! models (measured), the paper's headline 2.1-3.4x curve.

use tinyserve::config::KvDtype;
use tinyserve::harness::{measure_decode, scale};
use tinyserve::report::Series;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let steps = scale(16);
    let quick = tinyserve::harness::quick();
    let models: &[(&str, usize)] = if quick {
        &[("tiny-trained", 256)]
    } else {
        &[
            ("tiny-trained", 256),
            ("tinyllama-125m-sim", 512),
            ("gpt2-345m-sim", 512),
        ]
    };
    let ctxs: &[usize] = if quick { &[512, 2048] } else { &[512, 1024, 2048, 4096] };

    let mut s = Series::new("Figure 5: speedup vs FullCache over context", "ctx");
    s.x = ctxs.iter().map(|&c| c as f64).collect();
    for &(model, budget) in models {
        let info = manifest.model(model).expect("model");
        let max_budget = *info.budget_variants().last().unwrap();
        let mut col = Vec::new();
        for &ctx in ctxs {
            let ctx = ctx.min(max_budget); // FullCache budget must cover ctx
            let full_budget = tinyserve::harness::fullcache_budget(info, ctx);
            let full = measure_decode(
                &manifest, model, PolicyKind::FullCache, ctx, full_budget, 1,
                steps, KvDtype::F32,
            );
            let sel = measure_decode(
                &manifest, model, PolicyKind::TinyServe, ctx,
                budget.min(max_budget), 1, steps, KvDtype::F32,
            );
            match (full, sel) {
                (Ok(f), Ok(t)) => {
                    let sp = f.ms_per_token / t.ms_per_token;
                    println!(
                        "{model} ctx {ctx}: full {:.2} ms, tinyserve {:.2} ms -> {sp:.2}x",
                        f.ms_per_token, t.ms_per_token
                    );
                    col.push(sp);
                }
                _ => col.push(f64::NAN),
            }
        }
        s.columns.push((model.to_string(), col));
    }
    s.emit(&tinyserve::results_dir(), "fig5_speedup");
}
