//! Table 4 — per-task accuracy + latency on the trained model: every
//! retrieval task (the LongBench analogues, DESIGN.md §2) x every policy,
//! real prefill + greedy decode, exact-match scoring.

use tinyserve::harness::{measure_accuracy, scale};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::workload::tasks::Task;

const MODEL: &str = "tiny-trained";
const BUDGET: usize = 256;
const CHARS: usize = 700; // ~45 pages of 16 at byte-level

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let n = scale(10);
    let mut t = Table::new(
        &format!(
            "Table 4: task accuracy x policy ({MODEL}, ~{CHARS} chars, budget {BUDGET})"
        ),
        &[
            "task", "(LongBench analogue)", "policy", "exact %", "char %",
            "ms/tok", "KV hit %", "speedup",
        ],
    );
    let policies = [
        PolicyKind::FullCache,
        PolicyKind::StreamingLlm,
        PolicyKind::SoftPrune,
        PolicyKind::SnapKv,
        PolicyKind::PyramidKv,
        PolicyKind::TinyServe,
        PolicyKind::Oracle,
    ];
    for &task in Task::all() {
        let mut full_ms = f64::NAN;
        for &policy in &policies {
            // FullCache: smallest budget covering the whole prompt (fair)
            let info = manifest.model(MODEL).expect("model");
            let budget = if policy == PolicyKind::FullCache {
                tinyserve::harness::fullcache_budget(info, CHARS + 32)
            } else {
                BUDGET
            };
            match measure_accuracy(
                &manifest, MODEL, policy, task, n, CHARS, budget, 42,
            ) {
                Ok(r) => {
                    if policy == PolicyKind::FullCache {
                        full_ms = r.ms_per_token;
                    }
                    let speedup = full_ms / r.ms_per_token;
                    t.row(vec![
                        task.name().into(),
                        task.longbench_analogue().into(),
                        policy.name().into(),
                        format!("{:.0}", r.exact * 100.0),
                        format!("{:.0}", r.char_acc * 100.0),
                        format!("{:.2}", r.ms_per_token),
                        format!("{:.1}", r.hit_rate * 100.0),
                        if speedup.is_finite() {
                            format!("{speedup:.2}x")
                        } else {
                            "-".into()
                        },
                    ]);
                }
                Err(e) => eprintln!("skip {}/{:?}: {e}", task.name(), policy),
            }
        }
    }
    t.emit(&tinyserve::results_dir(), "table4_tasks");
}
