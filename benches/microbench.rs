//! Microbenchmarks of the L3 hot paths (the §Perf targets in
//! EXPERIMENTS.md): page scoring scan, top-k select, gather+dequant,
//! metadata update, and sampling.

use tinyserve::config::KvDtype;
use tinyserve::kvcache::{PagePool, SeqCache};
use tinyserve::sparsity::{score_page, top_k_indices};
use tinyserve::util::benchkit::Bench;
use tinyserve::util::rng::Rng;

fn main() {
    let mut b = Bench::new("microbench");
    let mut rng = Rng::new(1);

    // ---- page scoring: P pages x d channels (tau_meta * P term) ----
    for (p, d) in [(256usize, 128usize), (2048, 128), (2048, 640)] {
        let q: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let metas: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..2 * d).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut sink = 0.0f32;
        b.run_with_items(&format!("score/P{p}_d{d}"), p as f64, || {
            for m in &metas {
                sink += score_page(&q, m);
            }
        });
        std::hint::black_box(sink);
    }

    // ---- top-k over P scores ----
    for p in [256usize, 2048] {
        let scores: Vec<f32> = (0..p).map(|_| rng.normal() as f32).collect();
        let k = p * 3 / 10;
        b.run(&format!("topk/P{p}_k{k}"), || {
            std::hint::black_box(top_k_indices(&scores, k));
        });
    }

    // ---- gather + dequant: K pages of S=16 tokens (tau_hb * K*S term) ----
    for dt in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let d_kv = 128;
        let s = 16;
        let mut pool = PagePool::new(1, d_kv, s, dt);
        let mut seq = SeqCache::new();
        let row: Vec<f32> = (0..d_kv).map(|_| rng.normal() as f32).collect();
        for _ in 0..128 * s {
            let (page, slot) = seq.slot_for_next(&mut pool);
            pool.write_token(page, slot, 0, &row, &row);
            seq.commit_token();
        }
        let mut kdst = vec![0.0f32; 128 * s * d_kv];
        let mut vdst = vec![0.0f32; 128 * s * d_kv];
        let bytes = 128 * s * d_kv * 2 * 4;
        b.run_with_items(&format!("gather/{dt:?}_128pages"), bytes as f64, || {
            for (i, e) in seq.pages.iter().enumerate() {
                let off = i * s * d_kv;
                pool.gather_rows(
                    e.id,
                    0,
                    s,
                    &mut kdst[off..off + s * d_kv],
                    &mut vdst[off..off + s * d_kv],
                );
            }
        });
        std::hint::black_box((&kdst, &vdst));
    }

    // ---- metadata update (per-token append cost) ----
    {
        let d_kv = 128;
        let mut pool = PagePool::new(1, d_kv, 16, KvDtype::F32);
        let mut seq = SeqCache::new();
        let row: Vec<f32> = (0..d_kv).map(|_| rng.normal() as f32).collect();
        b.run("append/write_token_d128", || {
            let (page, slot) = seq.slot_for_next(&mut pool);
            pool.write_token(page, slot, 0, &row, &row);
            seq.commit_token();
        });
    }

    // ---- sampling over a vocab-512 logits row ----
    {
        let logits: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let mut r2 = Rng::new(2);
        b.run("sample/greedy_v512", || {
            std::hint::black_box(tinyserve::engine::sample(
                &logits,
                tinyserve::engine::Sampling::Greedy,
                &mut r2,
            ));
        });
    }
    b.finish();
}
