//! Table 6 — plugin/component ablation on the serving stack: full system
//! vs w/o query router (FullCache), w/o page manager (page_size = budget:
//! one giant page), w/o session reuse, w/o entropy early-exit, w/o
//! continuous batching.

// `serve_trace` is deprecated in favour of the Frontend lifecycle API but
// stays the trace-replay entry point for paper-table benches.
#![allow(deprecated)]

use tinyserve::config::ServingConfig;
use tinyserve::coordinator::batcher::BatcherConfig;
use tinyserve::coordinator::{serve_trace, ServeOptions};
use tinyserve::engine::Engine;
use tinyserve::harness::scale;
use tinyserve::plugins::{EntropyEarlyExit, Pipeline, RepetitionGuard};
use tinyserve::report::Table;
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::workload::{generate_trace, TraceConfig};

const MODEL: &str = "tiny-trained";

fn main() {
    let manifest = Manifest::load(&tinyserve::artifacts_dir()).expect("artifacts");
    let trace = generate_trace(&TraceConfig {
        n_requests: scale(32),
        prompt_chars: (150, 450),
        new_tokens: (10, 25),
        session_reuse_prob: 0.4,
        n_sessions: 6,
        seed: 11,
        ..Default::default()
    });

    let base_cfg = || ServingConfig {
        model: MODEL.into(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        ..Default::default()
    };
    let base_opts = || ServeOptions::default();

    struct Variant {
        name: &'static str,
        cfg: ServingConfig,
        opts: ServeOptions,
        plugins: fn() -> Pipeline,
    }
    fn full_plugins() -> Pipeline {
        let mut p = Pipeline::new();
        p.push(Box::new(EntropyEarlyExit::new(0.05, 3, 4)));
        p.push(Box::new(RepetitionGuard { max_run: 12 }));
        p
    }
    fn no_plugins() -> Pipeline {
        Pipeline::new()
    }

    let variants = vec![
        Variant { name: "Full TinyServe", cfg: base_cfg(), opts: base_opts(),
                  plugins: full_plugins },
        Variant {
            name: "w/o Query Router (FullCache)",
            cfg: ServingConfig { policy: PolicyKind::FullCache, budget: 1024, ..base_cfg() },
            opts: base_opts(),
            plugins: full_plugins,
        },
        Variant {
            name: "w/o Page Manager (coarse S=64)",
            cfg: ServingConfig { page_size: 64, recent_pages: 1, sink_pages: 1, ..base_cfg() },
            opts: base_opts(),
            plugins: full_plugins,
        },
        Variant {
            name: "w/o Session Reuse",
            cfg: base_cfg(),
            opts: ServeOptions { max_sessions: 0, ..base_opts() },
            plugins: full_plugins,
        },
        Variant {
            name: "w/o Early-Exit Plugins",
            cfg: base_cfg(),
            opts: base_opts(),
            plugins: no_plugins,
        },
        Variant {
            name: "w/o Continuous Batching (batch=1)",
            cfg: ServingConfig { max_batch: 1, ..base_cfg() },
            opts: ServeOptions {
                batcher: BatcherConfig {
                    max_active: 1,
                    batch_timeout_s: 0.05,
                    prefill_per_round: 1,
                },
                ..base_opts()
            },
            plugins: full_plugins,
        },
    ];

    let mut t = Table::new(
        &format!("Table 6: system component ablation ({MODEL})"),
        &[
            "configuration", "P50 e2e ms", "tok/s", "ms/tok", "KV hit %",
            "acc %", "mem MB peak", "session reuse %",
        ],
    );
    for v in variants {
        let mut engine = match Engine::from_manifest(&manifest, v.cfg.clone()) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skip {}: {e}", v.name);
                continue;
            }
        };
        let mut plugins = (v.plugins)();
        match serve_trace(&mut engine, &trace, &v.opts, &mut plugins) {
            Ok(r) => {
                let mut m = r.metrics;
                t.row(vec![
                    v.name.into(),
                    format!("{:.0}", m.request_e2e.p50() * 1e3),
                    format!("{:.1}", m.throughput_tps()),
                    format!("{:.2}", m.ms_per_token()),
                    format!("{:.1}", m.hit_rate.mean() * 100.0),
                    format!("{:.1}", r.accuracy * 100.0),
                    format!(
                        "{:.1}",
                        engine.pool.peak_pages as f64
                            * engine.cfg.page_size as f64
                            * engine.d_kv as f64
                            * 2.0 * 4.0 * engine.n_layer as f64 / 1e6
                    ),
                    format!("{:.0}", r.session_stats.reuse_rate() * 100.0),
                ]);
            }
            Err(e) => eprintln!("serve {} failed: {e}", v.name),
        }
    }
    t.emit(&tinyserve::results_dir(), "table6_plugins");
}
