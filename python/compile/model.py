"""L2 JAX model: the transformer pieces the Rust coordinator orchestrates.

The decode path is split into per-layer executables so that the Rust L3 can
run the paper's Algorithm 1 *between* them — it owns the paged KV cache and
page metadata, scores pages against the fresh query, gathers the selected
pages, and only then dispatches the fused attention kernel:

    embed -> [ qkv -> (rust: append KV, update metadata, score, top-K,
               gather) -> post ] x n_layer -> logits -> (rust: sample)

Every function here is pure and is lowered once by aot.py to HLO text.
Weight tensors are ordinary parameters (never baked constants): the Rust
runtime uploads them to device buffers once and passes them to `execute_b`
on every call, so the request path moves only activations and gathered KV.

`decode_fused` is the single-call ablation variant ("Fused Kernel" rows of
paper Table 2): page scoring (Pallas), top-K, gather and attention all run
in-graph and the whole KV cache round-trips as device buffers.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import ref
from .kernels.page_score import page_scores
from .kernels.sparse_attn import attn_decode

# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

LAYER_PARAMS = ("ln1", "wqkv", "wo", "ln2", "w1", "w2")


def param_names(cfg: ModelConfig) -> List[str]:
    """Canonical parameter order shared with the Rust runtime manifest."""
    names = ["embed", "lnf"]
    for l in range(cfg.n_layer):
        names += [f"{p}.{l}" for p in LAYER_PARAMS]
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, m, v = cfg.d_model, cfg.mlp_dim, cfg.vocab
    shapes = {"embed": (v, d), "lnf": (d,)}
    for l in range(cfg.n_layer):
        shapes[f"ln1.{l}"] = (d,)
        shapes[f"wqkv.{l}"] = (d, 3 * d)
        shapes[f"wo.{l}"] = (d, d)
        shapes[f"ln2.{l}"] = (d,)
        shapes[f"w1.{l}"] = (d, m)
        shapes[f"w2.{l}"] = (m, d)
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Seeded scaled-gaussian init (the weights of the -sim scale family)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in param_shapes(cfg).items():
        if name.startswith("ln") or name == "lnf":
            out[name] = np.ones(shape, np.float32)
        else:
            fan_in = shape[0]
            std = 1.0 / np.sqrt(fan_in)
            if name.startswith(("wo", "w2")):
                std /= np.sqrt(2.0 * cfg.n_layer)  # gpt2-style residual scaling
            out[name] = rng.normal(0.0, std, size=shape).astype(np.float32)
    return out


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


def rmsnorm(x, scale):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _act(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.relu(x)


def mlp(h, w1, w2, act: str):
    return _act(h @ w1, act) @ w2


# --------------------------------------------------------------------------
# decode-path executables (one per `kind` in the artifact manifest)
# --------------------------------------------------------------------------


def embed_fn(cfg: ModelConfig):
    def f(embed, tokens):
        # tokens: i32[B] -> h f32[B, d]
        return (jnp.take(embed, tokens, axis=0),)

    return f


def qkv_fn(cfg: ModelConfig):
    H, hd = cfg.n_head, cfg.head_dim

    def f(ln1, wqkv, h):
        # h: f32[B, d] -> q, k, v: f32[B, H, hd] (ALiBi: no rotation on k)
        B = h.shape[0]
        x = rmsnorm(h, ln1)
        qkv = x @ wqkv  # [B, 3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        return (
            q.reshape(B, H, hd),
            k.reshape(B, H, hd),
            v.reshape(B, H, hd),
        )

    return f


def post_fn(cfg: ModelConfig):
    H = cfg.n_head

    def f(wo, ln2, w1, w2, h, q, kg, vg, mask, dist):
        # h: [B, d]; q: [B, H, hd]; kg/vg: [B, T, H, hd];
        # mask/dist: [B, T] -> h_out [B, d], mass [B, T], ent [B]
        B, d = h.shape
        o, alpha = attn_decode(q, kg, vg, mask, dist)
        h1 = h + o.reshape(B, d) @ wo
        h2 = h1 + mlp(rmsnorm(h1, ln2), w1, w2, cfg.act)
        mass = jnp.mean(alpha, axis=1)  # [B, T] mean attention over heads
        ent = ref.entropy_ref(alpha)    # [B]
        return (h2, mass, ent)

    return f


def logits_fn(cfg: ModelConfig):
    def f(lnf, embed, h):
        # h: [B, d] -> logits f32[B, V] (tied LM head)
        return (rmsnorm(h, lnf) @ embed.T,)

    return f


# --------------------------------------------------------------------------
# prefill (chunked, flash-style over the key axis to bound memory)
# --------------------------------------------------------------------------


def _flash_prefill_attn(q, kbuf, vbuf, q_pos, prior_len, slopes, block=1024):
    """Causal chunk attention against a [B, Tp, H, hd] key buffer.

    Memory-bounded lax.scan over Tp blocks with online softmax; keys at
    index >= prior_len + C are invalid, enforced with the causal mask
    (q_pos >= k_pos covers it because invalid slots sit beyond the chunk).
    """
    B, C, H, hd = q.shape
    Tp = kbuf.shape[1]
    scale = np.float32(1.0 / np.sqrt(hd))
    qs = q * scale
    block = min(block, Tp)
    n_blocks = Tp // block

    def body(carry, i):
        m, s, acc = carry
        k = jax.lax.dynamic_slice_in_dim(kbuf, i * block, block, axis=1)
        v = jax.lax.dynamic_slice_in_dim(vbuf, i * block, block, axis=1)
        k_pos = i * block + jnp.arange(block)  # [block]
        logits = jnp.einsum("bchd,bthd->bhct", qs, k)  # [B,H,C,block]
        dist = (q_pos[:, :, None] - k_pos[None, None, :]).astype(jnp.float32)
        valid = dist >= 0
        logits = logits - slopes[None, :, None, None] * jnp.maximum(dist, 0.0)[:, None]
        logits = jnp.where(valid[:, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard: rows with no valid key yet keep m = -inf; exp(-inf - -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        p = jnp.where(valid[:, None], p, 0.0)
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhct,bthd->bhcd", p, v)
        return (m_new, s_new, acc_new), 0

    m0 = jnp.full((B, H, C), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((B, H, C), jnp.float32)
    a0 = jnp.zeros((B, H, C, hd), jnp.float32)
    (m, s, acc), _ = jax.lax.scan(body, (m0, s0, a0), jnp.arange(n_blocks))
    o = acc / s[..., None]
    return jnp.transpose(o, (0, 2, 1, 3))  # [B, C, H, hd]


def prefill_fn(cfg: ModelConfig):
    """One prompt chunk through all layers.

    Inputs:  params..., tokens i32[B, C], prior_len i32[],
             kbuf/vbuf f32[Lyr, B, Tp, H, hd] (host-staged by the Rust engine)
    Outputs: k_chunk/v_chunk f32[Lyr, B, C, H, hd] (only the new tokens — the
             engine owns the full buffer and writes the chunk in, so the
             PJRT tuple result stays small), h_last f32[B, d]
    """
    H, hd, L = cfg.n_head, cfg.head_dim, cfg.n_layer
    slopes = jnp.asarray(ref.alibi_slopes(H))

    def f(*args):
        names = param_names(cfg)
        params = dict(zip(names, args[: len(names)]))
        tokens, prior_len, kbuf, vbuf = args[len(names):]
        B, C = tokens.shape
        h = jnp.take(params["embed"], tokens, axis=0)  # [B, C, d]
        q_pos = prior_len + jnp.arange(C)[None, :] * jnp.ones((B, 1), jnp.int32)
        new_k, new_v = [], []
        for l in range(L):
            x = rmsnorm(h, params[f"ln1.{l}"])
            qkv = x @ params[f"wqkv.{l}"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(B, C, H, hd)
            k = k.reshape(B, C, H, hd)
            v = v.reshape(B, C, H, hd)
            kb = jax.lax.dynamic_update_slice(
                kbuf[l], k, (0, prior_len, 0, 0))
            vb = jax.lax.dynamic_update_slice(
                vbuf[l], v, (0, prior_len, 0, 0))
            new_k.append(k)
            new_v.append(v)
            o = _flash_prefill_attn(q, kb, vb, q_pos, prior_len, slopes)
            h = h + o.reshape(B, C, -1) @ params[f"wo.{l}"]
            h = h + mlp(rmsnorm(h, params[f"ln2.{l}"]),
                        params[f"w1.{l}"], params[f"w2.{l}"], cfg.act)
        kout = jnp.stack(new_k)
        vout = jnp.stack(new_v)
        return (kout, vout, h[:, -1, :])

    return f


# --------------------------------------------------------------------------
# fully-fused decode step (ablation variant: selection in-graph)
# --------------------------------------------------------------------------


def decode_fused_fn(cfg: ModelConfig, n_pages: int, k_pages: int, page_size: int):
    """Single-call decode step with in-graph query-aware page selection.

    The KV cache + metadata round-trip as device buffers; Rust only feeds
    tokens/positions. Used by the "fused kernel" ablation rows and as an
    upper-bound comparator for the Rust-orchestrated path.

    Inputs:  params..., token i32[B], pos i32[],
             kcache/vcache f32[Lyr, B, P*S, H, hd],
             meta f32[Lyr, B, P, 2, d]
    Outputs: kcache', vcache', meta', logits f32[B, V], sel i32[Lyr, B, K]
    """
    H, hd, L, d = cfg.n_head, cfg.head_dim, cfg.n_layer, cfg.d_model
    S, P, K = page_size, n_pages, k_pages
    slopes = jnp.asarray(ref.alibi_slopes(H))

    def f(*args):
        names = param_names(cfg)
        params = dict(zip(names, args[: len(names)]))
        token, pos, kcache, vcache, meta = args[len(names):]
        B = token.shape[0]
        h = jnp.take(params["embed"], token, axis=0)  # [B, d]
        page_of_pos = pos // S
        slot = pos % S
        ks, vs, ms, sels = [], [], [], []
        for l in range(L):
            x = rmsnorm(h, params[f"ln1.{l}"])
            qkv = x @ params[f"wqkv.{l}"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(B, H, hd)
            kc = jax.lax.dynamic_update_slice(
                kcache[l], k.reshape(B, 1, H, hd), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                vcache[l], v.reshape(B, 1, H, hd), (0, pos, 0, 0))
            # incremental metadata update for the page holding `pos`
            mt = meta[l]  # [B, P, 2, d]
            old = jax.lax.dynamic_slice(mt, (0, page_of_pos, 0, 0), (B, 1, 2, d))
            kflat = k.reshape(B, 1, 1, d)
            fresh = slot == 0
            new_min = jnp.where(fresh, kflat, jnp.minimum(old[:, :, 0:1], kflat))
            new_max = jnp.where(fresh, kflat, jnp.maximum(old[:, :, 1:2], kflat))
            mt = jax.lax.dynamic_update_slice(
                mt, jnp.concatenate([new_min, new_max], axis=2),
                (0, page_of_pos, 0, 0))
            # Algorithm 1 step 1-2: score + top-K (Pallas scorer in-graph)
            scores = page_scores(q.reshape(B, d), mt.reshape(B, P, 2, d))
            page_idx = jnp.arange(P)
            valid_page = page_idx[None, :] * S <= pos  # page has >= 1 token
            forced = (page_idx[None, :] == page_of_pos) | (page_idx[None, :] == 0)
            scores = jnp.where(valid_page, scores, -jnp.inf)
            scores = jnp.where(forced & valid_page, jnp.float32(3.4e38), scores)
            # argsort instead of lax.top_k: the TopK HLO op carries a
            # `largest=` attribute the xla_extension 0.5.1 text parser
            # rejects; sort lowers to plain `sort`, which round-trips.
            sel = jnp.argsort(-scores, axis=-1)[:, :K]  # [B, K]
            sel = jnp.sort(sel, axis=-1)
            # Algorithm 1 step 3: gather selected pages
            tok_idx = sel[:, :, None] * S + jnp.arange(S)[None, None, :]
            tok_idx = tok_idx.reshape(B, K * S)  # [B, T]
            kg = jnp.take_along_axis(kc, tok_idx[:, :, None, None], axis=1)
            vg = jnp.take_along_axis(vc, tok_idx[:, :, None, None], axis=1)
            dist = (pos - tok_idx).astype(jnp.float32)
            mask = jnp.where((tok_idx <= pos) & (dist >= 0), 0.0, -1e9)
            dist = jnp.maximum(dist, 0.0)
            # step 4: fused attention kernel
            o, _ = attn_decode(q, kg, vg, mask, dist, block_t=min(128, K * S))
            h = h + o.reshape(B, d) @ params[f"wo.{l}"]
            h = h + mlp(rmsnorm(h, params[f"ln2.{l}"]),
                        params[f"w1.{l}"], params[f"w2.{l}"], cfg.act)
            ks.append(kc)
            vs.append(vc)
            ms.append(mt)
            sels.append(sel)
        logits = rmsnorm(h, params["lnf"]) @ params["embed"].T
        return (jnp.stack(ks), jnp.stack(vs), jnp.stack(ms), logits,
                jnp.stack(sels))

    return f


# --------------------------------------------------------------------------
# dense training forward (used by train.py only; never exported)
# --------------------------------------------------------------------------


def train_loss_fn(cfg: ModelConfig):
    H, hd, L = cfg.n_head, cfg.head_dim, cfg.n_layer
    slopes = jnp.asarray(ref.alibi_slopes(H))

    def f(params: Dict[str, jnp.ndarray], tokens):
        # tokens: i32[B, T+1]; next-token cross-entropy over the window.
        x, y = tokens[:, :-1], tokens[:, 1:]
        B, T = x.shape
        h = jnp.take(params["embed"], x, axis=0)
        pos = jnp.arange(T)
        dist = (pos[:, None] - pos[None, :]).astype(jnp.float32)
        causal = dist >= 0
        bias = -slopes[:, None, None] * jnp.maximum(dist, 0.0)[None]
        bias = jnp.where(causal[None], bias, -1e9)  # [H, T, T]
        scale = np.float32(1.0 / np.sqrt(hd))
        for l in range(L):
            xn = rmsnorm(h, params[f"ln1.{l}"])
            qkv = xn @ params[f"wqkv.{l}"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(B, T, H, hd)
            k = k.reshape(B, T, H, hd)
            v = v.reshape(B, T, H, hd)
            logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale + bias[None]
            alpha = jax.nn.softmax(logits, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", alpha, v)
            h = h + o.reshape(B, T, -1) @ params[f"wo.{l}"]
            h = h + mlp(rmsnorm(h, params[f"ln2.{l}"]),
                        params[f"w1.{l}"], params[f"w2.{l}"], cfg.act)
        out = rmsnorm(h, params["lnf"]) @ params["embed"].T
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        return nll.mean()

    return f
