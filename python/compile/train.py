"""Build-time trainer for the accuracy-bearing `tiny-trained` model.

Trains a byte-level tiny transformer on the structured synthetic corpus
(corpus.py) so that serving-time retrieval tasks (passkey, kv-recall,
repetition, rare token, aliasing) have *real* exact-match accuracy — the
substitution for the paper's pretrained checkpoints (DESIGN.md §2).

ALiBi makes the model length-extrapolate: trained at `seq_len` (default 384)
it is served at 4K context, which is exactly the regime where page selection
matters. Runs once under `make artifacts`; skipped when the weights file
already exists. Single-core CPU budget: a few minutes.

Usage: python -m compile.train --out ../artifacts [--steps N]
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model, tensorfile
from .configs import CONFIGS


def adamw_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, wd=0.01, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new = {}
    for k in params:
        update = (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps)
        if k.startswith(("wqkv", "wo", "w1", "w2", "embed")):
            update = update + wd * params[k]
        new[k] = params[k] - lr * update
    return new, {"m": m, "v": v, "t": t}


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads.values()))


def train(steps: int = 500, batch: int = 4, seq_len: int = 384,
          lr_peak: float = 1.5e-3, seed: int = 42, log_every: int = 25,
          out_dir: str = "../artifacts", resume: bool = False):
    cfg = CONFIGS["tiny-trained"]
    rng = np.random.default_rng(seed)
    resume_path = os.path.join(out_dir, "tiny-trained.weights.bin")
    if resume and os.path.exists(resume_path):
        loaded, meta = tensorfile.read(resume_path)
        params = {k: jnp.asarray(v) for k, v in loaded.items()}
        rng = np.random.default_rng(seed + int(meta.get("steps", 0)))
        print(f"resumed from {resume_path} ({meta.get('steps')} prior steps)")
    else:
        params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed).items()}
    loss_fn = model.train_loss_fn(cfg)
    opt = adamw_init(params)
    warmup = max(1, steps // 10)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens)
        gn = global_norm(grads)
        clip = jnp.minimum(1.0, 1.0 / (gn + 1e-6))
        grads = {k: g * clip for k, g in grads.items()}
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss, gn

    t0 = time.time()
    losses = []
    for i in range(steps):
        if i < warmup:
            lr = lr_peak * (i + 1) / warmup
        else:
            frac = (i - warmup) / max(1, steps - warmup)
            lr = lr_peak * 0.5 * (1 + np.cos(np.pi * frac))
        tokens = jnp.asarray(corpus.training_batch(rng, batch, seq_len))
        params, opt, loss, gn = step_fn(params, opt, tokens, jnp.float32(lr))
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            dt = time.time() - t0
            print(f"step {i:4d}  loss {float(loss):.4f}  gnorm {float(gn):.3f}"
                  f"  lr {lr:.2e}  {dt:.1f}s", flush=True)

    # held-out perplexity
    eval_rng = np.random.default_rng(seed + 1)
    eval_tokens = jnp.asarray(corpus.training_batch(eval_rng, 8, seq_len))
    eval_loss = float(loss_fn(params, eval_tokens))
    ppl = float(np.exp(eval_loss))
    print(f"eval loss {eval_loss:.4f}  ppl {ppl:.2f}")

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "tiny-trained.weights.bin")
    tensorfile.write(
        path,
        {k: np.asarray(v) for k, v in params.items()},
        meta={"config": cfg.name, "steps": steps, "seq_len": seq_len,
              "final_loss": losses[-1], "eval_ppl": ppl, "seed": seed},
    )
    print(f"wrote {path}")
    return params, losses, ppl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=384)
    ap.add_argument("--lr", type=float, default=1.5e-3)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    train(steps=args.steps, batch=args.batch, seq_len=args.seq_len,
          out_dir=args.out, resume=args.resume, lr_peak=args.lr)


if __name__ == "__main__":
    main()
