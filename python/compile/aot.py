"""AOT exporter: lowers every model executable to HLO *text* artifacts.

Interchange is HLO text, never serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (the version the
published `xla` 0.1.6 crate links) rejects; the text parser reassigns ids.

Outputs under artifacts/:
    manifest.json               everything the Rust runtime needs: model
                                configs, parameter order/shapes, and one
                                entry per executable variant with its input
                                and output specs.
    <model>.weights.bin         tensorfile with all parameters (trained for
                                tiny-trained, seeded random for the -sim
                                scale family).
    hlo/<model>/<kind>_...txt   HLO text per executable variant.
    golden.json                 fixed-seed reference vectors replayed by
                                rust/tests (page scoring, top-k, f16, attn).

Usage: python -m compile.aot --out ../artifacts [--models a,b] [--golden-only]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, tensorfile
from .configs import CONFIGS, ModelConfig
from .kernels import ref

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return {"shape": list(shape), "dtype": dtype}


def _st(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_variant(fn, arg_shapes):
    args = [_st(s["shape"], jnp.int32 if s["dtype"] == I32 else jnp.float32)
            for s in arg_shapes]
    # keep_unused: the Rust runtime passes every manifest-listed parameter,
    # so jit must not drop args the graph doesn't consume (e.g. lnf in
    # prefill, which never computes logits).
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))


def weight_specs(cfg: ModelConfig):
    shapes = model.param_shapes(cfg)
    return [dict(name=n, **spec(shapes[n])) for n in model.param_names(cfg)]


def export_model(cfg: ModelConfig, out_dir: str, quick: bool = False):
    """Lower all executable variants for one model config."""
    hlo_dir = os.path.join(out_dir, "hlo", cfg.name)
    os.makedirs(hlo_dir, exist_ok=True)
    H, hd, d, L, V = cfg.n_head, cfg.head_dim, cfg.d_model, cfg.n_layer, cfg.vocab
    wspecs = weight_specs(cfg)
    entries = []

    def emit(kind, fn, params_used, data_inputs, outputs, tag, **attrs):
        path = os.path.join("hlo", cfg.name, tag + ".hlo.txt")
        full = os.path.join(out_dir, path)
        arg_shapes = [dict(s) for s in params_used] + list(data_inputs)
        t0 = time.time()
        text = lower_variant(fn, arg_shapes)
        with open(full, "w") as f:
            f.write(text)
        entries.append({
            "kind": kind, "path": path,
            "params": [s["name"] for s in params_used],
            "inputs": data_inputs, "outputs": outputs, **attrs,
        })
        print(f"  {tag:36s} {len(text)//1024:6d} KiB  {time.time()-t0:5.1f}s",
              flush=True)

    batch_sizes = cfg.batch_sizes if not quick else cfg.batch_sizes[:1]
    budgets = cfg.budgets if not quick else cfg.budgets[:1]
    by_name = {s["name"]: s for s in wspecs}

    for B in batch_sizes:
        emit("embed", model.embed_fn(cfg), [by_name["embed"]],
             [spec((B,), I32)], [spec((B, d))], f"embed_b{B}", batch=B)
        emit("qkv", model.qkv_fn(cfg),
             [dict(by_name["ln1.0"], name="ln1"),
              dict(by_name["wqkv.0"], name="wqkv")],
             [spec((B, d))],
             [spec((B, H, hd))] * 3, f"qkv_b{B}", batch=B)
        emit("logits", model.logits_fn(cfg),
             [by_name["lnf"], by_name["embed"]],
             [spec((B, d))], [spec((B, V))], f"logits_b{B}", batch=B)
        for T in budgets:
            emit("post", model.post_fn(cfg),
                 [dict(by_name["wo.0"], name="wo"),
                  dict(by_name["ln2.0"], name="ln2"),
                  dict(by_name["w1.0"], name="w1"),
                  dict(by_name["w2.0"], name="w2")],
                 [spec((B, d)), spec((B, H, hd)),
                  spec((B, T, H, hd)), spec((B, T, H, hd)),
                  spec((B, T)), spec((B, T))],
                 [spec((B, d)), spec((B, T)), spec((B,))],
                 f"post_b{B}_t{T}", batch=B, budget=T)

    # prefill: B=1 only (prompt ingest; decode is the hot path)
    C, Tp = cfg.prefill_chunk, cfg.ctx
    emit("prefill", model.prefill_fn(cfg), wspecs,
         [spec((1, C), I32), spec((), I32),
          spec((L, 1, Tp, H, hd)), spec((L, 1, Tp, H, hd))],
         [spec((L, 1, C, H, hd)), spec((L, 1, C, H, hd)), spec((1, d))],
         f"prefill_b1_c{C}", batch=1, chunk=C, ctx=Tp)

    # fused in-graph decode (ablation) — small page count variant only;
    # P*S = ctx capped at 4096 to bound the cache round-trip buffer.
    if cfg.name in ("tiny-trained", "tinyllama-125m-sim") and not quick:
        S = 16
        P = min(cfg.ctx, 4096) // S
        # multiple of 8 so K*S tiles cleanly into 128-token kernel blocks
        K = max(8, (int(0.3 * P) // 8) * 8)
        B = 1
        emit("decode_fused", model.decode_fused_fn(cfg, P, K, S), wspecs,
             [spec((B,), I32), spec((), I32),
              spec((L, B, P * S, H, hd)), spec((L, B, P * S, H, hd)),
              spec((L, B, P, 2, d))],
             [spec((L, B, P * S, H, hd)), spec((L, B, P * S, H, hd)),
              spec((L, B, P, 2, d)), spec((B, V)), spec((L, B, K), I32)],
             f"decode_fused_b{B}_p{P}_k{K}_s{S}",
             batch=B, n_pages=P, k_pages=K, page_size=S)

    return entries


def export_weights(cfg: ModelConfig, out_dir: str):
    path = os.path.join(out_dir, f"{cfg.name}.weights.bin")
    if cfg.trained:
        if not os.path.exists(path):
            raise SystemExit(
                f"{path} missing: run `python -m compile.train` first "
                "(make artifacts does this)")
        return os.path.basename(path)
    if not os.path.exists(path):
        params = model.init_params(cfg, seed=hash(cfg.name) % 2**31)
        tensorfile.write(path, params, meta={"config": cfg.name, "trained": False})
    return os.path.basename(path)


def model_manifest(cfg: ModelConfig):
    return {
        "d_model": cfg.d_model, "n_layer": cfg.n_layer, "n_head": cfg.n_head,
        "head_dim": cfg.head_dim, "vocab": cfg.vocab, "ctx": cfg.ctx,
        "act": cfg.act, "trained": cfg.trained,
        "mlp_dim": cfg.mlp_dim, "n_params": cfg.n_params,
        "param_order": model.param_names(cfg),
        "alibi_slopes": [float(s) for s in ref.alibi_slopes(cfg.n_head)],
    }


# --------------------------------------------------------------------------
# golden vectors for the Rust-side reimplementations
# --------------------------------------------------------------------------


def golden_vectors() -> dict:
    rng = np.random.default_rng(1234)
    out = {}

    # page scoring + top-k (spec for rust/src/sparsity/score.rs)
    B, P, D, K = 2, 16, 24, 5
    q = rng.normal(size=(B, D)).astype(np.float32)
    meta = np.sort(rng.normal(size=(B, P, 2, D)).astype(np.float32), axis=2)
    scores = np.asarray(ref.page_score_ref(jnp.asarray(q), jnp.asarray(meta)))
    topk = np.asarray(ref.topk_pages_ref(jnp.asarray(scores), K))
    out["page_score"] = {
        "q": q.tolist(), "meta": meta.tolist(),
        "scores": scores.tolist(), "topk": topk.tolist(), "k": K,
    }

    # page metadata construction (spec for rust/src/kvcache/meta.rs)
    Bm, L, Dm, S = 1, 32, 8, 8
    keys = rng.normal(size=(Bm, L, Dm)).astype(np.float32)
    meta2 = np.asarray(ref.page_meta_ref(jnp.asarray(keys), S))
    out["page_meta"] = {"keys": keys.tolist(), "page_size": S,
                        "meta": meta2.tolist()}

    # decode attention on a tiny case (spec for integration testing)
    Ba, H, hd, T = 1, 2, 8, 16
    qa = rng.normal(size=(Ba, H, hd)).astype(np.float32)
    kg = rng.normal(size=(Ba, T, H, hd)).astype(np.float32)
    vg = rng.normal(size=(Ba, T, H, hd)).astype(np.float32)
    mask = np.where(np.arange(T) < 12, 0.0, -1e9).astype(np.float32)[None]
    dist = rng.integers(0, 64, size=(Ba, T)).astype(np.float32)
    o, alpha = ref.attn_decode_ref(*map(jnp.asarray, (qa, kg, vg, mask, dist)))
    out["attn_decode"] = {
        "q": qa.tolist(), "kg": kg.tolist(), "vg": vg.tolist(),
        "mask": mask.tolist(), "dist": dist.tolist(),
        "o": np.asarray(o).tolist(), "alpha": np.asarray(alpha).tolist(),
        "slopes": [float(s) for s in ref.alibi_slopes(H)],
    }

    # alibi slopes for every head count used by the configs
    out["alibi"] = {str(h): [float(s) for s in ref.alibi_slopes(h)]
                    for h in (2, 4, 8, 12, 16)}

    # f16 conversion pins (spec for rust/src/util/f16.rs)
    vals = np.asarray(
        [0.0, -0.0, 1.0, -1.0, 0.5, 65504.0, 1e-8, 3.14159, -2.71828,
         1024.0, 0.099976], np.float32)
    f16 = vals.astype(np.float16)
    out["f16"] = {"f32": vals.tolist(),
                  "bits": [int(b) for b in f16.view(np.uint16)],
                  "back": f16.astype(np.float32).tolist()}
    return out


def kernel_report(out_dir: str):
    """DESIGN.md §8: VMEM footprint + MXU/roofline estimates for the L1
    decode kernel per model config and budget. interpret=True gives no TPU
    wallclock, so these are *structural* estimates: per-(b,h) program VMEM
    working set, arithmetic intensity, and HBM-bound time on a TPUv4-class
    part (1.2 TB/s HBM, 275 TFLOP/s bf16 MXU)."""
    hbm_bw = 1.2e12
    mxu_flops = 275e12
    rows = []
    for cfg in CONFIGS.values():
        H, hd = cfg.n_head, cfg.head_dim
        for T in cfg.budgets:
            block_t = 128 if T % 128 == 0 else 64
            # per-program VMEM: K/V tiles (block_t x hd) + bias + q + alpha
            vmem = (2 * block_t * hd + 2 * block_t + hd + T) * 4
            # per-(b,h) flops: 2*T*hd (qk) + 2*T*hd (av)
            flops = 4 * T * hd
            # HBM bytes per program: K,V streamed once + alpha out
            bytes_moved = (2 * T * hd + T) * 4
            ai = flops / bytes_moved  # arithmetic intensity (flops/byte)
            t_hbm = bytes_moved / hbm_bw
            t_mxu = flops / mxu_flops
            bound = "HBM" if t_hbm > t_mxu else "MXU"
            util = min(1.0, t_mxu / max(t_hbm, t_mxu))
            rows.append({
                "model": cfg.name, "budget_T": T, "block_t": block_t,
                "vmem_bytes_per_program": vmem,
                "arith_intensity_flops_per_byte": round(ai, 3),
                "bound": bound,
                "mxu_utilization_at_roofline": round(util, 4),
                "hbm_time_us_per_head": round(t_hbm * 1e6, 3),
            })
            print(f"{cfg.name:22s} T={T:5d} block={block_t:3d} "
                  f"VMEM={vmem/1024:7.1f}KiB  AI={ai:5.2f} fl/B  bound={bound}"
                  f"  MXU@roofline={util*100:5.1f}%")
    with open(os.path.join(out_dir, "kernel_report.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print("wrote kernel_report.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of model names")
    ap.add_argument("--quick", action="store_true",
                    help="first batch/budget variant only (CI smoke)")
    ap.add_argument("--golden-only", action="store_true")
    ap.add_argument("--report", action="store_true",
                    help="emit the kernel VMEM/MXU report only")
    args = ap.parse_args()
    if args.report:
        os.makedirs(args.out, exist_ok=True)
        kernel_report(args.out)
        return
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden_vectors(), f)
    print("wrote golden.json")
    if args.golden_only:
        return

    names = (args.models.split(",") if args.models else list(CONFIGS))
    # merge with an existing manifest so `--models subset` doesn't drop the
    # other models' entries
    manifest = {"format": 1, "models": {}}
    prev = os.path.join(out_dir, "manifest.json")
    if args.models and os.path.exists(prev):
        with open(prev) as f:
            manifest = json.load(f)
    for name in names:
        cfg = CONFIGS[name]
        print(f"[{name}] d={cfg.d_model} L={cfg.n_layer} H={cfg.n_head} "
              f"ctx={cfg.ctx} params={cfg.n_params/1e6:.1f}M", flush=True)
        weights = export_weights(cfg, out_dir)
        entries = export_model(cfg, out_dir, quick=args.quick)
        m = model_manifest(cfg)
        m["weights"] = weights
        m["artifacts"] = entries
        manifest["models"][name] = m

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
