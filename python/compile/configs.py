"""Model scale family + artifact variant matrix.

The paper evaluates TinyLLaMA-125M, GPT2-345M, OPT-350M, GPT2-774M and
LLaMA-1.3B. Checkpoints are not available in this image (see DESIGN.md §2),
so each scale is replaced by a `-sim` transformer from the same architecture
family whose KV cache scales identically in (d_model, n_layer, context):
latency/memory behaviour of cache selection depends only on those shapes.
`tiny-trained` is additionally *trained* (python/compile/train.py) on the
structured synthetic corpus so accuracy-bearing experiments use a model that
genuinely solves the retrieval tasks.

Conventions shared with the Rust side (mirrored in rust/src/config/mod.rs):
  * byte-level vocab of 512: ids 0..255 = raw bytes, 256 = BOS, 257 = EOS,
    rest unused (power-of-two padding for the logits matmul).
  * ALiBi positional scheme (no RoPE) — extrapolates beyond the training
    window, so a model trained at 512 tokens can be *served* at 4K-32K.
  * pre-norm RMSNorm, MHA, GELU MLP with 4x expansion, untied biases absent,
    tied embedding / LM head.
"""

from __future__ import annotations

import dataclasses
from typing import List

VOCAB = 512
BOS = 256
EOS = 257


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layer: int
    n_head: int
    ctx: int                    # max serving context (tokens)
    vocab: int = VOCAB
    act: str = "gelu"           # "gelu" (gpt2/llama-sim) or "relu" (opt-sim)
    trained: bool = False       # weights from train.py vs seeded random
    batch_sizes: tuple = (1, 4, 8)
    budgets: tuple = ()         # decode attention T variants (tokens)
    prefill_chunk: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head

    @property
    def mlp_dim(self) -> int:
        return 4 * self.d_model

    @property
    def n_params(self) -> int:
        d, v = self.d_model, self.vocab
        per_layer = 3 * d * d + d * d + 2 * (d * self.mlp_dim) + 2 * d
        return v * d + self.n_layer * per_layer + d


def _cfg(**kw) -> ModelConfig:
    return ModelConfig(**kw)


CONFIGS = {
    c.name: c
    for c in [
        # Trained accuracy-bearing model (see train.py). Served up to 4K ctx.
        _cfg(name="tiny-trained", d_model=128, n_layer=4, n_head=8, ctx=4096,
             trained=True, budgets=(128, 256, 512, 1024, 4096)),
        # Scale family mirroring the paper's Table 1 rows.
        _cfg(name="tinyllama-125m-sim", d_model=256, n_layer=4, n_head=8,
             ctx=4096, budgets=(512, 1024, 2048, 4096)),
        _cfg(name="gpt2-345m-sim", d_model=384, n_layer=6, n_head=12,
             ctx=8192, budgets=(512, 2048, 8192)),
        _cfg(name="opt-350m-sim", d_model=384, n_layer=6, n_head=12,
             ctx=8192, act="relu", batch_sizes=(1, 4),
             budgets=(2048, 8192)),
        _cfg(name="gpt2-774m-sim", d_model=512, n_layer=8, n_head=16,
             ctx=16384, batch_sizes=(1, 4), budgets=(2048, 4096)),
        _cfg(name="llama-1p3b-sim", d_model=640, n_layer=10, n_head=16,
             ctx=32768, batch_sizes=(1,), budgets=(2048, 4096)),
    ]
}

# Table 1 row order (paper) -> sim config.
PAPER_SCALE_ROWS: List[str] = [
    "tinyllama-125m-sim",
    "gpt2-345m-sim",
    "opt-350m-sim",
    "gpt2-774m-sim",
    "llama-1p3b-sim",
]
