"""Dependency-free tensor container shared with the Rust runtime.

Format (little-endian), mirrored by `rust/src/util/tensorfile.rs`:

    magic   b"TSWT"            4 bytes
    version u32 = 1
    hlen    u32                header length in bytes
    header  JSON               {"tensors": [{"name", "dtype", "shape",
                                             "offset", "nbytes"}, ...],
                                "meta": {...}}
    data    raw bytes          each tensor at 64-byte-aligned offset
"""

from __future__ import annotations

import json
from typing import Dict

import numpy as np

MAGIC = b"TSWT"
_DTYPES = {"f32": np.float32, "i32": np.int32, "f16": np.float16, "u8": np.uint8}
_ALIGN = 64


def write(path: str, tensors: Dict[str, np.ndarray], meta: dict | None = None):
    entries = []
    offset = 0
    blobs = []
    rev = {np.dtype(v): k for k, v in _DTYPES.items()}
    for name, arr in tensors.items():
        dtype = rev[np.dtype(arr.dtype)]
        raw = np.ascontiguousarray(arr).tobytes()
        pad = (-offset) % _ALIGN
        offset += pad
        blobs.append((pad, raw))
        entries.append({
            "name": name, "dtype": dtype, "shape": list(arr.shape),
            "offset": offset, "nbytes": len(raw),
        })
        offset += len(raw)
    header = json.dumps({"tensors": entries, "meta": meta or {}}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(1).tobytes())
        f.write(np.uint32(len(header)).tobytes())
        f.write(header)
        for pad, raw in blobs:
            f.write(b"\0" * pad)
            f.write(raw)


def read(path: str) -> tuple[Dict[str, np.ndarray], dict]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version = np.frombuffer(f.read(4), np.uint32)[0]
        assert version == 1, f"unsupported version {version}"
        hlen = int(np.frombuffer(f.read(4), np.uint32)[0])
        header = json.loads(f.read(hlen))
        base = f.tell()
        out = {}
        for e in header["tensors"]:
            f.seek(base + e["offset"])
            raw = f.read(e["nbytes"])
            arr = np.frombuffer(raw, _DTYPES[e["dtype"]]).reshape(e["shape"])
            out[e["name"]] = arr
    return out, header.get("meta", {})
