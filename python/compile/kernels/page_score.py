"""L1 Pallas kernel: directional bounding-box page scoring (paper Eq. 2).

Algorithm 1, step 1: estimate max_{k in page} q.k from per-page channel-wise
(min, max) key bounds. The identity

    sum_i (q_i >= 0 ? q_i * M_i : q_i * m_i)  ==  sum_i max(q_i*M_i, q_i*m_i)

(valid because M >= m elementwise) turns the paper's sign-split form into a
branch-free vectorized max — exactly what the TPU VPU (and the Rust SIMD
scan in `rust/src/sparsity/score.rs`) wants.

Layout: metadata lives as `[P, 2, D]` per batch row (the "SRAM/L2 resident"
structure of the paper's hardware model); the kernel tiles P so the VMEM
working set is `2 * block_p * D * 4B` regardless of page count.

Used in-graph by the fully-fused decode variant (`model.decode_fused`) and
as the spec for the Rust scorer; oracle: `ref.page_score_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(q_ref, meta_ref, out_ref):
    q = q_ref[0, :]          # [D]
    m = meta_ref[0, :, 0, :]  # [block_p, D]
    M = meta_ref[0, :, 1, :]
    qm = q[None, :] * m
    qM = q[None, :] * M
    out_ref[0, :] = jnp.sum(jnp.maximum(qM, qm), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_p",))
def page_scores(q, meta, block_p: int = 128):
    """Score pages against the query. q: [B, D], meta: [B, P, 2, D] -> [B, P]."""
    B, D = q.shape
    P = meta.shape[1]
    bp = min(block_p, P)
    if P % bp != 0:
        raise ValueError(f"P={P} must be a multiple of block_p={bp}")
    return pl.pallas_call(
        _score_kernel,
        grid=(B, P // bp),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p: (b, 0)),
            pl.BlockSpec((1, bp, 2, D), lambda b, p: (b, p, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda b, p: (b, p)),
        out_shape=jax.ShapeDtypeStruct((B, P), jnp.float32),
        interpret=True,
    )(q, meta)
