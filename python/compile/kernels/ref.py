"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match the corresponding function here to float tolerance (pytest +
hypothesis sweep shapes/dtypes/seeds in python/tests/test_kernels.py).

They are also the *semantic spec* for the Rust-side reimplementations
(page scoring, top-k) — `aot.py --golden` evaluates these on fixed seeds and
dumps the vectors that `rust/tests/golden.rs` replays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def alibi_slopes(n_heads: int) -> np.ndarray:
    """ALiBi per-head slopes, standard geometric formula (power-of-two safe)."""
    # For power-of-two H this is 2^(-8i/H) for i in 1..H.
    return np.asarray(
        [2.0 ** (-8.0 * (i + 1) / n_heads) for i in range(n_heads)],
        dtype=np.float32,
    )


def attn_decode_ref(q, kg, vg, mask, dist, slopes=None):
    """Single-token sparse attention over gathered pages (reference).

    Args:
      q:    [B, H, hd]   query for the new token (one per head).
      kg:   [B, T, H, hd] gathered keys (budget T tokens; padded entries
            are masked out via `mask`).
      vg:   [B, T, H, hd] gathered values.
      mask: [B, T] additive mask (0 for valid, -1e9 for padding).
      dist: [B, T] token distance (pos_query - pos_token, >= 0) for ALiBi.
      slopes: [H] ALiBi slopes; default = alibi_slopes(H).

    Returns:
      o:     [B, H, hd] attention output.
      alpha: [B, H, T]  attention weights (softmax probabilities).
    """
    B, H, hd = q.shape
    if slopes is None:
        slopes = jnp.asarray(alibi_slopes(H))
    scale = np.float32(1.0 / np.sqrt(hd))
    # [B, H, T]
    logits = jnp.einsum("bhd,bthd->bht", q, kg) * scale
    bias = -slopes[None, :, None] * dist[:, None, :]
    logits = logits + bias + mask[:, None, :]
    alpha = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bht,bthd->bhd", alpha, vg)
    return o, alpha


def attn_prefill_ref(q, k, v, q_pos, k_pos, k_valid, slopes=None):
    """Chunked causal prefill attention (reference).

    Args:
      q:       [B, C, H, hd] chunk queries.
      k:       [B, Tk, H, hd] keys = prior context + this chunk.
      v:       [B, Tk, H, hd] values.
      q_pos:   [B, C] absolute positions of chunk tokens.
      k_pos:   [B, Tk] absolute positions of key tokens.
      k_valid: [B, Tk] 1.0 for valid keys, 0.0 for padding.

    Returns: o [B, C, H, hd]
    """
    B, C, H, hd = q.shape
    if slopes is None:
        slopes = jnp.asarray(alibi_slopes(H))
    scale = np.float32(1.0 / np.sqrt(hd))
    logits = jnp.einsum("bchd,bthd->bhct", q, k) * scale
    dist = (q_pos[:, :, None] - k_pos[:, None, :]).astype(jnp.float32)  # [B,C,Tk]
    causal = (dist >= 0) & (k_valid[:, None, :] > 0.5)
    logits = logits - slopes[None, :, None, None] * jnp.maximum(dist, 0.0)[:, None]
    logits = jnp.where(causal[:, None], logits, -1e9)
    alpha = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhct,bthd->bchd", alpha, v)


def page_score_ref(q, meta):
    """Directional bounding-box page relevance (paper Eq. 2), reference.

    Args:
      q:    [B, D]      query with all heads concatenated (D = H * hd).
      meta: [B, P, 2, D] per-page channel-wise (min, max) of stored keys.

    Returns: scores [B, P] with score_j = sum_i max(q_i*M_ji, q_i*m_ji)
    (equivalent to the paper's sign-split form because M >= m).
    """
    m = meta[:, :, 0, :]  # [B, P, D]
    M = meta[:, :, 1, :]
    qe = q[:, None, :]
    return jnp.sum(jnp.maximum(qe * M, qe * m), axis=-1)


def page_meta_ref(keys, page_size):
    """Per-page channel-wise min/max metadata over stored keys.

    Args:
      keys: [B, L, D] stored (unpadded) keys, L a multiple of page_size.
    Returns: meta [B, P, 2, D].
    """
    B, L, D = keys.shape
    P = L // page_size
    pages = keys.reshape(B, P, page_size, D)
    return jnp.stack([pages.min(axis=2), pages.max(axis=2)], axis=2)


def topk_pages_ref(scores, k, forced=None):
    """Top-k page selection with optional forced pages (sink/recent).

    Args:
      scores: [B, P]; forced: optional [B, P] bool — pages that must be kept.
    Returns: indices [B, k] (ascending order per row).
    """
    if forced is not None:
        scores = jnp.where(forced, jnp.float32(np.finfo(np.float32).max), scores)
    _, idx = jax.lax.top_k(scores, k)
    return jnp.sort(idx, axis=-1)


def entropy_ref(alpha):
    """Mean per-head attention entropy, [B,H,T] -> [B]."""
    p = jnp.clip(alpha, 1e-12, 1.0)
    h = -jnp.sum(p * jnp.log(p), axis=-1)  # [B, H]
    return h.mean(axis=-1)
