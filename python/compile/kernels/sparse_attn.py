"""L1 Pallas kernel: fused sparse decode attention over gathered KV pages.

This is the TPU rethink of the paper's fused CUDA kernel (Algorithm 1,
steps 3-4): the Rust coordinator has already scored pages against the query
(step 1-2, see `rust/src/sparsity/`) and gathered the selected pages into a
contiguous `[B, T, H, hd]` budget buffer (the host-side analogue of the
HBM->SRAM page fetch). The kernel computes masked, ALiBi-biased attention of
one fresh query per head over that buffer in a single fused pass.

Design notes (hardware adaptation, see DESIGN.md §5):
  * grid = (B, H): one program per (batch row, head) — the TPU analogue of
    a CUDA threadblock per head.
  * The T axis is processed in `block_t`-sized tiles streamed HBM->VMEM via
    `pl.load` dynamic slices: two-pass flash-style online softmax
    (pass 1: running max / denominator / weighted-value accumulator;
    pass 2: recompute logits per tile and emit normalized probabilities).
    VMEM working set per program = 2 * block_t * hd * 4B + O(block_t),
    independent of T.
  * Probabilities are emitted because the serving system consumes them:
    per-page attention mass feeds the SoftPrune/SnapKV/PyramidKV feedback
    policies and the entropy early-exit plugin (paper §3.1(2)).
  * `interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
    custom-calls; interpret mode lowers to plain HLO so the same artifact
    runs under the Rust runtime.

Correctness oracle: `ref.attn_decode_ref` (pytest + hypothesis sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

NEG_INF = np.float32(-1e9)


def _decode_kernel(
    q_ref,      # [1, 1, hd]
    kg_ref,     # [1, T, 1, hd]
    vg_ref,     # [1, T, 1, hd]
    bias_ref,   # [1, T]   additive bias: mask + (-slope_h * dist), prescaled
    o_ref,      # [1, 1, hd]
    alpha_ref,  # [1, 1, T]
    *,
    block_t: int,
    n_blocks: int,
    scale: float,
):
    q = q_ref[0, 0, :] * scale  # [hd]
    hd = q.shape[0]

    def logits_tile(i):
        k = pl.load(kg_ref, (0, pl.dslice(i * block_t, block_t), 0, slice(None)))
        b = pl.load(bias_ref, (0, pl.dslice(i * block_t, block_t)))
        # [block_t]
        return jnp.sum(k * q[None, :], axis=-1) + b

    # ---- pass 1: online max / denominator / value accumulator ----
    def body(i, carry):
        m, s, acc = carry
        l = logits_tile(i)
        v = pl.load(vg_ref, (0, pl.dslice(i * block_t, block_t), 0, slice(None)))
        m_new = jnp.maximum(m, jnp.max(l))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(l - m_new)  # [block_t]
        s_new = s * corr + jnp.sum(p)
        acc_new = acc * corr + jnp.sum(p[:, None] * v, axis=0)
        return m_new, s_new, acc_new

    m0 = jnp.float32(-jnp.inf)
    s0 = jnp.float32(0.0)
    acc0 = jnp.zeros((hd,), dtype=jnp.float32)
    m, s, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, s0, acc0))

    o_ref[0, 0, :] = acc / s

    # ---- pass 2: emit normalized probabilities ----
    def emit(i, _):
        l = logits_tile(i)
        p = jnp.exp(l - m) / s
        pl.store(alpha_ref, (0, 0, pl.dslice(i * block_t, block_t)), p)
        return 0

    jax.lax.fori_loop(0, n_blocks, emit, 0)


@functools.partial(jax.jit, static_argnames=("block_t",))
def attn_decode(q, kg, vg, mask, dist, block_t: int = 128):
    """Fused sparse decode attention (Pallas, interpret mode).

    Args/returns exactly as `ref.attn_decode_ref`; `block_t` is the T-tile
    size (T must be a multiple of it).
    """
    B, H, hd = q.shape
    T = kg.shape[1]
    while T % block_t != 0 and block_t > 1:
        block_t //= 2  # fall back to the largest power-of-two tile
    if T % block_t != 0:
        raise ValueError(f"budget T={T} has no power-of-two tile")
    n_blocks = T // block_t
    slopes = jnp.asarray(ref.alibi_slopes(H))
    # Pre-fold the per-head ALiBi bias with the padding mask so the kernel
    # streams a single [B*H, T] bias plane.
    bias = mask[:, None, :] - slopes[None, :, None] * dist[:, None, :]  # [B,H,T]
    bias = bias.reshape(B * H, T)

    kernel = functools.partial(
        _decode_kernel,
        block_t=block_t,
        n_blocks=n_blocks,
        scale=float(1.0 / np.sqrt(hd)),
    )
    o, alpha = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, T), lambda b, h: (b * H + h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, T), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, T), jnp.float32),
        ],
        interpret=True,
    )(q, kg, vg, bias)
    return o, alpha
