"""L2 model consistency: the split decode-path executables must reproduce
the dense training forward exactly (same weights, same tokens).

This is the python mirror of what the Rust engine does per token —
if this passes and the Rust golden tests pass, the serving path computes
the same function as the trained model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig
from compile.kernels import ref


CFG = ModelConfig(name="t", d_model=32, n_layer=2, n_head=4, ctx=128,
                  vocab=64, budgets=(32,))


@pytest.fixture(scope="module")
def params():
    p = model.init_params(CFG, seed=3)
    return {k: jnp.asarray(v) for k, v in p.items()}


def dense_next_token_logits(params, tokens):
    """Teacher-forcing forward; logits for every position."""
    H, hd, L = CFG.n_head, CFG.head_dim, CFG.n_layer
    slopes = jnp.asarray(ref.alibi_slopes(H))
    x = jnp.asarray([tokens])
    B, T = x.shape
    h = jnp.take(params["embed"], x, axis=0)
    pos = jnp.arange(T)
    dist = (pos[:, None] - pos[None, :]).astype(jnp.float32)
    bias = -slopes[:, None, None] * jnp.maximum(dist, 0.0)[None]
    bias = jnp.where((dist >= 0)[None], bias, -1e9)
    scale = 1.0 / np.sqrt(hd)
    for l in range(L):
        xn = model.rmsnorm(h, params[f"ln1.{l}"])
        qkv = xn @ params[f"wqkv.{l}"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, T, H, hd)
        k = k.reshape(B, T, H, hd)
        v = v.reshape(B, T, H, hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale + bias[None]
        alpha = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", alpha, v)
        h = h + o.reshape(B, T, -1) @ params[f"wo.{l}"]
        h = h + model.mlp(model.rmsnorm(h, params[f"ln2.{l}"]),
                          params[f"w1.{l}"], params[f"w2.{l}"], CFG.act)
    return model.rmsnorm(h, params["lnf"]) @ params["embed"].T


def decode_path_logits(params, tokens, budget=128):
    """Step-by-step decode using the exported function family with a
    FullCache gather — mirrors rust/src/engine exactly."""
    embed_f = model.embed_fn(CFG)
    qkv_f = model.qkv_fn(CFG)
    post_f = model.post_fn(CFG)
    logits_f = model.logits_fn(CFG)
    H, hd, L = CFG.n_head, CFG.head_dim, CFG.n_layer
    d_kv = H * hd
    T = budget
    kcache = [np.zeros((T, H, hd), np.float32) for _ in range(L)]
    vcache = [np.zeros((T, H, hd), np.float32) for _ in range(L)]
    out_logits = []
    for t, tok in enumerate(tokens):
        (h,) = embed_f(params["embed"], jnp.asarray([tok]))
        for l in range(L):
            q, k, v = qkv_f(params[f"ln1.{l}"], params[f"wqkv.{l}"], h)
            kcache[l][t] = np.asarray(k[0])
            vcache[l][t] = np.asarray(v[0])
            mask = np.full((1, T), -1e9, np.float32)
            mask[0, : t + 1] = 0.0
            dist = np.zeros((1, T), np.float32)
            dist[0, : t + 1] = t - np.arange(t + 1)
            h, _, _ = post_f(
                params[f"wo.{l}"], params[f"ln2.{l}"],
                params[f"w1.{l}"], params[f"w2.{l}"],
                h, q,
                jnp.asarray(kcache[l][None]), jnp.asarray(vcache[l][None]),
                jnp.asarray(mask), jnp.asarray(dist),
            )
        (lg,) = logits_f(params["lnf"], params["embed"], h)
        out_logits.append(np.asarray(lg[0]))
    return np.stack(out_logits)


def test_decode_path_matches_dense_forward(params):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, CFG.vocab, size=12).tolist()
    dense = np.asarray(dense_next_token_logits(params, tokens))[0]
    stepwise = decode_path_logits(params, tokens)
    np.testing.assert_allclose(stepwise, dense, atol=5e-4, rtol=5e-4)


def test_prefill_fn_matches_decode_path(params):
    rng = np.random.default_rng(1)
    tokens = rng.integers(0, CFG.vocab, size=10).tolist()
    L, H, hd, C, Tp = CFG.n_layer, CFG.n_head, CFG.head_dim, 4, 64
    pre_f = model.prefill_fn(CFG)
    names = model.param_names(CFG)
    wargs = [params[n] for n in names]
    kbuf = jnp.zeros((L, 1, Tp, H, hd))
    vbuf = jnp.zeros((L, 1, Tp, H, hd))
    done = 0
    while done < len(tokens):
        take = min(C, len(tokens) - done)
        chunk = tokens[done:done + take] + [0] * (C - take)
        kc, vc, h_last = pre_f(*wargs, jnp.asarray([chunk], jnp.int32),
                               jnp.asarray(done, jnp.int32), kbuf, vbuf)
        kbuf = jax.lax.dynamic_update_slice(
            kbuf, kc[:, :, :take], (0, 0, done, 0, 0))
        vbuf = jax.lax.dynamic_update_slice(
            vbuf, vc[:, :, :take], (0, 0, done, 0, 0))
        done += take
    # compare the stored keys of layer 0 against the decode path's cache
    embed_f = model.embed_fn(CFG)
    qkv_f = model.qkv_fn(CFG)
    post_f = model.post_fn(CFG)
    T = 64
    kexp = np.zeros((T, H, hd), np.float32)
    h = None
    for t, tok in enumerate(tokens):
        (h,) = embed_f(params["embed"], jnp.asarray([tok]))
        for l in range(CFG.n_layer):
            q, k, v = qkv_f(params[f"ln1.{l}"], params[f"wqkv.{l}"], h)
            if l == 0:
                kexp[t] = np.asarray(k[0])
            # full-cache attention to propagate h correctly
            # (reuse the prefill buffer as the gather source)
            mask = np.full((1, T), -1e9, np.float32)
            mask[0, : t + 1] = 0.0
            dist = np.zeros((1, T), np.float32)
            dist[0, : t + 1] = t - np.arange(t + 1)
            kg = np.asarray(kbuf[l, 0][:T])[None]
            vg = np.asarray(vbuf[l, 0][:T])[None]
            # overwrite positions > t with zeros to avoid peeking
            h, _, _ = post_f(
                params[f"wo.{l}"], params[f"ln2.{l}"],
                params[f"w1.{l}"], params[f"w2.{l}"],
                h, q, jnp.asarray(kg), jnp.asarray(vg),
                jnp.asarray(mask), jnp.asarray(dist),
            )
    np.testing.assert_allclose(
        np.asarray(kbuf[0, 0, : len(tokens)]), kexp[: len(tokens)],
        atol=5e-4, rtol=5e-4,
    )


def test_param_order_is_stable(params):
    names = model.param_names(CFG)
    assert names[0] == "embed"
    assert names[1] == "lnf"
    assert names[2:8] == ["ln1.0", "wqkv.0", "wo.0", "ln2.0", "w1.0", "w2.0"]
    shapes = model.param_shapes(CFG)
    assert set(names) == set(shapes)


def test_init_scaling():
    p = model.init_params(CFG, seed=0)
    # residual projections are downscaled by sqrt(2L)
    assert np.std(p["wo.0"]) < np.std(p["wqkv.0"])
    assert (p["ln1.0"] == 1.0).all()


def test_decode_fused_matches_decode_path(params):
    """The in-graph fused variant must agree with the orchestrated path
    while the page count is within budget (selection = all pages)."""
    S, P, K = 4, 8, 8  # budget covers everything -> exact match expected
    fused = model.decode_fused_fn(CFG, P, K, S)
    names = model.param_names(CFG)
    wargs = [params[n] for n in names]
    L, H, hd, d = CFG.n_layer, CFG.n_head, CFG.head_dim, CFG.d_model
    kc = jnp.zeros((L, 1, P * S, H, hd))
    vc = jnp.zeros((L, 1, P * S, H, hd))
    meta = jnp.zeros((L, 1, P, 2, d))
    rng = np.random.default_rng(2)
    tokens = rng.integers(0, CFG.vocab, size=8).tolist()
    fused_logits = None
    for t, tok in enumerate(tokens):
        kc, vc, meta, fused_logits, _sel = fused(
            *wargs, jnp.asarray([tok], jnp.int32), jnp.asarray(t, jnp.int32),
            kc, vc, meta)
    stepwise = decode_path_logits(params, tokens, budget=P * S)
    np.testing.assert_allclose(
        np.asarray(fused_logits)[0], stepwise[-1], atol=5e-4, rtol=5e-4)
