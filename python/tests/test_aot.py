"""AOT exporter sanity: golden vectors, HLO text shape, manifest schema."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot, model
from compile.configs import CONFIGS, ModelConfig


def test_golden_vectors_self_consistent():
    g = aot.golden_vectors()
    ps = g["page_score"]
    q = np.asarray(ps["q"], np.float32)
    meta = np.asarray(ps["meta"], np.float32)
    scores = np.asarray(ps["scores"], np.float32)
    # recompute eq. 2 with numpy and compare
    m, M = meta[:, :, 0, :], meta[:, :, 1, :]
    re = np.maximum(q[:, None, :] * M, q[:, None, :] * m).sum(-1)
    np.testing.assert_allclose(re, scores, rtol=1e-5)
    # top-k indices actually have the k best scores
    k = ps["k"]
    for b, row in enumerate(np.asarray(ps["topk"])):
        best = set(np.argsort(-scores[b])[:k].tolist())
        assert set(int(i) for i in row) == best

    # f16 pins agree with numpy
    f = g["f16"]
    bits = np.asarray(f["f32"], np.float32).astype(np.float16).view(np.uint16)
    assert [int(b) for b in bits] == f["bits"]


def test_lowering_produces_hlo_text():
    cfg = ModelConfig(name="t", d_model=16, n_layer=1, n_head=2, ctx=64,
                      vocab=32, budgets=(16,))
    text = aot.lower_variant(
        model.embed_fn(cfg),
        [aot.spec((32, 16)), aot.spec((2,), aot.I32)],
    )
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "parameter(1)" in text


def test_quick_export_manifest_schema():
    cfg_name = "tiny-trained"
    with tempfile.TemporaryDirectory() as d:
        # reuse the trained weights if present, else fabricate them
        src = os.path.join(os.path.dirname(__file__), "..", "..",
                           "artifacts", f"{cfg_name}.weights.bin")
        if os.path.exists(src):
            import shutil
            shutil.copy(src, os.path.join(d, f"{cfg_name}.weights.bin"))
        else:
            from compile import tensorfile
            params = model.init_params(CONFIGS[cfg_name], seed=0)
            tensorfile.write(os.path.join(d, f"{cfg_name}.weights.bin"),
                             params, meta={})
        entries = aot.export_model(CONFIGS[cfg_name], d, quick=True)
        kinds = {e["kind"] for e in entries}
        assert {"embed", "qkv", "post", "logits", "prefill"} <= kinds
        for e in entries:
            path = os.path.join(d, e["path"])
            assert os.path.exists(path), e["path"]
            head = open(path).read(64)
            assert head.startswith("HloModule"), e["path"]
            assert isinstance(e["params"], list)
            assert all("shape" in s for s in e["inputs"])
            assert all("shape" in s for s in e["outputs"])


def test_model_manifest_fields():
    m = aot.model_manifest(CONFIGS["tinyllama-125m-sim"])
    assert m["d_model"] == 256
    assert len(m["param_order"]) == 2 + 6 * m["n_layer"]
    assert len(m["alibi_slopes"]) == m["n_head"]
    # json-serializable
    json.dumps(m)
