"""Pins the corpus formats shared with rust/src/workload/tasks.rs."""

import numpy as np
import pytest

from compile import corpus
from compile.configs import BOS, EOS


def test_passkey_format():
    rng = np.random.default_rng(0)
    prompt, answer = corpus.passkey_doc(rng, 400)
    assert prompt.startswith(f"The pass key is {answer}. Remember it. ")
    assert prompt.endswith("What is the pass key? Answer: ")
    assert len(answer) == 5 and answer.isdigit()


def test_kvrecall_format():
    rng = np.random.default_rng(1)
    prompt, answer = corpus.kvrecall_doc(rng, 500)
    assert f"holds {answer}. " in prompt
    assert "Recall what " in prompt and prompt.endswith("holds: ")


def test_raretoken_format():
    rng = np.random.default_rng(2)
    prompt, answer = corpus.raretoken_doc(rng, 300)
    assert answer.startswith("zyx") and answer.endswith("qj")
    assert prompt.endswith("Repeat the rare token: ")


def test_alias_latest_wins():
    rng = np.random.default_rng(3)
    prompt, answer = corpus.alias_doc(rng, 600)
    assert f"now holds {answer}. " in prompt


def test_word_lists_match_rust():
    # first/last entries pinned — rust/src/workload/tasks.rs mirrors these
    assert corpus.WORDS[0] == "the" and corpus.WORDS[-1] == "tide"
    assert len(corpus.WORDS) == 30
    assert corpus.NAMES[0] == "alpha" and corpus.NAMES[-1] == "tango"
    assert len(corpus.NAMES) == 20


def test_encode_is_bytes():
    ids = corpus.encode("Ab!")
    assert ids.tolist() == [65, 98, 33]
    assert corpus.decode_ids(ids) == "Ab!"
    assert BOS == 256 and EOS == 257


def test_training_batch_shape_and_range():
    rng = np.random.default_rng(4)
    b = corpus.training_batch(rng, 3, 128)
    assert b.shape == (3, 129)
    assert b.min() >= 0 and b.max() <= EOS
    assert (b[:, 0] == BOS).all()


def test_filler_is_sentences():
    rng = np.random.default_rng(5)
    f = corpus.filler(rng, 200)
    assert len(f) == 200
    # truncation may clip the final word; all earlier words are from WORDS
    words = f.replace(".", "").split()[:-1]
    assert set(words).issubset(set(corpus.WORDS))
