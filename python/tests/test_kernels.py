"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

Hypothesis sweeps shapes/seeds; tolerances are float32-tight. Pallas runs
under interpret=True, exactly as the exported artifacts do.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import page_score, ref, sparse_attn

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def make_case(seed, B, H, hd, T, valid_frac=1.0):
    rng = np.random.default_rng(seed)
    q = rand(rng, B, H, hd)
    kg = rand(rng, B, T, H, hd)
    vg = rand(rng, B, T, H, hd)
    n_valid = max(1, int(T * valid_frac))
    mask = jnp.where(jnp.arange(T)[None, :] < n_valid, 0.0, -1e9)
    mask = (mask * jnp.ones((B, 1))).astype(jnp.float32)
    dist = jnp.asarray(rng.integers(0, 4 * T, size=(B, T)), jnp.float32)
    return q, kg, vg, mask, dist


class TestDecodeAttention:
    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31),
        B=st.sampled_from([1, 2, 4]),
        H=st.sampled_from([1, 2, 4, 8]),
        hd=st.sampled_from([8, 16, 32]),
        T=st.sampled_from([128, 256, 384]),
        valid=st.floats(0.05, 1.0),
    )
    def test_matches_reference(self, seed, B, H, hd, T, valid):
        case = make_case(seed, B, H, hd, T, valid)
        o, a = sparse_attn.attn_decode(*case)
        o_ref, a_ref = ref.attn_decode_ref(*case)
        np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(a, a_ref, atol=2e-6, rtol=2e-5)

    def test_block_sizes_agree(self):
        case = make_case(0, 2, 4, 16, 512)
        o128, _ = sparse_attn.attn_decode(*case, block_t=128)
        o64, _ = sparse_attn.attn_decode(*case, block_t=64)
        o512, _ = sparse_attn.attn_decode(*case, block_t=512)
        np.testing.assert_allclose(o128, o64, atol=1e-5)
        np.testing.assert_allclose(o128, o512, atol=1e-5)

    def test_non_power_of_two_budget_falls_back(self):
        # T = 1216 (the decode_fused K*S case) must auto-tile
        case = make_case(1, 1, 2, 8, 1216)
        o, _ = sparse_attn.attn_decode(*case)
        o_ref, _ = ref.attn_decode_ref(*case)
        np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)

    def test_alpha_rows_sum_to_one(self):
        case = make_case(3, 2, 2, 8, 128, valid_frac=0.3)
        _, a = sparse_attn.attn_decode(*case)
        np.testing.assert_allclose(a.sum(-1), 1.0, atol=1e-5)

    def test_single_valid_token(self):
        q, kg, vg, _, dist = make_case(4, 1, 2, 8, 128)
        mask = jnp.full((1, 128), -1e9).at[:, 0].set(0.0)
        o, a = sparse_attn.attn_decode(q, kg, vg, mask, dist)
        np.testing.assert_allclose(a[..., 0], 1.0, atol=1e-5)
        np.testing.assert_allclose(o, jnp.transpose(vg[:, 0], (0, 1, 2)), atol=1e-5)

    def test_alibi_prefers_near_tokens(self):
        # identical keys: nearer token (smaller dist) must get more mass
        B, H, hd, T = 1, 2, 8, 128
        q = jnp.ones((B, H, hd))
        kg = jnp.ones((B, T, H, hd))
        vg = jnp.ones((B, T, H, hd))
        mask = jnp.zeros((B, T))
        dist = jnp.arange(T, dtype=jnp.float32)[None, :]
        _, a = sparse_attn.attn_decode(q, kg, vg, mask, dist)
        a = np.asarray(a)[0, 0]
        assert a[0] > a[1] > a[T - 1]


class TestPageScore:
    @settings(**SETTINGS)
    @given(
        seed=st.integers(0, 2**31),
        B=st.sampled_from([1, 2, 4]),
        D=st.sampled_from([16, 64, 128]),
        P=st.sampled_from([8, 64, 256]),
    )
    def test_matches_reference(self, seed, B, D, P):
        rng = np.random.default_rng(seed)
        q = rand(rng, B, D)
        meta = jnp.asarray(
            np.sort(rng.normal(size=(B, P, 2, D)), axis=2), jnp.float32
        )
        s = page_score.page_scores(q, meta)
        s_ref = ref.page_score_ref(q, meta)
        np.testing.assert_allclose(s, s_ref, atol=1e-4, rtol=1e-4)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31))
    def test_upper_bounds_true_max_dot(self, seed):
        # Eq. 2 must upper-bound max_k q.k for keys inside the box
        rng = np.random.default_rng(seed)
        B, P, S, D = 1, 4, 8, 16
        keys = rand(rng, B, P * S, D)
        meta = ref.page_meta_ref(keys, S)
        q = rand(rng, B, D)
        scores = np.asarray(ref.page_score_ref(q, meta))
        dots = np.asarray(jnp.einsum("bd,btd->bt", q, keys)).reshape(B, P, S)
        assert (scores + 1e-4 >= dots.max(-1)).all()

    def test_topk_selects_best_pages(self):
        scores = jnp.asarray([[1.0, 5.0, 3.0, 4.0]])
        idx = np.asarray(ref.topk_pages_ref(scores, 2))
        assert sorted(idx[0].tolist()) == [1, 3]


class TestEntropy:
    def test_uniform_alpha(self):
        a = jnp.full((1, 2, 8), 1 / 8)
        h = ref.entropy_ref(a)
        np.testing.assert_allclose(h, np.log(8), atol=1e-6)

    def test_peaked_alpha(self):
        a = jnp.zeros((1, 1, 8)).at[0, 0, 3].set(1.0)
        h = ref.entropy_ref(a)
        assert float(h[0]) < 1e-6


class TestAlibiSlopes:
    @pytest.mark.parametrize("H", [2, 4, 8, 16])
    def test_geometric(self, H):
        s = ref.alibi_slopes(H)
        assert len(s) == H
        ratios = s[1:] / s[:-1]
        np.testing.assert_allclose(ratios, ratios[0], rtol=1e-6)
        assert s[0] < 1.0 and (s > 0).all()
