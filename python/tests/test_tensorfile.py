"""Tensorfile container roundtrip (python writer <-> python reader; the
rust reader is pinned by rust/src/util/tensorfile.rs tests + golden)."""

import os
import tempfile

import numpy as np
import pytest

from compile import tensorfile


def test_roundtrip_multiple_dtypes():
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.asarray([1, -2, 3], np.int32),
        "c": np.asarray([1.5, -0.25], np.float16),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        tensorfile.write(path, tensors, meta={"x": 7})
        out, meta = tensorfile.read(path)
    assert meta == {"x": 7}
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_alignment():
    tensors = {"a": np.ones(3, np.float32), "b": np.ones(5, np.float32)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        tensorfile.write(path, tensors)
        raw = open(path, "rb").read()
        out, _ = tensorfile.read(path)
    assert raw[:4] == b"TSWT"
    np.testing.assert_array_equal(out["b"], np.ones(5, np.float32))


def test_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.bin")
        open(path, "wb").write(b"NOPE" + b"\0" * 16)
        with pytest.raises(AssertionError):
            tensorfile.read(path)
