//! Property-based tests (util::prop) on coordinator/kvcache invariants:
//! allocator balance, snapshot isolation, top-k correctness, batcher
//! conservation, session-store page accounting, f16 bounds.

use tinyserve::config::KvDtype;
use tinyserve::coordinator::batcher::{Batcher, BatcherConfig, QueuedItem, Round};
use tinyserve::coordinator::session::SessionStore;
use tinyserve::kvcache::{PagePool, SeqCache};
use tinyserve::sparsity::top_k_indices;
use tinyserve::util::prop::prop_check;

#[test]
fn prop_pool_alloc_free_balance() {
    prop_check("pool_alloc_free_balance", 100, |ctx| {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let n_ops = ctx.scaled(1, 300);
        let mut live: Vec<u32> = Vec::new();
        for _ in 0..n_ops {
            if live.is_empty() || ctx.rng.bool(0.6) {
                live.push(pool.alloc());
            } else {
                let i = ctx.rng.usize(live.len());
                pool.release(live.swap_remove(i));
            }
        }
        if pool.pages_in_use() != live.len() {
            return Err(format!(
                "in_use {} != live {}",
                pool.pages_in_use(),
                live.len()
            ));
        }
        for id in live.drain(..) {
            pool.release(id);
        }
        if pool.pages_in_use() != 0 {
            return Err("leak after full release".into());
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_refcounted_sharing_never_leaks() {
    prop_check("refcount_sharing", 60, |ctx| {
        let mut pool = PagePool::new(2, 4, 4, KvDtype::F32);
        let mut seq = SeqCache::new();
        let n = ctx.scaled(1, 40);
        for i in 0..n {
            let (page, slot) = seq.slot_for_next(&mut pool);
            for l in 0..2 {
                pool.write_token(page, slot, l, &[i as f32; 4], &[i as f32; 4]);
            }
            seq.commit_token();
        }
        // random snapshot/restore chains
        let mut snaps: Vec<SeqCache> = Vec::new();
        for _ in 0..ctx.scaled(0, 6) {
            if snaps.is_empty() || ctx.rng.bool(0.5) {
                snaps.push(seq.snapshot(&mut pool));
            } else {
                let s = SeqCache::restore(snaps.last().unwrap(), &mut pool);
                snaps.push(s);
            }
        }
        seq.clear(&mut pool);
        for mut s in snaps {
            s.clear(&mut pool);
        }
        if pool.pages_in_use() != 0 {
            return Err(format!("{} pages leaked", pool.pages_in_use()));
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_snapshot_isolation() {
    prop_check("snapshot_isolation", 60, |ctx| {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut seq = SeqCache::new();
        let n = ctx.scaled(1, 30);
        for i in 0..n {
            let (page, slot) = seq.slot_for_next(&mut pool);
            pool.write_token(page, slot, 0, &[i as f32; 4], &[0.0; 4]);
            seq.commit_token();
        }
        let snap = seq.snapshot(&mut pool);
        let frozen: Vec<Vec<f32>> = snap
            .pages
            .iter()
            .flat_map(|e| {
                (0..pool.filled(e.id)).map(|s| pool.key_row(e.id, 0, s)).collect::<Vec<_>>()
            })
            .collect();
        // mutate the live sequence heavily
        for j in 0..ctx.scaled(1, 20) {
            let (page, slot) = seq.slot_for_next(&mut pool);
            pool.write_token(page, slot, 0, &[-(j as f32); 4], &[0.0; 4]);
            seq.commit_token();
        }
        let after: Vec<Vec<f32>> = snap
            .pages
            .iter()
            .flat_map(|e| {
                (0..pool.filled(e.id)).map(|s| pool.key_row(e.id, 0, s)).collect::<Vec<_>>()
            })
            .collect();
        if frozen != after {
            return Err("snapshot contents changed under live appends".into());
        }
        seq.clear(&mut pool);
        let mut snap = snap;
        snap.clear(&mut pool);
        Ok(())
    });
}

#[test]
fn prop_topk_is_exactly_the_k_largest() {
    prop_check("topk_exact", 200, |ctx| {
        let n = ctx.scaled(1, 200);
        let k = 1 + ctx.rng.usize(n);
        let scores: Vec<f32> = (0..n)
            .map(|_| (ctx.rng.normal() * 10.0) as f32)
            .collect();
        let got = top_k_indices(&scores, k);
        if got.len() != k.min(n) {
            return Err(format!("len {} != {}", got.len(), k.min(n)));
        }
        if got.windows(2).any(|w| w[0] >= w[1]) {
            return Err("indices not strictly ascending".into());
        }
        let worst_in = got
            .iter()
            .map(|&i| scores[i])
            .fold(f32::INFINITY, f32::min);
        let best_out = (0..n)
            .filter(|i| !got.contains(i))
            .map(|i| scores[i])
            .fold(f32::NEG_INFINITY, f32::max);
        if best_out > worst_in {
            return Err(format!("excluded {best_out} beats included {worst_in}"));
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_requests() {
    prop_check("batcher_conservation", 80, |ctx| {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 1 + ctx.rng.usize(8),
            batch_timeout_s: ctx.rng.f64() * 0.1,
            prefill_per_round: 1 + ctx.rng.usize(4),
        });
        let n = ctx.scaled(1, 60);
        let mut now = 0.0;
        let mut admitted = 0usize;
        let mut enqueued = 0usize;
        let mut active = 0usize;
        let mut next_id = 0usize;
        for _ in 0..n * 3 {
            // random arrivals
            if next_id < n && ctx.rng.bool(0.5) {
                b.enqueue(QueuedItem {
                    request_idx: next_id,
                    arrival_s: now,
                    prompt_len: 10,
                });
                next_id += 1;
                enqueued += 1;
            }
            match b.schedule(now, if next_id < n { Some(now + 0.01) } else { None }) {
                Round::Admit(items) => {
                    admitted += items.len();
                    active += items.len();
                    if active > b.cfg.max_active {
                        return Err("exceeded max_active".into());
                    }
                }
                Round::Decode => {
                    // finish a random number of active seqs
                    if active > 0 && ctx.rng.bool(0.7) {
                        let f = 1 + ctx.rng.usize(active);
                        b.on_finished(f);
                        active -= f;
                    }
                }
                Round::Idle(t) => {
                    if t.is_finite() {
                        now = now.max(t);
                    } else {
                        now += 0.01;
                    }
                }
            }
            now += ctx.rng.f64() * 0.01;
        }
        // drain
        let mut guard = 0;
        loop {
            guard += 1;
            if guard > 10_000 {
                return Err("drain did not converge".into());
            }
            match b.schedule(now, None) {
                Round::Admit(items) => {
                    admitted += items.len();
                    active += items.len();
                }
                Round::Decode => {
                    b.on_finished(active);
                    active = 0;
                }
                Round::Idle(_) => break,
            }
            now += 0.01;
        }
        if admitted != enqueued {
            return Err(format!("admitted {admitted} != enqueued {enqueued}"));
        }
        Ok(())
    });
}

#[test]
fn prop_session_store_page_accounting() {
    prop_check("session_store_accounting", 50, |ctx| {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(1 + ctx.rng.usize(4));
        for round in 0..ctx.scaled(1, 20) {
            let mut seq = SeqCache::new();
            let toks = 1 + ctx.rng.usize(12);
            for i in 0..toks {
                let (page, slot) = seq.slot_for_next(&mut pool);
                pool.write_token(page, slot, 0, &[i as f32; 4], &[0.0; 4]);
                seq.commit_token();
            }
            let id = ctx.rng.usize(6) as u64;
            let tok_ids: Vec<i32> = (0..toks as i32).collect();
            store.store(id, &seq, &tok_ids, 0, &mut pool);
            if ctx.rng.bool(0.5) {
                let mut longer = tok_ids.clone();
                longer.push(99);
                if let Some((mut r, _)) = store.try_reuse(id, &longer, &mut pool) {
                    r.clear(&mut pool);
                }
            }
            seq.clear(&mut pool);
            let _ = round;
        }
        store.clear(&mut pool);
        if pool.pages_in_use() != 0 {
            return Err(format!("{} pages leaked", pool.pages_in_use()));
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_f16_roundtrip_relative_error() {
    prop_check("f16_roundtrip", 300, |ctx| {
        use tinyserve::util::f16::f32_to_f16_to_f32;
        let x = (ctx.rng.normal() * 100.0) as f32;
        if x.abs() < 6.2e-5 || x.abs() > 65000.0 {
            return Ok(()); // outside the normal range
        }
        let y = f32_to_f16_to_f32(x);
        let rel = ((y - x) / x).abs();
        if rel > 1.0 / 2048.0 {
            return Err(format!("{x} -> {y} rel {rel}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use tinyserve::util::json::Json;
    prop_check("json_roundtrip", 150, |ctx| {
        fn gen(ctx: &mut tinyserve::util::prop::CaseCtx, depth: usize) -> Json {
            match if depth > 3 { ctx.rng.usize(4) } else { ctx.rng.usize(6) } {
                0 => Json::Null,
                1 => Json::Bool(ctx.rng.bool(0.5)),
                2 => Json::Num((ctx.rng.normal() * 1e3).round()),
                3 => Json::Str(format!("s{}-\"q\"\n", ctx.rng.usize(1000))),
                4 => Json::Arr((0..ctx.rng.usize(4)).map(|_| gen(ctx, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..ctx.rng.usize(4))
                        .map(|i| (format!("k{i}"), gen(ctx, depth + 1)))
                        .collect(),
                ),
            }
        }
        let j = gen(ctx, 0);
        let j2 = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        if j != j2 {
            return Err(format!("{j} != {j2}"));
        }
        Ok(())
    });
}
