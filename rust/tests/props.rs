//! Property-based tests (util::prop) on coordinator/kvcache invariants:
//! allocator balance, snapshot isolation, top-k correctness, batcher
//! conservation, session-store page accounting, budgeted-store residency,
//! f16 bounds.

use tinyserve::config::KvDtype;
use tinyserve::coordinator::batcher::{Batcher, BatcherConfig, QueuedItem, Round};
use tinyserve::coordinator::session::SessionStore;
use tinyserve::kvcache::{
    default_spill_root, EvictionPolicyKind, PagePool, PageStore, PrefixIndex,
    SeqCache, SpillConfig,
};
use tinyserve::sparsity::top_k_indices;
use tinyserve::util::prop::prop_check;
use tinyserve::workload::SloTier;

#[test]
fn prop_pool_alloc_free_balance() {
    prop_check("pool_alloc_free_balance", 100, |ctx| {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let n_ops = ctx.scaled(1, 300);
        let mut live: Vec<u32> = Vec::new();
        for _ in 0..n_ops {
            if live.is_empty() || ctx.rng.bool(0.6) {
                live.push(pool.alloc());
            } else {
                let i = ctx.rng.usize(live.len());
                pool.release(live.swap_remove(i));
            }
        }
        if pool.pages_in_use() != live.len() {
            return Err(format!(
                "in_use {} != live {}",
                pool.pages_in_use(),
                live.len()
            ));
        }
        for id in live.drain(..) {
            pool.release(id);
        }
        if pool.pages_in_use() != 0 {
            return Err("leak after full release".into());
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_refcounted_sharing_never_leaks() {
    prop_check("refcount_sharing", 60, |ctx| {
        let mut pool = PagePool::new(2, 4, 4, KvDtype::F32);
        let mut seq = SeqCache::new();
        let n = ctx.scaled(1, 40);
        for i in 0..n {
            let (page, slot) = seq.slot_for_next(&mut pool);
            for l in 0..2 {
                pool.write_token(page, slot, l, &[i as f32; 4], &[i as f32; 4]);
            }
            seq.commit_token();
        }
        // random snapshot/restore chains
        let mut snaps: Vec<SeqCache> = Vec::new();
        for _ in 0..ctx.scaled(0, 6) {
            if snaps.is_empty() || ctx.rng.bool(0.5) {
                snaps.push(seq.snapshot(&mut pool));
            } else {
                let s = SeqCache::restore(snaps.last().unwrap(), &mut pool);
                snaps.push(s);
            }
        }
        seq.clear(&mut pool);
        for mut s in snaps {
            s.clear(&mut pool);
        }
        if pool.pages_in_use() != 0 {
            return Err(format!("{} pages leaked", pool.pages_in_use()));
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_snapshot_isolation() {
    prop_check("snapshot_isolation", 60, |ctx| {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut seq = SeqCache::new();
        let n = ctx.scaled(1, 30);
        for i in 0..n {
            let (page, slot) = seq.slot_for_next(&mut pool);
            pool.write_token(page, slot, 0, &[i as f32; 4], &[0.0; 4]);
            seq.commit_token();
        }
        let snap = seq.snapshot(&mut pool);
        let frozen: Vec<Vec<f32>> = snap
            .pages
            .iter()
            .flat_map(|e| {
                (0..pool.filled(e.id)).map(|s| pool.key_row(e.id, 0, s)).collect::<Vec<_>>()
            })
            .collect();
        // mutate the live sequence heavily
        for j in 0..ctx.scaled(1, 20) {
            let (page, slot) = seq.slot_for_next(&mut pool);
            pool.write_token(page, slot, 0, &[-(j as f32); 4], &[0.0; 4]);
            seq.commit_token();
        }
        let after: Vec<Vec<f32>> = snap
            .pages
            .iter()
            .flat_map(|e| {
                (0..pool.filled(e.id)).map(|s| pool.key_row(e.id, 0, s)).collect::<Vec<_>>()
            })
            .collect();
        if frozen != after {
            return Err("snapshot contents changed under live appends".into());
        }
        seq.clear(&mut pool);
        let mut snap = snap;
        snap.clear(&mut pool);
        Ok(())
    });
}

#[test]
fn prop_topk_is_exactly_the_k_largest() {
    prop_check("topk_exact", 200, |ctx| {
        let n = ctx.scaled(1, 200);
        let k = 1 + ctx.rng.usize(n);
        let scores: Vec<f32> = (0..n)
            .map(|_| (ctx.rng.normal() * 10.0) as f32)
            .collect();
        let got = top_k_indices(&scores, k);
        if got.len() != k.min(n) {
            return Err(format!("len {} != {}", got.len(), k.min(n)));
        }
        if got.windows(2).any(|w| w[0] >= w[1]) {
            return Err("indices not strictly ascending".into());
        }
        let worst_in = got
            .iter()
            .map(|&i| scores[i])
            .fold(f32::INFINITY, f32::min);
        let best_out = (0..n)
            .filter(|i| !got.contains(i))
            .map(|i| scores[i])
            .fold(f32::NEG_INFINITY, f32::max);
        if best_out > worst_in {
            return Err(format!("excluded {best_out} beats included {worst_in}"));
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_requests() {
    prop_check("batcher_conservation", 80, |ctx| {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 1 + ctx.rng.usize(8),
            batch_timeout_s: ctx.rng.f64() * 0.1,
            prefill_per_round: 1 + ctx.rng.usize(4),
        });
        let n = ctx.scaled(1, 60);
        let mut now = 0.0;
        let mut admitted = 0usize;
        let mut enqueued = 0usize;
        let mut active = 0usize;
        let mut next_id = 0usize;
        for _ in 0..n * 3 {
            // random arrivals, some carrying SLO deadlines (EDF reorders,
            // conservation must hold regardless)
            if next_id < n && ctx.rng.bool(0.5) {
                b.enqueue(QueuedItem {
                    request_idx: next_id,
                    arrival_s: now,
                    prompt_len: 10,
                    deadline_s: if ctx.rng.bool(0.3) {
                        Some(now + ctx.rng.f64())
                    } else {
                        None
                    },
                    tier: SloTier::Batch,
                    preempted: false,
                });
                next_id += 1;
                enqueued += 1;
            }
            match b.schedule(now, if next_id < n { Some(now + 0.01) } else { None }) {
                Round::Admit(items) => {
                    admitted += items.len();
                    active += items.len();
                    if active > b.cfg.max_active {
                        return Err("exceeded max_active".into());
                    }
                }
                Round::Decode => {
                    // finish a random number of active seqs
                    if active > 0 && ctx.rng.bool(0.7) {
                        let f = 1 + ctx.rng.usize(active);
                        b.on_finished(f);
                        active -= f;
                    }
                }
                Round::Idle(t) => {
                    if t.is_finite() {
                        now = now.max(t);
                    } else {
                        now += 0.01;
                    }
                }
            }
            now += ctx.rng.f64() * 0.01;
        }
        // drain
        let mut guard = 0;
        loop {
            guard += 1;
            if guard > 10_000 {
                return Err("drain did not converge".into());
            }
            match b.schedule(now, None) {
                Round::Admit(items) => {
                    admitted += items.len();
                    active += items.len();
                }
                Round::Decode => {
                    b.on_finished(active);
                    active = 0;
                }
                Round::Idle(_) => break,
            }
            now += 0.01;
        }
        if admitted != enqueued {
            return Err(format!("admitted {admitted} != enqueued {enqueued}"));
        }
        Ok(())
    });
}

#[test]
fn prop_edf_pop_order_is_total_and_stable() {
    // EDF invariant: whatever the enqueue order, the batcher pops items
    // sorted by (deadline or +inf, arrival, request id) — a total order,
    // so the pop sequence is exactly the sorted key sequence.
    prop_check("edf_pop_order", 120, |ctx| {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 1024,
            batch_timeout_s: 0.0,
            prefill_per_round: 1 + ctx.rng.usize(5),
        });
        let n = ctx.scaled(1, 80);
        let mut items: Vec<QueuedItem> = (0..n)
            .map(|i| {
                // coarse grids force deadline and arrival ties, so the
                // id tie-break is actually exercised
                let arrival = ctx.rng.usize(5) as f64 * 0.01;
                QueuedItem {
                    request_idx: i,
                    arrival_s: arrival,
                    prompt_len: 10,
                    deadline_s: if ctx.rng.bool(0.6) {
                        Some(arrival + ctx.rng.usize(3) as f64 * 0.05)
                    } else {
                        None
                    },
                    tier: SloTier::Batch,
                    preempted: false,
                }
            })
            .collect();
        ctx.rng.shuffle(&mut items);
        for it in &items {
            b.enqueue(it.clone());
        }
        let mut want = items.clone();
        want.sort_by(|a, x| {
            let ka = (a.deadline_s.unwrap_or(f64::INFINITY), a.arrival_s, a.request_idx);
            let kx = (x.deadline_s.unwrap_or(f64::INFINITY), x.arrival_s, x.request_idx);
            ka.partial_cmp(&kx).unwrap()
        });
        let mut got: Vec<usize> = Vec::new();
        let mut guard = 0;
        while b.queue_len() > 0 {
            guard += 1;
            if guard > 10_000 {
                return Err("drain did not converge".into());
            }
            match b.schedule(10.0, None) {
                Round::Admit(v) => {
                    got.extend(v.iter().map(|i| i.request_idx));
                    b.on_finished(v.len());
                }
                Round::Decode => {
                    let n = b.active();
                    b.on_finished(n);
                }
                Round::Idle(_) => return Err("idle with a non-empty queue".into()),
            }
        }
        let want_ids: Vec<usize> = want.iter().map(|i| i.request_idx).collect();
        if got != want_ids {
            return Err(format!("pop order {got:?} != EDF order {want_ids:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_worker_budget_split_conserves_total() {
    // The WorkerPool budget-split rule: a global budget B over n workers
    // gives each worker B/n, and each worker's PageStore enforces its
    // slice independently — so the summed bytes_in_use never exceeds B
    // (unless a worker recorded an overflow: everything evictable pinned
    // or partial), under random alloc/release/pin/unpin/score
    // interleavings across all four eviction policies.
    prop_check("worker_budget_split", 50, |ctx| {
        let n_workers = 1 + ctx.rng.usize(4);
        let kind = *ctx.rng.choice(&[
            EvictionPolicyKind::Lru,
            EvictionPolicyKind::Clock,
            EvictionPolicyKind::QueryAware,
            EvictionPolicyKind::Sieve,
        ]);
        let mut pools: Vec<PagePool> =
            (0..n_workers).map(|_| PagePool::new(2, 8, 4, KvDtype::F32)).collect();
        let total_budget = (2 + ctx.rng.usize(8)) * pools[0].page_bytes()
            + ctx.rng.usize(pools[0].page_bytes());
        let per_worker = total_budget / n_workers;
        if per_worker == 0 {
            return Ok(());
        }
        let mut stores: Vec<PageStore> = (0..n_workers)
            .map(|_| PageStore::new(Some(per_worker), kind))
            .collect();
        let mut refs: Vec<Vec<u32>> = vec![Vec::new(); n_workers];
        for _ in 0..ctx.scaled(4, 100) {
            let w = ctx.rng.usize(n_workers);
            match ctx.rng.usize(8) {
                0..=3 => {
                    let id = stores[w].alloc(&mut pools[w]);
                    for slot in 0..4 {
                        for l in 0..2 {
                            let v = ctx.rng.normal() as f32;
                            pools[w].write_token(id, slot, l, &[v; 8], &[v; 8]);
                        }
                    }
                    refs[w].push(id);
                }
                4..=5 => {
                    if !refs[w].is_empty() {
                        let i = ctx.rng.usize(refs[w].len());
                        let id = refs[w].swap_remove(i);
                        pools[w].release(id);
                    }
                }
                6 => {
                    if !refs[w].is_empty() {
                        let id = refs[w][ctx.rng.usize(refs[w].len())];
                        if stores[w].is_hot(id) {
                            stores[w].pin(id);
                        }
                    }
                }
                _ => {
                    if !refs[w].is_empty() {
                        let id = refs[w][ctx.rng.usize(refs[w].len())];
                        stores[w].note_score(id, ctx.rng.normal() as f32);
                    }
                    if ctx.rng.bool(0.3) {
                        stores[w].unpin_all();
                    }
                }
            }
            let ovf_before: Vec<u64> =
                (0..n_workers).map(|w| stores[w].stats.overflows).collect();
            for w in 0..n_workers {
                stores[w].enforce_budget(&mut pools[w]);
            }
            let sum: usize =
                (0..n_workers).map(|w| stores[w].bytes_in_use(&pools[w])).sum();
            // an overflow recorded by *this* enforcement pass (pinned or
            // partial pages blocked demotion) is the only excuse
            let overflowed = (0..n_workers)
                .any(|w| stores[w].stats.overflows > ovf_before[w]);
            if sum > total_budget && !overflowed {
                return Err(format!(
                    "sum bytes_in_use {sum} > global budget {total_budget} \
                     ({n_workers} workers x {per_worker}, policy {kind:?}) \
                     without an overflow"
                ));
            }
        }
        // full release drains every worker
        for w in 0..n_workers {
            stores[w].unpin_all();
            for id in refs[w].drain(..) {
                pools[w].release(id);
            }
            stores[w].sync(&pools[w]);
            if stores[w].bytes_in_use(&pools[w]) != 0 {
                return Err(format!("worker {w} bytes after release"));
            }
            pools[w].validate().map_err(|e| e.to_string())?;
        }
        Ok(())
    });
}

#[test]
fn prop_session_store_page_accounting() {
    prop_check("session_store_accounting", 50, |ctx| {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(1 + ctx.rng.usize(4));
        for round in 0..ctx.scaled(1, 20) {
            let mut seq = SeqCache::new();
            let toks = 1 + ctx.rng.usize(12);
            for i in 0..toks {
                let (page, slot) = seq.slot_for_next(&mut pool);
                pool.write_token(page, slot, 0, &[i as f32; 4], &[0.0; 4]);
                seq.commit_token();
            }
            let id = ctx.rng.usize(6) as u64;
            let tok_ids: Vec<i32> = (0..toks as i32).collect();
            store.store(id, &seq, &tok_ids, 0, &mut pool);
            if ctx.rng.bool(0.5) {
                let mut longer = tok_ids.clone();
                longer.push(99);
                if let Some((mut r, _)) = store.try_reuse(id, &longer, &mut pool) {
                    r.clear(&mut pool);
                }
            }
            seq.clear(&mut pool);
            let _ = round;
        }
        store.clear(&mut pool);
        if pool.pages_in_use() != 0 {
            return Err(format!("{} pages leaked", pool.pages_in_use()));
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_store_budget_pinning_and_conservation() {
    prop_check("store_budget_invariants", 60, |ctx| {
        let mut pool = PagePool::new(2, 8, 4, KvDtype::F32);
        let kind = *ctx
            .rng
            .choice(&[
                EvictionPolicyKind::Lru,
                EvictionPolicyKind::Clock,
                EvictionPolicyKind::QueryAware,
                EvictionPolicyKind::Sieve,
            ]);
        let budget_pages = 3 + ctx.rng.usize(6);
        let budget = budget_pages * pool.page_bytes();
        let mut store = PageStore::new(Some(budget), kind);
        // refs: one entry per outstanding reference (retain duplicates ids)
        let mut refs: Vec<u32> = Vec::new();
        let mut pinned_hot: Vec<u32> = Vec::new();
        let n_ops = ctx.scaled(4, 120);
        for _ in 0..n_ops {
            match ctx.rng.usize(10) {
                0..=3 => {
                    let id = store.alloc(&mut pool);
                    // fill the page completely so it is demotable
                    for slot in 0..4 {
                        for l in 0..2 {
                            let v = ctx.rng.normal() as f32;
                            pool.write_token(id, slot, l, &[v; 8], &[v; 8]);
                        }
                    }
                    refs.push(id);
                }
                4 => {
                    if !refs.is_empty() {
                        let id = refs[ctx.rng.usize(refs.len())];
                        pool.retain(id);
                        refs.push(id);
                    }
                }
                5..=6 => {
                    if !refs.is_empty() {
                        let i = ctx.rng.usize(refs.len());
                        let id = refs.swap_remove(i);
                        pool.release(id);
                        if !refs.contains(&id) {
                            pinned_hot.retain(|&p| p != id);
                        }
                    }
                }
                7 => {
                    if !refs.is_empty() {
                        let id = refs[ctx.rng.usize(refs.len())];
                        if store.is_hot(id) {
                            store.pin(id);
                            if !pinned_hot.contains(&id) {
                                pinned_hot.push(id);
                            }
                        }
                    }
                }
                8 => {
                    store.unpin_all();
                    pinned_hot.clear();
                }
                _ => {
                    if !refs.is_empty() {
                        let id = refs[ctx.rng.usize(refs.len())];
                        store.note_score(id, ctx.rng.normal() as f32);
                    }
                }
            }
            store.enforce_budget(&mut pool);
            // 1. pages pinned while hot must stay hot
            for &id in &pinned_hot {
                if !store.is_hot(id) {
                    return Err(format!("pinned page {id} left the hot tier"));
                }
            }
            // 2. refcounts conserved: pool residency == live references
            let mut distinct: Vec<u32> = refs.clone();
            distinct.sort_unstable();
            distinct.dedup();
            if pool.pages_in_use() != distinct.len() {
                return Err(format!(
                    "in_use {} != distinct refs {}",
                    pool.pages_in_use(),
                    distinct.len()
                ));
            }
            // 3. bytes within budget after enforcement, unless everything
            //    evictable is already cold or pinned (recorded overflow)
            let bytes = store.bytes_in_use(&pool);
            if bytes > budget {
                let demotable = distinct.iter().any(|&id| {
                    store.is_hot(id) && !store.is_pinned(id) && pool.filled(id) == 4
                });
                if demotable {
                    return Err(format!(
                        "bytes {bytes} > budget {budget} with demotable pages left"
                    ));
                }
            }
        }
        // drain: all references released -> store and pool empty
        store.unpin_all();
        for id in refs.drain(..) {
            pool.release(id);
        }
        store.sync(&pool);
        if pool.pages_in_use() != 0 || store.bytes_in_use(&pool) != 0 {
            return Err("store/pool not empty after full release".into());
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_prefix_sharing_is_token_identical_across_policies_and_dtypes() {
    // The shared-prefix cache's correctness contract at the KV level:
    // prefill writes are a pure function of (token, position), so a
    // request that adopts published pages must end up with KV rows
    // bit-identical to a from-scratch prefill of the same prompt — for
    // every storage dtype and under every eviction policy's budgeted
    // store. Also pins the COW contract (decode appends by a sharer never
    // mutate the publisher's pages) and full-release conservation.
    prop_check("prefix_token_identity", 60, |ctx| {
        const PAGE: usize = 4;
        let dt = *ctx.rng.choice(&[KvDtype::F32, KvDtype::F16]);
        let kind = *ctx.rng.choice(&[
            EvictionPolicyKind::Lru,
            EvictionPolicyKind::Clock,
            EvictionPolicyKind::QueryAware,
            EvictionPolicyKind::Sieve,
        ]);
        let mut pool = PagePool::new(2, 8, PAGE, dt);
        let mut px = PrefixIndex::new(None, 1);

        // prefill writes derived purely from (token, position, layer)
        fn prefill(
            pool: &mut PagePool,
            tokens: &[i32],
            from: usize,
            cache: &mut SeqCache,
        ) {
            for (pos, &t) in tokens.iter().enumerate().skip(from) {
                let (page, slot) = cache.slot_for_next(pool);
                for l in 0..2 {
                    let row: Vec<f32> = (0..8)
                        .map(|j| {
                            t as f32 * 1e-3 + pos as f32 + (l * 8 + j) as f32 * 0.01
                        })
                        .collect();
                    pool.write_token(page, slot, l, &row, &row);
                }
                cache.commit_token();
            }
        }
        fn rows(pool: &PagePool, cache: &SeqCache, n: usize) -> Vec<Vec<f32>> {
            let mut out = Vec::new();
            for pos in 0..n {
                let e = &cache.pages[pos / PAGE];
                for l in 0..2 {
                    out.push(pool.key_row(e.id, l, pos % PAGE));
                }
            }
            out
        }

        let len_a = 8 + ctx.rng.usize(32);
        let prompt_a: Vec<i32> =
            (0..len_a).map(|_| 1 + ctx.rng.usize(499) as i32).collect();
        let mut a = SeqCache::new();
        prefill(&mut pool, &prompt_a, 0, &mut a);
        px.publish(&prompt_a, &a, &mut pool);

        // prompt B: a shared prefix of A plus a fresh tail
        let share = 1 + ctx.rng.usize(len_a);
        let mut prompt_b: Vec<i32> = prompt_a[..share].to_vec();
        let tail = 1 + ctx.rng.usize(12);
        prompt_b.extend((0..tail).map(|_| 500 + ctx.rng.usize(499) as i32));

        // sharing-off baseline: full from-scratch prefill
        let mut b_fresh = SeqCache::new();
        prefill(&mut pool, &prompt_b, 0, &mut b_fresh);

        // sharing-on: adopt the published prefix, prefill only the tail
        let mut b_shared = SeqCache::new();
        let covered = match px.adopt(&prompt_b, &mut pool) {
            Some((cache, n)) => {
                b_shared = cache;
                n
            }
            None => 0,
        };
        if covered % PAGE != 0 || covered >= prompt_b.len() {
            return Err(format!(
                "adoption coverage {covered} not page-aligned below len {}",
                prompt_b.len()
            ));
        }
        prefill(&mut pool, &prompt_b, covered, &mut b_shared);
        if rows(&pool, &b_fresh, prompt_b.len())
            != rows(&pool, &b_shared, prompt_b.len())
        {
            return Err(format!(
                "adopted KV differs from fresh prefill (dt {dt:?}, share \
                 {share}, covered {covered})"
            ));
        }

        // COW contract: decode appends by the sharer never touch the
        // publisher's pages
        let frozen_a = rows(&pool, &a, len_a);
        for extra in 0..1 + ctx.rng.usize(2 * PAGE) {
            let (page, slot) = b_shared.slot_for_next(&mut pool);
            for l in 0..2 {
                pool.write_token(page, slot, l, &[-(extra as f32); 8], &[0.5; 8]);
            }
            b_shared.commit_token();
        }
        if rows(&pool, &a, len_a) != frozen_a {
            return Err("sharer decode appends mutated published pages".into());
        }

        // sharing-aware budgeted store: register everything live, enforce
        // a tight budget, and the byte invariant must hold whenever a
        // demotable page remains
        let budget = 2 * pool.page_bytes();
        let mut store = PageStore::new(Some(budget), kind);
        store.sync(&pool);
        store.enforce_budget(&mut pool);
        let bytes = store.bytes_in_use(&pool);
        if bytes > budget {
            let demotable = (0..pool.cap_pages() as u32).any(|id| {
                pool.refcount(id) > 0
                    && store.is_hot(id)
                    && !store.is_pinned(id)
                    && pool.filled(id) == PAGE
            });
            if demotable {
                return Err(format!(
                    "bytes {bytes} > budget {budget} with demotable pages left"
                ));
            }
        }

        // full release drains everything (index refs included)
        a.clear(&mut pool);
        b_fresh.clear(&mut pool);
        b_shared.clear(&mut pool);
        px.clear(&mut pool);
        store.sync(&pool);
        if pool.pages_in_use() != 0 || store.bytes_in_use(&pool) != 0 {
            return Err(format!(
                "{} pages / {} bytes leaked after full release",
                pool.pages_in_use(),
                store.bytes_in_use(&pool)
            ));
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

#[test]
fn prop_demote_promote_roundtrip_within_tolerance() {
    prop_check("demote_roundtrip", 80, |ctx| {
        let dt = *ctx.rng.choice(&[KvDtype::F32, KvDtype::F16]);
        let mut pool = PagePool::new(1, 8, 4, dt);
        let budget = pool.page_bytes(); // forces the second page cold
        let mut store = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = store.alloc(&mut pool);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for slot in 0..4 {
            let row: Vec<f32> = (0..8).map(|_| (ctx.rng.normal() * 2.0) as f32).collect();
            pool.write_token(a, slot, 0, &row, &row);
            rows.push(row);
        }
        let b = store.alloc(&mut pool); // alloc demotes `a`
        if !store.is_cold(a) {
            store.enforce_budget(&mut pool);
        }
        if !store.is_cold(a) {
            return Err("page a not demoted under one-page budget".into());
        }
        // q8 round-trip tolerance: per-row symmetric int8 keeps values
        // within amax/100 (scale amax/127, error <= scale/2), plus the
        // storage dtype's own quantum for f16 pools
        for (slot, row) in rows.iter().enumerate() {
            let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let got = pool.key_row(a, 0, slot);
            for (x, y) in row.iter().zip(&got) {
                let tol = amax / 100.0 + x.abs() / 1024.0 + 1e-6;
                if (x - y).abs() > tol {
                    return Err(format!("slot {slot}: {x} vs {y} (tol {tol})"));
                }
            }
        }
        // promotion restores the hot tier without further data change
        let frozen: Vec<Vec<f32>> = (0..4).map(|s| pool.key_row(a, 0, s)).collect();
        store.ensure_hot(&mut pool, a).map_err(|e| e.to_string())?;
        if !store.is_hot(a) {
            return Err("promotion did not restore the hot tier".into());
        }
        for (s, f) in frozen.iter().enumerate() {
            if pool.key_row(a, 0, s) != *f {
                return Err("promotion changed page contents".into());
            }
        }
        pool.release(a);
        pool.release(b);
        store.sync(&pool);
        if store.bytes_in_use(&pool) != 0 {
            return Err("bytes after release".into());
        }
        Ok(())
    });
}

#[test]
fn prop_spill_roundtrip_is_bit_exact_across_policies_and_dtypes() {
    // Spill durability property, in three phases:
    //   1. fill pages under a one-hot-page RAM budget with the disk tier's
    //      byte budget at ZERO — the cascade demotes everything to q8 but
    //      cannot spill, so the cold pages' exact contents are observable;
    //   2. snapshot the cold pages (rows + bounding boxes), open the disk
    //      budget, enforce — the cascade now spills cold pages, zeroing
    //      their pool rows;
    //   3. fault every snapshotted page back via ensure_hot and require
    //      its rows AND bboxes to match the snapshot bit-exactly.
    // Holds across all four eviction policies and all three KV dtypes
    // (int8 pools take the raw-copy codec path; f32/f16 the q8 path,
    // whose demote->spill->fault pipeline is quantizer-idempotent).
    prop_check("spill_roundtrip_bit_exact", 40, |ctx| {
        let kind = *ctx.rng.choice(&[
            EvictionPolicyKind::Lru,
            EvictionPolicyKind::Clock,
            EvictionPolicyKind::QueryAware,
            EvictionPolicyKind::Sieve,
        ]);
        let dt = *ctx.rng.choice(&[KvDtype::F32, KvDtype::F16, KvDtype::Int8]);
        let mut pool = PagePool::new(2, 8, 4, dt);
        let budget = pool.page_bytes(); // room for one hot page
        let dir = default_spill_root().join(format!("prop-{}", ctx.index));
        let mut sc = SpillConfig::new(dir, 0); // tier attached, budget shut
        // small staging buffers so flushed segment slots get exercised
        sc.staging_slots = 1 + ctx.rng.usize(3);
        let mut store =
            PageStore::with_spill(Some(budget), kind, sc).map_err(|e| e.to_string())?;
        let n = 3 + ctx.scaled(0, 5);
        let mut ids = Vec::new();
        for _ in 0..n {
            let id = store.alloc(&mut pool);
            for slot in 0..4 {
                for l in 0..2 {
                    let row: Vec<f32> =
                        (0..8).map(|_| (ctx.rng.normal() * 2.0) as f32).collect();
                    pool.write_token(id, slot, l, &row, &row);
                }
            }
            store.note_score(id, ctx.rng.normal() as f32);
            ids.push(id);
            store.enforce_budget(&mut pool);
        }
        if store.tier_residency().2 != 0 {
            return Err("pages spilled under a zero disk budget".into());
        }
        // phase 2: snapshot the cold set, open the tier, cascade
        let cold: Vec<u32> = ids.iter().copied().filter(|&id| store.is_cold(id)).collect();
        if cold.is_empty() {
            return Err("workload produced no cold pages".into());
        }
        let snapshot: Vec<(u32, Vec<Vec<f32>>, Vec<Vec<f32>>)> = cold
            .iter()
            .map(|&id| {
                let rows = (0..2)
                    .flat_map(|l| (0..4).map(move |s| (l, s)))
                    .map(|(l, s)| pool.key_row(id, l, s))
                    .collect();
                let meta = (0..2).map(|l| pool.meta(id, l).to_vec()).collect();
                (id, rows, meta)
            })
            .collect();
        store.set_spill_budget_bytes(1 << 20);
        store.enforce_budget(&mut pool);
        if store.stats.spill_outs == 0 {
            return Err(format!("cascade never spilled ({kind:?}, {dt:?})"));
        }
        if let Some(&spilled) = ids.iter().find(|&&id| store.is_on_disk(id)) {
            if !pool.key_row(spilled, 0, 0).iter().all(|&x| x == 0.0) {
                return Err("disk page rows not purged from the pool".into());
            }
        }
        store.flush_spill().map_err(|e| e.to_string())?;
        // phase 3: fault back and compare bit-exactly
        for (id, rows, meta) in &snapshot {
            store.ensure_hot(&mut pool, *id).map_err(|e| e.to_string())?;
            let mut i = 0usize;
            for l in 0..2 {
                for s in 0..4 {
                    let got = pool.key_row(*id, l, s);
                    if got != rows[i] {
                        return Err(format!(
                            "page {id} layer {l} slot {s} not bit-exact after \
                             spill round-trip ({kind:?}, {dt:?}): {got:?} vs {:?}",
                            rows[i]
                        ));
                    }
                    i += 1;
                }
                if pool.meta(*id, l) != meta[l].as_slice() {
                    return Err(format!(
                        "page {id} layer {l} bbox not bit-exact after spill \
                         round-trip ({kind:?}, {dt:?})"
                    ));
                }
            }
        }
        if store.stats.faults == 0 {
            return Err("promoting disk pages must count faults".into());
        }
        // drain: the spill tier must empty with the pool
        store.unpin_all();
        for id in ids {
            pool.release(id);
        }
        store.sync(&pool);
        if store.spill_bytes() != 0 {
            return Err("spill tier holds bytes after full release".into());
        }
        if store.bytes_in_use(&pool) != 0 {
            return Err("bytes after release".into());
        }
        pool.validate().map_err(|e| e.to_string())
    });
}

/// Shared worker body for the concurrent store->pool->spill regressions:
/// hammer one worker's private three-tier stack through the
/// enforce/promote/fault cascade for `rounds` rounds, assert its leak
/// invariants, and return `(spill_outs, faults)`. One copy of the
/// workload, driven below by both raw `thread::spawn` and the round
/// executor — a change to the store API or the invariants lands in both
/// harnesses at once.
fn spill_hammer(w: u64, dir: std::path::PathBuf, rounds: usize) -> (u64, u64) {
    let mut pool = PagePool::new(2, 8, 4, KvDtype::F32);
    let budget = pool.page_bytes();
    let mut store = PageStore::with_spill(
        Some(budget),
        EvictionPolicyKind::Lru,
        SpillConfig::new(dir, 1 << 20),
    )
    .expect("spill store");
    let mut rng = tinyserve::util::rng::Rng::new(0xC0FFEE ^ w);
    let mut live: Vec<u32> = Vec::new();
    for round in 0..rounds {
        let id = store.alloc(&mut pool);
        for slot in 0..4 {
            for l in 0..2 {
                let v = rng.normal() as f32;
                pool.write_token(id, slot, l, &[v; 8], &[v; 8]);
            }
        }
        live.push(id);
        store.enforce_budget(&mut pool);
        // promote a random resident page (faults disk pages)
        let pick = live[rng.usize(live.len())];
        store.ensure_hot(&mut pool, pick).expect("fault");
        store.enforce_budget(&mut pool);
        if round % 3 == 0 && live.len() > 2 {
            let i = rng.usize(live.len());
            pool.release(live.swap_remove(i));
            store.sync(&pool);
        }
    }
    let stats = store.stats.clone();
    for id in live {
        pool.release(id);
    }
    store.sync(&pool);
    assert_eq!(store.spill_bytes(), 0, "worker {w} leaked spill bytes");
    assert_eq!(pool.pages_in_use(), 0, "worker {w} leaked pages");
    (stats.spill_outs, stats.faults)
}

#[test]
fn two_workers_concurrent_enforce_promote_without_deadlock() {
    // Concurrency regression for per-worker store -> pool -> spill stacks
    // (see docs/pagestore_design.md): each worker owns its stack
    // exclusively, so two workers hammering the enforce/promote cascade
    // concurrently must run to completion with both tiers exercised. This
    // pins the *exclusive-ownership* contract that makes the stack
    // lock-free — it cannot detect an ordering bug in a future
    // shared-pool mutex protocol, which will need its own battery. A
    // regression (accidental cross-worker sharing, a lock added to one
    // layer) shows up as this test hanging; a panic as a join error.
    let root = default_spill_root();
    let handles: Vec<_> = (0..2u64)
        .map(|w| {
            let dir = root.join(format!("worker-{w}"));
            std::thread::spawn(move || spill_hammer(w, dir, 200))
        })
        .collect();
    for (w, h) in handles.into_iter().enumerate() {
        let (spill_outs, faults) = h.join().expect("worker thread panicked");
        assert!(spill_outs > 0, "worker {w} never spilled to disk");
        assert!(faults > 0, "worker {w} never faulted a page back");
    }
}

#[test]
fn prop_round_executor_threaded_matches_sequential() {
    // The round-executor determinism contract at the store level: the same
    // per-worker workload (own PagePool + PageStore, seeded ops over the
    // enforce/promote cascade) must produce byte-identical digests whether
    // the workers run sequentially or chunked over 2/4/8 scoped threads,
    // in the same (ascending-worker) result order, for every eviction
    // policy. This is the engine-free core of the `--threads N` ==
    // `--threads 1` event-log guarantee (the full frontend version is the
    // artifact-gated integration test).
    use tinyserve::coordinator::pool::{execute_round, RoundExecutor};
    prop_check("round_executor_equivalence", 30, |ctx| {
        let n_workers = 1 + ctx.rng.usize(4);
        let policy =
            EvictionPolicyKind::all()[ctx.rng.usize(EvictionPolicyKind::all().len())];
        let seeds: Vec<u64> = (0..n_workers).map(|_| ctx.rng.next_u64()).collect();
        let n_rounds = ctx.scaled(5, 60);
        let digest = |exec: RoundExecutor| -> Vec<(usize, String)> {
            let work: Vec<(usize, u64)> = seeds.iter().cloned().enumerate().collect();
            execute_round(exec, work, &|w, seed: u64| {
                let mut pool = PagePool::new(2, 8, 4, KvDtype::F32);
                let budget = 2 * pool.page_bytes();
                let mut store = PageStore::new(Some(budget), policy);
                let mut rng = tinyserve::util::rng::Rng::new(seed);
                let mut live: Vec<u32> = Vec::new();
                for _ in 0..n_rounds {
                    let id = store.alloc(&mut pool);
                    for slot in 0..4 {
                        for l in 0..2 {
                            let v = rng.normal() as f32;
                            pool.write_token(id, slot, l, &[v; 8], &[v; 8]);
                        }
                    }
                    live.push(id);
                    store.enforce_budget(&mut pool);
                    let pick = live[rng.usize(live.len())];
                    store.ensure_hot(&mut pool, pick).expect("promote");
                    if live.len() > 3 && rng.bool(0.3) {
                        let i = rng.usize(live.len());
                        pool.release(live.swap_remove(i));
                        store.sync(&pool);
                    }
                }
                let (hot, cold, disk) = store.tier_residency();
                let s = &store.stats;
                let out = format!(
                    "w{w} hot{hot} cold{cold} disk{disk} hit{} miss{} dem{} pro{} \
                     bytes{}",
                    s.hits,
                    s.misses,
                    s.demotions,
                    s.promotions,
                    store.bytes_in_use(&pool)
                );
                for id in live {
                    pool.release(id);
                }
                out
            })
        };
        let base = digest(RoundExecutor::Sequential);
        let order: Vec<usize> = base.iter().map(|(w, _)| *w).collect();
        if order != (0..n_workers).collect::<Vec<_>>() {
            return Err(format!("sequential order drifted: {order:?}"));
        }
        for threads in [2usize, n_workers.max(2), 8] {
            let got = digest(RoundExecutor::Threaded { threads });
            if got != base {
                return Err(format!(
                    "[{}] threads={threads} diverged:\n{got:?}\n!=\n{base:?}",
                    policy.name()
                ));
            }
            // long-lived persistent workers must replay the same digests —
            // same contract, different thread lifetime
            let got = digest(RoundExecutor::Persistent { threads });
            if got != base {
                return Err(format!(
                    "[{}] persistent threads={threads} diverged:\n{got:?}\n!=\n{base:?}",
                    policy.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn round_executor_concurrent_spill_stacks_without_deadlock() {
    // Concurrency stress for the threaded step phase: four workers run
    // the same spill_hammer workload *through the executor* (the exact
    // code path `--threads 4` serving takes), concurrently driving
    // enforce_budget / ensure_hot cascades against per-worker spill
    // directory slices. Like the raw-thread variant above, this pins the
    // exclusive-ownership contract (each worker's whole store -> pool ->
    // spill stack moves onto its thread with no cross-worker sharing);
    // a regression shows up as this test hanging or a join panic.
    use tinyserve::coordinator::pool::{execute_round, RoundExecutor};
    let root = default_spill_root();
    let work: Vec<(usize, std::path::PathBuf)> = (0..4usize)
        .map(|w| (w, root.join(format!("worker-{w}"))))
        .collect();
    let results = execute_round(
        RoundExecutor::Threaded { threads: 4 },
        work,
        &|w, dir: std::path::PathBuf| spill_hammer(w as u64, dir, 150),
    );
    assert_eq!(results.len(), 4);
    for (w, (spill_outs, faults)) in results {
        assert!(spill_outs > 0, "worker {w} never spilled to disk");
        assert!(faults > 0, "worker {w} never faulted a page back");
    }
}

#[test]
fn prop_f16_roundtrip_relative_error() {
    prop_check("f16_roundtrip", 300, |ctx| {
        use tinyserve::util::f16::f32_to_f16_to_f32;
        let x = (ctx.rng.normal() * 100.0) as f32;
        if x.abs() < 6.2e-5 || x.abs() > 65000.0 {
            return Ok(()); // outside the normal range
        }
        let y = f32_to_f16_to_f32(x);
        let rel = ((y - x) / x).abs();
        if rel > 1.0 / 2048.0 {
            return Err(format!("{x} -> {y} rel {rel}"));
        }
        Ok(())
    });
}

#[test]
fn prop_analytics_recorder_deterministic_across_executors() {
    // The analytics stream's executor-independence contract: identical
    // per-worker access/audit workloads (own PagePool + budgeted PageStore
    // driving genuine hot/cold tier transitions) must snapshot to
    // byte-identical JSONL whether the workers run sequentially, on scoped
    // threads or on persistent decode threads, for every eviction policy.
    // This is the engine-free core of the CI `--analytics-out` byte-diff
    // (the full frontend version is the artifact-gated integration test).
    use tinyserve::coordinator::pool::{execute_round, RoundExecutor};
    use tinyserve::trace::{AccessTier, AnalyticsRecorder};
    prop_check("analytics_executor_equivalence", 20, |ctx| {
        let n_workers = 1 + ctx.rng.usize(4);
        let policy =
            EvictionPolicyKind::all()[ctx.rng.usize(EvictionPolicyKind::all().len())];
        let seeds: Vec<u64> = (0..n_workers).map(|_| ctx.rng.next_u64()).collect();
        let n_steps = ctx.scaled(5, 50);
        let digest = |exec: RoundExecutor| -> Vec<(usize, Vec<String>)> {
            let work: Vec<(usize, u64)> = seeds.iter().cloned().enumerate().collect();
            execute_round(exec, work, &|w, seed: u64| {
                let mut pool = PagePool::new(2, 8, 4, KvDtype::F32);
                let budget = 2 * pool.page_bytes();
                let mut store = PageStore::new(Some(budget), policy);
                let mut rng = tinyserve::util::rng::Rng::new(seed);
                let mut an = AnalyticsRecorder::new();
                let mut live: Vec<u32> = Vec::new();
                let mut lines: Vec<String> = Vec::new();
                for step in 0..n_steps {
                    let id = store.alloc(&mut pool);
                    live.push(id);
                    store.enforce_budget(&mut pool);
                    // a few accesses per step; tier recorded *before* the
                    // access promotes the page, like the engine feed
                    for _ in 0..1 + rng.usize(3) {
                        let pick = live[rng.usize(live.len())];
                        let tier = if store.is_hot(pick) {
                            AccessTier::Hot
                        } else if store.is_on_disk(pick) {
                            AccessTier::Disk
                        } else {
                            AccessTier::Cold
                        };
                        an.on_access(pick as u64, tier);
                        store.ensure_hot(&mut pool, pick).expect("promote");
                        store.enforce_budget(&mut pool);
                    }
                    if step % 4 == 0 {
                        let k = 1 + rng.usize(4);
                        an.on_audit(step % 2, k, rng.usize(k + 1));
                    }
                    let (hot, cold, disk) = store.tier_residency();
                    an.on_step_end(hot, cold, disk);
                    // mid-run snapshot exercises the drain-vs-cumulative
                    // split across the executor boundary too
                    if step == n_steps / 2 {
                        an.snapshot_into(w, step as u64, step as f64 * 0.5, &mut lines);
                    }
                }
                an.snapshot_into(w, n_steps as u64, n_steps as f64 * 0.5, &mut lines);
                for id in live {
                    pool.release(id);
                }
                lines
            })
        };
        let base = digest(RoundExecutor::Sequential);
        let variants = [
            ("threaded", RoundExecutor::Threaded { threads: 4 }),
            ("persistent", RoundExecutor::Persistent { threads: 4 }),
        ];
        for (name, exec) in variants {
            let got = digest(exec);
            if got != base {
                return Err(format!(
                    "[{}] {name} diverged:\n{got:?}\n!=\n{base:?}",
                    policy.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use tinyserve::util::json::Json;
    prop_check("json_roundtrip", 150, |ctx| {
        fn gen(ctx: &mut tinyserve::util::prop::CaseCtx, depth: usize) -> Json {
            match if depth > 3 { ctx.rng.usize(4) } else { ctx.rng.usize(6) } {
                0 => Json::Null,
                1 => Json::Bool(ctx.rng.bool(0.5)),
                2 => Json::Num((ctx.rng.normal() * 1e3).round()),
                3 => Json::Str(format!("s{}-\"q\"\n", ctx.rng.usize(1000))),
                4 => Json::Arr((0..ctx.rng.usize(4)).map(|_| gen(ctx, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..ctx.rng.usize(4))
                        .map(|i| (format!("k{i}"), gen(ctx, depth + 1)))
                        .collect(),
                ),
            }
        }
        let j = gen(ctx, 0);
        let j2 = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        if j != j2 {
            return Err(format!("{j} != {j2}"));
        }
        Ok(())
    });
}
