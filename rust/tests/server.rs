//! Scenario tests for the network serving front door (rust/src/server/):
//! slow consumers stay bounded and get evicted, bursts shed typed
//! overloads while admitted work meets its deadlines, deferred submits
//! carry a usable retry hint, and a single-connection closed loop is
//! byte-deterministic end to end. Everything runs over the engine-free
//! [`MockBackend`]; the one real-engine test (disconnect frees KV pages
//! mid-flight through `Frontend::cancel`) skips when artifacts are absent,
//! same as the integration suite.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

use tinyserve::server::proto::{ClientMsg, ServerMsg, PROTO_SCHEMA};
use tinyserve::server::shed::{AdmissionConfig, ShedPolicy};
use tinyserve::server::{MockBackend, ServeBackend, Server, ServerConfig, ServerStats};
use tinyserve::workload::{run_closed_loop, ClientConfig};

fn pallas_seed() -> u64 {
    std::env::var("PALLAS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

fn write_ci_log(name: &str, content: &str) {
    if let Ok(dir) = std::env::var("TINYSERVE_EVENT_LOG") {
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(std::path::Path::new(&dir).join(name), content);
    }
}

/// Bind an ephemeral loopback server over a caller-configured MockBackend
/// and run it to completion on its own thread.
fn serve_mock(
    cfg: ServerConfig,
    make: impl FnOnce() -> MockBackend + Send + 'static,
) -> (SocketAddr, std::thread::JoinHandle<(ServerStats, MockBackend)>) {
    let server = Server::bind(cfg).expect("bind loopback");
    let addr = server.local_addr().expect("bound addr");
    let handle = std::thread::spawn(move || {
        let mut backend = make();
        let stats = server.run(&mut backend).expect("server run");
        (stats, backend)
    });
    (addr, handle)
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    let reader = BufReader::new(stream.try_clone().expect("clone"));
    (stream, reader)
}

fn read_msg(reader: &mut BufReader<TcpStream>) -> Option<ServerMsg> {
    let mut line = String::new();
    if reader.read_line(&mut line).ok()? == 0 {
        return None;
    }
    Some(ServerMsg::parse(line.trim_end()).expect("valid server line"))
}

fn send(stream: &mut TcpStream, msg: &ClientMsg) {
    stream
        .write_all(format!("{}\n", msg.to_line()).as_bytes())
        .expect("write");
}

fn submit(id: u64, max_new: usize, deadline_ms: Option<f64>) -> ClientMsg {
    ClientMsg::Submit {
        id,
        prompt: format!("request {id}"),
        max_new,
        session: None,
        deadline_ms,
        tier: None,
    }
}

#[test]
fn slow_consumer_is_bounded_then_evicted_and_its_kv_freed() {
    // A client that submits a long stream and never reads must not grow
    // server memory without bound: tokens park in the per-conn deferred
    // queue up to `deferred_cap`, then the connection is force-closed and
    // its live request cancelled (KV freed). The structural bound is
    // send_buffer + deferred_cap lines per connection — everything past
    // that is backpressure on the pump, never a bigger buffer.
    let cfg = ServerConfig {
        exit_when_idle: true,
        send_buffer: 2,
        deferred_cap: 8,
        ..ServerConfig::default()
    };
    let (addr, server) = serve_mock(cfg, MockBackend::new);

    let (mut stream, reader) = connect(addr);
    // never read a byte: the kernel window fills, the writer thread
    // blocks, the outbox fills, the deferred queue fills, overflow
    send(&mut stream, &submit(0, 1_000_000, None));

    let (stats, backend) = server.join().unwrap();
    drop(reader);
    assert_eq!(stats.submitted, 1);
    assert_eq!(
        stats.shed.slow_consumer_closes, 1,
        "the non-reading connection was evicted exactly once"
    );
    assert!(
        stats.shed.slow_consumer_deferrals >= 1,
        "lines parked in the bounded deferred queue before eviction"
    );
    assert_eq!(stats.closed, 1);
    assert_eq!(
        backend.kv_bytes_in_use(),
        0,
        "evicting the slow consumer cancelled its request and freed KV"
    );
    assert!(!backend.has_work(), "no orphaned work after eviction");
}

#[test]
fn burst_sheds_typed_overloads_while_admitted_requests_meet_deadlines() {
    // Shed policy under a one-packet burst: with one decode slot pinned by
    // a long request and queue_depth 2, exactly two of the five burst
    // submits are admitted and the other three get a typed `overload`
    // naming the limit — while everything admitted still finishes within
    // its deadline. No unbounded queue, no silent drops.
    let cfg = ServerConfig {
        exit_when_idle: true,
        admission: AdmissionConfig {
            queue_depth: 2,
            policy: ShedPolicy::Shed,
            ..AdmissionConfig::default()
        },
        ..ServerConfig::default()
    };
    let (addr, server) = serve_mock(cfg, || {
        let mut b = MockBackend::new();
        b.max_active = 1;
        b
    });

    let (mut stream, mut reader) = connect(addr);
    assert_eq!(read_msg(&mut reader), Some(ServerMsg::Hello { schema: PROTO_SCHEMA }));
    let deadline = Some(120_000.0);
    // pin the only decode slot (long enough to outlast any scheduling
    // jitter while the burst lands), and wait for the admission so the
    // burst below deterministically hits a full queue
    send(&mut stream, &submit(0, 50_000, deadline));
    loop {
        match read_msg(&mut reader).expect("open") {
            ServerMsg::Admitted { id: 0, .. } => break,
            other => panic!("expected admitted first, got {other:?}"),
        }
    }
    let burst: Vec<String> =
        (1..=5).map(|id| submit(id, 4, deadline).to_line()).collect();
    stream
        .write_all((burst.join("\n") + "\n").as_bytes())
        .expect("write burst");

    let mut overloaded = Vec::new();
    let mut finished = std::collections::BTreeMap::new();
    while finished.len() < 3 {
        match read_msg(&mut reader).expect("open until all terminals") {
            ServerMsg::Overload { id: Some(id), limit, max } => {
                assert_eq!(limit, "queue_depth", "overload names the limit");
                assert_eq!(max, 2, "and reports its configured cap");
                overloaded.push(id);
            }
            ServerMsg::Finished { id, e2e_s, .. } => {
                finished.insert(id, e2e_s);
            }
            ServerMsg::Token { .. } | ServerMsg::Admitted { .. } => {}
            other => panic!("unexpected message: {other:?}"),
        }
    }
    assert_eq!(overloaded, vec![3, 4, 5], "burst tail shed in order");
    assert_eq!(
        finished.keys().copied().collect::<Vec<_>>(),
        vec![0, 1, 2],
        "slot-holder plus the two queued submits all finished"
    );
    for (id, e2e_s) in &finished {
        assert!(
            e2e_s * 1000.0 <= 120_000.0,
            "request {id} blew its deadline: {e2e_s}s"
        );
    }

    send(&mut stream, &ClientMsg::Close);
    assert_eq!(read_msg(&mut reader), None);
    let (stats, backend) = server.join().unwrap();
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.shed.submits_shed, 3);
    assert_eq!(backend.kv_bytes_in_use(), 0);
}

#[test]
fn deferred_submits_get_a_retry_hint_and_succeed_on_resubmit() {
    // Defer policy: an over-depth submit is answered with a typed `retry`
    // carrying a load-scaled hint instead of queueing unboundedly, and the
    // same client id resubmitted after the queue drains is admitted.
    let cfg = ServerConfig {
        exit_when_idle: true,
        admission: AdmissionConfig {
            queue_depth: 1,
            policy: ShedPolicy::Defer,
            ..AdmissionConfig::default()
        },
        ..ServerConfig::default()
    };
    let (addr, server) = serve_mock(cfg, || {
        let mut b = MockBackend::new();
        b.max_active = 1;
        b
    });

    let (mut stream, mut reader) = connect(addr);
    assert_eq!(read_msg(&mut reader), Some(ServerMsg::Hello { schema: PROTO_SCHEMA }));
    send(&mut stream, &submit(0, 20_000, None));
    loop {
        match read_msg(&mut reader).expect("open") {
            ServerMsg::Admitted { id: 0, .. } => break,
            other => panic!("expected admitted first, got {other:?}"),
        }
    }
    // id 1 fills the queue; id 2 overflows it and must be deferred
    send(&mut stream, &submit(1, 4, None));
    send(&mut stream, &submit(2, 4, None));
    let mut resubmitted = false;
    let mut finished = Vec::new();
    while finished.len() < 3 {
        match read_msg(&mut reader).expect("open until all terminals") {
            ServerMsg::Retry { id, retry_after_ms } => {
                assert_eq!(id, 2, "the over-depth submit is the one deferred");
                assert!(retry_after_ms > 0.0, "hint tells the client how long");
                assert!(!resubmitted, "deferred exactly once");
            }
            ServerMsg::Admitted { id: 1, .. } if !resubmitted => {
                // queue drained (id 1 left it for the decode slot): retry
                resubmitted = true;
                send(&mut stream, &submit(2, 4, None));
            }
            ServerMsg::Finished { id, .. } => finished.push(id),
            ServerMsg::Token { .. } | ServerMsg::Admitted { .. } => {}
            other => panic!("unexpected message: {other:?}"),
        }
    }
    assert!(resubmitted);
    assert_eq!(finished, vec![0, 1, 2]);

    send(&mut stream, &ClientMsg::Close);
    assert_eq!(read_msg(&mut reader), None);
    let (stats, backend) = server.join().unwrap();
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.shed.submits_deferred, 1);
    assert_eq!(backend.kv_bytes_in_use(), 0);
}

#[test]
fn stats_op_snapshots_before_and_after_a_request() {
    // The wire-level introspection op (proto schema 3): an idle backend
    // answers `{"op":"stats"}` with an all-zero snapshot, and after a
    // request fully drains the follow-up snapshot shows its KV released.
    // `stats` is never terminal, so probing mid-session must not disturb
    // the request lifecycle. CI's loopback smoke runs this by name
    // (`cargo test --test server stats_`).
    let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
    let (addr, server) = serve_mock(cfg, MockBackend::new);

    let (mut stream, mut reader) = connect(addr);
    assert_eq!(
        read_msg(&mut reader),
        Some(ServerMsg::Hello { schema: PROTO_SCHEMA }),
        "hello advertises the stats-capable schema"
    );
    assert_eq!(PROTO_SCHEMA, 3, "stats op landed in schema 3");

    send(&mut stream, &ClientMsg::Stats);
    match read_msg(&mut reader).expect("stats reply") {
        ServerMsg::Stats { stats, net } => {
            assert_eq!(stats.queued_by_tier, [0, 0, 0], "idle: nothing queued");
            assert_eq!(stats.active, 0);
            assert_eq!(stats.workers.len(), 1, "mock backend is one worker");
            assert_eq!(stats.workers[0].kv_bytes_in_use, 0);
            assert_eq!(net.conns_shed, 0, "nothing shed yet");
        }
        other => panic!("expected stats, got {other:?}"),
    }

    send(&mut stream, &submit(0, 3, None));
    loop {
        match read_msg(&mut reader).expect("open until terminal") {
            ServerMsg::Finished { id: 0, .. } => break,
            ServerMsg::Admitted { .. } | ServerMsg::Token { .. } => {}
            other => panic!("unexpected message: {other:?}"),
        }
    }
    send(&mut stream, &ClientMsg::Stats);
    match read_msg(&mut reader).expect("second stats reply") {
        ServerMsg::Stats { stats, .. } => {
            assert_eq!(stats.active, 0, "request drained");
            assert_eq!(
                stats.workers[0].kv_bytes_in_use, 0,
                "finished request released its KV"
            );
            assert!(stats.t > 0.0, "virtual clock advanced through the decode");
        }
        other => panic!("expected stats, got {other:?}"),
    }

    send(&mut stream, &ClientMsg::Close);
    assert_eq!(read_msg(&mut reader), None);
    let (stats, backend) = server.join().unwrap();
    assert_eq!(stats.submitted, 1);
    assert!(!backend.has_work());
}

#[test]
fn single_conn_closed_loop_is_byte_deterministic() {
    // The determinism contract the CI loopback smoke leans on: one
    // connection driven closed-loop against the MockBackend's virtual
    // clock produces a byte-identical conn-span trace and event-signature
    // log on every same-seed run, because the clock freezes while idle and
    // arrival times are therefore a pure function of the protocol
    // exchange. Also writes the log for the cross-run CI diff.
    let seed = pallas_seed();
    let run = || -> String {
        let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
        let (addr, server) = serve_mock(cfg, MockBackend::new);
        let client = ClientConfig {
            addr: addr.to_string(),
            conns: 1,
            requests_per_conn: 5,
            max_new_tokens: 6,
            seed,
            ..ClientConfig::default()
        };
        let stats = run_closed_loop(&client).expect("client run");
        assert_eq!(stats.finished, 5, "closed loop completes every request");
        assert_eq!(stats.tokens, 30);
        let (_, backend) = server.join().unwrap();
        let mut lines = backend.trace.clone();
        lines.extend(backend.event_log.iter().cloned());
        lines.join("\n")
    };
    let a = run();
    let b = run();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed, same serve trace bytes");
    write_ci_log("serve_net_loopback.log", &a);
}

#[test]
fn prefix_second_same_template_request_prefills_less_in_modeled_time() {
    // Loopback smoke for the shared-prefix cache contract: two requests
    // sharing a long template preamble, served back to back — the second
    // admission must prefill strictly fewer prompt tokens (the shared
    // page-aligned chunks are adopted, not recomputed) and its modeled
    // prefill span must shrink accordingly. CI runs this by name
    // (`cargo test --test server prefix_`).
    let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
    let page = 4usize;
    let (addr, server) = serve_mock(cfg, move || {
        let mut b = MockBackend::new();
        b.prefix_page = page;
        b.prefill_s_per_token = 0.001;
        b
    });
    let template = "system: you are a terse assistant; answer from the \
                    context only. context: alpha beta gamma delta epsilon \
                    zeta eta theta iota kappa lambda mu. ";

    let (mut stream, mut reader) = connect(addr);
    assert_eq!(read_msg(&mut reader), Some(ServerMsg::Hello { schema: PROTO_SCHEMA }));
    for (id, tail) in [(0u64, "question: first?"), (1u64, "question: again?")] {
        send(
            &mut stream,
            &ClientMsg::Submit {
                id,
                prompt: format!("{template}{tail}"),
                max_new: 3,
                session: None,
                deadline_ms: None,
                tier: None,
            },
        );
        loop {
            match read_msg(&mut reader).expect("open until terminal") {
                ServerMsg::Finished { id: fid, .. } => {
                    assert_eq!(fid, id);
                    break;
                }
                ServerMsg::Admitted { .. } | ServerMsg::Token { .. } => {}
                other => panic!("unexpected message: {other:?}"),
            }
        }
    }
    send(&mut stream, &ClientMsg::Close);
    assert_eq!(read_msg(&mut reader), None);

    let (stats, backend) = server.join().unwrap();
    assert_eq!(stats.submitted, 2);
    assert_eq!(backend.prefill_log.len(), 2, "one prefill record per admission");
    let (id0, tokens0, span0) = backend.prefill_log[0];
    let (id1, tokens1, span1) = backend.prefill_log[1];
    assert_eq!((id0, id1), (0, 1));
    assert!(
        tokens1 + 2 * page <= tokens0,
        "second request prefilled {tokens1} tokens vs {tokens0}: the shared \
         template must skip at least two full pages"
    );
    assert!(
        span1 < span0,
        "modeled prefill span must shrink with the skipped pages \
         ({span1} vs {span0})"
    );
    assert_eq!(backend.kv_bytes_in_use(), 0);
}

#[test]
fn disconnect_frees_real_engine_kv_mid_flight() {
    // The one real-engine scenario: a TCP client vanishes mid-decode and
    // the front door's cancel path must release the request's KV pages in
    // the actual page pool (`Frontend::kv_bytes_in_use` back to zero), not
    // just the mock's counter. Skips without artifacts, like the
    // integration suite.
    use tinyserve::config::ServingConfig;
    use tinyserve::coordinator::{
        DispatchKind, Frontend, ServeOptions, TimeModel, WorkerPool,
    };
    use tinyserve::plugins::Pipeline;
    use tinyserve::runtime::Manifest;
    use tinyserve::sparsity::PolicyKind;

    let m = match Manifest::load(&tinyserve::artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            return;
        }
    };
    let cfg = ServingConfig {
        model: "tiny-trained".to_string(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        ..Default::default()
    };
    let pool = WorkerPool::build(&m, &cfg, 2, DispatchKind::LeastLoaded).expect("pool");
    let opts = ServeOptions {
        time_model: TimeModel::Modeled,
        seed: pallas_seed(),
        ..Default::default()
    };
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);

    let server = Server::bind(ServerConfig {
        exit_when_idle: true,
        ..ServerConfig::default()
    })
    .expect("bind loopback");
    let addr = server.local_addr().expect("bound addr");
    let client = std::thread::spawn(move || {
        let (mut stream, mut reader) = connect(addr);
        assert_eq!(
            read_msg(&mut reader),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        send(&mut stream, &submit(0, 512, None));
        loop {
            match read_msg(&mut reader).expect("open") {
                ServerMsg::Token { .. } => break, // decoding for real: vanish
                _ => continue,
            }
        }
    });
    let stats = server.run(&mut fe).expect("server run");
    client.join().unwrap();
    assert_eq!(stats.submitted, 1);
    assert_eq!(stats.closed, 1);
    assert_eq!(
        fe.kv_bytes_in_use(),
        0,
        "disconnect released the engine's KV pages mid-flight"
    );
    assert!(!fe.has_work(), "no orphaned work after disconnect");
}
