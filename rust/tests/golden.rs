//! Golden-vector tests: the Rust reimplementations (page scoring, top-k,
//! metadata, f16, ALiBi slopes) replay fixed-seed vectors produced by the
//! python oracle (`python -m compile.aot` writes artifacts/golden.json),
//! and the multi-worker serve snapshot pins admission counters under
//! deterministic modeled time.
//!
//! Skipped (with a loud message) when artifacts/golden.json is missing —
//! run `make artifacts` first.

use tinyserve::sparsity::{score_page, top_k_indices};
use tinyserve::util::f16;
use tinyserve::util::json::Json;

fn load_golden() -> Option<Json> {
    let path = tinyserve::artifacts_dir().join("golden.json");
    let text = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&text).expect("golden.json parses"))
}

macro_rules! require_golden {
    () => {
        match load_golden() {
            Some(g) => g,
            None => {
                eprintln!("SKIP: artifacts/golden.json missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn page_scores_match_python_oracle() {
    let g = require_golden!();
    let ps = g.get("page_score").unwrap();
    let q = ps.get("q").unwrap().as_f32_flat();
    let meta = ps.get("meta").unwrap().as_f32_flat();
    let want = ps.get("scores").unwrap().as_f32_flat();
    let (b, p) = (2usize, 16usize);
    let d = q.len() / b;
    for bi in 0..b {
        let qrow = &q[bi * d..(bi + 1) * d];
        for pi in 0..p {
            // python layout [B, P, 2, D]: min plane then max plane
            let off = (bi * p + pi) * 2 * d;
            let meta_slice = &meta[off..off + 2 * d];
            let got = score_page(qrow, meta_slice);
            let exp = want[bi * p + pi];
            assert!(
                (got - exp).abs() <= 1e-3 * exp.abs().max(1.0),
                "b={bi} p={pi}: {got} vs {exp}"
            );
        }
    }
}

#[test]
fn topk_matches_python_oracle() {
    let g = require_golden!();
    let ps = g.get("page_score").unwrap();
    let scores = ps.get("scores").unwrap().as_f32_flat();
    let want: Vec<i64> = ps.get("topk").unwrap().as_i64_flat();
    let k = ps.get("k").unwrap().as_usize().unwrap();
    let p = 16usize;
    for bi in 0..2 {
        let row = &scores[bi * p..(bi + 1) * p];
        let got = top_k_indices(row, k);
        let exp: Vec<usize> =
            want[bi * k..(bi + 1) * k].iter().map(|&x| x as usize).collect();
        assert_eq!(got, exp, "row {bi}");
    }
}

#[test]
fn page_meta_matches_python_oracle() {
    let g = require_golden!();
    let pm = g.get("page_meta").unwrap();
    let keys = pm.get("keys").unwrap().as_f32_flat();
    let want = pm.get("meta").unwrap().as_f32_flat();
    let s = pm.get("page_size").unwrap().as_usize().unwrap();
    let d = 8usize;
    let l = keys.len() / d; // 32 tokens
    let n_pages = l / s;
    // rebuild metadata through the PagePool (the production path)
    use tinyserve::config::KvDtype;
    use tinyserve::kvcache::{PagePool, SeqCache};
    let mut pool = PagePool::new(1, d, s, KvDtype::F32);
    let mut seq = SeqCache::new();
    for t in 0..l {
        let (page, slot) = seq.slot_for_next(&mut pool);
        let row = &keys[t * d..(t + 1) * d];
        pool.write_token(page, slot, 0, row, row);
        seq.commit_token();
    }
    for p in 0..n_pages {
        let got = pool.meta(seq.pages[p].id, 0);
        // python layout [P, 2, D]
        let exp = &want[p * 2 * d..(p + 1) * 2 * d];
        for i in 0..2 * d {
            assert!(
                (got[i] - exp[i]).abs() < 1e-6,
                "page {p} [{i}]: {} vs {}",
                got[i],
                exp[i]
            );
        }
    }
}

#[test]
fn f16_bits_match_numpy() {
    let g = require_golden!();
    let f = g.get("f16").unwrap();
    let vals = f.get("f32").unwrap().as_f32_flat();
    let bits = f.get("bits").unwrap().as_i64_flat();
    let back = f.get("back").unwrap().as_f32_flat();
    for i in 0..vals.len() {
        let got = f16::f32_to_f16_bits(vals[i]);
        assert_eq!(got as i64, bits[i], "encode {} (idx {i})", vals[i]);
        let dec = f16::f16_bits_to_f32(got);
        assert!(
            (dec - back[i]).abs() < 1e-9 || (dec.is_infinite() && back[i].is_infinite()),
            "decode {}: {} vs {}",
            vals[i],
            dec,
            back[i]
        );
    }
}

#[test]
fn alibi_slopes_match_python() {
    let g = require_golden!();
    let a = g.get("alibi").unwrap();
    for h in [2usize, 4, 8, 16] {
        let want = a.get(&h.to_string()).unwrap().as_f32_flat();
        for (i, &w) in want.iter().enumerate() {
            let got = (2.0f32).powf(-8.0 * (i as f32 + 1.0) / h as f32);
            assert!((got - w).abs() < 1e-6, "H={h} i={i}");
        }
    }
}

/// Golden serve snapshot: a `--workers 2 --arrival poisson` run under
/// deterministic modeled time, reduced to counters only (no wall timings).
/// The snapshot pins admission behaviour so dispatch-policy refactors
/// cannot silently change it: on first run (no snapshot committed yet) the
/// test writes `rust/tests/snapshots/serve_workers2.golden` and passes;
/// once that file is checked in, any drift fails here. Either way the
/// counters must be identical across two in-process runs.
#[test]
fn workers2_poisson_serve_counters_golden() {
    use tinyserve::config::ServingConfig;
    use tinyserve::coordinator::{
        DispatchKind, Frontend, ServeOptions, TimeModel, WorkerPool,
    };
    use tinyserve::plugins::Pipeline;
    use tinyserve::runtime::Manifest;
    use tinyserve::sparsity::PolicyKind;
    use tinyserve::workload::{
        ArrivalProcess, LoadShape, OpenLoopConfig, OpenLoopGen,
    };

    let m = match Manifest::load(&tinyserve::artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            return;
        }
    };
    let run = || -> String {
        let cfg = ServingConfig {
            model: "tiny-trained".to_string(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 4,
            ..Default::default()
        };
        let pool = WorkerPool::build(&m, &cfg, 2, DispatchKind::LeastLoaded)
            .expect("pool");
        let opts =
            ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
        let mut plugins = Pipeline::new();
        let mut fe =
            Frontend::builder().options(opts).build_pool(pool, &mut plugins);
        fe.set_source(Box::new(OpenLoopGen::new(OpenLoopConfig {
            n_requests: 16,
            rate_rps: 30.0,
            process: ArrivalProcess::Poisson,
            shape: LoadShape::Steady,
            prompt_chars: (100, 300),
            new_tokens: (4, 8),
            session_reuse_prob: 0.25,
            n_sessions: 3,
            deadline_ms: None,
            deadline_every: 1,
            tier_interactive: 0.0,
            tier_background: 0.0,
            seed: 42,
        })));
        while fe.has_work() {
            fe.step().expect("step");
        }
        let r = fe.into_report();
        // structural pins that hold with or without a committed snapshot
        assert_eq!(r.metrics.total_requests, 16, "all open-loop requests complete");
        assert_eq!(r.worker_stats.len(), 2);
        let finished: u64 = r.worker_stats.iter().map(|w| w.finished).sum();
        let tokens: u64 = r.worker_stats.iter().map(|w| w.new_tokens).sum();
        assert_eq!(finished, r.metrics.total_requests);
        assert_eq!(tokens, r.metrics.total_new_tokens, "per-worker tokens sum up");
        let per_worker: Vec<String> = r
            .worker_stats
            .iter()
            .map(|w| format!("({},{},{})", w.admitted, w.finished, w.new_tokens))
            .collect();
        format!(
            "requests={} tokens={} admitted={} deferred={} cancelled={} \
             expired={} workers=[{}]",
            r.metrics.total_requests,
            r.metrics.total_new_tokens,
            r.batcher_stats.admitted,
            r.batcher_stats.deferred,
            r.metrics.total_cancelled,
            r.metrics.total_expired,
            per_worker.join(" ")
        )
    };
    let got = run();
    assert_eq!(got, run(), "modeled-time serve counters must be deterministic");

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/snapshots/serve_workers2.golden");
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            got,
            want.trim(),
            "serve counters drifted from the committed snapshot {}; if the \
             change is intentional, delete the file and rerun to regenerate",
            path.display()
        ),
        Err(_) => {
            let _ = std::fs::create_dir_all(path.parent().unwrap());
            std::fs::write(&path, format!("{got}\n")).expect("seed snapshot");
            eprintln!("seeded golden snapshot at {}", path.display());
        }
    }
}

#[test]
fn bounding_box_score_upper_bounds_oracle_dot() {
    // cross-check the invariant Eq. 2 relies on, on golden data
    let g = require_golden!();
    let pm = g.get("page_meta").unwrap();
    let keys = pm.get("keys").unwrap().as_f32_flat();
    let meta = pm.get("meta").unwrap().as_f32_flat();
    let s = pm.get("page_size").unwrap().as_usize().unwrap();
    let d = 8usize;
    let q: Vec<f32> = (0..d).map(|i| (i as f32 - 3.5) * 0.37).collect();
    for p in 0..keys.len() / d / s {
        let bound = score_page(&q, &meta[p * 2 * d..(p + 1) * 2 * d]);
        for t in 0..s {
            let row = &keys[(p * s + t) * d..(p * s + t + 1) * d];
            let dot: f32 = q.iter().zip(row).map(|(a, b)| a * b).sum();
            assert!(dot <= bound + 1e-4);
        }
    }
}
