//! Integration tests over the real runtime + artifacts. These exercise the
//! full stack (manifest -> PJRT compile -> engine decode/prefill -> serving
//! loop). They require `make artifacts` to have run; otherwise they skip.

// `serve_trace` is deprecated in favour of the Frontend lifecycle API but
// stays under test: the shim must keep producing seed-identical reports.
#![allow(deprecated)]

use tinyserve::config::{KvDtype, ServingConfig};
use tinyserve::coordinator::{
    event_log_header, serve_trace, BatcherConfig, DispatchKind, ExecutorKind,
    Frontend, Lifecycle, ServeEvent, ServeOptions, ServeReport, TimeModel,
    WorkerPool,
};
use tinyserve::trace::{SharedVecSink, Tracer};
use tinyserve::engine::{Engine, Sampling};
use tinyserve::kvcache::EvictionPolicyKind;
use tinyserve::metrics::StepMetrics;
use tinyserve::plugins::{EntropyEarlyExit, Pipeline, RepetitionGuard};
use tinyserve::runtime::Manifest;
use tinyserve::sparsity::PolicyKind;
use tinyserve::util::rng::Rng;
use tinyserve::workload::{
    generate_trace, tasks, ArrivalProcess, LoadShape, OpenLoopConfig, OpenLoopGen,
    SloTier, TraceConfig,
};

const MODEL: &str = "tiny-trained";

fn manifest() -> Option<Manifest> {
    let dir = tinyserve::artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts missing (run `make artifacts`)");
            None
        }
    }
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn engine(m: &Manifest, policy: PolicyKind, budget: usize, batch: usize) -> Engine {
    let cfg = ServingConfig {
        model: MODEL.to_string(),
        policy,
        budget,
        max_batch: batch,
        ..Default::default()
    };
    Engine::from_manifest(m, cfg).expect("engine")
}

#[test]
fn decode_is_deterministic() {
    let m = require!(manifest());
    let run = || -> Vec<i32> {
        let mut e = engine(&m, PolicyKind::TinyServe, 256, 1);
        let mut rng = Rng::new(5);
        let mut seq = e.new_sequence();
        seq.tokens = tasks::encode_prompt("the river and the stone. ");
        seq.max_new_tokens = 8;
        let mut sm = StepMetrics::default();
        e.prefill(&mut seq, &mut sm).unwrap();
        while !seq.finished {
            let mut sm = StepMetrics::default();
            let mut b = [&mut seq];
            e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut sm).unwrap();
        }
        let out = seq.generated_tokens().to_vec();
        e.release(&mut seq);
        out
    };
    assert_eq!(run(), run());
}

#[test]
fn prefill_artifact_matches_stepwise_decode_path() {
    // The chunked prefill artifact and the token-by-token absorb path must
    // produce the same cache state, hence identical continuations.
    let m = require!(manifest());
    let prompt = "alpha holds q7xk2. the river and the stone and the light. \
                  Recall what alpha holds: ";
    let gen_with = |artifact: bool| -> Vec<i32> {
        let mut e = engine(&m, PolicyKind::FullCache, 4096, 1);
        let mut rng = Rng::new(5);
        let mut seq = e.new_sequence();
        seq.tokens = tasks::encode_prompt(prompt);
        seq.max_new_tokens = 6;
        let mut sm = StepMetrics::default();
        if artifact {
            e.prefill(&mut seq, &mut sm).unwrap();
        } else {
            e.prefill_stepwise(&mut seq, &mut sm).unwrap();
        }
        while !seq.finished {
            let mut sm = StepMetrics::default();
            let mut b = [&mut seq];
            e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut sm).unwrap();
        }
        let out = seq.generated_tokens().to_vec();
        e.release(&mut seq);
        out
    };
    let a = gen_with(true);
    let b = gen_with(false);
    assert_eq!(a, b, "artifact vs stepwise prefill diverged");
}

#[test]
fn fullcache_budget_equals_policy_budget_when_short() {
    // With a short prompt (< budget), TinyServe selects everything, so it
    // must produce exactly FullCache's output.
    let m = require!(manifest());
    let prompt = "the time stone river. ";
    let gen_with = |policy: PolicyKind| -> Vec<i32> {
        let mut e = engine(&m, policy, 256, 1);
        let mut rng = Rng::new(9);
        let mut seq = e.new_sequence_with_policy(policy);
        seq.tokens = tasks::encode_prompt(prompt);
        seq.max_new_tokens = 8;
        let mut sm = StepMetrics::default();
        e.prefill(&mut seq, &mut sm).unwrap();
        while !seq.finished {
            let mut sm = StepMetrics::default();
            let mut b = [&mut seq];
            e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut sm).unwrap();
        }
        let out = seq.generated_tokens().to_vec();
        e.release(&mut seq);
        out
    };
    assert_eq!(gen_with(PolicyKind::TinyServe), gen_with(PolicyKind::FullCache));
}

#[test]
fn batched_decode_matches_single() {
    // Batch-of-2 rows must generate the same tokens as two single runs.
    let m = require!(manifest());
    let prompts = ["the river. ", "winter morning bridge. "];
    let single: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let mut e = engine(&m, PolicyKind::TinyServe, 256, 1);
            let mut rng = Rng::new(1);
            let mut seq = e.new_sequence();
            seq.tokens = tasks::encode_prompt(p);
            seq.max_new_tokens = 5;
            let mut sm = StepMetrics::default();
            e.prefill(&mut seq, &mut sm).unwrap();
            while !seq.finished {
                let mut sm = StepMetrics::default();
                let mut b = [&mut seq];
                e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut sm).unwrap();
            }
            let out = seq.generated_tokens().to_vec();
            e.release(&mut seq);
            out
        })
        .collect();

    let mut e = engine(&m, PolicyKind::TinyServe, 256, 4);
    let mut rng = Rng::new(1);
    let mut seqs: Vec<_> = prompts
        .iter()
        .map(|p| {
            let mut s = e.new_sequence();
            s.tokens = tasks::encode_prompt(p);
            s.max_new_tokens = 5;
            let mut sm = StepMetrics::default();
            e.prefill(&mut s, &mut sm).unwrap();
            s
        })
        .collect();
    for _ in 0..5 {
        let mut sm = StepMetrics::default();
        let mut refs: Vec<&mut _> = seqs.iter_mut().filter(|s| !s.finished).collect();
        if refs.is_empty() {
            break;
        }
        e.decode_step(&mut refs, Sampling::Greedy, &mut rng, &mut sm).unwrap();
    }
    for (i, s) in seqs.iter_mut().enumerate() {
        assert_eq!(s.generated_tokens(), &single[i][..], "row {i}");
    }
}

#[test]
fn kv_dtypes_stay_close_to_f32() {
    let m = require!(manifest());
    let prompt = "alpha holds q7xk2. Recall what alpha holds: ";
    let gen_with = |dt: KvDtype| -> String {
        let cfg = ServingConfig {
            model: MODEL.to_string(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 1,
            kv_dtype: dt,
            ..Default::default()
        };
        let mut e = Engine::from_manifest(&m, cfg).unwrap();
        let mut rng = Rng::new(2);
        let mut seq = e.new_sequence();
        seq.tokens = tasks::encode_prompt(prompt);
        seq.max_new_tokens = 6;
        let mut sm = StepMetrics::default();
        e.prefill_stepwise(&mut seq, &mut sm).unwrap();
        while !seq.finished {
            let mut sm = StepMetrics::default();
            let mut b = [&mut seq];
            e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut sm).unwrap();
        }
        let out = tasks::decode_ids(seq.generated_tokens());
        e.release(&mut seq);
        out
    };
    let f32_out = gen_with(KvDtype::F32);
    let f16_out = gen_with(KvDtype::F16);
    // f16 KV should rarely change greedy tokens on a short prompt
    assert_eq!(f32_out, f16_out, "f16 KV diverged from f32");
}

#[test]
fn policies_reduce_gather_bytes() {
    let m = require!(manifest());
    let mut e = engine(&m, PolicyKind::TinyServe, 256, 1);
    let mut rng = Rng::new(11);
    // long synthetic context so selection actually prunes
    let mut seq = e.new_sequence();
    e.synthetic_fill(&mut seq, 2047, &mut rng);
    seq.tokens.push(1);
    seq.max_new_tokens = 4;
    let mut m1 = StepMetrics::default();
    {
        let mut b = [&mut seq];
        e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m1).unwrap();
    }
    // full-cache comparator at matching budget
    let mut e2 = engine(&m, PolicyKind::FullCache, 4096, 1);
    let mut seq2 = e2.new_sequence_with_policy(PolicyKind::FullCache);
    e2.synthetic_fill(&mut seq2, 2047, &mut rng);
    seq2.tokens.push(1);
    seq2.max_new_tokens = 4;
    let mut m2 = StepMetrics::default();
    {
        let mut b = [&mut seq2];
        e2.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut m2).unwrap();
    }
    assert!(
        m1.gather_bytes * 4 < m2.gather_bytes,
        "sparse {} vs full {}",
        m1.gather_bytes,
        m2.gather_bytes
    );
    e.release(&mut seq);
    e2.release(&mut seq2);
}

#[test]
fn fused_engine_matches_orchestrated_path() {
    // While the context fits within the fused variant's K pages, its
    // in-graph selection keeps everything — so it must generate exactly
    // what the orchestrated FullCache path generates.
    let m = require!(manifest());
    let mut fused = match tinyserve::engine::fused::FusedEngine::from_manifest(&m, MODEL)
    {
        Ok(f) => f,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let prompt = tasks::encode_prompt("alpha holds q7xk2. Recall what alpha holds: ");
    let fused_out = fused.generate(&prompt, 5).expect("fused generate");

    let mut e = engine(&m, PolicyKind::FullCache, 4096, 1);
    let mut rng = Rng::new(1);
    let mut seq = e.new_sequence_with_policy(PolicyKind::FullCache);
    seq.tokens = prompt.clone();
    seq.max_new_tokens = 5;
    let mut sm = StepMetrics::default();
    e.prefill_stepwise(&mut seq, &mut sm).unwrap();
    while !seq.finished {
        let mut sm = StepMetrics::default();
        let mut b = [&mut seq];
        e.decode_step(&mut b, Sampling::Greedy, &mut rng, &mut sm).unwrap();
    }
    let mut orch: Vec<i32> = seq.generated_tokens().to_vec();
    if orch.last() == Some(&tinyserve::engine::EOS) {
        orch.pop();
    }
    e.release(&mut seq);
    assert_eq!(fused_out, orch, "fused vs orchestrated generation diverged");
}

#[test]
fn serve_trace_end_to_end() {
    let m = require!(manifest());
    let cfg = ServingConfig {
        model: MODEL.to_string(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        ..Default::default()
    };
    let mut e = Engine::from_manifest(&m, cfg).unwrap();
    let trace = generate_trace(&TraceConfig {
        n_requests: 6,
        prompt_chars: (80, 200),
        new_tokens: (4, 8),
        session_reuse_prob: 0.5,
        n_sessions: 2,
        ..Default::default()
    });
    let mut plugins = Pipeline::new();
    let r = serve_trace(&mut e, &trace, &ServeOptions::default(), &mut plugins)
        .expect("serve");
    assert_eq!(r.metrics.total_requests, 6);
    assert!(r.metrics.total_new_tokens >= 6);
    assert!(r.wall_s > 0.0);
    assert!(r.busy_frac > 0.0 && r.busy_frac <= 1.0);
    // sessions were exercised
    assert!(r.session_stats.stores > 0);
    // all pages returned to the pool
    assert_eq!(e.pool.pages_in_use(), 0, "page leak after serving");
}

#[test]
fn budgeted_store_enforces_kv_budget_in_serving() {
    // Acceptance: with kv_budget_mb at 50% of the unbounded peak, the trace
    // completes with bytes_in_use <= budget after every decode step, the
    // query-aware policy stays within 1% exact-match of the unbounded run,
    // and beats LRU on residency hit rate.
    let m = require!(manifest());
    let trace = generate_trace(&TraceConfig {
        n_requests: 16,
        prompt_chars: (250, 600),
        new_tokens: (4, 10),
        session_reuse_prob: 0.3,
        n_sessions: 3,
        ..Default::default()
    });
    let run = |kv_budget_mb: Option<f64>, eviction: EvictionPolicyKind| {
        let cfg = ServingConfig {
            model: MODEL.to_string(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 4,
            kv_budget_mb,
            eviction,
            ..Default::default()
        };
        let mut e = Engine::from_manifest(&m, cfg).expect("engine");
        let mut plugins = Pipeline::new();
        let r = serve_trace(&mut e, &trace, &ServeOptions::default(), &mut plugins)
            .expect("serve");
        let peak = e.pool.bytes_peak();
        assert_eq!(e.pool.pages_in_use(), 0, "page leak after budgeted serving");
        (r, peak)
    };

    let (r0, unbounded_peak) = run(None, EvictionPolicyKind::QueryAware);
    assert_eq!(r0.metrics.total_requests, 16);
    assert!(unbounded_peak > 0);

    let budget_mb = unbounded_peak as f64 * 0.5 / 1e6;
    let (r1, _) = run(Some(budget_mb), EvictionPolicyKind::QueryAware);
    assert_eq!(r1.metrics.total_requests, 16, "budgeted run must complete");
    assert_eq!(
        r1.metrics.budget_violations, 0,
        "bytes_in_use exceeded the budget after a decode step"
    );
    assert!(
        (r1.metrics.kv_bytes_peak as f64) <= budget_mb * 1e6,
        "post-step peak {} above budget {}",
        r1.metrics.kv_bytes_peak,
        budget_mb * 1e6
    );
    assert!(
        r1.metrics.total_demotions > 0,
        "a 50% budget must force cold-tier demotions"
    );
    if r0.accuracy.is_finite() && r1.accuracy.is_finite() {
        assert!(
            (r0.accuracy - r1.accuracy).abs() <= 0.0101,
            "accuracy drifted: unbounded {} vs budgeted {}",
            r0.accuracy,
            r1.accuracy
        );
    }

    let (r2, _) = run(Some(budget_mb), EvictionPolicyKind::Lru);
    assert!(
        r1.metrics.residency_hit_rate.mean()
            >= r2.metrics.residency_hit_rate.mean() - 1e-9,
        "query-aware {} must match or beat LRU {}",
        r1.metrics.residency_hit_rate.mean(),
        r2.metrics.residency_hit_rate.mean()
    );
}

#[test]
fn spill_tier_is_token_transparent_under_int8_budget() {
    // Acceptance for the disk spill tier: int8 KV pools make q8 demotion
    // value-neutral (`demote_page_in_place` is the identity there) and
    // the spill codec copies raw q8 rows verbatim, so a budgeted run that
    // cascades pages all the way to disk must decode token-identically to
    // the unbounded run — while `bytes_in_use <= budget` holds after
    // every step and real spill-out/fault traffic flows. int8 is the
    // regime where the disk tier is the ONLY relief: `page_bytes_cold ==
    // page_bytes`, so q8 demotion frees nothing and a sub-peak budget is
    // unreachable without fully evicting pages from RAM.
    let m = require!(manifest());
    let trace = generate_trace(&TraceConfig {
        n_requests: 16,
        prompt_chars: (250, 600),
        new_tokens: (4, 10),
        // sessions would couple the two runs through snapshot shedding
        // (a shed session re-prefills with full-precision staging, which
        // is a pre-existing resume-vs-prefill difference, not a spill one)
        session_reuse_prob: 0.0,
        ..Default::default()
    });
    let run = |kv_mb: Option<f64>, spill_mb: Option<f64>| {
        let cfg = ServingConfig {
            model: MODEL.to_string(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 4,
            kv_dtype: KvDtype::Int8,
            kv_budget_mb: kv_mb,
            spill_budget_mb: spill_mb,
            readahead_pages: if spill_mb.is_some() { 2 } else { 0 },
            eviction: EvictionPolicyKind::Lru,
            ..Default::default()
        };
        let mut e = Engine::from_manifest(&m, cfg).expect("engine");
        let mut plugins = Pipeline::new();
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            ..Default::default()
        };
        let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
        for req in &trace {
            fe.submit(req.clone());
        }
        let events = pump_all(&mut fe);
        let mut tokens: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
        for ev in &events {
            if let ServeEvent::Token { id, tok, .. } = ev {
                tokens.entry(*id).or_default().push(*tok);
            }
        }
        let log = event_log(&events);
        let r = fe.into_report();
        assert_eq!(e.pool.pages_in_use(), 0, "page leak after spill serving");
        (tokens, r, e.pool.bytes_peak(), log)
    };

    let (tok0, r0, peak, _) = run(None, None);
    assert_eq!(r0.metrics.total_requests, 16);
    assert!(peak > 0);

    let budget_mb = peak as f64 * 0.5 / 1e6;
    // ample disk headroom (spill slots carry bbox metadata on top of the
    // q8 payload): admission must never defer, so the budgeted run admits
    // on the unbounded run's exact schedule
    let spill_mb = peak as f64 * 2.0 / 1e6 + 1.0;
    let (tok1, r1, _, log1) = run(Some(budget_mb), Some(spill_mb));
    assert_eq!(r1.metrics.total_requests, 16, "spill-backed run completes");
    assert_eq!(
        r1.metrics.budget_violations, 0,
        "bytes_in_use exceeded the budget after a decode step"
    );
    assert!(
        (r1.metrics.kv_bytes_peak as f64) <= budget_mb * 1e6,
        "post-step peak {} above budget {}",
        r1.metrics.kv_bytes_peak,
        budget_mb * 1e6
    );
    assert!(
        r1.metrics.total_spill_out_bytes > 0,
        "int8 pressure at a 50% budget must spill pages to disk"
    );
    assert!(
        r1.metrics.total_disk_faults > 0,
        "selection must fault spilled pages back"
    );
    assert!(r1.metrics.disk_pages_peak > 0);
    assert_eq!(
        tok0, tok1,
        "disk spill must be token-transparent (int8 demote is the \
         identity and the raw-q8 codec is bit-exact)"
    );

    // determinism battery: the spill-enabled modeled-time event stream
    // (timestamps include hwmodel-priced disk transfers) must replay
    // bit-exactly; the CI double-run gate diffs this log across processes
    let (_, _, _, log2) = run(Some(budget_mb), Some(spill_mb));
    assert_eq!(log1, log2, "same seed, same spill-enabled event stream");
    let header = event_log_header(42, 1, 1, "tinyserve", Some(budget_mb));
    write_ci_log("spill_serve_events.log", &format!("{header}\n{log1}"));
}

#[test]
fn prefix_sharing_is_token_transparent_and_saves_prefill() {
    // Acceptance for the shared prefix cache: a multi-tenant template
    // workload served with the prefix cache on must decode
    // token-identically to the sharing-off run (adopted pages are
    // bit-identical to the prefill they replace), while skipping a real
    // fraction of prefill tokens and shrinking modeled TTFT. Also feeds
    // the determinism battery: the sharing-on modeled-time event stream
    // must replay bit-exactly (CI double-runs and cross-diffs the log).
    let m = require!(manifest());
    let trace = OpenLoopGen::new(OpenLoopConfig {
        n_requests: 16,
        rate_rps: 40.0,
        prompt_chars: (250, 600),
        new_tokens: (4, 10),
        // sessions off so only the prefix index can carry cross-request
        // reuse (template requests arrive with `session = None`)
        session_reuse_prob: 0.0,
        n_sessions: 0,
        n_tenants: 2,
        templates_per_tenant: 2,
        template_prob: 0.7,
        seed: 42,
        ..Default::default()
    })
    .collect_all();
    let run = |prefix_mb: Option<f64>| {
        let cfg = ServingConfig {
            model: MODEL.to_string(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 4,
            prefix_cache_mb: prefix_mb,
            prefix_min_pages: if prefix_mb.is_some() { 1 } else { 0 },
            ..Default::default()
        };
        let mut e = Engine::from_manifest(&m, cfg).expect("engine");
        let mut plugins = Pipeline::new();
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            ..Default::default()
        };
        let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
        for req in &trace {
            fe.submit(req.clone());
        }
        let events = pump_all(&mut fe);
        let mut tokens: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
        for ev in &events {
            if let ServeEvent::Token { id, tok, .. } = ev {
                tokens.entry(*id).or_default().push(*tok);
            }
        }
        let log = event_log(&events);
        let r = fe.into_report();
        assert_eq!(e.pool.pages_in_use(), 0, "page leak after prefix serving");
        (tokens, r, log)
    };

    let (tok0, r0, _) = run(None);
    assert_eq!(r0.metrics.total_requests, 16);
    assert_eq!(
        r0.prefix_stats.lookups, 0,
        "sharing off: the index is never consulted"
    );

    let (tok1, r1, log1) = run(Some(16.0));
    assert_eq!(r1.metrics.total_requests, 16, "sharing-on run completes");
    assert!(
        r1.prefix_stats.hits > 0,
        "template workload must hit the prefix index"
    );
    assert!(
        r1.prefix_stats.tokens_skipped > 0,
        "adoption must skip real prefill tokens"
    );
    assert_eq!(
        r1.metrics.total_prefix_tokens_skipped,
        r1.prefix_stats.tokens_skipped,
        "step counters and index stats agree"
    );
    assert_eq!(
        tok0, tok1,
        "prefix sharing must be token-transparent (adopted pages are \
         bit-identical to the prefill they replace)"
    );
    assert!(
        r1.metrics.request_ttft.p50() <= r0.metrics.request_ttft.p50() + 1e-9,
        "skipped prefill is priced out of modeled time: TTFT P50 {} vs {}",
        r1.metrics.request_ttft.p50(),
        r0.metrics.request_ttft.p50()
    );

    let (_, _, log2) = run(Some(16.0));
    assert_eq!(log1, log2, "same seed, same sharing-on event stream");
    let header = event_log_header(42, 1, 1, "tinyserve", None);
    write_ci_log("serve_prefix_events.log", &format!("{header}\n{log1}"));
}

fn lifecycle_req(
    id: u64,
    arrival_s: f64,
    prompt: &str,
    max_new: usize,
) -> tinyserve::workload::Request {
    tinyserve::workload::Request {
        id,
        arrival_s,
        prompt: tasks::encode_prompt(prompt),
        max_new_tokens: max_new,
        session: None,
        task: None,
        answer: None,
        deadline_ms: None,
        tier: tinyserve::workload::SloTier::default(),
    }
}

#[test]
fn frontend_cancel_before_admission() {
    let m = require!(manifest());
    let mut e = engine(&m, PolicyKind::TinyServe, 256, 2);
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder()
        .options(ServeOptions::default())
        .build(&mut e, &mut plugins);
    let h0 = fe.submit(lifecycle_req(0, 0.0, "the river and the stone. ", 4));
    let h1 = fe.submit(lifecycle_req(1, 0.0, "winter morning bridge. ", 4));
    assert_eq!(fe.state_of(h1.id), Some(Lifecycle::Pending));
    assert!(fe.cancel(h1.id), "cancellable before admission");
    assert!(!fe.cancel(h1.id), "terminal state rejects a second cancel");
    assert!(!fe.cancel(99), "unknown id");
    let events = fe.drain().expect("drain");
    let cancelled: Vec<u64> = events
        .iter()
        .filter(|ev| matches!(ev, ServeEvent::Cancelled { .. }))
        .map(|ev| ev.id())
        .collect();
    assert_eq!(cancelled, vec![1], "exactly one Cancelled event");
    assert!(
        !events.iter().any(|ev| matches!(ev, ServeEvent::Token { id: 1, .. })),
        "cancelled-before-admission request must never stream"
    );
    assert_eq!(fe.state_of(h0.id), Some(Lifecycle::Finished));
    assert_eq!(fe.state_of(h1.id), Some(Lifecycle::Cancelled));
    let r = fe.into_report();
    assert_eq!(r.metrics.total_requests, 1);
    assert_eq!(r.metrics.total_cancelled, 1);
    assert_eq!(e.pool.pages_in_use(), 0, "no pages leaked");
}

#[test]
fn frontend_cancel_mid_decode_frees_pages() {
    let m = require!(manifest());
    let run = |kv_budget_mb: Option<f64>| -> usize {
        let cfg = ServingConfig {
            model: MODEL.to_string(),
            policy: PolicyKind::TinyServe,
            budget: 256,
            max_batch: 2,
            kv_budget_mb,
            ..Default::default()
        };
        let mut e = Engine::from_manifest(&m, cfg).expect("engine");
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder()
            .options(ServeOptions::default())
            .build(&mut e, &mut plugins);
        let prompt = "the river and the stone and the light. ".repeat(6);
        fe.submit(lifecycle_req(7, 0.0, &prompt, 32));
        let mut cancelled = false;
        while fe.has_work() {
            for ev in fe.step().expect("step") {
                if matches!(ev, ServeEvent::Token { .. }) && !cancelled {
                    // mid-stream: the request has decoded at least one
                    // token and still holds all of its KV pages
                    let before = fe.engine().store.bytes_in_use(&fe.engine().pool);
                    assert!(fe.engine().pool.pages_in_use() > 0);
                    assert!(fe.cancel(7), "cancellable mid-decode");
                    let after = fe.engine().store.bytes_in_use(&fe.engine().pool);
                    assert!(
                        after < before,
                        "bytes_in_use must drop at the cancel point \
                         ({after} !< {before}, budget {kv_budget_mb:?})"
                    );
                    assert_eq!(
                        fe.engine().pool.pages_in_use(),
                        0,
                        "sole request: every page returns to the pool"
                    );
                    cancelled = true;
                }
            }
        }
        assert!(cancelled, "request streamed before cancellation");
        assert_eq!(fe.state_of(7), Some(Lifecycle::Cancelled));
        let r = fe.into_report();
        assert_eq!(r.metrics.total_cancelled, 1);
        assert_eq!(r.metrics.total_requests, 0, "never completed");
        assert_eq!(
            r.metrics.request_ttft.len(),
            1,
            "ttft recorded from the streamed prefix despite cancellation"
        );
        // refcount conservation after the mid-flight release
        e.pool.validate().expect("pool invariants");
        assert_eq!(e.pool.pages_in_use(), 0);
        e.pool.bytes_peak()
    };
    // unbounded pool first; then a budgeted store at 60% of that peak so
    // the release path also exercises tier accounting + pin clearing
    let peak = run(None);
    run(Some(peak as f64 * 0.6 / 1e6));
}

#[test]
fn frontend_deadline_expiry_emits_exactly_once() {
    let m = require!(manifest());
    let mut e = engine(&m, PolicyKind::TinyServe, 256, 2);
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder()
        .options(ServeOptions::default())
        .build(&mut e, &mut plugins);
    // 10us deadline: any real prefill overshoots it, so the request is
    // aborted (or shed) long before its 64 tokens complete
    let mut doomed = lifecycle_req(1, 0.0, "the river and the stone and the light. ", 64);
    doomed.deadline_ms = Some(0.01);
    fe.submit(doomed);
    fe.submit(lifecycle_req(2, 0.0, "winter morning bridge. ", 4));
    let events = fe.drain().expect("drain");
    let expired: Vec<u64> = events
        .iter()
        .filter(|ev| matches!(ev, ServeEvent::DeadlineExpired { .. }))
        .map(|ev| ev.id())
        .collect();
    assert_eq!(expired, vec![1], "exactly one DeadlineExpired, for request 1");
    assert_eq!(fe.state_of(1), Some(Lifecycle::Expired));
    assert_eq!(fe.state_of(2), Some(Lifecycle::Finished));
    assert!(!fe.cancel(1), "expired is terminal");
    let r = fe.into_report();
    assert_eq!(r.metrics.total_expired, 1);
    assert_eq!(r.metrics.total_requests, 1, "only the undeadlined one finished");
    assert_eq!(e.pool.pages_in_use(), 0, "expired request's pages released");
}

#[test]
fn serve_trace_shim_matches_hand_pumped_frontend() {
    let m = require!(manifest());
    // session-free trace: decode is deterministic per request regardless of
    // batch grouping, so everything but measured timings must be identical
    let trace = generate_trace(&TraceConfig {
        n_requests: 8,
        prompt_chars: (80, 200),
        new_tokens: (4, 8),
        session_reuse_prob: 0.0,
        n_sessions: 0,
        ..Default::default()
    });
    let cfg = || ServingConfig {
        model: MODEL.to_string(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        ..Default::default()
    };
    let summarize = |r: &ServeReport| {
        let mut reqs: Vec<(u64, usize, usize, usize)> = r
            .requests
            .iter()
            .map(|q| (q.id, q.prompt_tokens, q.new_tokens, q.session_reused_tokens))
            .collect();
        reqs.sort();
        format!(
            "n={} tokens={} acc={:?} char={:?} admitted={} reqs={:?}",
            r.metrics.total_requests,
            r.metrics.total_new_tokens,
            r.accuracy,
            r.char_accuracy,
            r.batcher_stats.admitted,
            reqs
        )
    };

    let mut e1 = Engine::from_manifest(&m, cfg()).expect("engine");
    let mut p1 = Pipeline::new();
    let r1 = serve_trace(&mut e1, &trace, &ServeOptions::default(), &mut p1)
        .expect("shim serve");

    let mut e2 = Engine::from_manifest(&m, cfg()).expect("engine");
    let mut p2 = Pipeline::new();
    let mut fe = Frontend::builder()
        .options(ServeOptions::default())
        .build(&mut e2, &mut p2);
    for req in &trace {
        fe.submit(req.clone());
    }
    let mut streamed = 0u64;
    while fe.has_work() {
        for ev in fe.step().expect("step") {
            if matches!(ev, ServeEvent::Token { .. }) {
                streamed += 1;
            }
        }
    }
    let r2 = fe.into_report();

    assert_eq!(
        summarize(&r1),
        summarize(&r2),
        "shim and hand-pumped frontend diverged on deterministic fields"
    );
    assert_eq!(
        streamed, r2.metrics.total_new_tokens,
        "every decoded token surfaced as a Token event"
    );
    assert_eq!(e1.pool.pages_in_use(), 0);
    assert_eq!(e2.pool.pages_in_use(), 0);
}

fn pallas_seed() -> u64 {
    std::env::var("PALLAS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42)
}

/// Round-executor width for the determinism battery (CI sets
/// `TINYSERVE_THREADS=4` for the threaded double-run; the cross-executor
/// gate then diffs those event logs against the sequential runs' — they
/// must be byte-identical under modeled time).
fn env_threads() -> usize {
    std::env::var("TINYSERVE_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Step-phase executor for the determinism battery (CI re-runs the whole
/// battery with `TINYSERVE_EXECUTOR=scoped` and byte-diffs its event logs
/// against the default persistent runs' — executor choice must never leak
/// into the modeled-time streams).
fn env_executor() -> ExecutorKind {
    std::env::var("TINYSERVE_EXECUTOR")
        .ok()
        .and_then(|s| ExecutorKind::parse(&s))
        .unwrap_or(ExecutorKind::Persistent)
}

/// Serialize an event stream for diffing; under `TimeModel::Modeled` the
/// timestamps are deterministic and included bit-exactly.
fn event_log(events: &[ServeEvent]) -> String {
    events.iter().map(|e| e.sig(true)).collect::<Vec<_>>().join("\n")
}

fn write_ci_log(name: &str, content: &str) {
    if let Ok(dir) = std::env::var("TINYSERVE_EVENT_LOG") {
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(std::path::Path::new(&dir).join(name), content);
    }
}

fn pump_all(fe: &mut Frontend<'_>) -> Vec<ServeEvent> {
    let mut events = Vec::new();
    while fe.has_work() {
        events.extend(fe.step().expect("step"));
    }
    events
}

fn serve_cfg(budget_mb: Option<f64>) -> ServingConfig {
    ServingConfig {
        model: MODEL.to_string(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        kv_budget_mb: budget_mb,
        ..Default::default()
    }
}

fn bursty_openloop(seed: u64) -> OpenLoopGen {
    OpenLoopGen::new(OpenLoopConfig {
        n_requests: 12,
        rate_rps: 40.0,
        process: ArrivalProcess::Gamma { shape: 0.5 },
        shape: LoadShape::Bursts { period_s: 0.5, burst_s: 0.15, factor: 4.0 },
        prompt_chars: (100, 300),
        new_tokens: (4, 8),
        session_reuse_prob: 0.3,
        n_sessions: 3,
        deadline_ms: None,
        deadline_every: 1,
        tier_interactive: 0.0,
        tier_background: 0.0,
        seed,
    })
}

#[test]
fn openloop_pool_event_stream_is_deterministic() {
    // Determinism battery: the same seed must yield a bit-identical
    // ServeEvent stream (timestamps included) across two full runs of a
    // 2-worker pool fed by the open-loop generator under modeled time.
    // Also the CI double-run gate's serve-level log writer.
    let m = require!(manifest());
    let seed = pallas_seed();
    let run = || -> String {
        let pool = WorkerPool::build(&m, &serve_cfg(None), 2, DispatchKind::LeastLoaded)
            .expect("pool");
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            seed,
            threads: env_threads(),
            executor: env_executor(),
            ..Default::default()
        };
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
        fe.set_source(Box::new(bursty_openloop(seed)));
        let mut events = Vec::new();
        while fe.has_work() {
            events.extend(fe.step().expect("step"));
        }
        let (r, pool) = fe.into_parts();
        assert_eq!(r.metrics.total_requests, 12, "every request completes");
        for w in 0..pool.len() {
            assert_eq!(pool.engine(w).pool.pages_in_use(), 0, "worker {w} leak");
        }
        event_log(&events)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed, same event stream (timestamps included)");
    // schema-versioned run header: identical for same-config double runs;
    // the cross-executor CI diff (threads 1 vs 4 dirs) skips this line
    // because it records the executor width
    let header = event_log_header(seed, env_threads(), 2, "tinyserve", None);
    write_ci_log("serve_events.log", &format!("{header}\n{a}"));
}

#[test]
fn threaded_rounds_replay_sequential_event_logs_exactly() {
    // The `--threads N` determinism contract end to end: a 4-worker pool
    // serving the bursty open-loop mix under modeled time must produce a
    // *byte-identical* serialized event log (timestamps included) with the
    // scoped-thread round executor and with sequential stepping. Covered
    // across all dispatch kinds and eviction policies (each axis swept in
    // full against a fixed partner to bound runtime) with a distinct seed
    // per config, under KV-budget pressure so demotion/promotion paths run
    // inside the parallel step phase.
    let m = require!(manifest());
    let base_seed = pallas_seed();
    let run = |dispatch: DispatchKind,
               eviction: EvictionPolicyKind,
               seed: u64,
               threads: usize,
               budget_mb: Option<f64>|
     -> (String, ServeReport) {
        let cfg = ServingConfig { eviction, ..serve_cfg(budget_mb) };
        let pool = WorkerPool::build(&m, &cfg, 4, dispatch).expect("pool");
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            seed,
            threads,
            executor: env_executor(),
            ..Default::default()
        };
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
        fe.set_source(Box::new(bursty_openloop(seed)));
        let mut events = Vec::new();
        while fe.has_work() {
            events.extend(fe.step().expect("step"));
        }
        let (r, pool) = fe.into_parts();
        for w in 0..pool.len() {
            assert_eq!(pool.engine(w).pool.pages_in_use(), 0, "worker {w} leak");
        }
        (event_log(&events), r)
    };
    // unbounded probe sizes a global budget that forces evictions
    let (_, probe) = run(
        DispatchKind::LeastLoaded,
        EvictionPolicyKind::QueryAware,
        base_seed,
        1,
        None,
    );
    assert!(probe.metrics.kv_bytes_peak > 0);
    let budget_mb = probe.metrics.kv_bytes_peak as f64 * 0.8 / 1e6;
    let mut configs: Vec<(DispatchKind, EvictionPolicyKind)> = DispatchKind::all()
        .iter()
        .map(|&d| (d, EvictionPolicyKind::QueryAware))
        .collect();
    configs.extend(
        EvictionPolicyKind::all()
            .iter()
            .filter(|&&e| e != EvictionPolicyKind::QueryAware)
            .map(|&e| (DispatchKind::LeastLoaded, e)),
    );
    let mut threaded_log = String::new();
    for (i, &(dispatch, eviction)) in configs.iter().enumerate() {
        let seed = base_seed + i as u64;
        let (log_seq, r_seq) = run(dispatch, eviction, seed, 1, Some(budget_mb));
        let (log_par, r_par) = run(dispatch, eviction, seed, 4, Some(budget_mb));
        assert_eq!(
            log_seq,
            log_par,
            "[{} / {} / seed {seed}] threaded rounds diverged from sequential",
            dispatch.name(),
            eviction.name()
        );
        assert_eq!(r_seq.metrics.total_requests, r_par.metrics.total_requests);
        assert_eq!(r_seq.metrics.total_new_tokens, r_par.metrics.total_new_tokens);
        for (ws, wp) in r_seq.worker_stats.iter().zip(r_par.worker_stats.iter()) {
            assert_eq!(ws.new_tokens, wp.new_tokens);
            assert_eq!(ws.steps, wp.steps);
            assert!(
                (ws.busy_s - wp.busy_s).abs() < 1e-12,
                "virtual per-worker busy time is executor-independent"
            );
        }
        threaded_log = log_par;
    }
    // this file always records the threads=4 executor, so its header is
    // identical across the sequential- and threaded-env CI runs
    let header = event_log_header(
        base_seed + (configs.len() - 1) as u64,
        4,
        4,
        "tinyserve",
        Some(budget_mb),
    );
    write_ci_log("serve_events_threads4.log", &format!("{header}\n{threaded_log}"));
}

#[test]
fn trace_and_metrics_streams_are_deterministic_across_executors() {
    // Tentpole acceptance: under modeled time the structured span trace
    // and the periodic metrics snapshots are byte-identical across two
    // runs of the same seed AND across round executors (threads 1 vs 4).
    // Also the CI writer for the trace/metrics artifacts.
    let m = require!(manifest());
    let seed = pallas_seed();
    let run = |threads: usize, executor: ExecutorKind| -> (String, String) {
        let pool = WorkerPool::build(&m, &serve_cfg(None), 2, DispatchKind::LeastLoaded)
            .expect("pool");
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            seed,
            threads,
            executor,
            metrics_every: 8,
            ..Default::default()
        };
        let (trace_sink, trace_lines) = SharedVecSink::new();
        let (metrics_sink, metrics_lines) = SharedVecSink::new();
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder()
            .options(opts)
            .tracer(Tracer::to_sink(Box::new(trace_sink)))
            .metrics_sink(Box::new(metrics_sink))
            .build_pool(pool, &mut plugins);
        fe.set_source(Box::new(bursty_openloop(seed)));
        while fe.has_work() {
            fe.step().expect("step");
        }
        let r = fe.into_report();
        assert_eq!(r.metrics.total_requests, 12, "every request completes");
        let t = trace_lines.lock().unwrap().join("\n");
        let s = metrics_lines.lock().unwrap().join("\n");
        (t, s)
    };
    let (t1a, m1a) = run(1, ExecutorKind::Persistent);
    let (t1b, m1b) = run(1, ExecutorKind::Persistent);
    assert_eq!(t1a, t1b, "same seed, same trace bytes");
    assert_eq!(m1a, m1b, "same seed, same metrics snapshot bytes");
    let (t4, m4) = run(4, ExecutorKind::Persistent);
    assert_eq!(t1a, t4, "trace stream is executor-independent");
    assert_eq!(m1a, m4, "metrics stream is executor-independent");
    // scoped spawn/join threads vs long-lived persistent workers: same
    // dispatch/step/commit seam, so the streams must not move by a byte
    let (t4s, m4s) = run(4, ExecutorKind::Scoped);
    assert_eq!(t1a, t4s, "trace stream is identical under scoped threads");
    assert_eq!(m1a, m4s, "metrics stream is identical under scoped threads");

    // stream shape: run header first (schema-versioned, no thread count —
    // that is what makes the cross-executor byte-diff above possible),
    // then span / snapshot lines
    let first = t1a.lines().next().expect("nonempty trace");
    assert!(first.contains(r#""kind":"header""#), "header first: {first}");
    assert!(first.contains(r#""schema":1"#), "{first}");
    assert!(!first.contains("threads"), "header is executor-independent");
    for kind in ["queued", "admitted", "prefill", "round", "finished"] {
        assert!(
            t1a.contains(&format!(r#""kind":"{kind}""#)),
            "trace missing {kind} spans"
        );
    }
    assert!(
        m1a.lines().next().expect("nonempty metrics").contains(r#""kind":"header""#)
    );
    assert!(m1a.lines().nth(1).is_some(), "snapshots at --metrics-every 8");
    assert!(m1a.lines().skip(1).all(|l| l.contains(r#""kind":"metrics""#)));
    write_ci_log("serve_trace.jsonl", &t1a);
    write_ci_log("serve_metrics.jsonl", &m1a);
}

#[test]
fn analytics_stream_is_deterministic_across_executors() {
    // Analytics tentpole acceptance: with per-worker recorders and the
    // selection audit on, the `--analytics-out` JSONL is byte-identical
    // across same-seed runs and across executor kinds/widths under
    // modeled time (snapshots drain serially at the commit seam, in
    // worker order). Runs under a KV budget so accesses cross tiers.
    // Also the CI writer for the analytics artifact — `*.jsonl` CI logs
    // are whole-file diffed across widths.
    let m = require!(manifest());
    let seed = pallas_seed();
    let run = |threads: usize, executor: ExecutorKind| -> String {
        let pool =
            WorkerPool::build(&m, &serve_cfg(Some(0.75)), 2, DispatchKind::LeastLoaded)
                .expect("pool");
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            seed,
            threads,
            executor,
            metrics_every: 8,
            analytics: true,
            audit_every: 4,
            ..Default::default()
        };
        let (sink, lines) = SharedVecSink::new();
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder()
            .options(opts)
            .analytics_sink(Box::new(sink))
            .build_pool(pool, &mut plugins);
        fe.set_source(Box::new(bursty_openloop(seed)));
        while fe.has_work() {
            fe.step().expect("step");
        }
        let r = fe.into_report();
        assert!(!r.analytics.is_empty(), "report carries analytics summaries");
        assert!(
            r.analytics.iter().any(|a| a.accesses > 0),
            "recorders saw page accesses"
        );
        assert!(
            r.analytics.iter().any(|a| a.audit_records > 0),
            "the selection audit fired on its cadence"
        );
        lines.lock().unwrap().join("\n")
    };
    let a = run(1, ExecutorKind::Persistent);
    let b = run(1, ExecutorKind::Persistent);
    assert_eq!(a, b, "same seed, same analytics bytes");
    let c = run(4, ExecutorKind::Persistent);
    assert_eq!(a, c, "analytics stream is width-independent");
    let d = run(4, ExecutorKind::Scoped);
    assert_eq!(a, d, "analytics stream is executor-independent");

    // stream shape: the shared run header first (schema-versioned, no
    // thread count), then per-worker summary / rank / audit lines
    let first = a.lines().next().expect("nonempty analytics stream");
    assert!(first.contains(r#""kind":"header""#), "header first: {first}");
    assert!(!first.contains("threads"), "header is executor-independent");
    for kind in ["analytics", "page_ranks", "audit"] {
        assert!(
            a.contains(&format!(r#""kind":"{kind}""#)),
            "analytics stream missing {kind} lines"
        );
    }
    write_ci_log("serve_analytics.jsonl", &a);
}

#[test]
fn trace_span_trees_are_well_formed_across_policies_and_dispatch() {
    // Span-tree well-formedness property, swept over eviction policies x
    // dispatch kinds x seeds under KV-budget pressure (so store events
    // flow inside prefill and round spans). For every run the stream must
    // parse as JSONL and satisfy:
    //   - exactly one header line, and it comes first;
    //   - per request: exactly one `queued`, at most one `admitted` and
    //     one `prefill`, exactly one terminal (finished|cancelled|expired);
    //   - the lifecycle chain is monotone in virtual time:
    //     queued.t <= admitted.t <= prefill.t0 <= prefill.t1 <= terminal.t;
    //   - `prefill` requires `admitted`; `finished` requires `prefill`;
    //   - `round` spans have t0 <= t1 and only reference prefilled,
    //     non-terminal requests;
    //   - store events anchor to an already-opened span (a `prefill` line
    //     for ctx=prefill, a `round` line with that number for ctx=round).
    let m = require!(manifest());
    use tinyserve::util::json::Json;
    let base_seed = pallas_seed();
    let run = |dispatch: DispatchKind,
               eviction: EvictionPolicyKind,
               seed: u64,
               budget_mb: Option<f64>|
     -> (Vec<String>, ServeReport) {
        let cfg = ServingConfig { eviction, ..serve_cfg(budget_mb) };
        let pool = WorkerPool::build(&m, &cfg, 2, dispatch).expect("pool");
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            seed,
            ..Default::default()
        };
        let (sink, lines) = SharedVecSink::new();
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder()
            .options(opts)
            .tracer(Tracer::to_sink(Box::new(sink)))
            .build_pool(pool, &mut plugins);
        fe.set_source(Box::new(bursty_openloop(seed)));
        while fe.has_work() {
            fe.step().expect("step");
        }
        let r = fe.into_report();
        let lines = lines.lock().unwrap().clone();
        (lines, r)
    };
    let num = |v: &Json, k: &str, tag: &str| -> f64 {
        v.get(k)
            .and_then(|j| j.as_f64())
            .unwrap_or_else(|| panic!("{tag}: missing numeric field {k:?}"))
    };
    #[derive(Default)]
    struct Span {
        queued: u32,
        admitted: u32,
        prefilled: u32,
        terminal: u32,
        last_t: f64,
    }
    // returns (n_requests, n_store_events) seen in the stream
    let check = |lines: &[String], tag: &str| -> (usize, usize) {
        use std::collections::{HashMap, HashSet};
        let mut spans: HashMap<u64, Span> = HashMap::new();
        let mut rounds_seen: HashSet<u64> = HashSet::new();
        let mut store_events = 0usize;
        for (i, line) in lines.iter().enumerate() {
            let v = Json::parse(line)
                .unwrap_or_else(|e| panic!("{tag}: line {i} not JSON: {e}"));
            let kind = v.get("kind").and_then(|j| j.as_str()).expect("kind");
            if i == 0 {
                assert_eq!(kind, "header", "{tag}: header must come first");
                continue;
            }
            assert_ne!(kind, "header", "{tag}: duplicate header at line {i}");
            match kind {
                "queued" => {
                    let id = num(&v, "id", tag) as u64;
                    let s = spans.entry(id).or_default();
                    assert_eq!(s.queued, 0, "{tag}: request {id} queued twice");
                    s.queued = 1;
                    s.last_t = num(&v, "t", tag);
                }
                "deferred" => {
                    let id = num(&v, "id", tag) as u64;
                    let s = spans
                        .get(&id)
                        .unwrap_or_else(|| panic!("{tag}: deferred unknown {id}"));
                    assert_eq!(s.queued, 1);
                    assert_eq!(s.terminal, 0, "{tag}: deferred after terminal");
                }
                "admitted" => {
                    let id = num(&v, "id", tag) as u64;
                    let t = num(&v, "t", tag);
                    let s = spans
                        .get_mut(&id)
                        .unwrap_or_else(|| panic!("{tag}: admitted unknown {id}"));
                    assert_eq!(s.queued, 1, "{tag}: {id} admitted before queued");
                    assert_eq!(s.admitted, 0, "{tag}: {id} admitted twice");
                    assert_eq!(s.terminal, 0);
                    assert!(t >= s.last_t, "{tag}: {id} admitted before queued.t");
                    s.admitted = 1;
                    s.last_t = t;
                }
                "prefill" => {
                    let id = num(&v, "id", tag) as u64;
                    let (t0, t1) = (num(&v, "t0", tag), num(&v, "t1", tag));
                    let s = spans
                        .get_mut(&id)
                        .unwrap_or_else(|| panic!("{tag}: prefill unknown {id}"));
                    assert_eq!(s.admitted, 1, "{tag}: {id} prefill before admit");
                    assert_eq!(s.prefilled, 0, "{tag}: {id} prefilled twice");
                    assert!(t0 >= s.last_t && t1 >= t0, "{tag}: {id} prefill span");
                    s.prefilled = 1;
                    s.last_t = t1;
                }
                "round" => {
                    let (t0, t1) = (num(&v, "t0", tag), num(&v, "t1", tag));
                    assert!(t1 >= t0, "{tag}: round span t1 < t0");
                    rounds_seen.insert(num(&v, "round", tag) as u64);
                    let ids = v.get("ids").and_then(|j| j.as_arr()).expect("ids");
                    assert!(!ids.is_empty(), "{tag}: round stepped no requests");
                    for j in ids {
                        let id = j.as_f64().expect("round id") as u64;
                        let s = spans
                            .get(&id)
                            .unwrap_or_else(|| panic!("{tag}: round unknown {id}"));
                        assert_eq!(s.prefilled, 1, "{tag}: {id} in round, no prefill");
                        assert_eq!(s.terminal, 0, "{tag}: {id} stepped after terminal");
                    }
                }
                "demote" | "spill_out" | "spill_fault" | "readahead" => {
                    store_events += 1;
                    match v.get("ctx").and_then(|j| j.as_str()) {
                        Some("prefill") => {
                            let id = num(&v, "id", tag) as u64;
                            let s = spans.get(&id).unwrap_or_else(|| {
                                panic!("{tag}: store event for unknown {id}")
                            });
                            assert_eq!(
                                s.prefilled, 1,
                                "{tag}: store event outside an open prefill span"
                            );
                        }
                        Some("round") => {
                            let r = num(&v, "round", tag) as u64;
                            assert!(
                                rounds_seen.contains(&r),
                                "{tag}: store event anchored to unseen round {r}"
                            );
                        }
                        other => panic!("{tag}: bad store ctx {other:?}"),
                    }
                }
                "finished" | "cancelled" | "expired" => {
                    let id = num(&v, "id", tag) as u64;
                    let t = num(&v, "t", tag);
                    let s = spans
                        .get_mut(&id)
                        .unwrap_or_else(|| panic!("{tag}: terminal unknown {id}"));
                    assert_eq!(s.queued, 1);
                    assert_eq!(s.terminal, 0, "{tag}: {id} terminated twice");
                    if kind == "finished" {
                        assert_eq!(s.prefilled, 1, "{tag}: {id} finished, no prefill");
                    }
                    assert!(t >= s.last_t, "{tag}: {id} terminal before {}", s.last_t);
                    s.terminal = 1;
                    s.last_t = t;
                }
                other => panic!("{tag}: unexpected event kind {other:?}"),
            }
        }
        for (id, s) in &spans {
            assert_eq!(s.terminal, 1, "{tag}: request {id} left without a terminal");
        }
        (spans.len(), store_events)
    };
    // unbounded probe sizes a budget that forces store traffic
    let (probe_lines, probe) = run(
        DispatchKind::LeastLoaded,
        EvictionPolicyKind::QueryAware,
        base_seed,
        None,
    );
    check(&probe_lines, "probe");
    assert!(probe.metrics.kv_bytes_peak > 0);
    let budget_mb = probe.metrics.kv_bytes_peak as f64 * 0.7 / 1e6;
    // each axis swept in full against a fixed partner (bounds runtime),
    // with a distinct seed per config
    let mut configs: Vec<(DispatchKind, EvictionPolicyKind)> = DispatchKind::all()
        .iter()
        .map(|&d| (d, EvictionPolicyKind::QueryAware))
        .collect();
    configs.extend(
        EvictionPolicyKind::all()
            .iter()
            .filter(|&&e| e != EvictionPolicyKind::QueryAware)
            .map(|&e| (DispatchKind::LeastLoaded, e)),
    );
    let mut total_store_events = 0usize;
    for (i, &(dispatch, eviction)) in configs.iter().enumerate() {
        let seed = base_seed + i as u64;
        let tag = format!("{}/{}/seed {seed}", dispatch.name(), eviction.name());
        let (lines, _) = run(dispatch, eviction, seed, Some(budget_mb));
        let (n_requests, n_store) = check(&lines, &tag);
        assert_eq!(n_requests, 12, "{tag}: every submitted request traced");
        total_store_events += n_store;
    }
    assert!(
        total_store_events > 0,
        "a 70% KV budget must surface store events inside spans"
    );
}

#[test]
fn pool_of_one_matches_single_engine_frontend() {
    // Extends the PR-2 shim-equivalence: a 1-worker owned pool must be
    // event-stream-equivalent (including modeled timestamps) to the
    // borrowed single-engine frontend over the same trace.
    let m = require!(manifest());
    let trace = generate_trace(&TraceConfig {
        n_requests: 8,
        prompt_chars: (80, 200),
        new_tokens: (4, 8),
        session_reuse_prob: 0.4,
        n_sessions: 2,
        ..Default::default()
    });
    let opts = || ServeOptions {
        time_model: TimeModel::Modeled,
        ..Default::default()
    };

    // run A: classic borrowed single engine
    let mut e = Engine::from_manifest(&m, serve_cfg(None)).expect("engine");
    let mut p1 = Pipeline::new();
    let mut fe = Frontend::builder().options(opts()).build(&mut e, &mut p1);
    for req in &trace {
        fe.submit(req.clone());
    }
    let ev_a = pump_all(&mut fe);
    let r_a = fe.into_report();
    assert_eq!(e.pool.pages_in_use(), 0);

    // run B: owned pool with one worker
    let pool = WorkerPool::build(&m, &serve_cfg(None), 1, DispatchKind::RoundRobin)
        .expect("pool");
    let mut p2 = Pipeline::new();
    let mut fe = Frontend::builder().options(opts()).build_pool(pool, &mut p2);
    for req in &trace {
        fe.submit(req.clone());
    }
    let ev_b = pump_all(&mut fe);
    let (r_b, pool) = fe.into_parts();
    assert_eq!(pool.engine(0).pool.pages_in_use(), 0);

    assert_eq!(
        event_log(&ev_a),
        event_log(&ev_b),
        "1-worker pool must replay the single-engine event stream exactly"
    );
    assert_eq!(r_a.metrics.total_requests, r_b.metrics.total_requests);
    assert_eq!(r_a.metrics.total_new_tokens, r_b.metrics.total_new_tokens);
    assert_eq!(r_a.batcher_stats.admitted, r_b.batcher_stats.admitted);
    assert_eq!(r_b.worker_stats.len(), 1);
    assert_eq!(r_b.worker_stats[0].finished, r_b.metrics.total_requests);
}

/// Deferral scaffolding for the Deferred-lifecycle battery: a blocker
/// request whose pages fill the budget, and an oversized victim arriving
/// mid-decode that must defer. Returns (blocker, victim, budget_mb),
/// all derived from a deterministic modeled-time probe.
fn deferral_setup(
    m: &Manifest,
) -> (tinyserve::workload::Request, tinyserve::workload::Request, f64) {
    let blocker_prompt = "the river and the stone and the light. ".repeat(4);
    let victim_prompt = "winter morning bridge over the quiet water. ".repeat(12);
    // probe: solo blocker, unbounded, modeled time — peak bytes and the
    // mid-decode instant at which the victim should arrive
    let mut e = Engine::from_manifest(m, serve_cfg(None)).expect("engine");
    let mut plugins = Pipeline::new();
    let opts = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
    let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
    fe.submit(lifecycle_req(0, 0.0, &blocker_prompt, 24));
    let mut first_token_t = None;
    let mut finish_t = None;
    while fe.has_work() {
        for ev in fe.step().expect("step") {
            match ev {
                ServeEvent::Token { t, .. } if first_token_t.is_none() => {
                    first_token_t = Some(t)
                }
                ServeEvent::Finished(rec) => finish_t = Some(rec.e2e_seconds),
                _ => {}
            }
        }
    }
    drop(fe);
    let peak = e.pool.bytes_peak();
    let (t0, t1) = (first_token_t.expect("streamed"), finish_t.expect("finished"));
    assert!(t1 > t0);
    let budget_mb = peak as f64 * 1.2 / 1e6;
    let arrival = (t0 + t1) / 2.0;
    let blocker = lifecycle_req(0, 0.0, &blocker_prompt, 24);
    let victim = lifecycle_req(1, arrival, &victim_prompt, 8);
    (blocker, victim, budget_mb)
}

#[test]
fn pool_budget_invariant_under_random_lifecycle_interleavings() {
    // The pool-level serving invariant: with a global kv_budget split
    // across 2 workers, the summed bytes_in_use never exceeds the global
    // budget after any pump step, under randomized submit/cancel/deadline
    // interleavings, for all four eviction policies.
    let m = require!(manifest());
    // size the global budget from an unbounded probe of the same workload
    let trace = generate_trace(&TraceConfig {
        n_requests: 10,
        prompt_chars: (150, 400),
        new_tokens: (4, 8),
        session_reuse_prob: 0.3,
        n_sessions: 2,
        ..Default::default()
    });
    let mut probe = Engine::from_manifest(&m, serve_cfg(None)).expect("engine");
    let mut pp = Pipeline::new();
    let r = serve_trace(&mut probe, &trace, &ServeOptions::default(), &mut pp)
        .expect("probe serve");
    assert_eq!(r.metrics.total_requests, 10);
    let budget_mb = probe.pool.bytes_peak() as f64 * 0.7 / 1e6;
    drop(probe);

    for eviction in EvictionPolicyKind::all() {
        let cfg = ServingConfig { eviction: *eviction, ..serve_cfg(Some(budget_mb)) };
        let pool = WorkerPool::build(&m, &cfg, 2, DispatchKind::LeastLoaded)
            .expect("pool");
        let budget = pool.total_budget_bytes().expect("bounded");
        assert!(
            budget <= (budget_mb * 1e6) as usize,
            "split sums past the global budget"
        );
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            ..Default::default()
        };
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
        let mut chaos = Rng::new(0x5EED ^ *eviction as u64);
        for (i, req) in trace.iter().enumerate() {
            let mut req = req.clone();
            // every third request carries a tightish SLO
            if i % 3 == 0 {
                req.deadline_ms = Some(5.0 + chaos.f64() * 200.0);
            }
            fe.submit(req);
        }
        // `excused` is armed by a *fresh* overflow (pinned/partial pages
        // blocked demotion) and disarmed the moment the pool returns
        // under budget — so a later genuine violation needs its own
        // overflow to pass, instead of hiding behind an early one
        let mut excused = false;
        let mut last_overflows = vec![0u64; fe.n_pool_workers()];
        while fe.has_work() {
            fe.step().expect("step");
            // random mid-flight cancellations
            if chaos.bool(0.1) {
                let id = chaos.usize(10) as u64;
                let _ = fe.cancel(id);
            }
            let total: usize = (0..fe.n_pool_workers())
                .map(|w| {
                    let e = fe.worker_engine(w);
                    e.store.bytes_in_use(&e.pool)
                })
                .sum();
            let mut fresh_overflow = false;
            for (w, last) in last_overflows.iter_mut().enumerate() {
                let o = fe.worker_engine(w).store.stats.overflows;
                if o > *last {
                    fresh_overflow = true;
                }
                *last = o;
            }
            if total <= budget {
                excused = false;
            } else {
                excused = excused || fresh_overflow;
                assert!(
                    excused,
                    "[{}] summed bytes_in_use {total} > pool budget {budget} \
                     without an overflow",
                    eviction.name()
                );
            }
        }
        let (_, pool) = fe.into_parts();
        for w in 0..pool.len() {
            assert_eq!(
                pool.engine(w).pool.pages_in_use(),
                0,
                "[{}] worker {w} leaked pages",
                eviction.name()
            );
        }
    }
}

#[test]
fn session_turns_follow_their_snapshot_across_pool_workers() {
    // Regression for count-oblivious dispatch orphaning session
    // snapshots: under round-robin (which would alternate workers), the
    // second turn of a session must be routed back to the worker holding
    // its snapshot and reuse the prefix instead of re-prefilling.
    let m = require!(manifest());
    let pool = WorkerPool::build(&m, &serve_cfg(None), 2, DispatchKind::RoundRobin)
        .expect("pool");
    let opts = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
    let mut plugins = Pipeline::new();
    let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
    let mut rng = Rng::new(3);
    let sess = tasks::kvrecall_session(&mut rng, 400, 4);
    let mk = |id: u64, doc: &tasks::Doc, t: f64| tinyserve::workload::Request {
        id,
        arrival_s: t,
        prompt: tasks::encode_prompt(&doc.prompt),
        max_new_tokens: 4,
        session: Some(7),
        task: None,
        answer: Some(doc.answer.clone()),
        deadline_ms: None,
        tier: tinyserve::workload::SloTier::default(),
    };
    let q0 = sess.question(0);
    let q1 = sess.question(1);
    fe.submit(mk(0, &q0, 0.0));
    fe.submit(mk(1, &q1, 0.1));
    while fe.has_work() {
        fe.step().expect("step");
    }
    let (r, pool) = fe.into_parts();
    assert_eq!(r.metrics.total_requests, 2);
    assert_eq!(r.session_stats.hits, 1, "turn 2 must hit the stored prefix");
    assert!(r.session_stats.reused_tokens > 300, "{:?}", r.session_stats);
    let rec1 = &r.requests[1];
    assert!(rec1.session_reused_tokens > 300, "reused {}", rec1.session_reused_tokens);
    for w in 0..pool.len() {
        assert_eq!(pool.engine(w).pool.pages_in_use(), 0, "worker {w} leak");
    }
}

#[test]
fn deferred_request_eventually_finishes() {
    // Deferred -> Active -> Finished: the victim defers under budget
    // pressure while the blocker decodes, then admits once the blocker
    // retires and frees its pages.
    let m = require!(manifest());
    let (blocker, victim, budget_mb) = deferral_setup(&m);
    let mut e = Engine::from_manifest(&m, serve_cfg(Some(budget_mb))).expect("engine");
    let mut plugins = Pipeline::new();
    let opts = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
    let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
    fe.submit(blocker);
    fe.submit(victim);
    let mut saw_deferred = false;
    while fe.has_work() {
        for ev in fe.step().expect("step") {
            if matches!(ev, ServeEvent::Deferred { id: 1, .. }) {
                saw_deferred = true;
                assert_eq!(
                    fe.state_of(1),
                    Some(Lifecycle::Deferred),
                    "state tracks the deferral"
                );
            }
        }
    }
    assert!(saw_deferred, "budget pressure must defer the victim at least once");
    assert_eq!(fe.state_of(0), Some(Lifecycle::Finished));
    assert_eq!(fe.state_of(1), Some(Lifecycle::Finished), "deferred -> finished");
    let r = fe.into_report();
    assert_eq!(r.metrics.total_requests, 2);
    assert!(r.batcher_stats.deferred > 0);
    assert_eq!(e.pool.pages_in_use(), 0);
}

#[test]
fn cancel_while_deferred_emits_cancelled() {
    // The regression this PR fixes: cancelling a Deferred request must
    // emit a Cancelled event and count in total_cancelled — not silently
    // vanish from the batcher queue.
    let m = require!(manifest());
    let (blocker, victim, budget_mb) = deferral_setup(&m);
    let mut e = Engine::from_manifest(&m, serve_cfg(Some(budget_mb))).expect("engine");
    let mut plugins = Pipeline::new();
    let opts = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
    let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
    fe.submit(blocker);
    fe.submit(victim);
    let mut cancelled_events = 0u32;
    let mut cancelled = false;
    while fe.has_work() {
        for ev in fe.step().expect("step") {
            match ev {
                ServeEvent::Deferred { id: 1, .. } if !cancelled => {
                    assert_eq!(fe.state_of(1), Some(Lifecycle::Deferred));
                    assert!(fe.cancel(1), "deferred request is cancellable");
                    assert_eq!(fe.state_of(1), Some(Lifecycle::Cancelled));
                    assert!(!fe.cancel(1), "terminal after cancellation");
                    cancelled = true;
                }
                ServeEvent::Cancelled { id: 1, .. } => cancelled_events += 1,
                ServeEvent::Token { id: 1, .. } => {
                    panic!("cancelled-while-deferred request must never stream")
                }
                _ => {}
            }
        }
    }
    assert!(cancelled, "victim never deferred — budget sizing broke");
    assert_eq!(cancelled_events, 1, "exactly one Cancelled event");
    assert_eq!(fe.state_of(0), Some(Lifecycle::Finished));
    let r = fe.into_report();
    assert_eq!(r.metrics.total_cancelled, 1);
    assert_eq!(r.metrics.total_requests, 1, "only the blocker completed");
    assert_eq!(e.pool.pages_in_use(), 0);
}

#[test]
fn deadline_expiry_while_deferred_emits_expired() {
    // Deferred -> Expired: first run a deadline-free probe to learn the
    // (deterministic, modeled-time) instants of the victim's first
    // deferral and eventual admission, then rerun with a deadline strictly
    // between them — the victim must defer at least once and then be shed
    // with exactly one DeadlineExpired, never admitted.
    let m = require!(manifest());
    let (blocker, victim, budget_mb) = deferral_setup(&m);
    let run = |deadline_ms: Option<f64>| -> (Vec<ServeEvent>, ServeReport, usize) {
        let mut e =
            Engine::from_manifest(&m, serve_cfg(Some(budget_mb))).expect("engine");
        let mut plugins = Pipeline::new();
        let opts =
            ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
        let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
        fe.submit(blocker.clone());
        let mut v = victim.clone();
        v.deadline_ms = deadline_ms;
        fe.submit(v);
        let mut events = Vec::new();
        while fe.has_work() {
            events.extend(fe.step().expect("step"));
        }
        let r = fe.into_report();
        let leaked = e.pool.pages_in_use();
        (events, r, leaked)
    };
    // probe: victim defers at t_def, admits at t_adm
    let (probe_events, _, _) = run(None);
    let t_def = probe_events
        .iter()
        .find_map(|ev| match ev {
            ServeEvent::Deferred { id: 1, t } => Some(*t),
            _ => None,
        })
        .expect("probe run must defer the victim");
    let t_adm = probe_events
        .iter()
        .find_map(|ev| match ev {
            ServeEvent::Admitted { id: 1, t } => Some(*t),
            _ => None,
        })
        .expect("probe run must eventually admit the victim");
    assert!(t_adm > t_def);
    // deadline halfway between first deferral and admission, relative to
    // the victim's arrival
    let mid = (t_def + t_adm) / 2.0;
    let deadline_ms = (mid - victim.arrival_s) * 1e3;
    assert!(deadline_ms > 0.0);
    let (events, r, leaked) = run(Some(deadline_ms));
    let deferred_n = events
        .iter()
        .filter(|ev| matches!(ev, ServeEvent::Deferred { id: 1, .. }))
        .count();
    let expired: Vec<u64> = events
        .iter()
        .filter(|ev| matches!(ev, ServeEvent::DeadlineExpired { .. }))
        .map(|ev| ev.id())
        .collect();
    assert!(deferred_n >= 1, "victim must defer before expiring");
    assert_eq!(expired, vec![1], "exactly one DeadlineExpired, for the victim");
    assert!(
        !events
            .iter()
            .any(|ev| matches!(ev, ServeEvent::Admitted { id: 1, .. })),
        "expired-while-deferred request is never admitted"
    );
    assert_eq!(r.metrics.total_expired, 1);
    assert_eq!(r.metrics.total_requests, 1, "only the blocker completed");
    assert_eq!(leaked, 0, "no pages leaked");
}

#[test]
fn session_reuse_cuts_prefill_time() {
    let m = require!(manifest());
    let cfg = ServingConfig {
        model: MODEL.to_string(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 1,
        ..Default::default()
    };
    let mut e = Engine::from_manifest(&m, cfg).unwrap();
    // same session twice: second request must reuse the prefix
    let mut rng = Rng::new(3);
    let sess = tasks::kvrecall_session(&mut rng, 400, 4);
    let q0 = sess.question(0);
    let q1 = sess.question(1);
    let mk = |id: u64, doc: &tasks::Doc, t: f64| tinyserve::workload::Request {
        id,
        arrival_s: t,
        prompt: tasks::encode_prompt(&doc.prompt),
        max_new_tokens: 4,
        session: Some(7),
        task: None,
        answer: Some(doc.answer.clone()),
        deadline_ms: None,
        tier: tinyserve::workload::SloTier::default(),
    };
    let trace = vec![mk(0, &q0, 0.0), mk(1, &q1, 0.1)];
    let mut plugins = Pipeline::new();
    let r = serve_trace(&mut e, &trace, &ServeOptions::default(), &mut plugins).unwrap();
    assert_eq!(r.session_stats.hits, 1, "second request must hit");
    assert!(r.session_stats.reused_tokens > 300);
    let rec1 = &r.requests[1];
    assert!(
        rec1.session_reused_tokens > 300,
        "reused {}",
        rec1.session_reused_tokens
    );
}

// ---- SLO-class preemption, fairness, and abort-path regression suite ----

/// Token stream one request produced, in order.
fn tokens_of(events: &[ServeEvent], id: u64) -> Vec<i32> {
    events
        .iter()
        .filter_map(|ev| match ev {
            ServeEvent::Token { id: i, tok, .. } if *i == id => Some(*tok),
            _ => None,
        })
        .collect()
}

#[test]
fn round_window_rotation_steps_every_active_to_completion() {
    // Fairness regression: with more actives than the engine's compiled
    // batch width, plan_round used to step a fixed prefix of the active
    // set in stable order — everything behind the window starved until an
    // early request happened to retire. The rotating window must walk the
    // whole active set, so every request finishes.
    let m = require!(manifest());
    let mut e = engine(&m, PolicyKind::TinyServe, 256, 2); // batch width 2
    let mut plugins = Pipeline::new();
    let opts = ServeOptions {
        time_model: TimeModel::Modeled,
        batcher: BatcherConfig {
            max_active: 6,
            batch_timeout_s: 0.0,
            prefill_per_round: 6,
        },
        ..Default::default()
    };
    let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
    for i in 0..6u64 {
        fe.submit(lifecycle_req(i, 0.0, "the river and the stone. ", 6));
    }
    let events = pump_all(&mut fe);
    for i in 0..6u64 {
        assert_eq!(
            fe.state_of(i),
            Some(Lifecycle::Finished),
            "request {i} starved behind the batch window"
        );
        assert_eq!(tokens_of(&events, i).len(), 6, "request {i} short-streamed");
    }
    let r = fe.into_report();
    assert_eq!(r.metrics.total_requests, 6);
    assert_eq!(e.pool.pages_in_use(), 0);
}

#[test]
fn cancelling_one_request_leaves_survivor_stream_untouched() {
    // Abort-scoping regression: cancelling B mid-batch must not disturb
    // A's decode — the aborted request's plugin state dies with its own
    // forked pipeline, and resetting anything shared would change the
    // survivor's stream. A's tokens must be byte-identical with and
    // without the doomed co-tenant, under stateful plugins.
    let m = require!(manifest());
    let prompt_a = "the river and the stone and the light. ";
    let prompt_b = "winter morning bridge over the quiet water. ";
    let run = |with_b: bool| -> Vec<i32> {
        let mut e = engine(&m, PolicyKind::TinyServe, 256, 4);
        let mut plugins = Pipeline::new();
        plugins.push(Box::new(EntropyEarlyExit::new(0.05, 3, 4)));
        plugins.push(Box::new(RepetitionGuard { max_run: 16 }));
        let opts = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
        let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
        fe.submit(lifecycle_req(0, 0.0, prompt_a, 16));
        if with_b {
            fe.submit(lifecycle_req(1, 0.0, prompt_b, 16));
        }
        let mut a_tokens = Vec::new();
        let mut b_streamed = 0usize;
        while fe.has_work() {
            for ev in fe.step().expect("step") {
                match ev {
                    ServeEvent::Token { id: 0, tok, .. } => a_tokens.push(tok),
                    ServeEvent::Token { id: 1, .. } => {
                        b_streamed += 1;
                        if b_streamed == 1 {
                            assert!(fe.cancel(1), "B cancellable mid-stream");
                        }
                    }
                    _ => {}
                }
            }
        }
        assert_eq!(fe.state_of(0), Some(Lifecycle::Finished));
        if with_b {
            assert_eq!(fe.state_of(1), Some(Lifecycle::Cancelled));
        }
        drop(fe);
        assert_eq!(e.pool.pages_in_use(), 0, "mid-batch abort leaked pages");
        a_tokens
    };
    let solo = run(false);
    let with_cancelled_b = run(true);
    assert_eq!(
        solo, with_cancelled_b,
        "survivor's token stream changed when a co-tenant was aborted"
    );
}

#[test]
fn preempt_resume_decodes_token_identical_across_policies_and_seeds() {
    // The preemption contract: pause -> KV snapshot down the tier ladder
    // -> resume must continue the sequence *exactly* where it paused. With
    // int8 KV the demote/fault round-trip is bit-exact and greedy sampling
    // draws no randomness, so the background's token stream must match an
    // uninterrupted baseline run bit-for-bit, whatever the eviction policy
    // shuffles underneath.
    let m = require!(manifest());
    let bg_prompt = "the river and the stone and the light. ".repeat(3);
    for eviction in [EvictionPolicyKind::Lru, EvictionPolicyKind::QueryAware] {
        for seed in [7u64, 42] {
            let cfg = || ServingConfig {
                model: MODEL.to_string(),
                policy: PolicyKind::TinyServe,
                budget: 256,
                max_batch: 4,
                kv_dtype: KvDtype::Int8,
                eviction,
                ..Default::default()
            };
            let opts = |preempt: bool| ServeOptions {
                time_model: TimeModel::Modeled,
                seed,
                preempt,
                batcher: BatcherConfig {
                    max_active: 1,
                    batch_timeout_s: 0.0,
                    prefill_per_round: 1,
                },
                ..Default::default()
            };
            // baseline: the background runs alone, uninterrupted
            let baseline = {
                let mut e = Engine::from_manifest(&m, cfg()).expect("engine");
                let mut plugins = Pipeline::new();
                let mut fe =
                    Frontend::builder().options(opts(false)).build(&mut e, &mut plugins);
                let mut bg = lifecycle_req(0, 0.0, &bg_prompt, 32);
                bg.tier = SloTier::Background;
                fe.submit(bg);
                let events = pump_all(&mut fe);
                assert_eq!(fe.state_of(0), Some(Lifecycle::Finished));
                drop(fe);
                assert_eq!(e.pool.pages_in_use(), 0);
                tokens_of(&events, 0)
            };
            // preempted run: same background, interrupted mid-decode by an
            // interactive arrival
            let mut e = Engine::from_manifest(&m, cfg()).expect("engine");
            let mut plugins = Pipeline::new();
            let mut fe = Frontend::builder().options(opts(true)).build(&mut e, &mut plugins);
            let mut bg = lifecycle_req(0, 0.0, &bg_prompt, 32);
            bg.tier = SloTier::Background;
            fe.submit(bg);
            let mut events = Vec::new();
            let mut bg_streamed = 0usize;
            while fe.has_work() && bg_streamed < 4 {
                for ev in fe.step().expect("step") {
                    if matches!(ev, ServeEvent::Token { id: 0, .. }) {
                        bg_streamed += 1;
                    }
                    events.push(ev);
                }
            }
            assert_eq!(fe.state_of(0), Some(Lifecycle::Active), "bg decoding");
            // the interactive arrives already starving: its arrival sits far
            // enough in the virtual past that the preemptor's half-TTFT wait
            // gate passes on the next scheduling round
            let mut fg = lifecycle_req(1, fe.now() - 1.0, "winter morning. ", 4);
            fg.tier = SloTier::Interactive;
            fe.submit(fg);
            events.extend(pump_all(&mut fe));
            assert!(
                events
                    .iter()
                    .any(|ev| matches!(ev, ServeEvent::Preempted { id: 0, .. })),
                "background was never preempted ({eviction:?}, seed {seed})"
            );
            assert!(
                events
                    .iter()
                    .any(|ev| matches!(ev, ServeEvent::Resumed { id: 0, .. })),
                "background never resumed ({eviction:?}, seed {seed})"
            );
            assert_eq!(fe.state_of(0), Some(Lifecycle::Finished));
            assert_eq!(fe.state_of(1), Some(Lifecycle::Finished));
            drop(fe);
            assert_eq!(e.pool.pages_in_use(), 0, "snapshot pages leaked");
            e.pool.validate().expect("pool invariants after preempt/resume");
            let got = tokens_of(&events, 0);
            assert_eq!(
                got, baseline,
                "preempt/resume diverged from the uninterrupted decode \
                 ({eviction:?}, seed {seed})"
            );
        }
    }
}

#[test]
fn cancel_and_expiry_are_idempotent_with_single_release() {
    let m = require!(manifest());
    // double-cancel an active request: the first wins, the second is a
    // typed no-op, exactly one Cancelled event, one page release
    let mut e = engine(&m, PolicyKind::TinyServe, 256, 2);
    let mut plugins = Pipeline::new();
    let opts = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
    let mut fe = Frontend::builder().options(opts).build(&mut e, &mut plugins);
    fe.submit(lifecycle_req(0, 0.0, "the river and the stone. ", 24));
    let mut events = Vec::new();
    let mut streamed = 0usize;
    while fe.has_work() && streamed < 2 {
        for ev in fe.step().expect("step") {
            if matches!(ev, ServeEvent::Token { .. }) {
                streamed += 1;
            }
            events.push(ev);
        }
    }
    assert!(fe.cancel(0), "first cancel succeeds");
    assert!(!fe.cancel(0), "second cancel is a no-op on a terminal request");
    events.extend(pump_all(&mut fe));
    let cancels = events
        .iter()
        .filter(|ev| matches!(ev, ServeEvent::Cancelled { id: 0, .. }))
        .count();
    assert_eq!(cancels, 1, "exactly one Cancelled event");
    assert_eq!(fe.state_of(0), Some(Lifecycle::Cancelled));
    let r = fe.into_report();
    assert_eq!(r.metrics.total_cancelled, 1);
    drop(r);
    assert_eq!(e.pool.pages_in_use(), 0);
    e.pool.validate().expect("pool invariants after double cancel");

    // cancel after deadline expiry: the expiry is the request's one
    // terminal transition — the late cancel must not emit anything or
    // release pages a second time
    let mut e2 = engine(&m, PolicyKind::TinyServe, 256, 2);
    let mut plugins2 = Pipeline::new();
    let opts2 = ServeOptions { time_model: TimeModel::Modeled, ..Default::default() };
    let mut fe2 = Frontend::builder().options(opts2).build(&mut e2, &mut plugins2);
    let mut doomed = lifecycle_req(0, 0.0, "the river and the stone and the light. ", 64);
    doomed.deadline_ms = Some(0.01);
    fe2.submit(doomed);
    let events2 = fe2.drain().expect("drain");
    assert_eq!(fe2.state_of(0), Some(Lifecycle::Expired));
    assert!(!fe2.cancel(0), "cancel after expiry is a no-op");
    let late = fe2.drain().expect("drain after late cancel");
    let expired_n = events2
        .iter()
        .chain(late.iter())
        .filter(|ev| matches!(ev, ServeEvent::DeadlineExpired { id: 0, .. }))
        .count();
    let cancelled_n = events2
        .iter()
        .chain(late.iter())
        .filter(|ev| matches!(ev, ServeEvent::Cancelled { id: 0, .. }))
        .count();
    assert_eq!(expired_n, 1, "exactly one DeadlineExpired");
    assert_eq!(cancelled_n, 0, "no Cancelled event after expiry");
    let r2 = fe2.into_report();
    assert_eq!(r2.metrics.total_expired, 1);
    assert_eq!(r2.metrics.total_cancelled, 0);
    drop(r2);
    assert_eq!(e2.pool.pages_in_use(), 0);
    e2.pool.validate().expect("pool invariants after cancel-post-expiry");
}

#[test]
fn preempt_tiered_burst_event_stream_is_deterministic() {
    // CI preemption gate (TINYSERVE_PREEMPT=1): a preemption-heavy tiered
    // burst over a 2-worker pool with preemption + stealing enabled must
    // produce a bit-identical event stream across two full runs; the log
    // is written for the workflow's cross-process double-run byte-diff.
    if std::env::var("TINYSERVE_PREEMPT").ok().as_deref() != Some("1") {
        eprintln!("SKIP: set TINYSERVE_PREEMPT=1 for the preemption gate");
        return;
    }
    let m = require!(manifest());
    let seed = pallas_seed();
    let run = || -> String {
        let pool = WorkerPool::build(&m, &serve_cfg(None), 2, DispatchKind::LeastLoaded)
            .expect("pool");
        let opts = ServeOptions {
            time_model: TimeModel::Modeled,
            threads: env_threads(),
            executor: env_executor(),
            preempt: true,
            steal: true,
            batcher: BatcherConfig {
                max_active: 2,
                batch_timeout_s: 0.01,
                prefill_per_round: 2,
            },
            seed,
            ..Default::default()
        };
        let mut plugins = Pipeline::new();
        let mut fe = Frontend::builder().options(opts).build_pool(pool, &mut plugins);
        // scripted starvation first: two long background requests fill both
        // admission slots, then an interactive arrival lands already past
        // the preemptor's wait gate — guaranteeing at least one preemption
        for i in 0..2u64 {
            let mut bg = lifecycle_req(
                1000 + i,
                0.0,
                &"the river and the stone and the light. ".repeat(2),
                48,
            );
            bg.tier = SloTier::Background;
            fe.submit(bg);
        }
        let mut events = Vec::new();
        let mut streamed = 0usize;
        while fe.has_work() && streamed < 4 {
            for ev in fe.step().expect("step") {
                if matches!(ev, ServeEvent::Token { .. }) {
                    streamed += 1;
                }
                events.push(ev);
            }
        }
        let mut fg = lifecycle_req(1002, fe.now() - 1.0, "winter morning. ", 4);
        fg.tier = SloTier::Interactive;
        fe.submit(fg);
        // then a tiered burst through the live open-loop source
        fe.set_source(Box::new(OpenLoopGen::new(OpenLoopConfig {
            n_requests: 10,
            rate_rps: 40.0,
            process: ArrivalProcess::Gamma { shape: 0.5 },
            shape: LoadShape::Bursts { period_s: 0.5, burst_s: 0.15, factor: 4.0 },
            prompt_chars: (100, 300),
            new_tokens: (4, 8),
            session_reuse_prob: 0.0,
            n_sessions: 1,
            deadline_ms: None,
            deadline_every: 1,
            tier_interactive: 0.3,
            tier_background: 0.4,
            seed,
        })));
        events.extend(pump_all(&mut fe));
        let (r, pool) = fe.into_parts();
        assert!(r.batcher_stats.preempted >= 1, "scenario must preempt");
        for w in 0..pool.len() {
            assert_eq!(pool.engine(w).pool.pages_in_use(), 0, "worker {w} leak");
        }
        event_log(&events)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "preemption event stream must be seed-deterministic");
    write_ci_log("serve_preempt_tiered.log", &a);
}
