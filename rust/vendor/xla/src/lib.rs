//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! Provides a type-correct mirror of the small API surface
//! `runtime::ModelRuntime` uses, so the crate builds and the unit /
//! property test suite runs without the native XLA toolchain. Every
//! constructor returns `Error::Unavailable`, which surfaces as the usual
//! "artifacts missing / runtime unavailable" skip path in integration
//! tests and benches. Swap this path dependency for a real xla_extension
//! binding to run the serving path.

use std::fmt;
use std::marker::PhantomData;

/// Raw-pointer marker: the real PJRT wrappers are `!Send + !Sync`, and
/// code is written against that (one runtime per worker) — keep the stub
/// honest so threading bugs can't creep in silently.
type NotSend = PhantomData<*const ()>;

#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the native XLA/PJRT runtime, \
                 which is not linked into this build"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types the runtime moves across the host/device boundary.
pub trait NativeType: sealed::Sealed + Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient {
    _not_send: NotSend,
}

pub struct PjRtBuffer {
    _not_send: NotSend,
}

pub struct PjRtLoadedExecutable {
    _not_send: NotSend,
}

pub struct Literal {
    _not_send: NotSend,
}

pub struct HloModuleProto {
    _not_send: NotSend,
}

pub struct XlaComputation {
    _not_send: NotSend,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }

    pub fn size_bytes(&self) -> usize {
        0
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _not_send: PhantomData }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("PjRtClient::cpu"));
        assert!(format!("{e:?}").contains("Unavailable"));
        let proto = HloModuleProto::from_text_file("x");
        assert!(proto.is_err());
    }
}
