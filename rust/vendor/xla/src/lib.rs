//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! Provides a type-correct mirror of the small API surface
//! `runtime::ModelRuntime` uses, so the crate builds and the unit /
//! property test suite runs without the native XLA toolchain. Every
//! constructor returns `Error::Unavailable`, which surfaces as the usual
//! "artifacts missing / runtime unavailable" skip path in integration
//! tests and benches. Swap this path dependency for a real xla_extension
//! binding to run the serving path.

use std::fmt;
use std::marker::PhantomData;

/// Raw-pointer marker suppressing the auto traits, so every wrapper's
/// thread-safety is an *explicit, documented decision* below rather than
/// an accident of field types. The real PJRT C++ objects behind these
/// wrappers are internally synchronized: `PjRtClient` and
/// `PjRtLoadedExecutable` are documented thread-safe (compilation and
/// execution may be issued from any thread), while buffers and literals
/// are plain owned data that may *move* between threads but are not
/// synchronized for shared mutation. The stub mirrors exactly that
/// contract — `Send` everywhere, `Sync` only where PJRT guarantees it —
/// so the thread-parallel worker stepping in `coordinator::pool` is
/// type-checked against the same bounds a real binding would impose.
type RawHandle = PhantomData<*const ()>;

#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the native XLA/PJRT runtime, \
                 which is not linked into this build"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types the runtime moves across the host/device boundary.
pub trait NativeType: sealed::Sealed + Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient {
    _handle: RawHandle,
}

pub struct PjRtBuffer {
    _handle: RawHandle,
}

pub struct PjRtLoadedExecutable {
    _handle: RawHandle,
}

pub struct Literal {
    _handle: RawHandle,
}

pub struct HloModuleProto {
    _handle: RawHandle,
}

pub struct XlaComputation {
    _handle: RawHandle,
}

// Thread-safety contract (see `RawHandle` docs). PJRT clients and loaded
// executables are internally synchronized by the runtime, so they may be
// both moved across and shared between threads — which is what lets
// `runtime::ModelRuntime` cache executables in `Arc`s. Buffers, literals
// and HLO protos are owned payloads: movable (`Send`) but accessed from
// one thread at a time (`!Sync`), matching how the engine uses them
// (per-call uploads and results that never outlive a decode step).
unsafe impl Send for PjRtClient {}
unsafe impl Sync for PjRtClient {}
unsafe impl Send for PjRtLoadedExecutable {}
unsafe impl Sync for PjRtLoadedExecutable {}
unsafe impl Send for PjRtBuffer {}
unsafe impl Send for Literal {}
unsafe impl Send for HloModuleProto {}
unsafe impl Send for XlaComputation {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }

    pub fn size_bytes(&self) -> usize {
        0
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _handle: PhantomData }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("PjRtClient::cpu"));
        assert!(format!("{e:?}").contains("Unavailable"));
        let proto = HloModuleProto::from_text_file("x");
        assert!(proto.is_err());
    }

    #[test]
    fn thread_safety_contract_is_exactly_as_documented() {
        fn send<T: Send>() {}
        fn send_sync<T: Send + Sync>() {}
        // internally synchronized by PJRT: shareable
        send_sync::<PjRtClient>();
        send_sync::<PjRtLoadedExecutable>();
        // owned payloads: movable only
        send::<PjRtBuffer>();
        send::<Literal>();
        send::<HloModuleProto>();
        send::<XlaComputation>();
    }
}
