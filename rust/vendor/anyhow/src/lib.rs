//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the surface this repository uses — `Error`,
//! `Result`, the `Context` extension trait for `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros — so the crate builds without
//! crates.io access. Semantics match anyhow where it matters here:
//! `Display` shows the outermost context, `Debug` shows the full chain,
//! and any `std::error::Error` converts via `?`.

use std::fmt;

/// String-backed error with a context chain (outermost last).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    /// Wrap with an additional layer of context (most recent wins Display).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.context.push(c.to_string());
        self
    }

    pub fn to_string_full(&self) -> String {
        format!("{self:?}")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(c) => write!(f, "{c}"),
            None => write!(f, "{}", self.msg),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, which makes
// this blanket conversion legal (the same trick real anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        ensure!(flag);
        Ok(7)
    }

    #[test]
    fn macros_and_context() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");

        let r: Result<u32> = None.context("missing");
        assert_eq!(r.unwrap_err().to_string(), "missing");

        let r: Result<u32> = "no".parse::<u32>().context("parsing");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "parsing");
        assert!(format!("{e:?}").starts_with("parsing: "));

        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<String> {
            let s = std::str::from_utf8(&[0xff, 0xfe])?;
            Ok(s.to_string())
        }
        assert!(io_fail().is_err());
    }
}
