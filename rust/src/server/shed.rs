//! Admission backpressure for the network front door.
//!
//! The server never queues unboundedly. Two explicit limits gate intake,
//! and crossing either produces a *typed* response instead of silent
//! buffering:
//!
//! - `max_conns` — connection cap, checked at accept. Over the cap the
//!   server answers `hello` + `overload{limit:"max_conns"}` and closes,
//!   so the client learns *why* instead of timing out.
//! - `queue_depth` — cap on *new* submissions the backend has accepted
//!   but not started decoding (its batcher queue plus pending intake;
//!   preempted requests waiting to resume are excluded — they hold no
//!   unserved submission), checked per `submit` against the count the
//!   backend's `queued_len()` reports. Over the cap the configured
//!   [`ShedPolicy`] decides: **defer** answers `retry` with a
//!   deterministic `retry_after_ms` hint (the client resubmits), **shed**
//!   answers `overload{limit:"queue_depth"}` (the request is dropped).
//!
//! [`AdmissionGate`] is pure bookkeeping — no sockets, no clock — so the
//! policy is unit-testable and every decision is a deterministic function
//! of (config, current occupancy). Counters publish through the run's
//! `trace::registry::MetricsRegistry` under `net_*` names.

use crate::trace::registry::MetricsRegistry;

/// What to do with a `submit` that lands while the backend queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// answer `retry` with a retry-after hint; the client owns resubmission
    Defer,
    /// answer `overload` naming the limit; the request is dropped
    Shed,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "defer" => Some(ShedPolicy::Defer),
            "shed" => Some(ShedPolicy::Shed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShedPolicy::Defer => "defer",
            ShedPolicy::Shed => "shed",
        }
    }

    pub fn names() -> Vec<&'static str> {
        vec!["defer", "shed"]
    }
}

/// Intake limits for [`AdmissionGate`].
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// concurrent connection cap (accept-time limit)
    pub max_conns: usize,
    /// cap on new submissions the backend has not started decoding — the
    /// backend's `queued_len()`: batcher-queued + pending intake, never
    /// preempted resumes
    pub queue_depth: usize,
    pub policy: ShedPolicy,
    /// base retry hint; the emitted hint scales with how far over the cap
    /// the queue is, so heavier backlogs push clients further out
    pub retry_after_ms: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_conns: 64,
            queue_depth: 256,
            policy: ShedPolicy::Defer,
            retry_after_ms: 50.0,
        }
    }
}

/// One admission decision.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    Accept,
    /// bounced under `ShedPolicy::Defer`: client should retry after the hint
    Defer { retry_after_ms: f64 },
    /// shed: the named limit was hit at value `max`
    Shed { limit: &'static str, max: usize },
}

/// Backpressure counters, published as `net_*` metrics.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ShedCounters {
    /// connections refused at accept (`max_conns`)
    pub conns_shed: u64,
    /// submits answered with `retry` (`queue_depth` under `Defer`)
    pub submits_deferred: u64,
    /// submits answered with `overload` (`queue_depth` under `Shed`)
    pub submits_shed: u64,
    /// response lines parked because a connection's send buffer was full
    pub slow_consumer_deferrals: u64,
    /// connections force-closed after their parked backlog overflowed
    pub slow_consumer_closes: u64,
}

impl ShedCounters {
    pub fn publish(&self, reg: &mut MetricsRegistry) {
        reg.counter("net_conns_shed", self.conns_shed);
        reg.counter("net_submits_deferred", self.submits_deferred);
        reg.counter("net_submits_shed", self.submits_shed);
        reg.counter("net_slow_consumer_deferrals", self.slow_consumer_deferrals);
        reg.counter("net_slow_consumer_closes", self.slow_consumer_closes);
    }
}

/// Stateful admission decisions over [`AdmissionConfig`] limits.
#[derive(Debug)]
pub struct AdmissionGate {
    pub cfg: AdmissionConfig,
    pub counters: ShedCounters,
}

impl AdmissionGate {
    pub fn new(cfg: AdmissionConfig) -> AdmissionGate {
        AdmissionGate { cfg, counters: ShedCounters::default() }
    }

    /// Accept-time gate: may a new connection join `open_conns` live ones?
    pub fn admit_conn(&mut self, open_conns: usize) -> Admission {
        if open_conns >= self.cfg.max_conns {
            self.counters.conns_shed += 1;
            return Admission::Shed { limit: "max_conns", max: self.cfg.max_conns };
        }
        Admission::Accept
    }

    /// Submit-time gate over the backend's not-yet-started depth.
    pub fn admit_submit(&mut self, queued: usize) -> Admission {
        if queued < self.cfg.queue_depth {
            return Admission::Accept;
        }
        match self.cfg.policy {
            ShedPolicy::Defer => {
                self.counters.submits_deferred += 1;
                Admission::Defer { retry_after_ms: self.retry_hint(queued) }
            }
            ShedPolicy::Shed => {
                self.counters.submits_shed += 1;
                Admission::Shed {
                    limit: "queue_depth",
                    max: self.cfg.queue_depth,
                }
            }
        }
    }

    /// Deterministic retry hint: the base scaled by queue overshoot, so a
    /// queue at 2x its cap asks clients to wait twice the base.
    fn retry_hint(&self, queued: usize) -> f64 {
        let depth = self.cfg.queue_depth.max(1) as f64;
        self.cfg.retry_after_ms * (queued as f64 / depth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_points() {
        assert_eq!(ShedPolicy::parse("defer"), Some(ShedPolicy::Defer));
        assert_eq!(ShedPolicy::parse("shed"), Some(ShedPolicy::Shed));
        assert_eq!(ShedPolicy::parse("drop"), None);
        for name in ShedPolicy::names() {
            assert_eq!(ShedPolicy::parse(name).unwrap().name(), name);
        }
    }

    #[test]
    fn conn_gate_sheds_over_the_cap_and_counts() {
        let mut gate = AdmissionGate::new(AdmissionConfig {
            max_conns: 2,
            ..AdmissionConfig::default()
        });
        assert_eq!(gate.admit_conn(0), Admission::Accept);
        assert_eq!(gate.admit_conn(1), Admission::Accept);
        assert_eq!(
            gate.admit_conn(2),
            Admission::Shed { limit: "max_conns", max: 2 }
        );
        assert_eq!(gate.counters.conns_shed, 1);
    }

    #[test]
    fn submit_gate_defers_with_a_scaling_hint() {
        let mut gate = AdmissionGate::new(AdmissionConfig {
            queue_depth: 4,
            policy: ShedPolicy::Defer,
            retry_after_ms: 50.0,
            ..AdmissionConfig::default()
        });
        assert_eq!(gate.admit_submit(3), Admission::Accept);
        assert_eq!(
            gate.admit_submit(4),
            Admission::Defer { retry_after_ms: 50.0 },
            "at the cap the hint is exactly the base"
        );
        assert_eq!(
            gate.admit_submit(8),
            Admission::Defer { retry_after_ms: 100.0 },
            "2x overshoot doubles the hint"
        );
        assert_eq!(gate.counters.submits_deferred, 2);
        assert_eq!(gate.counters.submits_shed, 0);
    }

    #[test]
    fn default_config_retry_hints_are_pinned() {
        // the wire-visible hint under the stock config is part of the
        // client-facing contract: pin it so a refactor of `retry_hint`
        // cannot silently shift client backoff behaviour
        let mut gate = AdmissionGate::new(AdmissionConfig::default());
        assert_eq!(gate.cfg.queue_depth, 256);
        assert_eq!(gate.cfg.retry_after_ms, 50.0);
        assert_eq!(gate.admit_submit(255), Admission::Accept);
        assert_eq!(
            gate.admit_submit(256),
            Admission::Defer { retry_after_ms: 50.0 }
        );
        assert_eq!(
            gate.admit_submit(384),
            Admission::Defer { retry_after_ms: 75.0 }
        );
        assert_eq!(
            gate.admit_submit(512),
            Admission::Defer { retry_after_ms: 100.0 }
        );
    }

    #[test]
    fn submit_gate_sheds_with_the_limit_named() {
        let mut gate = AdmissionGate::new(AdmissionConfig {
            queue_depth: 4,
            policy: ShedPolicy::Shed,
            ..AdmissionConfig::default()
        });
        assert_eq!(gate.admit_submit(0), Admission::Accept);
        assert_eq!(
            gate.admit_submit(4),
            Admission::Shed { limit: "queue_depth", max: 4 }
        );
        assert_eq!(gate.counters.submits_shed, 1);
        assert_eq!(gate.counters.submits_deferred, 0);
    }

    #[test]
    fn counters_publish_under_net_names() {
        let counters = ShedCounters {
            conns_shed: 1,
            submits_deferred: 2,
            submits_shed: 3,
            slow_consumer_deferrals: 4,
            slow_consumer_closes: 5,
        };
        let mut reg = MetricsRegistry::new();
        counters.publish(&mut reg);
        let prom = reg.prometheus();
        for needle in [
            "net_conns_shed 1",
            "net_submits_deferred 2",
            "net_submits_shed 3",
            "net_slow_consumer_deferrals 4",
            "net_slow_consumer_closes 5",
        ] {
            assert!(prom.contains(needle), "missing {needle} in:\n{prom}");
        }
    }
}
