//! Per-connection I/O plumbing: one reader thread, one writer thread, and
//! a bounded send path between the serving pump and each client.
//!
//! The pump thread never blocks on a socket. Reads arrive as [`Ctl`]
//! messages over a shared channel (one reader thread per connection parses
//! lines into `ClientMsg` and forwards them); writes go through a bounded
//! `sync_channel` outbox drained by a writer thread. When a client stops
//! reading (slow consumer) the outbox fills and further lines park in a
//! capped `deferred` queue retried each pump round — so a stalled client
//! costs at most `send_buffer + deferred_cap` lines of memory, never an
//! unbounded buffer. Overflowing the cap is reported as
//! [`SendOutcome::Overflow`]; the server responds by cancelling the
//! connection's in-flight requests and force-closing it.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Sender, SyncSender, TrySendError};
use std::thread::JoinHandle;

use super::proto::ClientMsg;

/// Control-plane messages funneled to the serving pump from the accept
/// loop and every connection's reader thread.
#[derive(Debug)]
pub(crate) enum Ctl {
    /// accept loop: a new TCP connection (pre-admission)
    NewConn(TcpStream),
    /// a parsed request line from connection `conn`
    Msg { conn: u64, msg: ClientMsg },
    /// an unparseable request line from connection `conn`
    Bad { conn: u64, reason: String },
    /// connection `conn` hung up (EOF or read error)
    Gone { conn: u64 },
}

/// Result of queueing one response line toward a client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SendOutcome {
    /// handed to the writer thread
    Sent,
    /// outbox full (slow consumer); parked in the deferred queue
    Deferred,
    /// deferred queue over its cap, or the writer is gone — close the conn
    Overflow,
}

/// Pump-side state for one live connection.
pub(crate) struct Conn {
    pub id: u64,
    /// bounded outbox to the writer thread; `None` once closing
    outbox: Option<SyncSender<String>>,
    /// lines bounced off a full outbox, retried each pump round (FIFO
    /// after the outbox, so per-connection ordering is preserved)
    deferred: VecDeque<String>,
    deferred_cap: usize,
    /// live requests on this conn: server global id → client id
    pub live: HashMap<u64, u64>,
    /// client asked to close; conn shuts down once `live` drains
    pub closing: bool,
    /// marked for removal by the pump (overflow, hangup, protocol close)
    pub dead: bool,
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
    writer: Option<JoinHandle<()>>,
}

impl Conn {
    /// Wrap an accepted stream: spawn its reader (lines → `ctl`) and
    /// writer (bounded outbox → socket) threads.
    pub fn spawn(
        id: u64,
        stream: TcpStream,
        ctl: Sender<Ctl>,
        send_buffer: usize,
        deferred_cap: usize,
    ) -> std::io::Result<Conn> {
        // the accept loop's listener is non-blocking; the per-conn threads
        // want plain blocking sockets
        stream.set_nonblocking(false)?;
        stream.set_nodelay(true).ok();

        let read_half = stream.try_clone()?;
        let reader = std::thread::Builder::new()
            .name(format!("tinyserve-conn-{id}-rd"))
            .spawn(move || {
                let mut lines = BufReader::new(read_half).lines();
                loop {
                    match lines.next() {
                        Some(Ok(line)) => {
                            if line.trim().is_empty() {
                                continue;
                            }
                            let out = match ClientMsg::parse(&line) {
                                Ok(msg) => Ctl::Msg { conn: id, msg },
                                Err(reason) => Ctl::Bad { conn: id, reason },
                            };
                            if ctl.send(out).is_err() {
                                return; // pump is gone
                            }
                        }
                        // EOF or read error: either way the client is done
                        Some(Err(_)) | None => {
                            let _ = ctl.send(Ctl::Gone { conn: id });
                            return;
                        }
                    }
                }
            })?;

        let write_half = stream.try_clone()?;
        let (tx, rx) = std::sync::mpsc::sync_channel::<String>(send_buffer.max(1));
        let writer = std::thread::Builder::new()
            .name(format!("tinyserve-conn-{id}-wr"))
            .spawn(move || {
                let mut out = std::io::BufWriter::new(write_half);
                while let Ok(line) = rx.recv() {
                    // flush per line: token streaming wants timely delivery
                    if out.write_all(line.as_bytes()).is_err()
                        || out.write_all(b"\n").is_err()
                        || out.flush().is_err()
                    {
                        return; // broken pipe; reader reports the hangup
                    }
                }
            })?;

        Ok(Conn {
            id,
            outbox: Some(tx),
            deferred: VecDeque::new(),
            deferred_cap: deferred_cap.max(1),
            live: HashMap::new(),
            closing: false,
            dead: false,
            stream,
            reader: Some(reader),
            writer: Some(writer),
        })
    }

    /// Queue one response line, preserving order behind any parked lines.
    pub fn send(&mut self, line: String) -> SendOutcome {
        if self.dead {
            return SendOutcome::Overflow;
        }
        self.flush_deferred();
        if self.deferred.is_empty() {
            match self.try_send(line) {
                Ok(()) => return SendOutcome::Sent,
                Err(Some(line)) => self.deferred.push_back(line),
                Err(None) => return SendOutcome::Overflow, // writer gone
            }
        } else {
            self.deferred.push_back(line);
        }
        if self.deferred.len() > self.deferred_cap {
            SendOutcome::Overflow
        } else {
            SendOutcome::Deferred
        }
    }

    /// Retry parked lines against the outbox; called each pump round.
    pub fn flush_deferred(&mut self) {
        while let Some(line) = self.deferred.pop_front() {
            match self.try_send(line) {
                Ok(()) => continue,
                Err(Some(line)) => {
                    self.deferred.push_front(line);
                    return;
                }
                Err(None) => {
                    self.dead = true;
                    self.deferred.clear();
                    return;
                }
            }
        }
    }

    pub fn has_deferred(&self) -> bool {
        !self.deferred.is_empty()
    }

    /// `Ok` = handed off; `Err(Some)` = outbox full (line returned);
    /// `Err(None)` = writer thread exited.
    fn try_send(&mut self, line: String) -> Result<(), Option<String>> {
        let Some(tx) = &self.outbox else { return Err(None) };
        match tx.try_send(line) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(line)) => Err(Some(line)),
            Err(TrySendError::Disconnected(_)) => {
                self.dead = true;
                Err(None)
            }
        }
    }

    /// Tear the connection down and join its threads. `graceful` lets the
    /// writer drain queued lines first (client-initiated close, where the
    /// peer is still reading); force-close severs the socket immediately so
    /// a non-reading peer can never wedge the pump.
    pub fn close(&mut self, graceful: bool) {
        self.dead = true;
        self.deferred.clear();
        if graceful {
            // the drain below must stay bounded even if the peer stops
            // reading: SO_SNDTIMEO is per-socket, so this caps every
            // in-flight write on the writer thread's cloned handle too
            let timeout = std::time::Duration::from_millis(500);
            let _ = self.stream.set_write_timeout(Some(timeout));
        } else {
            let _ = self.stream.shutdown(std::net::Shutdown::Both);
        }
        // dropping the outbox ends the writer once it drains
        self.outbox = None;
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
        // unblock the reader if it is still parked in read()
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Conn {
    fn drop(&mut self) {
        if self.reader.is_some() || self.writer.is_some() {
            self.close(false);
        }
    }
}
