//! Network serving front door: TCP token streaming with admission
//! backpressure over the request-lifecycle frontend.
//!
//! `tinyserve serve --listen ADDR` binds a [`Server`] that accepts
//! concurrent TCP connections speaking the line-delimited JSON protocol in
//! [`proto`] (schema-versioned; `hello` first, then `submit`/`cancel`/
//! `close` inbound and per-token lifecycle events outbound). The layering:
//!
//! ```text
//!   accept loop (listener.rs)  ─┐
//!   conn reader threads (conn.rs) ─┤→ Ctl channel → pump (this module)
//!                                                     │ admission (shed.rs)
//!                                                     │ submit/cancel/step
//!                                                     ▼
//!                                              ServeBackend (Frontend)
//!                                                     │ ServeEvents
//!                                                     ▼
//!                            conn writer threads ← bounded outboxes
//! ```
//!
//! All scheduling state lives on the single pump thread: it drains control
//! messages, applies the [`shed::AdmissionGate`] (defer/shed instead of
//! unbounded queueing), steps the backend one decode round at a time, and
//! routes each `ServeEvent` to its connection's bounded outbox. Client
//! disconnects and cancels free KV pages mid-flight through the frontend's
//! existing `cancel` path. The backend is abstracted as [`ServeBackend`]
//! so the whole network layer is testable without engine artifacts (see
//! [`MockBackend`]).
//!
//! Determinism: with a single connection driven closed-loop under
//! `TimeModel::Modeled`, the virtual clock is frozen whenever the backend
//! is idle, so arrival timestamps — and therefore the whole event/trace
//! stream — are a pure function of the protocol exchange and the seed. CI
//! byte-diffs a seeded loopback run's trace across two runs on exactly
//! this setup. Multi-connection interleaving is wall-clock racy by nature
//! and is exercised for liveness, not byte-equality.

pub mod proto;
pub mod shed;

mod conn;
mod listener;

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Frontend, ServeEvent};
use crate::metrics::RequestRecord;
use crate::trace::registry::MetricsRegistry;
use crate::trace::TraceEvent;
use crate::workload::{tasks, Request, SloTier};

use conn::{Conn, Ctl, SendOutcome};
use listener::Listener;
use proto::{ClientMsg, ServerMsg, PROTO_SCHEMA};
use shed::{Admission, AdmissionConfig, AdmissionGate, ShedCounters};

/// What the network pump needs from a serving engine. `Frontend`
/// implements it; [`MockBackend`] stands in for engine-free tests.
pub trait ServeBackend {
    /// Enqueue a request (the server assigns `req.id` and `req.arrival_s`).
    fn submit(&mut self, req: Request);
    /// Cancel by server-global id from any pre-terminal state, releasing
    /// KV pages mid-flight; idempotent.
    fn cancel(&mut self, id: u64) -> bool;
    /// One scheduling round; returns the events it produced.
    fn step(&mut self) -> Result<Vec<ServeEvent>>;
    fn has_work(&self) -> bool;
    /// Current virtual time (stamps `arrival_s` and connection spans).
    fn now(&self) -> f64;
    /// New client submissions accepted but not yet decoding — the count
    /// the admission gate's `queue_depth` cap applies to. Preempted
    /// requests waiting to resume are *not* counted: they hold no
    /// unserved submission, and counting them would let a preemption
    /// storm shed fresh traffic the queue could actually absorb.
    fn queued_len(&self) -> usize;
    fn kv_bytes_in_use(&self) -> usize;
    /// Live introspection snapshot behind the wire `stats` op (schema 3):
    /// queue depths by SLO tier, active/preempted/deferred counts,
    /// per-worker KV residency, TTFT attainment, stall firings.
    fn live_stats(&self) -> crate::coordinator::LiveStats;
    /// Emit a connection-lifecycle span into the backend's trace stream.
    fn trace_event(&mut self, ev: &TraceEvent);
}

impl ServeBackend for Frontend<'_> {
    fn submit(&mut self, req: Request) {
        Frontend::submit(self, req);
    }

    fn cancel(&mut self, id: u64) -> bool {
        Frontend::cancel(self, id)
    }

    fn step(&mut self) -> Result<Vec<ServeEvent>> {
        Frontend::step(self)
    }

    fn has_work(&self) -> bool {
        Frontend::has_work(self)
    }

    fn now(&self) -> f64 {
        Frontend::now(self)
    }

    fn queued_len(&self) -> usize {
        Frontend::queued_len(self)
    }

    fn kv_bytes_in_use(&self) -> usize {
        Frontend::kv_bytes_in_use(self)
    }

    fn live_stats(&self) -> crate::coordinator::LiveStats {
        Frontend::live_stats(self)
    }

    fn trace_event(&mut self, ev: &TraceEvent) {
        Frontend::trace_event(self, ev);
    }
}

/// Front-door configuration (`--listen` + the `--max-conns`,
/// `--queue-depth`, `--shed-policy` backpressure knobs).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// bind address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`])
    pub listen: String,
    pub admission: AdmissionConfig,
    /// per-connection writer outbox, in lines; beyond it lines park in the
    /// deferred queue (slow consumer)
    pub send_buffer: usize,
    /// parked-line cap per connection; overflow force-closes the conn
    pub deferred_cap: usize,
    /// exit once at least one connection was served and everything
    /// drained (loopback smoke runs and tests; a real deployment loops
    /// until [`ServerHandle::stop`])
    pub exit_when_idle: bool,
    /// control-channel poll interval while the backend is idle
    pub idle_poll_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            admission: AdmissionConfig::default(),
            send_buffer: 64,
            deferred_cap: 1024,
            exit_when_idle: false,
            idle_poll_ms: 5,
        }
    }
}

/// Run counters for one `Server::run`, published as `net_*` metrics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub accepted: u64,
    pub closed: u64,
    pub submitted: u64,
    /// accepted submissions broken out by SLO tier, indexed by
    /// [`SloTier::rank`] (interactive/batch/background)
    pub submitted_by_tier: [u64; 3],
    pub cancels: u64,
    pub bad_lines: u64,
    pub shed: ShedCounters,
}

impl ServerStats {
    pub fn publish(&self, reg: &mut MetricsRegistry) {
        reg.counter("net_conns_accepted", self.accepted);
        reg.counter("net_conns_closed", self.closed);
        reg.counter("net_submits", self.submitted);
        for tier in SloTier::all() {
            let name = match tier {
                SloTier::Interactive => "net_submits_interactive",
                SloTier::Batch => "net_submits_batch",
                SloTier::Background => "net_submits_background",
            };
            reg.counter(name, self.submitted_by_tier[tier.rank() as usize]);
        }
        reg.counter("net_cancels", self.cancels);
        reg.counter("net_bad_lines", self.bad_lines);
        self.shed.publish(reg);
    }
}

/// Remote stop switch for a running server (shareable across threads).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// A bound TCP front door. `bind` then `run` over a backend; the pump
/// runs on the calling thread until stopped or (with `exit_when_idle`)
/// drained.
pub struct Server {
    cfg: ServerConfig,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("bind {}", cfg.listen))?;
        Ok(Server { cfg, listener, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: Arc::clone(&self.stop) }
    }

    /// Serve until stopped. Clean shutdown: the accept loop is joined,
    /// every live request cancelled (freeing its KV pages) and every
    /// connection's reader/writer threads joined before returning.
    pub fn run<B: ServeBackend>(self, backend: &mut B) -> Result<ServerStats> {
        let Server { cfg, listener, stop } = self;
        let (ctl_tx, ctl_rx) = std::sync::mpsc::channel();
        let mut listener =
            Listener::spawn(listener, ctl_tx.clone()).context("accept loop")?;
        let gate = AdmissionGate::new(cfg.admission.clone());
        let mut pump = Pump {
            cfg: &cfg,
            backend,
            gate,
            conns: HashMap::new(),
            routes: HashMap::new(),
            next_conn: 0,
            next_global: 1,
            stats: ServerStats::default(),
            ctl_tx,
        };
        let result = pump.run_loop(&ctl_rx, &stop);
        listener.stop();
        pump.shutdown();
        let mut stats = pump.stats;
        stats.shed = pump.gate.counters.clone();
        result.map(|()| stats)
    }
}

/// Single-threaded serving pump: owns every connection's send side, the
/// admission gate, and the global↔client request-id routes.
struct Pump<'a, B: ServeBackend> {
    cfg: &'a ServerConfig,
    backend: &'a mut B,
    gate: AdmissionGate,
    conns: HashMap<u64, Conn>,
    /// server-global request id → (conn id, client's per-conn id)
    routes: HashMap<u64, (u64, u64)>,
    next_conn: u64,
    next_global: u64,
    stats: ServerStats,
    ctl_tx: Sender<Ctl>,
}

impl<B: ServeBackend> Pump<'_, B> {
    fn run_loop(&mut self, ctl_rx: &Receiver<Ctl>, stop: &AtomicBool) -> Result<()> {
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            // drain the control plane; block briefly only when idle so
            // decoding never waits on the network
            let busy = self.backend.has_work()
                || self.conns.values().any(|c| c.has_deferred());
            let mut msgs = Vec::new();
            if !busy {
                let timeout = Duration::from_millis(self.cfg.idle_poll_ms.max(1));
                match ctl_rx.recv_timeout(timeout) {
                    Ok(m) => msgs.push(m),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }
            while let Ok(m) = ctl_rx.try_recv() {
                msgs.push(m);
            }
            for m in msgs {
                self.handle_ctl(m);
            }
            // retry slow-consumer parked lines once per round
            for c in self.conns.values_mut() {
                c.flush_deferred();
            }
            if self.backend.has_work() {
                let events = self.backend.step()?;
                for ev in events {
                    self.route(&ev);
                }
            }
            self.cleanup();
            if self.cfg.exit_when_idle
                && self.stats.accepted > 0
                && self.conns.is_empty()
                && !self.backend.has_work()
            {
                return Ok(());
            }
        }
    }

    fn handle_ctl(&mut self, ctl: Ctl) {
        match ctl {
            Ctl::NewConn(stream) => self.new_conn(stream),
            Ctl::Msg { conn, msg } => match msg {
                ClientMsg::Submit { id, prompt, max_new, session, deadline_ms, tier } => {
                    self.submit(conn, id, prompt, max_new, session, deadline_ms, tier)
                }
                ClientMsg::Cancel { id } => self.cancel(conn, id),
                ClientMsg::Stats => {
                    // backend snapshot plus this listener's shed counters —
                    // one consistent line, never terminal for any request
                    let msg = ServerMsg::Stats {
                        stats: self.backend.live_stats(),
                        net: self.gate.counters.clone(),
                    };
                    self.send_to(conn, msg);
                }
                ClientMsg::Close => {
                    if let Some(c) = self.conns.get_mut(&conn) {
                        c.closing = true;
                    }
                }
            },
            Ctl::Bad { conn, reason } => {
                self.stats.bad_lines += 1;
                self.send_to(conn, ServerMsg::Error { reason });
            }
            Ctl::Gone { conn } => {
                if let Some(c) = self.conns.get_mut(&conn) {
                    // cleanup cancels its live requests and closes it
                    c.dead = true;
                }
            }
        }
    }

    fn new_conn(&mut self, stream: TcpStream) {
        match self.gate.admit_conn(self.conns.len()) {
            Admission::Accept => {}
            Admission::Shed { limit, max } => {
                // typed rejection: the client learns which limit fired
                // instead of watching an unexplained hangup
                let mut stream = stream;
                let _ = stream.set_nonblocking(false);
                let hello = ServerMsg::Hello { schema: PROTO_SCHEMA }.to_line();
                let over =
                    ServerMsg::Overload { id: None, limit: limit.into(), max }
                        .to_line();
                let _ = stream.write_all(format!("{hello}\n{over}\n").as_bytes());
                let _ = stream.shutdown(std::net::Shutdown::Both);
                return;
            }
            Admission::Defer { .. } => unreachable!("conn gate never defers"),
        }
        let id = self.next_conn;
        self.next_conn += 1;
        let spawned = Conn::spawn(
            id,
            stream,
            self.ctl_tx.clone(),
            self.cfg.send_buffer,
            self.cfg.deferred_cap,
        );
        // spawn failure (fd/thread pressure) just drops the stream; the
        // client sees a hangup before `hello`, the retryable signal
        let Ok(mut conn) = spawned else { return };
        conn.send(ServerMsg::Hello { schema: PROTO_SCHEMA }.to_line());
        let t = self.backend.now();
        self.backend.trace_event(&TraceEvent::ConnOpen { conn: id, t });
        self.conns.insert(id, conn);
        self.stats.accepted += 1;
    }

    #[allow(clippy::too_many_arguments)]
    fn submit(
        &mut self,
        conn_id: u64,
        client_id: u64,
        prompt: String,
        max_new: usize,
        session: Option<u64>,
        deadline_ms: Option<f64>,
        tier: Option<SloTier>,
    ) {
        let Some(conn) = self.conns.get(&conn_id) else { return };
        if conn.closing {
            let reason = format!("submit {client_id} after close");
            self.send_to(conn_id, ServerMsg::Error { reason });
            return;
        }
        if conn.live.values().any(|&c| c == client_id) {
            let reason = format!("duplicate in-flight id {client_id}");
            self.send_to(conn_id, ServerMsg::Error { reason });
            return;
        }
        match self.gate.admit_submit(self.backend.queued_len()) {
            Admission::Accept => {
                let global = self.next_global;
                self.next_global += 1;
                // omitted tier = batch (the wire default documented in
                // `proto`), so v1 clients keep their old scheduling class
                let tier = tier.unwrap_or_default();
                self.backend.submit(Request {
                    id: global,
                    arrival_s: self.backend.now(),
                    prompt: tasks::encode_prompt(&prompt),
                    max_new_tokens: max_new,
                    session,
                    task: None,
                    answer: None,
                    deadline_ms,
                    tier,
                });
                self.routes.insert(global, (conn_id, client_id));
                if let Some(c) = self.conns.get_mut(&conn_id) {
                    c.live.insert(global, client_id);
                }
                self.stats.submitted += 1;
                self.stats.submitted_by_tier[tier.rank() as usize] += 1;
            }
            Admission::Defer { retry_after_ms } => {
                self.send_to(conn_id, ServerMsg::Retry { id: client_id, retry_after_ms });
            }
            Admission::Shed { limit, max } => {
                self.send_to(
                    conn_id,
                    ServerMsg::Overload { id: Some(client_id), limit: limit.into(), max },
                );
            }
        }
    }

    fn cancel(&mut self, conn_id: u64, client_id: u64) {
        let Some(conn) = self.conns.get(&conn_id) else { return };
        let global = conn
            .live
            .iter()
            .find(|&(_, &c)| c == client_id)
            .map(|(&g, _)| g);
        // unknown or already-terminal ids are an idempotent no-op, same as
        // Frontend::cancel; the Cancelled event routes back on a later step
        if let Some(g) = global {
            self.backend.cancel(g);
            self.stats.cancels += 1;
        }
    }

    /// Forward one backend event to its connection, retiring the route on
    /// terminal events.
    fn route(&mut self, ev: &ServeEvent) {
        let global = ev.id();
        let Some(&(conn_id, client_id)) = self.routes.get(&global) else {
            return; // connection already torn down
        };
        let terminal = matches!(
            ev,
            ServeEvent::Finished(_)
                | ServeEvent::Cancelled { .. }
                | ServeEvent::DeadlineExpired { .. }
        );
        if terminal {
            self.routes.remove(&global);
            if let Some(c) = self.conns.get_mut(&conn_id) {
                c.live.remove(&global);
            }
        }
        self.send_to(conn_id, ServerMsg::from_event(ev, client_id));
    }

    fn send_to(&mut self, conn_id: u64, msg: ServerMsg) {
        let Some(conn) = self.conns.get_mut(&conn_id) else { return };
        match conn.send(msg.to_line()) {
            SendOutcome::Sent => {}
            SendOutcome::Deferred => {
                self.gate.counters.slow_consumer_deferrals += 1;
            }
            SendOutcome::Overflow => {
                // a writer-gone overflow is a hangup (reader reports it);
                // a deferred-cap overflow is a slow consumer we evict
                if !conn.dead {
                    conn.dead = true;
                    self.gate.counters.slow_consumer_closes += 1;
                }
            }
        }
    }

    /// Retire finished and dead connections (cancelling live work on the
    /// dead ones so their KV pages free immediately).
    fn cleanup(&mut self) {
        let done: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                !c.dead && c.closing && c.live.is_empty() && !c.has_deferred()
            })
            .map(|(&id, _)| id)
            .collect();
        for id in done {
            self.finish_conn(id, true);
        }
        let dead: Vec<u64> =
            self.conns.iter().filter(|(_, c)| c.dead).map(|(&id, _)| id).collect();
        for id in dead {
            self.finish_conn(id, false);
        }
    }

    fn finish_conn(&mut self, conn_id: u64, graceful: bool) {
        let Some(mut conn) = self.conns.remove(&conn_id) else { return };
        for (&global, _) in conn.live.iter() {
            self.backend.cancel(global);
            self.routes.remove(&global);
        }
        conn.live.clear();
        conn.close(graceful);
        let t = self.backend.now();
        self.backend.trace_event(&TraceEvent::ConnClose { conn: conn_id, t });
        self.stats.closed += 1;
    }

    fn shutdown(&mut self) {
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            self.finish_conn(id, false);
        }
    }
}

/// Deterministic in-process backend: requests admit up to `max_active` at
/// a time, stream one token per step, and finish after `max_new_tokens`
/// steps on a virtual clock. Lets the whole network layer — protocol,
/// backpressure, disconnect-cancel, trace spans — run in tests and smoke
/// jobs without engine artifacts.
pub struct MockBackend {
    /// virtual seconds per decode round
    pub step_s: f64,
    /// concurrent decode slots; excess submissions queue (visible to the
    /// `queue_depth` admission gate)
    pub max_active: usize,
    /// KV accounting per admitted token (prompt + budgeted new tokens)
    pub kv_bytes_per_token: usize,
    now: f64,
    queue: Vec<Request>,
    active: Vec<MockActive>,
    pending: Vec<ServeEvent>,
    kv_in_use: usize,
    /// trace lines captured via [`ServeBackend::trace_event`]
    pub trace: Vec<String>,
    /// `ServeEvent::sig(true)` of every event `step` produced, in order —
    /// the byte-diffable determinism record for loopback smoke runs
    pub event_log: Vec<String>,
    /// page granularity (tokens) of the mock's shared-prefix prefill
    /// model; 0 = off (every admission prices its full prompt)
    pub prefix_page: usize,
    /// modeled prefill seconds per prompt token (only read when
    /// `prefix_page > 0`; the knobs-off mock keeps `prefill_seconds: 0.0`
    /// exactly as before, so existing determinism logs are unchanged)
    pub prefill_s_per_token: f64,
    /// page-aligned (chunk index, token ids) prefixes already prefilled —
    /// the mock's stand-in for the engine-side `PrefixIndex`
    published: std::collections::HashSet<(usize, Vec<i32>)>,
    /// one record per admission: (request id, prompt tokens actually
    /// prefilled, modeled prefill seconds). The wire `finished` frame has
    /// no prefill field, so loopback tests read the win here.
    pub prefill_log: Vec<(u64, usize, f64)>,
}

struct MockActive {
    req: Request,
    admitted_at: f64,
    emitted: usize,
    kv: usize,
    /// modeled prefill span for this admission (0.0 with the model off)
    prefill_s: f64,
}

impl Default for MockBackend {
    fn default() -> Self {
        MockBackend::new()
    }
}

impl MockBackend {
    pub fn new() -> MockBackend {
        MockBackend {
            step_s: 0.001,
            max_active: 4,
            kv_bytes_per_token: 64,
            now: 0.0,
            queue: Vec::new(),
            active: Vec::new(),
            pending: Vec::new(),
            kv_in_use: 0,
            trace: Vec::new(),
            event_log: Vec::new(),
            prefix_page: 0,
            prefill_s_per_token: 0.0,
            published: std::collections::HashSet::new(),
            prefill_log: Vec::new(),
        }
    }

    /// Model one prompt's prefill: leading page-aligned chunks already
    /// published are skipped (longest match, capped so at least one token
    /// is always prefilled — mirroring the engine-side adoption cap), then
    /// every full chunk of this prompt is published for later arrivals.
    /// Returns (tokens prefilled, modeled prefill seconds).
    fn model_prefill(&mut self, prompt: &[i32]) -> (usize, f64) {
        let mut skipped = 0usize;
        if self.prefix_page > 0 {
            let p = self.prefix_page;
            for (i, chunk) in prompt.chunks_exact(p).enumerate() {
                if (i + 1) * p >= prompt.len() {
                    break;
                }
                if self.published.contains(&(i, chunk.to_vec())) {
                    skipped += p;
                } else {
                    break;
                }
            }
            for (i, chunk) in prompt.chunks_exact(p).enumerate() {
                self.published.insert((i, chunk.to_vec()));
            }
        }
        let prefilled = prompt.len() - skipped;
        let span = if self.prefix_page > 0 {
            prefilled as f64 * self.prefill_s_per_token
        } else {
            0.0
        };
        (prefilled, span)
    }
}

impl ServeBackend for MockBackend {
    fn submit(&mut self, req: Request) {
        self.queue.push(req);
    }

    fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(pos);
            self.pending.push(ServeEvent::Cancelled { id, t: self.now });
            return true;
        }
        if let Some(pos) = self.active.iter().position(|a| a.req.id == id) {
            let a = self.active.remove(pos);
            self.kv_in_use -= a.kv;
            self.pending.push(ServeEvent::Cancelled { id, t: self.now });
            return true;
        }
        false
    }

    fn step(&mut self) -> Result<Vec<ServeEvent>> {
        let mut out = std::mem::take(&mut self.pending);
        // admit into free decode slots
        while self.active.len() < self.max_active && !self.queue.is_empty() {
            let req = self.queue.remove(0);
            let kv =
                (req.prompt.len() + req.max_new_tokens) * self.kv_bytes_per_token;
            self.kv_in_use += kv;
            let (prefilled, prefill_s) = self.model_prefill(&req.prompt);
            self.prefill_log.push((req.id, prefilled, prefill_s));
            out.push(ServeEvent::Admitted { id: req.id, t: self.now });
            self.active.push(MockActive {
                req,
                admitted_at: self.now,
                emitted: 0,
                kv,
                prefill_s,
            });
        }
        if !self.active.is_empty() {
            self.now += self.step_s;
            let mut finished = Vec::new();
            for (i, a) in self.active.iter_mut().enumerate() {
                a.emitted += 1;
                out.push(ServeEvent::Token {
                    id: a.req.id,
                    tok: a.emitted as i32,
                    t: self.now,
                });
                if a.emitted >= a.req.max_new_tokens {
                    finished.push(i);
                }
            }
            for i in finished.into_iter().rev() {
                let a = self.active.remove(i);
                self.kv_in_use -= a.kv;
                out.push(ServeEvent::Finished(RequestRecord {
                    id: a.req.id,
                    tier: a.req.tier,
                    queue_seconds: a.admitted_at - a.req.arrival_s,
                    prefill_seconds: a.prefill_s,
                    ttft_seconds: a.admitted_at - a.req.arrival_s
                        + a.prefill_s
                        + self.step_s,
                    decode_seconds: a.emitted as f64 * self.step_s,
                    e2e_seconds: self.now - a.req.arrival_s,
                    prompt_tokens: a.req.prompt.len(),
                    new_tokens: a.emitted,
                    session_reused_tokens: 0,
                }));
            }
        }
        for ev in &out {
            self.event_log.push(ev.sig(true));
        }
        Ok(out)
    }

    fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.active.is_empty() || !self.pending.is_empty()
    }

    fn now(&self) -> f64 {
        self.now
    }

    fn queued_len(&self) -> usize {
        self.queue.len()
    }

    fn kv_bytes_in_use(&self) -> usize {
        self.kv_in_use
    }

    fn live_stats(&self) -> crate::coordinator::LiveStats {
        // the mock has one implicit worker and no paging tiers: everything
        // admitted counts as hot, first tokens always meet their target
        let mut queued_by_tier = [0u64; 3];
        for r in &self.queue {
            queued_by_tier[(r.tier.rank() as usize).min(2)] += 1;
        }
        crate::coordinator::LiveStats {
            t: self.now,
            queued_by_tier,
            active: self.active.len() as u64,
            preempted: 0,
            deferred: 0,
            workers: vec![crate::coordinator::WorkerKv {
                kv_bytes_in_use: self.kv_in_use as u64,
                pages_hot: self.active.len() as u64,
                pages_cold: 0,
                pages_disk: 0,
            }],
            ttft_attained: [0; 3],
            ttft_total: [0; 3],
            stalled: 0,
        }
    }

    fn trace_event(&mut self, ev: &TraceEvent) {
        self.trace.push(ev.to_line());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    fn read_msg(reader: &mut BufReader<TcpStream>) -> Option<ServerMsg> {
        let mut line = String::new();
        if reader.read_line(&mut line).ok()? == 0 {
            return None;
        }
        Some(ServerMsg::parse(line.trim_end()).expect("valid server line"))
    }

    fn spawn_server(
        cfg: ServerConfig,
    ) -> (SocketAddr, std::thread::JoinHandle<(ServerStats, MockBackend)>) {
        let server = Server::bind(cfg).expect("bind loopback");
        let addr = server.local_addr().expect("bound addr");
        let handle = std::thread::spawn(move || {
            let mut backend = MockBackend::new();
            let stats = server.run(&mut backend).expect("server run");
            (stats, backend)
        });
        (addr, handle)
    }

    #[test]
    fn loopback_submit_streams_tokens_then_finishes() {
        let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
        let (addr, server) = spawn_server(cfg);

        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(
            read_msg(&mut reader),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        let submit = ClientMsg::Submit {
            id: 0,
            prompt: "hello".into(),
            max_new: 3,
            session: None,
            deadline_ms: None,
            tier: None,
        };
        stream.write_all(format!("{}\n", submit.to_line()).as_bytes()).unwrap();

        let mut tokens = 0;
        loop {
            let msg = read_msg(&mut reader).expect("stream stays open to terminal");
            match msg {
                ServerMsg::Admitted { id: 0, .. } => {}
                ServerMsg::Token { id: 0, .. } => tokens += 1,
                ServerMsg::Finished { id: 0, new_tokens, .. } => {
                    assert_eq!(new_tokens, 3);
                    break;
                }
                other => panic!("unexpected message: {other:?}"),
            }
        }
        assert_eq!(tokens, 3, "every decoded token streams back");

        stream.write_all(format!("{}\n", ClientMsg::Close.to_line()).as_bytes()).unwrap();
        assert_eq!(read_msg(&mut reader), None, "server closes after close op");

        let (stats, backend) = server.join().unwrap();
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.closed, 1);
        assert_eq!(stats.submitted, 1);
        assert_eq!(backend.kv_bytes_in_use(), 0);
        // one conn_open and one conn_close span landed in the trace
        let kinds: Vec<bool> = vec![
            backend.trace.iter().any(|l| l.contains("conn_open")),
            backend.trace.iter().any(|l| l.contains("conn_close")),
        ];
        assert_eq!(kinds, vec![true, true], "trace: {:?}", backend.trace);
    }

    #[test]
    fn stats_op_answers_with_a_live_snapshot() {
        let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
        let (addr, server) = spawn_server(cfg);

        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(
            read_msg(&mut reader),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        stream
            .write_all(format!("{}\n", ClientMsg::Stats.to_line()).as_bytes())
            .unwrap();
        let msg = read_msg(&mut reader).expect("stats reply");
        let ServerMsg::Stats { stats, net } = msg else {
            panic!("expected stats, got {msg:?}");
        };
        // idle mock backend: empty queues, one worker row, nothing shed
        assert_eq!(stats.queued_by_tier, [0, 0, 0]);
        assert_eq!(stats.active, 0);
        assert_eq!(stats.workers.len(), 1, "mock reports one worker");
        assert_eq!(net, ShedCounters::default());

        stream
            .write_all(format!("{}\n", ClientMsg::Close.to_line()).as_bytes())
            .unwrap();
        assert_eq!(read_msg(&mut reader), None);
        let (stats, _) = server.join().unwrap();
        assert_eq!(stats.accepted, 1);
    }

    #[test]
    fn conn_over_max_conns_is_shed_with_the_limit_named() {
        let cfg = ServerConfig {
            exit_when_idle: true,
            admission: AdmissionConfig { max_conns: 1, ..AdmissionConfig::default() },
            ..ServerConfig::default()
        };
        let (addr, server) = spawn_server(cfg);

        let mut first = TcpStream::connect(addr).expect("connect");
        let mut reader1 = BufReader::new(first.try_clone().unwrap());
        assert_eq!(
            read_msg(&mut reader1),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );

        let second = TcpStream::connect(addr).expect("connect");
        let mut reader2 = BufReader::new(second);
        assert_eq!(
            read_msg(&mut reader2),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        assert_eq!(
            read_msg(&mut reader2),
            Some(ServerMsg::Overload { id: None, limit: "max_conns".into(), max: 1 }),
            "over-cap connection gets a typed overload, not a silent hangup"
        );
        assert_eq!(read_msg(&mut reader2), None, "then the server closes it");

        first
            .write_all(format!("{}\n", ClientMsg::Close.to_line()).as_bytes())
            .unwrap();
        let (stats, _) = server.join().unwrap();
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.shed.conns_shed, 1);
    }

    #[test]
    fn disconnect_mid_stream_cancels_and_frees_kv() {
        let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
        let (addr, server) = spawn_server(cfg);

        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(
            read_msg(&mut reader),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        let submit = ClientMsg::Submit {
            id: 0,
            prompt: "long running".into(),
            max_new: 100_000,
            session: None,
            deadline_ms: None,
            tier: None,
        };
        stream.write_all(format!("{}\n", submit.to_line()).as_bytes()).unwrap();
        // wait until the request is really decoding, then vanish
        loop {
            match read_msg(&mut reader).expect("open") {
                ServerMsg::Token { .. } => break,
                _ => continue,
            }
        }
        drop(reader);
        drop(stream);

        let (stats, backend) = server.join().unwrap();
        assert_eq!(
            backend.kv_bytes_in_use(),
            0,
            "disconnect frees the request's KV mid-flight"
        );
        assert!(!backend.has_work(), "no orphaned work after disconnect");
        assert_eq!(stats.closed, 1);
    }

    #[test]
    fn bad_lines_get_typed_errors_not_hangups() {
        let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
        let (addr, server) = spawn_server(cfg);

        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(
            read_msg(&mut reader),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        stream.write_all(b"this is not json\n").unwrap();
        match read_msg(&mut reader) {
            Some(ServerMsg::Error { .. }) => {}
            other => panic!("expected error line, got {other:?}"),
        }
        stream.write_all(format!("{}\n", ClientMsg::Close.to_line()).as_bytes()).unwrap();
        assert_eq!(read_msg(&mut reader), None);
        let (stats, _) = server.join().unwrap();
        assert_eq!(stats.bad_lines, 1);
    }

    #[test]
    fn mock_backend_is_deterministic_and_accounts_kv() {
        let run = || {
            let mut b = MockBackend::new();
            b.max_active = 1;
            b.submit(Request {
                id: 1,
                arrival_s: 0.0,
                prompt: vec![0; 4],
                max_new_tokens: 2,
                session: None,
                task: None,
                answer: None,
                deadline_ms: None,
                tier: SloTier::Batch,
            });
            b.submit(Request {
                id: 2,
                arrival_s: 0.0,
                prompt: vec![0; 4],
                max_new_tokens: 1,
                session: None,
                task: None,
                answer: None,
                deadline_ms: None,
                tier: SloTier::Batch,
            });
            let mut sigs = Vec::new();
            while b.has_work() {
                for ev in b.step().unwrap() {
                    sigs.push(ev.sig(true));
                }
            }
            assert_eq!(b.kv_bytes_in_use(), 0);
            sigs
        };
        let a = run();
        assert!(!a.is_empty());
        assert_eq!(a, run(), "same submissions, same event stream");
    }

    #[test]
    fn cancel_of_a_finished_client_id_is_an_idempotent_no_op() {
        // the route for a finished request is retired, so a late cancel
        // from the client must not touch the backend, emit a Cancelled
        // line, or disturb the connection — same idempotence contract as
        // Frontend::cancel on a terminal request
        let cfg = ServerConfig { exit_when_idle: true, ..ServerConfig::default() };
        let (addr, server) = spawn_server(cfg);

        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(
            read_msg(&mut reader),
            Some(ServerMsg::Hello { schema: PROTO_SCHEMA })
        );
        let submit = ClientMsg::Submit {
            id: 7,
            prompt: "hello".into(),
            max_new: 2,
            session: None,
            deadline_ms: None,
            tier: None,
        };
        stream.write_all(format!("{}\n", submit.to_line()).as_bytes()).unwrap();
        let mut finished = 0;
        loop {
            match read_msg(&mut reader).expect("stream open to terminal") {
                ServerMsg::Finished { id: 7, .. } => {
                    finished += 1;
                    break;
                }
                ServerMsg::Cancelled { .. } => panic!("nothing was cancelled"),
                _ => {}
            }
        }
        // the request is terminal server-side; cancel it anyway
        let cancel = ClientMsg::Cancel { id: 7 };
        stream.write_all(format!("{}\n", cancel.to_line()).as_bytes()).unwrap();
        stream.write_all(format!("{}\n", ClientMsg::Close.to_line()).as_bytes()).unwrap();
        // the late cancel produces no reply at all: the next thing the
        // client observes is the graceful close
        while let Some(msg) = read_msg(&mut reader) {
            assert!(
                !matches!(msg, ServerMsg::Cancelled { .. } | ServerMsg::Error { .. }),
                "late cancel must be silent, got {msg:?}"
            );
        }
        let (stats, backend) = server.join().unwrap();
        assert_eq!(finished, 1, "exactly one terminal event");
        assert_eq!(stats.cancels, 0, "terminal id never reaches the backend");
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.closed, 1);
        assert_eq!(backend.kv_bytes_in_use(), 0);
    }
}
