//! Accept loop: one thread turning inbound TCP connections into
//! [`Ctl::NewConn`] control messages for the serving pump.
//!
//! The listener socket runs non-blocking with a short sleep on
//! `WouldBlock` so the thread can notice the stop flag promptly; admission
//! (the `max_conns` gate) happens on the pump thread, not here, keeping
//! every shed decision on the same thread that owns the counters.

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::conn::Ctl;

pub(crate) struct Listener {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Listener {
    pub fn spawn(listener: TcpListener, ctl: Sender<Ctl>) -> std::io::Result<Listener> {
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("tinyserve-accept".into())
            .spawn(move || {
                while !stop_flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if ctl.send(Ctl::NewConn(stream)).is_err() {
                                return; // pump is gone
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        // transient accept errors (e.g. ECONNABORTED):
                        // back off and keep listening
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
            })?;
        Ok(Listener { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        self.stop();
    }
}
