//! Line-delimited JSON wire protocol for the network serving front door.
//!
//! One JSON object per `\n`-terminated line in each direction, built on
//! the deterministic `util::json::Json` serializer (sorted keys, ints
//! render without a decimal point), so identical messages always encode
//! to identical bytes. The server's first line is always
//! [`ServerMsg::Hello`] carrying [`PROTO_SCHEMA`] — clients reject a
//! version they do not speak, and archived captures stay
//! self-describing like the trace and event-log streams.
//!
//! Client → server operations (`"op"` field):
//!
//! ```text
//! {"op":"submit","id":0,"prompt":"…","max_new":16}        // + optional
//! {"op":"submit","id":1,"prompt":"…","max_new":16,        //   fields
//!  "session":7,"deadline_ms":250,"tier":"interactive"}
//! {"op":"cancel","id":0}
//! {"op":"stats"}
//! {"op":"close"}
//! ```
//!
//! Server → client messages (`"kind"` field) mirror the frontend's
//! `ServeEvent` lifecycle — `admitted`, `deferred`, `token`, `preempted`,
//! `resumed`, `finished`, `cancelled`, `expired` — plus the protocol-level
//! `hello`, the backpressure pair `retry` (typed retry-after: resubmit
//! later) and `overload` (typed shed naming the limit that fired), the
//! `stats` introspection snapshot answering the client op of the same
//! name, and `error` for unparseable input. `preempted`/`resumed` are informational
//! pauses in the token stream, NOT terminal — a well-behaved client keeps
//! the request open until `finished`/`cancelled`/`expired`. Request ids on the wire are always the *client's*
//! per-connection ids; the server translates to and from its global ids
//! at the connection boundary. Ids must stay below 2^53 (they ride JSON
//! numbers).

use crate::coordinator::{LiveStats, ServeEvent, WorkerKv};
use crate::metrics::RequestRecord;
use crate::util::json::Json;
use crate::workload::SloTier;

use super::shed::ShedCounters;

/// Wire-protocol schema version, carried by the `hello` line. Bump on any
/// message-shape change so old clients fail loudly instead of misparsing.
/// v2: `submit` takes an optional `tier` (SLO class); `preempted` and
/// `resumed` stream as non-terminal lifecycle messages.
/// v3: `stats` op returns a live introspection snapshot (queue depths by
/// tier, per-worker KV residency, TTFT attainment, `net_*` shed counters).
pub const PROTO_SCHEMA: u64 = 3;

/// One client → server operation.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// submit a prompt; `id` is the client's connection-local request id
    Submit {
        id: u64,
        prompt: String,
        max_new: usize,
        session: Option<u64>,
        deadline_ms: Option<f64>,
        /// SLO class (`"interactive"` / `"batch"` / `"background"`);
        /// omitted means batch, the default tier
        tier: Option<SloTier>,
    },
    /// cancel a previously submitted request (any pre-terminal state)
    Cancel { id: u64 },
    /// request a live introspection snapshot; answered with a single
    /// [`ServerMsg::Stats`] line (schema 3)
    Stats,
    /// done submitting; the server finishes streaming in-flight requests,
    /// then closes the connection
    Close,
}

impl ClientMsg {
    pub fn to_line(&self) -> String {
        match self {
            ClientMsg::Submit {
                id,
                prompt,
                max_new,
                session,
                deadline_ms,
                tier,
            } => {
                let mut pairs: Vec<(&str, Json)> = vec![
                    ("op", Json::from("submit")),
                    ("id", Json::Num(*id as f64)),
                    ("prompt", Json::from(prompt.as_str())),
                    ("max_new", Json::from(*max_new)),
                ];
                if let Some(s) = session {
                    pairs.push(("session", Json::Num(*s as f64)));
                }
                if let Some(d) = deadline_ms {
                    pairs.push(("deadline_ms", Json::Num(*d)));
                }
                if let Some(t) = tier {
                    pairs.push(("tier", Json::from(t.name())));
                }
                Json::obj(pairs).to_string()
            }
            ClientMsg::Cancel { id } => Json::obj(vec![
                ("op", Json::from("cancel")),
                ("id", Json::Num(*id as f64)),
            ])
            .to_string(),
            ClientMsg::Stats => {
                Json::obj(vec![("op", Json::from("stats"))]).to_string()
            }
            ClientMsg::Close => {
                Json::obj(vec![("op", Json::from("close"))]).to_string()
            }
        }
    }

    /// Parse one request line. Errors are protocol errors — the server
    /// answers them with a [`ServerMsg::Error`] instead of dropping the
    /// connection.
    pub fn parse(line: &str) -> Result<ClientMsg, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let op = v
            .get("op")
            .and_then(|j| j.as_str())
            .ok_or_else(|| "missing 'op'".to_string())?;
        let id = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(|j| j.as_f64())
                .filter(|f| *f >= 0.0)
                .map(|f| f as u64)
                .ok_or_else(|| format!("missing or invalid '{key}'"))
        };
        match op {
            "submit" => Ok(ClientMsg::Submit {
                id: id("id")?,
                prompt: v
                    .get("prompt")
                    .and_then(|j| j.as_str())
                    .ok_or_else(|| "missing or invalid 'prompt'".to_string())?
                    .to_string(),
                max_new: v
                    .get("max_new")
                    .and_then(|j| j.as_usize())
                    .filter(|&n| n > 0)
                    .ok_or_else(|| "missing or invalid 'max_new'".to_string())?,
                session: v.get("session").and_then(|j| j.as_f64()).map(|f| f as u64),
                deadline_ms: v.get("deadline_ms").and_then(|j| j.as_f64()),
                tier: match v.get("tier").and_then(|j| j.as_str()) {
                    None => None,
                    Some(name) => Some(
                        SloTier::parse(name)
                            .ok_or_else(|| format!("unknown tier '{name}'"))?,
                    ),
                },
            }),
            "cancel" => Ok(ClientMsg::Cancel { id: id("id")? }),
            "stats" => Ok(ClientMsg::Stats),
            "close" => Ok(ClientMsg::Close),
            other => Err(format!("unknown op '{other}'")),
        }
    }
}

/// One server → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    /// first line on every accepted connection
    Hello { schema: u64 },
    Admitted { id: u64, t: f64 },
    Deferred { id: u64, t: f64 },
    Token { id: u64, tok: i32, t: f64 },
    /// non-terminal: the request is paused for a higher SLO tier and will
    /// resume from its KV snapshot — the token stream continues later
    Preempted { id: u64, t: f64 },
    /// non-terminal: the paused request is decoding again
    Resumed { id: u64, t: f64 },
    Finished { id: u64, new_tokens: usize, e2e_s: f64 },
    Cancelled { id: u64, t: f64 },
    Expired { id: u64, t: f64 },
    /// admission backpressure (defer): resubmit after the hint
    Retry { id: u64, retry_after_ms: f64 },
    /// typed overload: the named limit shed this operation (or, with no
    /// `id`, this whole connection at accept)
    Overload { id: Option<u64>, limit: String, max: usize },
    /// live introspection snapshot answering a client `stats` op: backend
    /// queue/KV state plus this listener's `net_*` shed counters
    Stats { stats: LiveStats, net: ShedCounters },
    /// protocol error (e.g. an unparseable request line)
    Error { reason: String },
}

impl ServerMsg {
    /// Translate a frontend `ServeEvent` onto the wire, rewriting the
    /// server's global request id to the connection's `client_id`.
    pub fn from_event(ev: &ServeEvent, client_id: u64) -> ServerMsg {
        match ev {
            ServeEvent::Admitted { t, .. } => {
                ServerMsg::Admitted { id: client_id, t: *t }
            }
            ServeEvent::Deferred { t, .. } => {
                ServerMsg::Deferred { id: client_id, t: *t }
            }
            ServeEvent::Token { tok, t, .. } => {
                ServerMsg::Token { id: client_id, tok: *tok, t: *t }
            }
            ServeEvent::Preempted { t, .. } => {
                ServerMsg::Preempted { id: client_id, t: *t }
            }
            ServeEvent::Resumed { t, .. } => {
                ServerMsg::Resumed { id: client_id, t: *t }
            }
            ServeEvent::Finished(rec) => ServerMsg::finished(rec, client_id),
            ServeEvent::Cancelled { t, .. } => {
                ServerMsg::Cancelled { id: client_id, t: *t }
            }
            ServeEvent::DeadlineExpired { t, .. } => {
                ServerMsg::Expired { id: client_id, t: *t }
            }
        }
    }

    fn finished(rec: &RequestRecord, client_id: u64) -> ServerMsg {
        ServerMsg::Finished {
            id: client_id,
            new_tokens: rec.new_tokens,
            e2e_s: rec.e2e_seconds,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ServerMsg::Hello { .. } => "hello",
            ServerMsg::Admitted { .. } => "admitted",
            ServerMsg::Deferred { .. } => "deferred",
            ServerMsg::Token { .. } => "token",
            ServerMsg::Preempted { .. } => "preempted",
            ServerMsg::Resumed { .. } => "resumed",
            ServerMsg::Finished { .. } => "finished",
            ServerMsg::Cancelled { .. } => "cancelled",
            ServerMsg::Expired { .. } => "expired",
            ServerMsg::Retry { .. } => "retry",
            ServerMsg::Overload { .. } => "overload",
            ServerMsg::Stats { .. } => "stats",
            ServerMsg::Error { .. } => "error",
        }
    }

    pub fn to_line(&self) -> String {
        let mut pairs: Vec<(&str, Json)> = vec![("kind", Json::from(self.kind()))];
        match self {
            ServerMsg::Hello { schema } => {
                pairs.push(("schema", Json::Num(*schema as f64)));
            }
            ServerMsg::Admitted { id, t }
            | ServerMsg::Deferred { id, t }
            | ServerMsg::Preempted { id, t }
            | ServerMsg::Resumed { id, t }
            | ServerMsg::Cancelled { id, t }
            | ServerMsg::Expired { id, t } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("t", Json::Num(*t)));
            }
            ServerMsg::Token { id, tok, t } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("tok", Json::Num(*tok as f64)));
                pairs.push(("t", Json::Num(*t)));
            }
            ServerMsg::Finished { id, new_tokens, e2e_s } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("new_tokens", Json::from(*new_tokens)));
                pairs.push(("e2e_s", Json::Num(*e2e_s)));
            }
            ServerMsg::Retry { id, retry_after_ms } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("retry_after_ms", Json::Num(*retry_after_ms)));
            }
            ServerMsg::Overload { id, limit, max } => {
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                pairs.push(("limit", Json::from(limit.as_str())));
                pairs.push(("max", Json::from(*max)));
            }
            ServerMsg::Stats { stats, net } => {
                let arr3 =
                    |a: &[u64; 3]| Json::arr_f64(&a.map(|n| n as f64));
                pairs.push(("t", Json::Num(stats.t)));
                pairs.push(("queued", arr3(&stats.queued_by_tier)));
                pairs.push(("active", Json::Num(stats.active as f64)));
                pairs.push(("preempted", Json::Num(stats.preempted as f64)));
                pairs.push(("deferred", Json::Num(stats.deferred as f64)));
                pairs.push((
                    "workers",
                    Json::Arr(
                        stats
                            .workers
                            .iter()
                            .map(|w| {
                                Json::obj(vec![
                                    (
                                        "kv_bytes",
                                        Json::Num(w.kv_bytes_in_use as f64),
                                    ),
                                    ("hot", Json::Num(w.pages_hot as f64)),
                                    ("cold", Json::Num(w.pages_cold as f64)),
                                    ("disk", Json::Num(w.pages_disk as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ));
                pairs.push(("ttft_attained", arr3(&stats.ttft_attained)));
                pairs.push(("ttft_total", arr3(&stats.ttft_total)));
                pairs.push(("stalled", Json::Num(stats.stalled as f64)));
                pairs.push(("net_conns_shed", Json::Num(net.conns_shed as f64)));
                pairs.push((
                    "net_submits_deferred",
                    Json::Num(net.submits_deferred as f64),
                ));
                pairs.push((
                    "net_submits_shed",
                    Json::Num(net.submits_shed as f64),
                ));
                pairs.push((
                    "net_slow_consumer_deferrals",
                    Json::Num(net.slow_consumer_deferrals as f64),
                ));
                pairs.push((
                    "net_slow_consumer_closes",
                    Json::Num(net.slow_consumer_closes as f64),
                ));
            }
            ServerMsg::Error { reason } => {
                pairs.push(("reason", Json::from(reason.as_str())));
            }
        }
        Json::obj(pairs).to_string()
    }

    /// Parse one response line (the client side of the protocol).
    pub fn parse(line: &str) -> Result<ServerMsg, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let kind = v
            .get("kind")
            .and_then(|j| j.as_str())
            .ok_or_else(|| "missing 'kind'".to_string())?;
        let num = |key: &str| -> Result<f64, String> {
            v.get(key)
                .and_then(|j| j.as_f64())
                .ok_or_else(|| format!("missing or invalid '{key}'"))
        };
        let id = |key: &str| -> Result<u64, String> { num(key).map(|f| f as u64) };
        match kind {
            "hello" => Ok(ServerMsg::Hello { schema: id("schema")? }),
            "admitted" => Ok(ServerMsg::Admitted { id: id("id")?, t: num("t")? }),
            "deferred" => Ok(ServerMsg::Deferred { id: id("id")?, t: num("t")? }),
            "token" => Ok(ServerMsg::Token {
                id: id("id")?,
                tok: num("tok")? as i32,
                t: num("t")?,
            }),
            "finished" => Ok(ServerMsg::Finished {
                id: id("id")?,
                new_tokens: num("new_tokens")? as usize,
                e2e_s: num("e2e_s")?,
            }),
            "preempted" => {
                Ok(ServerMsg::Preempted { id: id("id")?, t: num("t")? })
            }
            "resumed" => Ok(ServerMsg::Resumed { id: id("id")?, t: num("t")? }),
            "cancelled" => Ok(ServerMsg::Cancelled { id: id("id")?, t: num("t")? }),
            "expired" => Ok(ServerMsg::Expired { id: id("id")?, t: num("t")? }),
            "retry" => Ok(ServerMsg::Retry {
                id: id("id")?,
                retry_after_ms: num("retry_after_ms")?,
            }),
            "overload" => Ok(ServerMsg::Overload {
                id: v.get("id").and_then(|j| j.as_f64()).map(|f| f as u64),
                limit: v
                    .get("limit")
                    .and_then(|j| j.as_str())
                    .ok_or_else(|| "missing 'limit'".to_string())?
                    .to_string(),
                max: v
                    .get("max")
                    .and_then(|j| j.as_usize())
                    .ok_or_else(|| "missing 'max'".to_string())?,
            }),
            "stats" => {
                let arr3 = |key: &str| -> Result<[u64; 3], String> {
                    let a = v
                        .get(key)
                        .and_then(|j| j.as_arr())
                        .filter(|a| a.len() == 3)
                        .ok_or_else(|| format!("missing or invalid '{key}'"))?;
                    let mut out = [0u64; 3];
                    for (slot, j) in out.iter_mut().zip(a) {
                        *slot = j
                            .as_f64()
                            .ok_or_else(|| format!("non-numeric '{key}'"))?
                            as u64;
                    }
                    Ok(out)
                };
                let workers = v
                    .get("workers")
                    .and_then(|j| j.as_arr())
                    .ok_or_else(|| "missing 'workers'".to_string())?
                    .iter()
                    .map(|w| {
                        let f = |key: &str| -> Result<u64, String> {
                            w.get(key)
                                .and_then(|j| j.as_f64())
                                .map(|f| f as u64)
                                .ok_or_else(|| format!("bad worker '{key}'"))
                        };
                        Ok(WorkerKv {
                            kv_bytes_in_use: f("kv_bytes")?,
                            pages_hot: f("hot")?,
                            pages_cold: f("cold")?,
                            pages_disk: f("disk")?,
                        })
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(ServerMsg::Stats {
                    stats: LiveStats {
                        t: num("t")?,
                        queued_by_tier: arr3("queued")?,
                        active: id("active")?,
                        preempted: id("preempted")?,
                        deferred: id("deferred")?,
                        workers,
                        ttft_attained: arr3("ttft_attained")?,
                        ttft_total: arr3("ttft_total")?,
                        stalled: id("stalled")?,
                    },
                    net: ShedCounters {
                        conns_shed: id("net_conns_shed")?,
                        submits_deferred: id("net_submits_deferred")?,
                        submits_shed: id("net_submits_shed")?,
                        slow_consumer_deferrals: id(
                            "net_slow_consumer_deferrals",
                        )?,
                        slow_consumer_closes: id("net_slow_consumer_closes")?,
                    },
                })
            }
            "error" => Ok(ServerMsg::Error {
                reason: v
                    .get("reason")
                    .and_then(|j| j.as_str())
                    .unwrap_or_default()
                    .to_string(),
            }),
            other => Err(format!("unknown kind '{other}'")),
        }
    }

    /// True for messages that end a request's lifecycle on the wire.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            ServerMsg::Finished { .. }
                | ServerMsg::Cancelled { .. }
                | ServerMsg::Expired { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_messages_roundtrip() {
        let msgs = vec![
            ClientMsg::Submit {
                id: 3,
                prompt: "find the passkey".into(),
                max_new: 16,
                session: Some(7),
                deadline_ms: Some(250.0),
                tier: Some(SloTier::Interactive),
            },
            ClientMsg::Submit {
                id: 0,
                prompt: String::new(),
                max_new: 1,
                session: None,
                deadline_ms: None,
                tier: None,
            },
            ClientMsg::Cancel { id: 3 },
            ClientMsg::Stats,
            ClientMsg::Close,
        ];
        for m in msgs {
            let line = m.to_line();
            assert!(!line.contains('\n'), "one message per line: {line}");
            assert_eq!(ClientMsg::parse(&line).unwrap(), m, "{line}");
        }
    }

    #[test]
    fn server_messages_roundtrip() {
        let msgs = vec![
            ServerMsg::Hello { schema: PROTO_SCHEMA },
            ServerMsg::Admitted { id: 1, t: 0.5 },
            ServerMsg::Deferred { id: 1, t: 0.25 },
            ServerMsg::Token { id: 1, tok: -2, t: 0.75 },
            ServerMsg::Preempted { id: 1, t: 0.8 },
            ServerMsg::Resumed { id: 1, t: 0.9 },
            ServerMsg::Finished { id: 1, new_tokens: 4, e2e_s: 1.5 },
            ServerMsg::Cancelled { id: 2, t: 0.1 },
            ServerMsg::Expired { id: 2, t: 0.2 },
            ServerMsg::Retry { id: 5, retry_after_ms: 50.0 },
            ServerMsg::Overload { id: Some(5), limit: "queue_depth".into(), max: 4 },
            ServerMsg::Overload { id: None, limit: "max_conns".into(), max: 2 },
            ServerMsg::Stats {
                stats: LiveStats {
                    t: 1.5,
                    queued_by_tier: [1, 2, 0],
                    active: 3,
                    preempted: 1,
                    deferred: 2,
                    workers: vec![
                        WorkerKv {
                            kv_bytes_in_use: 4096,
                            pages_hot: 4,
                            pages_cold: 2,
                            pages_disk: 1,
                        },
                        WorkerKv::default(),
                    ],
                    ttft_attained: [1, 0, 0],
                    ttft_total: [1, 3, 0],
                    stalled: 1,
                },
                net: ShedCounters {
                    conns_shed: 1,
                    submits_deferred: 2,
                    submits_shed: 3,
                    slow_consumer_deferrals: 4,
                    slow_consumer_closes: 5,
                },
            },
            ServerMsg::Stats {
                stats: LiveStats::default(),
                net: ShedCounters::default(),
            },
            ServerMsg::Error { reason: "missing 'op'".into() },
        ];
        for m in msgs {
            let line = m.to_line();
            assert_eq!(ServerMsg::parse(&line).unwrap(), m, "{line}");
        }
    }

    #[test]
    fn stats_is_not_terminal_and_parse_checks_shape() {
        let m = ServerMsg::Stats {
            stats: LiveStats::default(),
            net: ShedCounters::default(),
        };
        assert!(!m.is_terminal(), "stats never closes a request");
        assert_eq!(ClientMsg::Stats.to_line(), r#"{"op":"stats"}"#);
        // a tier array of the wrong arity is a protocol error
        let bad = m.to_line().replace("\"queued\":[0,0,0]", "\"queued\":[0,0]");
        assert!(ServerMsg::parse(&bad).is_err(), "{bad}");
    }

    #[test]
    fn encoding_is_deterministic_sorted_json() {
        let m = ServerMsg::Token { id: 3, tok: 17, t: 0.25 };
        assert_eq!(m.to_line(), r#"{"id":3,"kind":"token","t":0.25,"tok":17}"#);
        assert_eq!(m.to_line(), m.to_line());
        let c = ClientMsg::Cancel { id: 9 };
        assert_eq!(c.to_line(), r#"{"id":9,"op":"cancel"}"#);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(ClientMsg::parse("not json").is_err());
        assert!(ClientMsg::parse(r#"{"op":"teleport"}"#).is_err());
        assert!(
            ClientMsg::parse(
                r#"{"id":0,"max_new":1,"op":"submit","prompt":"x","tier":"gold"}"#
            )
            .is_err(),
            "unknown tier names are protocol errors, not silent defaults"
        );
        assert!(ClientMsg::parse(r#"{"op":"submit","id":0}"#).is_err(), "no prompt");
        assert!(
            ClientMsg::parse(r#"{"id":0,"max_new":0,"op":"submit","prompt":"x"}"#)
                .is_err(),
            "max_new must be positive"
        );
        assert!(ServerMsg::parse(r#"{"kind":"nope"}"#).is_err());
        assert!(ServerMsg::parse(r#"{"kind":"token","id":1}"#).is_err());
    }

    #[test]
    fn events_translate_to_client_ids() {
        let ev = ServeEvent::Token { id: 1000, tok: 5, t: 1.0 };
        assert_eq!(
            ServerMsg::from_event(&ev, 3),
            ServerMsg::Token { id: 3, tok: 5, t: 1.0 },
            "global id 1000 rewrites to the connection's id 3"
        );
        let rec = RequestRecord {
            id: 1001,
            tier: SloTier::Batch,
            queue_seconds: 0.0,
            prefill_seconds: 0.0,
            ttft_seconds: 0.0,
            decode_seconds: 0.0,
            e2e_seconds: 2.0,
            prompt_tokens: 8,
            new_tokens: 4,
            session_reused_tokens: 0,
        };
        let m = ServerMsg::from_event(&ServeEvent::Finished(rec), 0);
        assert_eq!(m, ServerMsg::Finished { id: 0, new_tokens: 4, e2e_s: 2.0 });
        assert!(m.is_terminal());
        assert!(!ServerMsg::Admitted { id: 0, t: 0.0 }.is_terminal());
        // a preempted request is paused, not done: its wire messages must
        // never close the client's request
        assert!(!ServerMsg::Preempted { id: 0, t: 0.0 }.is_terminal());
        assert!(!ServerMsg::Resumed { id: 0, t: 0.0 }.is_terminal());
    }
}
