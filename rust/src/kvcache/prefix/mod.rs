//! Cross-request shared prefix cache: page-granular KV dedup for common
//! prompt prefixes (system prompts, few-shot templates).
//!
//! `PrefixIndex` maps page-aligned token chunks of already-prefilled
//! prompts to the pool pages holding their KV rows. A new request hashes
//! its prompt chunk by chunk (rolling hash over token-id chunks of
//! `page_size`), walks the index for the longest published match, and
//! *adopts* the matching pages by refcount bump — only the unmatched tail
//! is prefilled. Prefill computes K/V purely from `(token, position)`, so
//! an adopted page is bit-identical to the page the request would have
//! produced itself: adoption is a pure compute/memory optimization and
//! token streams are unchanged (the property battery pins this).
//!
//! Published pages are copy-on-write: the index holds its own pool
//! reference, so a sharer that appends into a shared partial page trips
//! `SeqCache`'s COW guard and privatizes first. The index is bounded by a
//! byte budget (`--prefix-cache-mb`); over budget, leaf entries unpublish
//! in strict LRU order (unique virtual ticks, so victim choice is
//! deterministic) and release their page reference. Chunk token-ids are
//! stored verbatim and compared on every walk, so a hash collision can
//! never splice the wrong KV pages into a request.

use std::collections::HashMap;

use super::pool::{PageId, PagePool};
use super::seq::{PageEntry, SeqCache};

/// Index key: (chain depth in pages, cumulative chunk hash). Depth keeps
/// equal-hash prefixes of different lengths from colliding structurally.
type Key = (u32, u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend a cumulative FNV-1a hash with one page-sized token chunk.
fn extend_hash(mut h: u64, chunk: &[i32]) -> u64 {
    for &t in chunk {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

#[derive(Debug)]
struct Entry {
    page: PageId,
    /// the chunk's token ids, verbatim — collision-proof verification
    tokens: Vec<i32>,
    parent: Option<Key>,
    /// published children (deeper chunks whose chain runs through here);
    /// only childless leaves are unpublish victims, so a chain never
    /// dangles
    children: u32,
    /// strictly unique LRU tick (bumped on adoption)
    last_used: u64,
}

/// Counters for the serve report and the table10 bench. All integers, so
/// merging across workers is exact and deterministic.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixStats {
    /// prompts walked against the index
    pub lookups: u64,
    /// lookups that adopted at least one page
    pub hits: u64,
    /// lookups that adopted nothing
    pub misses: u64,
    /// shared pages adopted by refcount bump
    pub pages_adopted: u64,
    /// prefill tokens skipped thanks to adoption
    pub tokens_skipped: u64,
    /// KV bytes deduplicated (adopted pages at the hot rate)
    pub bytes_deduped: u64,
    /// pages published into the index over the run
    pub pages_published: u64,
    /// pages unpublished by budget pressure
    pub pages_unpublished: u64,
}

impl PrefixStats {
    pub fn merge(&mut self, o: &PrefixStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.misses += o.misses;
        self.pages_adopted += o.pages_adopted;
        self.tokens_skipped += o.tokens_skipped;
        self.bytes_deduped += o.bytes_deduped;
        self.pages_published += o.pages_published;
        self.pages_unpublished += o.pages_unpublished;
    }

    /// Fraction of lookups that adopted at least one page.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

/// Per-worker shared prefix index. Pages referenced here carry one pool
/// refcount owned by the index itself (`publish` retains, unpublish and
/// `clear` release), so a published page can never be freed behind the
/// index's back — "backing page freed" is exactly the unpublish path.
pub struct PrefixIndex {
    entries: HashMap<Key, Entry>,
    /// byte budget for published pages (hot rate); `None` = unbounded
    budget_bytes: Option<usize>,
    /// minimum matched pages before adoption pays off
    min_pages: usize,
    tick: u64,
    bytes: usize,
    pub stats: PrefixStats,
}

impl PrefixIndex {
    pub fn new(budget_bytes: Option<usize>, min_pages: usize) -> Self {
        PrefixIndex {
            entries: HashMap::new(),
            budget_bytes,
            min_pages: min_pages.max(1),
            tick: 0,
            bytes: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes of published pages charged against the index budget.
    pub fn bytes_published(&self) -> usize {
        self.bytes
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Longest-prefix match: walk `prompt` in page-sized chunks against
    /// the published chains and adopt every matching page by refcount
    /// bump. Coverage is capped at `prompt.len() - 1` so the final prompt
    /// token is always prefilled by the adopter (it produces the first
    /// logits). Returns the adopted cache and the tokens covered, or
    /// `None` when fewer than `min_pages` pages match.
    pub fn adopt(
        &mut self,
        prompt: &[i32],
        pool: &mut PagePool,
    ) -> Option<(SeqCache, usize)> {
        self.stats.lookups += 1;
        let s = pool.page_size;
        let max_cover = prompt.len().saturating_sub(1);
        let mut matched: Vec<Key> = Vec::new();
        let mut h = FNV_OFFSET;
        let mut depth = 0u32;
        for chunk in prompt.chunks_exact(s) {
            if (depth as usize + 1) * s > max_cover {
                break;
            }
            h = extend_hash(h, chunk);
            depth += 1;
            match self.entries.get(&(depth, h)) {
                Some(e) if e.tokens == chunk => matched.push((depth, h)),
                _ => break,
            }
        }
        if matched.len() < self.min_pages {
            self.stats.misses += 1;
            return None;
        }
        let mut pages = Vec::with_capacity(matched.len());
        for (i, key) in matched.iter().enumerate() {
            let tick = self.next_tick();
            let e = self.entries.get_mut(key).expect("matched entry");
            e.last_used = tick;
            pool.retain(e.page);
            pages.push(PageEntry { id: e.page, base_pos: i * s });
        }
        let covered = pages.len() * s;
        self.stats.hits += 1;
        self.stats.pages_adopted += pages.len() as u64;
        self.stats.tokens_skipped += covered as u64;
        self.stats.bytes_deduped += (pages.len() * pool.page_bytes()) as u64;
        Some((SeqCache { pages, pos: covered, resident: covered }, covered))
    }

    /// Publish a freshly-prefilled prompt's full pages into the index.
    /// Each newly published page gains one index-owned pool reference.
    /// Chunks already published (by this or an earlier request) are
    /// chained through, not duplicated; a token mismatch on an existing
    /// key (hash collision) stops the chain — nothing past it could ever
    /// be adopted. Over-budget publishing unpublishes LRU leaves.
    pub fn publish(
        &mut self,
        prompt: &[i32],
        cache: &SeqCache,
        pool: &mut PagePool,
    ) {
        let s = pool.page_size;
        let mut h = FNV_OFFSET;
        let mut parent: Option<Key> = None;
        for (i, chunk) in prompt.chunks_exact(s).enumerate() {
            // only fully-filled pages at the expected position qualify:
            // the page's rows must be exactly this chunk's prefill output
            let Some(e) = cache.pages.get(i) else { break };
            if e.base_pos != i * s || pool.filled(e.id) < s {
                break;
            }
            h = extend_hash(h, chunk);
            let key = ((i + 1) as u32, h);
            if let Some(existing) = self.entries.get(&key) {
                if existing.tokens != chunk {
                    break; // hash collision: never chain past a mismatch
                }
                parent = Some(key);
                continue;
            }
            pool.retain(e.id);
            let tick = self.next_tick();
            self.entries.insert(
                key,
                Entry {
                    page: e.id,
                    tokens: chunk.to_vec(),
                    parent,
                    children: 0,
                    last_used: tick,
                },
            );
            if let Some(pk) = parent {
                self.entries.get_mut(&pk).expect("parent entry").children += 1;
            }
            self.bytes += pool.page_bytes();
            self.stats.pages_published += 1;
            parent = Some(key);
        }
        self.enforce_budget(pool);
    }

    /// Unpublish LRU leaves until published bytes fit the budget.
    fn enforce_budget(&mut self, pool: &mut PagePool) {
        let Some(budget) = self.budget_bytes else { return };
        while self.bytes > budget {
            if !self.unpublish_lru(pool) {
                break; // only reachable when the index is already empty
            }
        }
    }

    /// Remove the least-recently-used childless entry, releasing its page
    /// reference. Returns false when nothing is removable.
    fn unpublish_lru(&mut self, pool: &mut PagePool) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(_, e)| e.children == 0)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(&k, _)| k);
        let Some(key) = victim else { return false };
        let e = self.entries.remove(&key).expect("victim entry");
        if let Some(pk) = e.parent {
            self.entries.get_mut(&pk).expect("parent entry").children -= 1;
        }
        pool.release(e.page);
        self.bytes -= pool.page_bytes();
        self.stats.pages_unpublished += 1;
        true
    }

    /// Drop every published entry, releasing the index's page references
    /// (run teardown; pairs with `SessionStore::clear`).
    pub fn clear(&mut self, pool: &mut PagePool) {
        for (_, e) in self.entries.drain() {
            pool.release(e.page);
        }
        self.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    fn pool() -> PagePool {
        PagePool::new(1, 4, 4, KvDtype::F32)
    }

    /// Simulate prefill: one page-table entry per 4 tokens, rows encode
    /// the token id so tests can check adopted content.
    fn prefill(tokens: &[i32], pool: &mut PagePool) -> SeqCache {
        let mut c = SeqCache::new();
        for &t in tokens {
            let (page, slot) = c.slot_for_next(pool);
            pool.write_token(page, slot, 0, &[t as f32; 4], &[t as f32; 4]);
            c.commit_token();
        }
        c
    }

    fn toks(n: usize, base: i32) -> Vec<i32> {
        (0..n as i32).map(|i| base + i).collect()
    }

    #[test]
    fn publish_then_adopt_shares_pages() {
        let mut p = pool();
        let mut ix = PrefixIndex::new(None, 1);
        let prompt = toks(10, 100);
        let cache = prefill(&prompt, &mut p);
        ix.publish(&prompt, &cache, &mut p);
        // pages 0 and 1 are full (8 tokens); the partial third never
        // publishes
        assert_eq!(ix.len(), 2);
        assert_eq!(p.refcount(cache.pages[0].id), 2);
        assert_eq!(p.refcount(cache.pages[2].id), 1);

        // same template, different tail: both full pages adopt
        let mut prompt2 = toks(8, 100);
        prompt2.extend_from_slice(&[900, 901, 902]);
        let (adopted, covered) = ix.adopt(&prompt2, &mut p).expect("hit");
        assert_eq!(covered, 8);
        assert_eq!(adopted.pages.len(), 2);
        assert_eq!(adopted.pages[0].id, cache.pages[0].id, "same page shared");
        assert_eq!(adopted.pos, 8);
        assert_eq!(p.refcount(cache.pages[0].id), 3);
        assert_eq!(p.key_row(adopted.pages[1].id, 0, 0), vec![104.0; 4]);
        assert_eq!(ix.stats.hits, 1);
        assert_eq!(ix.stats.tokens_skipped, 8);
        assert_eq!(ix.stats.pages_adopted, 2);
    }

    #[test]
    fn adoption_never_covers_the_last_prompt_token() {
        let mut p = pool();
        let mut ix = PrefixIndex::new(None, 1);
        let prompt = toks(8, 0);
        let cache = prefill(&prompt, &mut p);
        ix.publish(&prompt, &cache, &mut p);
        // identical 8-token prompt: only the first page may adopt — the
        // final token must be prefilled by the adopter
        let (_, covered) = ix.adopt(&prompt, &mut p).expect("hit");
        assert_eq!(covered, 4);
        // a 9-token prompt sharing both pages adopts both
        let prompt9 = toks(9, 0);
        let (_, covered) = ix.adopt(&prompt9, &mut p).expect("hit");
        assert_eq!(covered, 8);
    }

    #[test]
    fn divergent_chunk_stops_the_match() {
        let mut p = pool();
        let mut ix = PrefixIndex::new(None, 1);
        let prompt = toks(12, 0);
        let cache = prefill(&prompt, &mut p);
        ix.publish(&prompt, &cache, &mut p);
        // second chunk diverges: only page 0 matches
        let mut alt = toks(12, 0);
        alt[5] = -7;
        let (_, covered) = ix.adopt(&alt, &mut p).expect("hit");
        assert_eq!(covered, 4);
        // fully divergent prompt: miss
        assert!(ix.adopt(&toks(12, 500), &mut p).is_none());
        assert_eq!(ix.stats.misses, 1);
    }

    #[test]
    fn min_pages_gates_small_matches() {
        let mut p = pool();
        let mut ix = PrefixIndex::new(None, 2);
        let prompt = toks(12, 0);
        let cache = prefill(&prompt, &mut p);
        ix.publish(&prompt, &cache, &mut p);
        // only one page matches -> below min_pages, no adoption
        let mut alt = toks(12, 0);
        alt[5] = -7;
        assert!(ix.adopt(&alt, &mut p).is_none());
        // two matching pages clear the bar
        let long = toks(12, 0);
        let (_, covered) = ix.adopt(&long, &mut p).expect("hit");
        assert_eq!(covered, 8);
    }

    #[test]
    fn budget_unpublishes_lru_leaves_first() {
        let mut p = pool();
        let pb = p.page_bytes();
        // room for two published pages
        let mut ix = PrefixIndex::new(Some(2 * pb), 1);
        let a = toks(5, 0);
        let ca = prefill(&a, &mut p);
        ix.publish(&a, &ca, &mut p);
        let b = toks(5, 100);
        let cb = prefill(&b, &mut p);
        ix.publish(&b, &cb, &mut p);
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.bytes_published(), 2 * pb);
        // touch A so B becomes the LRU victim
        let (ad, _) = ix.adopt(&toks(5, 0), &mut p).expect("hit");
        // publishing C evicts B (LRU leaf), keeps A
        let c = toks(5, 200);
        let cc = prefill(&c, &mut p);
        ix.publish(&c, &cc, &mut p);
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.stats.pages_unpublished, 1);
        assert!(ix.adopt(&toks(5, 100), &mut p).is_none(), "B unpublished");
        assert!(ix.adopt(&toks(5, 0), &mut p).is_some(), "A survives");
        assert!(ix.adopt(&toks(5, 200), &mut p).is_some(), "C survives");
        // B's page reference was released by the unpublish
        assert_eq!(p.refcount(cb.pages[0].id), 1);
        let _ = ad;
    }

    #[test]
    fn chains_unpublish_leaf_first_and_clear_balances() {
        let mut p = pool();
        let pb = p.page_bytes();
        let mut ix = PrefixIndex::new(Some(3 * pb), 1);
        let prompt = toks(13, 0); // three full pages
        let cache = prefill(&prompt, &mut p);
        ix.publish(&prompt, &cache, &mut p);
        assert_eq!(ix.len(), 3);
        // a fresh one-page publish forces one eviction: the chain's LEAF
        // (depth 3) goes, never an interior page a child still needs
        let b = toks(5, 500);
        let cb = prefill(&b, &mut p);
        ix.publish(&b, &cb, &mut p);
        assert_eq!(ix.len(), 3);
        let (_, covered) = ix.adopt(&toks(13, 0), &mut p).expect("hit");
        assert_eq!(covered, 8, "depth-3 leaf gone, depth 1-2 intact");
        // teardown releases every index reference
        ix.clear(&mut p);
        assert_eq!(ix.bytes_published(), 0);
        for e in cache.pages.iter().chain(cb.pages.iter()) {
            assert_eq!(p.refcount(e.id), 1, "only the owning cache remains");
        }
    }

    #[test]
    fn republish_is_idempotent() {
        let mut p = pool();
        let mut ix = PrefixIndex::new(None, 1);
        let prompt = toks(9, 0);
        let c1 = prefill(&prompt, &mut p);
        ix.publish(&prompt, &c1, &mut p);
        let c2 = prefill(&prompt, &mut p);
        ix.publish(&prompt, &c2, &mut p);
        assert_eq!(ix.len(), 2, "second publish chained, not duplicated");
        assert_eq!(ix.stats.pages_published, 2);
        // the index still references c1's pages, not c2's
        assert_eq!(p.refcount(c1.pages[0].id), 2);
        assert_eq!(p.refcount(c2.pages[0].id), 1);
    }

    #[test]
    fn adopted_cache_appends_copy_on_write() {
        let mut p = pool();
        let mut ix = PrefixIndex::new(None, 1);
        let prompt = toks(9, 0);
        let cache = prefill(&prompt, &mut p);
        ix.publish(&prompt, &cache, &mut p);
        let (mut adopted, covered) = ix.adopt(&prompt, &mut p).expect("hit");
        assert_eq!(covered, 8);
        // finish the tail then append a decode token: the adopted full
        // pages are never written; fresh pages take the new tokens
        let shared: Vec<_> = adopted.pages.iter().map(|e| e.id).collect();
        for &t in &prompt[covered..] {
            let (page, slot) = adopted.slot_for_next(&mut p);
            assert!(!shared.contains(&page), "no write into a shared page");
            p.write_token(page, slot, 0, &[t as f32; 4], &[t as f32; 4]);
            adopted.commit_token();
        }
        assert_eq!(adopted.pos, 9);
        for id in &shared {
            assert_eq!(p.refcount(*id), 3, "cache + index + adopter");
        }
    }

    #[test]
    fn stats_merge_sums_counters() {
        let mut a = PrefixStats {
            lookups: 2,
            hits: 1,
            misses: 1,
            pages_adopted: 3,
            tokens_skipped: 12,
            bytes_deduped: 1024,
            pages_published: 4,
            pages_unpublished: 1,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.lookups, 4);
        assert_eq!(a.pages_adopted, 6);
        assert_eq!(a.tokens_skipped, 24);
        assert_eq!(a.bytes_deduped, 2048);
        assert_eq!(a.pages_published, 8);
        assert_eq!(a.pages_unpublished, 2);
        assert!((a.hit_rate() - 0.5).abs() < 1e-12);
    }
}
