//! KV storage precision: f32, f16, and per-token-row symmetric int8.
//!
//! The paper's Sparse Attention Executor supports "FP16/INT8 KV formats"
//! (§3.1). Storage conversion happens on append; dequantization happens in
//! the gather hot loop (`PagePool::gather_rows`). Page *metadata* (the
//! min/max bounding boxes) always stays f32 — it is the scoring input and
//! costs only 2*d floats per page.

use crate::config::KvDtype;
use crate::util::f16;

/// One storage slab: tokens-rows of `width` channels at the configured
/// precision. Int8 keeps one scale per row (per-token quantization, the
/// standard KV-quant granularity).
#[derive(Debug)]
pub enum Slab {
    F32(Vec<f32>),
    F16(Vec<u16>),
    I8 { data: Vec<i8>, scales: Vec<f32> },
}

impl Slab {
    pub fn new(dtype: KvDtype, rows: usize, width: usize) -> Slab {
        match dtype {
            KvDtype::F32 => Slab::F32(vec![0.0; rows * width]),
            KvDtype::F16 => Slab::F16(vec![0; rows * width]),
            KvDtype::Int8 => Slab::I8 {
                data: vec![0; rows * width],
                scales: vec![0.0; rows],
            },
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            Slab::F32(_) => KvDtype::F32,
            Slab::F16(_) => KvDtype::F16,
            Slab::I8 { .. } => KvDtype::Int8,
        }
    }

    pub fn rows(&self, width: usize) -> usize {
        match self {
            Slab::F32(v) => v.len() / width,
            Slab::F16(v) => v.len() / width,
            Slab::I8 { data, .. } => data.len() / width,
        }
    }

    /// Grow to hold at least `rows` rows.
    pub fn grow(&mut self, rows: usize, width: usize) {
        match self {
            Slab::F32(v) => v.resize(rows * width, 0.0),
            Slab::F16(v) => v.resize(rows * width, 0),
            Slab::I8 { data, scales } => {
                data.resize(rows * width, 0);
                scales.resize(rows, 0.0);
            }
        }
    }

    /// Store one token row (encode to the slab precision).
    pub fn store_row(&mut self, row: usize, width: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), width);
        let off = row * width;
        match self {
            Slab::F32(v) => v[off..off + width].copy_from_slice(src),
            Slab::F16(v) => {
                for (dst, &s) in v[off..off + width].iter_mut().zip(src) {
                    *dst = f16::f32_to_f16_bits(s);
                }
            }
            Slab::I8 { data, scales } => {
                let amax = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                scales[row] = scale;
                let inv = 1.0 / scale;
                for (dst, &s) in data[off..off + width].iter_mut().zip(src) {
                    *dst = (s * inv).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
    }

    /// Decode `n_rows` consecutive rows starting at `row` into `dst`
    /// (f32, row-major). This is the gather hot path.
    pub fn load_rows(&self, row: usize, n_rows: usize, width: usize, dst: &mut [f32]) {
        debug_assert!(dst.len() >= n_rows * width);
        let off = row * width;
        let n = n_rows * width;
        match self {
            Slab::F32(v) => dst[..n].copy_from_slice(&v[off..off + n]),
            Slab::F16(v) => {
                f16::f16_slice_to_f32(&v[off..off + n], &mut dst[..n]);
            }
            Slab::I8 { data, scales } => {
                for r in 0..n_rows {
                    let s = scales[row + r];
                    let src = &data[(row + r) * width..(row + r + 1) * width];
                    let out = &mut dst[r * width..(r + 1) * width];
                    for (d, &q) in out.iter_mut().zip(src) {
                        *d = q as f32 * s;
                    }
                }
            }
        }
    }

    /// Decode a single row into an owned Vec (oracle policy / tests).
    pub fn load_row_vec(&self, row: usize, width: usize) -> Vec<f32> {
        let mut out = vec![0.0; width];
        self.load_rows(row, 1, width, &mut out);
        out
    }

    /// Raw view of one quantized row (int8 slabs only): the i8 data and
    /// its per-row scale. The disk spill tier copies these bytes verbatim
    /// instead of re-quantizing — a dequantize/requantize cycle can drift
    /// the stored scale by an ulp, and spill must be bit-exact.
    pub fn q8_row(&self, row: usize, width: usize) -> Option<(&[i8], f32)> {
        match self {
            Slab::I8 { data, scales } => {
                Some((&data[row * width..(row + 1) * width], scales[row]))
            }
            _ => None,
        }
    }

    /// Store one raw quantized row (int8 slabs only). Returns false for
    /// other precisions — callers fall back to the f32 path.
    pub fn store_q8_row(&mut self, row: usize, width: usize, q: &[i8], scale: f32) -> bool {
        debug_assert_eq!(q.len(), width);
        match self {
            Slab::I8 { data, scales } => {
                data[row * width..(row + 1) * width].copy_from_slice(q);
                scales[row] = scale;
                true
            }
            _ => false,
        }
    }

    pub fn bytes_per_row(&self, width: usize) -> usize {
        match self {
            Slab::F32(_) => width * 4,
            Slab::F16(_) => width * 2,
            Slab::I8 { .. } => width + 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(dtype: KvDtype, tol: f32) {
        let width = 16;
        let mut slab = Slab::new(dtype, 4, width);
        let src: Vec<f32> = (0..width).map(|i| (i as f32 - 7.5) * 0.3).collect();
        slab.store_row(2, width, &src);
        let mut dst = vec![0.0; width];
        slab.load_rows(2, 1, width, &mut dst);
        for (a, b) in src.iter().zip(&dst) {
            assert!(
                (a - b).abs() <= tol * a.abs().max(1.0),
                "{dtype:?}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn f32_exact() {
        roundtrip(KvDtype::F32, 0.0);
    }

    #[test]
    fn f16_half_ulp() {
        roundtrip(KvDtype::F16, 1.0 / 2048.0);
    }

    #[test]
    fn int8_one_percent() {
        roundtrip(KvDtype::Int8, 0.01);
    }

    #[test]
    fn int8_zero_row() {
        let mut slab = Slab::new(KvDtype::Int8, 1, 4);
        slab.store_row(0, 4, &[0.0; 4]);
        let mut dst = [9.0; 4];
        slab.load_rows(0, 1, 4, &mut dst);
        assert_eq!(dst, [0.0; 4]);
    }

    #[test]
    fn multi_row_load() {
        let width = 8;
        let mut slab = Slab::new(KvDtype::F16, 4, width);
        for r in 0..4 {
            let row: Vec<f32> = (0..width).map(|i| (r * width + i) as f32).collect();
            slab.store_row(r, width, &row);
        }
        let mut dst = vec![0.0; 2 * width];
        slab.load_rows(1, 2, width, &mut dst);
        assert_eq!(dst[0], 8.0);
        assert_eq!(dst[15], 23.0);
    }

    #[test]
    fn q8_raw_roundtrip_is_bit_exact() {
        let width = 8;
        let mut a = Slab::new(KvDtype::Int8, 2, width);
        let src: Vec<f32> = (0..width).map(|i| (i as f32 - 3.3) * 0.7).collect();
        a.store_row(1, width, &src);
        let (q, s) = a.q8_row(1, width).unwrap();
        let (q, s) = (q.to_vec(), s);
        let mut b = Slab::new(KvDtype::Int8, 2, width);
        assert!(b.store_q8_row(1, width, &q, s));
        assert_eq!(a.load_row_vec(1, width), b.load_row_vec(1, width));
        assert_eq!(b.q8_row(1, width).unwrap().1, s);
        // non-int8 slabs refuse the raw path
        let mut f = Slab::new(KvDtype::F32, 2, width);
        assert!(f.q8_row(1, width).is_none());
        assert!(!f.store_q8_row(1, width, &q, s));
    }

    #[test]
    fn grow_preserves() {
        let mut slab = Slab::new(KvDtype::F32, 2, 4);
        slab.store_row(1, 4, &[1.0, 2.0, 3.0, 4.0]);
        slab.grow(8, 4);
        assert_eq!(slab.rows(4), 8);
        assert_eq!(slab.load_row_vec(1, 4), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
