//! Paged KV cache: pool, per-sequence page tables, storage precisions and
//! bounding-box page metadata (paper §3.4-§3.5).

pub mod dtype;
pub mod pool;
pub mod seq;

pub use dtype::Slab;
pub use pool::{PageId, PagePool};
pub use seq::{PageEntry, SeqCache};
