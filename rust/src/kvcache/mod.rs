//! Paged KV cache: pool, per-sequence page tables, storage precisions,
//! bounding-box page metadata (paper §3.4-§3.5), and the memory-budgeted
//! page store with pluggable eviction policies.

pub mod dtype;
pub mod pool;
pub mod prefix;
pub mod seq;
pub mod store;

pub use dtype::Slab;
pub use pool::{PageId, PagePool};
pub use prefix::{PrefixIndex, PrefixStats};
pub use seq::{PageEntry, SeqCache};
pub use store::{
    default_spill_root, EvictionPolicyKind, PageStore, SpillConfig, SpillError,
    StoreStats,
};
