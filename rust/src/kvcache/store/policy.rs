//! Pluggable page-replacement policies for the budgeted `PageStore`.
//!
//! Three contrasting metadata shapes (mirroring the buffer-replacement
//! design notes this module is modelled on — see docs/pagestore_design.md):
//!
//! * **LRU** — exact recency via an intrusive doubly-linked list of page
//!   indices (`prev`/`next` arrays, no allocation per access).
//! * **CLOCK** — one reference bit per page plus a sweeping hand
//!   (second-chance approximation of LRU at O(1) metadata per access).
//! * **Query-aware cold** — TinyServe-native: demote the page whose recent
//!   bounding-box relevance (EMA of `sparsity::score_page` against live
//!   decode queries) is lowest. Recency-blind but query-aligned: a page
//!   that no current query attends to is cold even if recently written.
//! * **SIEVE** — FIFO insertion with one visited bit and a hand that
//!   *survives* evictions (Zhang et al., NSDI'24). New pages get a fast
//!   path out unless re-accessed, long-lived hot pages stay resident; the
//!   retained hand is what separates it from CLOCK's circular sweep.
//!
//! Policies see pages as bare `PageId`s; residency/pin/refcount state stays
//! in the store, which passes an `evictable` predicate into `victim`.

use crate::kvcache::pool::PageId;

const NIL: u32 = u32::MAX;

/// Which replacement policy the store runs (parseable from CLI flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicyKind {
    Lru,
    Clock,
    QueryAware,
    Sieve,
}

impl EvictionPolicyKind {
    pub fn parse(s: &str) -> Option<EvictionPolicyKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lru" => EvictionPolicyKind::Lru,
            "clock" | "second-chance" => EvictionPolicyKind::Clock,
            "query-aware" | "queryaware" | "qa" => EvictionPolicyKind::QueryAware,
            "sieve" => EvictionPolicyKind::Sieve,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicyKind::Lru => "lru",
            EvictionPolicyKind::Clock => "clock",
            EvictionPolicyKind::QueryAware => "query-aware",
            EvictionPolicyKind::Sieve => "sieve",
        }
    }

    pub fn all() -> &'static [EvictionPolicyKind] {
        &[
            EvictionPolicyKind::Lru,
            EvictionPolicyKind::Clock,
            EvictionPolicyKind::QueryAware,
            EvictionPolicyKind::Sieve,
        ]
    }

    /// Canonical parseable names, for CLI errors and help text.
    pub fn names() -> Vec<&'static str> {
        Self::all().iter().map(|k| k.name()).collect()
    }
}

/// Replacement strategy behind the store's demotion decisions.
///
/// `Send` is a supertrait: each worker's `PageStore` (and the policy
/// inside it) moves onto a scoped OS thread when decode rounds execute
/// workers in parallel. Policies are per-store state (never shared
/// across workers), so all implementations are `Send` for free.
pub trait EvictionPolicy: Send {
    fn kind(&self) -> EvictionPolicyKind;

    /// Grow per-page metadata to cover `cap` page ids.
    fn ensure_capacity(&mut self, cap: usize);

    /// Page became resident or was used (allocation, selection, promotion).
    /// `now` is the store's monotonic access tick.
    fn on_access(&mut self, id: PageId, now: u64);

    /// Bounding-box relevance observation for this page (query-aware
    /// signal; other policies ignore it).
    fn on_score(&mut self, _id: PageId, _score: f32) {}

    /// Sharer-count observation from the store's refcount reconciliation:
    /// how many owners (sequences, session snapshots, prefix-index
    /// entries) currently reference this page. A shared page serves K
    /// requests at once, so demoting it multiplies the cost across every
    /// sharer — sharing-aware policies weight victims accordingly;
    /// recency policies ignore the signal.
    fn on_sharers(&mut self, _id: PageId, _sharers: u32) {}

    /// Page left residency entirely (freed back to the pool).
    fn on_remove(&mut self, id: PageId);

    /// Choose and claim the next demotion victim among pages for which
    /// `evictable` returns true. Claimed pages leave the policy's candidate
    /// structures; a later `on_access` re-enters them.
    fn victim(&mut self, evictable: &mut dyn FnMut(PageId) -> bool) -> Option<PageId>;

    /// Relative hotness (higher = keep). Drives `PruneColdest`.
    fn rank(&self, id: PageId) -> f64;
}

pub fn make_eviction_policy(kind: EvictionPolicyKind) -> Box<dyn EvictionPolicy> {
    match kind {
        EvictionPolicyKind::Lru => Box::new(LruPolicy::default()),
        EvictionPolicyKind::Clock => Box::new(ClockPolicy::default()),
        EvictionPolicyKind::QueryAware => Box::new(QueryAwareCold::new(0.7)),
        EvictionPolicyKind::Sieve => Box::new(SievePolicy::default()),
    }
}

/// Exact LRU over an intrusive doubly-linked list: `head` is the most
/// recently used page, `tail` the demotion candidate. All operations are a
/// handful of index assignments; victim search walks tail -> head skipping
/// non-evictable (pinned/partial/cold) pages.
pub struct LruPolicy {
    prev: Vec<u32>, // toward head (more recent)
    next: Vec<u32>, // toward tail (less recent)
    in_list: Vec<bool>,
    stamp: Vec<u64>,
    head: u32,
    tail: u32,
}

impl Default for LruPolicy {
    fn default() -> Self {
        LruPolicy {
            prev: Vec::new(),
            next: Vec::new(),
            in_list: Vec::new(),
            stamp: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }
}

impl LruPolicy {
    fn detach(&mut self, id: u32) {
        if !self.in_list[id as usize] {
            return;
        }
        let p = self.prev[id as usize];
        let n = self.next[id as usize];
        if p != NIL {
            self.next[p as usize] = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.prev[n as usize] = p;
        } else {
            self.tail = p;
        }
        self.prev[id as usize] = NIL;
        self.next[id as usize] = NIL;
        self.in_list[id as usize] = false;
    }

    fn push_head(&mut self, id: u32) {
        self.prev[id as usize] = NIL;
        self.next[id as usize] = self.head;
        if self.head != NIL {
            self.prev[self.head as usize] = id;
        } else {
            self.tail = id;
        }
        self.head = id;
        self.in_list[id as usize] = true;
    }
}

impl EvictionPolicy for LruPolicy {
    fn kind(&self) -> EvictionPolicyKind {
        EvictionPolicyKind::Lru
    }

    fn ensure_capacity(&mut self, cap: usize) {
        self.prev.resize(cap, NIL);
        self.next.resize(cap, NIL);
        self.in_list.resize(cap, false);
        self.stamp.resize(cap, 0);
    }

    fn on_access(&mut self, id: PageId, now: u64) {
        self.detach(id);
        self.push_head(id);
        self.stamp[id as usize] = now;
    }

    fn on_remove(&mut self, id: PageId) {
        self.detach(id);
    }

    fn victim(&mut self, evictable: &mut dyn FnMut(PageId) -> bool) -> Option<PageId> {
        let mut cur = self.tail;
        while cur != NIL {
            if evictable(cur) {
                self.detach(cur);
                return Some(cur);
            }
            cur = self.prev[cur as usize];
        }
        None
    }

    fn rank(&self, id: PageId) -> f64 {
        self.stamp
            .get(id as usize)
            .copied()
            .unwrap_or(0) as f64
    }
}

/// CLOCK / second chance: a circular scan over resident pages with one
/// reference bit each. An accessed page survives one sweep; the hand evicts
/// the first unreferenced evictable page it meets.
pub struct ClockPolicy {
    ring: Vec<PageId>,
    pos: Vec<u32>, // NIL when absent from the ring
    refbit: Vec<bool>,
    stamp: Vec<u64>,
    hand: usize,
}

impl Default for ClockPolicy {
    fn default() -> Self {
        ClockPolicy {
            ring: Vec::new(),
            pos: Vec::new(),
            refbit: Vec::new(),
            stamp: Vec::new(),
            hand: 0,
        }
    }
}

impl ClockPolicy {
    fn remove_at(&mut self, idx: usize) {
        let id = self.ring.swap_remove(idx);
        self.pos[id as usize] = NIL;
        if let Some(&moved) = self.ring.get(idx) {
            self.pos[moved as usize] = idx as u32;
        }
        if self.hand > idx {
            self.hand -= 1;
        }
        if !self.ring.is_empty() {
            self.hand %= self.ring.len();
        } else {
            self.hand = 0;
        }
    }
}

impl EvictionPolicy for ClockPolicy {
    fn kind(&self) -> EvictionPolicyKind {
        EvictionPolicyKind::Clock
    }

    fn ensure_capacity(&mut self, cap: usize) {
        self.pos.resize(cap, NIL);
        self.refbit.resize(cap, false);
        self.stamp.resize(cap, 0);
    }

    fn on_access(&mut self, id: PageId, now: u64) {
        if self.pos[id as usize] == NIL {
            self.pos[id as usize] = self.ring.len() as u32;
            self.ring.push(id);
        }
        self.refbit[id as usize] = true;
        self.stamp[id as usize] = now;
    }

    fn on_remove(&mut self, id: PageId) {
        let p = self.pos[id as usize];
        if p != NIL {
            self.remove_at(p as usize);
        }
        self.refbit[id as usize] = false;
    }

    fn victim(&mut self, evictable: &mut dyn FnMut(PageId) -> bool) -> Option<PageId> {
        if self.ring.is_empty() {
            return None;
        }
        // two full sweeps: the first clears reference bits, the second must
        // find a victim unless nothing is evictable
        let cap = 2 * self.ring.len() + 1;
        let mut scanned = 0usize;
        while scanned < cap && !self.ring.is_empty() {
            if self.hand >= self.ring.len() {
                self.hand = 0;
            }
            let id = self.ring[self.hand];
            if !evictable(id) {
                self.hand += 1;
                scanned += 1;
                continue;
            }
            if self.refbit[id as usize] {
                self.refbit[id as usize] = false;
                self.hand += 1;
                scanned += 1;
                continue;
            }
            let idx = self.hand;
            self.remove_at(idx);
            return Some(id);
        }
        None
    }

    fn rank(&self, id: PageId) -> f64 {
        self.stamp
            .get(id as usize)
            .copied()
            .unwrap_or(0) as f64
    }
}

/// TinyServe-native policy: demote the page with the lowest recent
/// bounding-box relevance. Scores arrive from the engine as
/// `score_page(q, meta)` observations against live decode queries and are
/// smoothed with an EMA; never-scored pages (e.g. idle session snapshots)
/// rank coldest, oldest first.
pub struct QueryAwareCold {
    ema: Vec<f32>,
    scored: Vec<bool>,
    tracked: Vec<bool>,
    stamp: Vec<u64>,
    /// pool refcount at the last store reconciliation (1 = private)
    sharers: Vec<u32>,
    decay: f32,
}

/// Rank boost per extra sharer: large enough that any shared page
/// outranks any private page's bbox score (scores are O(dot products),
/// nowhere near 1e12), small enough that the unscored-page sentinel
/// (-1e30) still dominates.
const SHARER_RANK_BOOST: f64 = 1e12;

impl QueryAwareCold {
    pub fn new(decay: f32) -> Self {
        QueryAwareCold {
            ema: Vec::new(),
            scored: Vec::new(),
            tracked: Vec::new(),
            stamp: Vec::new(),
            sharers: Vec::new(),
            decay,
        }
    }
}

impl EvictionPolicy for QueryAwareCold {
    fn kind(&self) -> EvictionPolicyKind {
        EvictionPolicyKind::QueryAware
    }

    fn ensure_capacity(&mut self, cap: usize) {
        self.ema.resize(cap, 0.0);
        self.scored.resize(cap, false);
        self.tracked.resize(cap, false);
        self.stamp.resize(cap, 0);
        self.sharers.resize(cap, 1);
    }

    fn on_access(&mut self, id: PageId, now: u64) {
        self.tracked[id as usize] = true;
        self.stamp[id as usize] = now;
    }

    fn on_score(&mut self, id: PageId, score: f32) {
        let i = id as usize;
        if i >= self.ema.len() {
            return;
        }
        if self.scored[i] {
            self.ema[i] = self.decay * self.ema[i] + (1.0 - self.decay) * score;
        } else {
            self.ema[i] = score;
            self.scored[i] = true;
        }
    }

    fn on_sharers(&mut self, id: PageId, sharers: u32) {
        let i = id as usize;
        if i < self.sharers.len() {
            self.sharers[i] = sharers.max(1);
        }
    }

    fn on_remove(&mut self, id: PageId) {
        let i = id as usize;
        self.tracked[i] = false;
        self.scored[i] = false;
        self.ema[i] = 0.0;
        self.sharers[i] = 1;
    }

    fn victim(&mut self, evictable: &mut dyn FnMut(PageId) -> bool) -> Option<PageId> {
        // victim key, minimized lexicographically: (sharers, score, stamp)
        // — every private page demotes before any shared one (demoting a
        // K-sharer page costs K requests a fault), then lowest bbox
        // relevance, then oldest; unscored pages are colder than scored
        let mut best: Option<(PageId, u32, f32, u64)> = None;
        for i in 0..self.tracked.len() {
            if !self.tracked[i] || !evictable(i as PageId) {
                continue;
            }
            let sh = self.sharers[i].max(1);
            let s = if self.scored[i] { self.ema[i] } else { f32::NEG_INFINITY };
            let t = self.stamp[i];
            let better = match best {
                None => true,
                Some((_, bsh, bs, bt)) => {
                    sh < bsh || (sh == bsh && (s < bs || (s == bs && t < bt)))
                }
            };
            if better {
                best = Some((i as PageId, sh, s, t));
            }
        }
        best.map(|(id, _, _, _)| {
            self.tracked[id as usize] = false;
            id
        })
    }

    fn rank(&self, id: PageId) -> f64 {
        let i = id as usize;
        let boost = self
            .sharers
            .get(i)
            .copied()
            .unwrap_or(1)
            .saturating_sub(1) as f64
            * SHARER_RANK_BOOST;
        if i < self.scored.len() && self.scored[i] {
            self.ema[i] as f64 + boost
        } else {
            // never-scored pages rank coldest, oldest first
            -1e30 + self.stamp.get(i).copied().unwrap_or(0) as f64 + boost
        }
    }
}

/// SIEVE: an intrusive FIFO list (`head` = newest insertion, `tail` =
/// oldest) with one `visited` bit per page and an eviction hand that walks
/// tail -> head and *keeps its position across evictions*. A page's first
/// access inserts it at the head unvisited; a re-access while resident
/// just sets the bit. The hand clears visited bits as it passes and evicts
/// the first unvisited evictable page, so one-touch pages get swept out
/// quickly while anything touched twice survives a full lap — CLOCK's
/// second chance without the hand reset that makes CLOCK scan-prone.
pub struct SievePolicy {
    prev: Vec<u32>, // toward head (newer)
    next: Vec<u32>, // toward tail (older)
    in_list: Vec<bool>,
    visited: Vec<bool>,
    stamp: Vec<u64>,
    head: u32,
    tail: u32,
    hand: u32,
    len: usize,
}

impl Default for SievePolicy {
    fn default() -> Self {
        SievePolicy {
            prev: Vec::new(),
            next: Vec::new(),
            in_list: Vec::new(),
            visited: Vec::new(),
            stamp: Vec::new(),
            head: NIL,
            tail: NIL,
            hand: NIL,
            len: 0,
        }
    }
}

impl SievePolicy {
    fn detach(&mut self, id: u32) {
        if !self.in_list[id as usize] {
            return;
        }
        // the hand never dangles: removing its node moves it to the next
        // candidate (toward the head; NIL restarts at the tail)
        if self.hand == id {
            self.hand = self.prev[id as usize];
        }
        let p = self.prev[id as usize];
        let n = self.next[id as usize];
        if p != NIL {
            self.next[p as usize] = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.prev[n as usize] = p;
        } else {
            self.tail = p;
        }
        self.prev[id as usize] = NIL;
        self.next[id as usize] = NIL;
        self.in_list[id as usize] = false;
        self.len -= 1;
    }

    fn push_head(&mut self, id: u32) {
        self.prev[id as usize] = NIL;
        self.next[id as usize] = self.head;
        if self.head != NIL {
            self.prev[self.head as usize] = id;
        } else {
            self.tail = id;
        }
        self.head = id;
        self.in_list[id as usize] = true;
        self.len += 1;
    }
}

impl EvictionPolicy for SievePolicy {
    fn kind(&self) -> EvictionPolicyKind {
        EvictionPolicyKind::Sieve
    }

    fn ensure_capacity(&mut self, cap: usize) {
        self.prev.resize(cap, NIL);
        self.next.resize(cap, NIL);
        self.in_list.resize(cap, false);
        self.visited.resize(cap, false);
        self.stamp.resize(cap, 0);
    }

    fn on_access(&mut self, id: PageId, now: u64) {
        if self.in_list[id as usize] {
            // resident hit: mark, do NOT move (FIFO order is immutable)
            self.visited[id as usize] = true;
        } else {
            self.push_head(id);
            self.visited[id as usize] = false;
        }
        self.stamp[id as usize] = now;
    }

    fn on_remove(&mut self, id: PageId) {
        self.detach(id);
        self.visited[id as usize] = false;
    }

    fn victim(&mut self, evictable: &mut dyn FnMut(PageId) -> bool) -> Option<PageId> {
        if self.len == 0 {
            return None;
        }
        let mut cur = if self.hand != NIL && self.in_list[self.hand as usize] {
            self.hand
        } else {
            self.tail
        };
        // two full laps suffice: the first clears every visited bit the
        // hand meets, the second must find a victim unless nothing is
        // evictable
        let cap = 2 * self.len + 1;
        let mut scanned = 0usize;
        while cur != NIL && scanned < cap {
            let toward_head = self.prev[cur as usize];
            if self.visited[cur as usize] {
                self.visited[cur as usize] = false;
            } else if evictable(cur) {
                self.hand = toward_head; // survives the eviction
                self.detach(cur);
                return Some(cur);
            }
            cur = if toward_head != NIL { toward_head } else { self.tail };
            scanned += 1;
        }
        self.hand = cur;
        None
    }

    fn rank(&self, id: PageId) -> f64 {
        self.stamp
            .get(id as usize)
            .copied()
            .unwrap_or(0) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn take_all(p: &mut dyn EvictionPolicy, n: usize) -> Vec<PageId> {
        let mut out = Vec::new();
        for _ in 0..n {
            match p.victim(&mut |_| true) {
                Some(id) => out.push(id),
                None => break,
            }
        }
        out
    }

    #[test]
    fn lru_evicts_in_recency_order() {
        let mut p = LruPolicy::default();
        p.ensure_capacity(8);
        for id in 0..4u32 {
            p.on_access(id, id as u64 + 1);
        }
        p.on_access(0, 10); // 0 becomes most recent
        assert_eq!(take_all(&mut p, 4), vec![1, 2, 3, 0]);
        assert_eq!(p.victim(&mut |_| true), None, "list drained");
    }

    #[test]
    fn lru_skips_non_evictable() {
        let mut p = LruPolicy::default();
        p.ensure_capacity(4);
        for id in 0..3u32 {
            p.on_access(id, id as u64 + 1);
        }
        let v = p.victim(&mut |id| id != 0);
        assert_eq!(v, Some(1), "oldest evictable wins");
    }

    #[test]
    fn lru_remove_unlinks() {
        let mut p = LruPolicy::default();
        p.ensure_capacity(4);
        for id in 0..3u32 {
            p.on_access(id, id as u64 + 1);
        }
        p.on_remove(0);
        assert_eq!(take_all(&mut p, 3), vec![1, 2]);
    }

    #[test]
    fn clock_gives_second_chance() {
        let mut p = ClockPolicy::default();
        p.ensure_capacity(4);
        for id in 0..3u32 {
            p.on_access(id, 1);
        }
        // all refbits set: first sweep clears them, victim is the first page
        assert_eq!(p.victim(&mut |_| true), Some(0));
        // 1 and 2 now have cleared bits; re-access 1 to protect it
        p.on_access(1, 2);
        assert_eq!(p.victim(&mut |_| true), Some(2));
        assert_eq!(p.victim(&mut |_| true), Some(1));
        assert_eq!(p.victim(&mut |_| true), None);
    }

    #[test]
    fn clock_all_pinned_returns_none() {
        let mut p = ClockPolicy::default();
        p.ensure_capacity(4);
        for id in 0..3u32 {
            p.on_access(id, 1);
        }
        assert_eq!(p.victim(&mut |_| false), None);
        // the sweep moved the hand but the ring stays intact: eviction
        // still works once pages become evictable again
        assert!(p.victim(&mut |_| true).is_some());
    }

    #[test]
    fn query_aware_picks_lowest_score() {
        let mut p = QueryAwareCold::new(0.5);
        p.ensure_capacity(4);
        for id in 0..3u32 {
            p.on_access(id, id as u64 + 1);
        }
        p.on_score(0, 5.0);
        p.on_score(1, -2.0);
        p.on_score(2, 9.0);
        assert_eq!(p.victim(&mut |_| true), Some(1));
        // promoted back in, now with a high score
        p.on_access(1, 9);
        p.on_score(1, 50.0);
        assert_eq!(p.victim(&mut |_| true), Some(0));
    }

    #[test]
    fn query_aware_prefers_unscored_then_oldest() {
        let mut p = QueryAwareCold::new(0.5);
        p.ensure_capacity(4);
        p.on_access(0, 1);
        p.on_access(1, 2);
        p.on_access(2, 3);
        p.on_score(2, -100.0); // scored, but unscored pages are colder
        assert_eq!(p.victim(&mut |_| true), Some(0), "oldest unscored first");
        assert_eq!(p.victim(&mut |_| true), Some(1));
        assert_eq!(p.victim(&mut |_| true), Some(2));
    }

    #[test]
    fn query_aware_shared_page_outlives_private_cold() {
        let mut p = QueryAwareCold::new(0.5);
        p.ensure_capacity(4);
        for id in 0..3u32 {
            p.on_access(id, id as u64 + 1);
        }
        // page 0 has the WORST score but 3 sharers: private pages demote
        // first regardless of score
        p.on_score(0, -100.0);
        p.on_score(1, 5.0);
        p.on_score(2, 80.0);
        p.on_sharers(0, 3);
        assert_eq!(p.victim(&mut |_| true), Some(1), "lowest-score private");
        assert_eq!(p.victim(&mut |_| true), Some(2));
        assert_eq!(p.victim(&mut |_| true), Some(0), "shared page goes last");
        // rank reflects the sharer boost for PruneColdest too
        assert!(p.rank(0) > 1e11, "sharer boost dominates the bbox score");
    }

    #[test]
    fn query_aware_sharer_signal_resets_on_remove() {
        let mut p = QueryAwareCold::new(0.5);
        p.ensure_capacity(2);
        p.on_access(0, 1);
        p.on_access(1, 2);
        p.on_score(0, -1.0);
        p.on_score(1, 1.0);
        p.on_sharers(0, 4);
        assert_eq!(p.victim(&mut |_| true), Some(1));
        p.on_remove(0);
        // re-tracked after removal: the stale sharer count must not leak
        p.on_access(0, 3);
        p.on_access(1, 4);
        p.on_score(0, -1.0);
        p.on_score(1, 1.0);
        assert_eq!(p.victim(&mut |_| true), Some(0), "private again");
    }

    #[test]
    fn query_aware_ema_smooths() {
        let mut p = QueryAwareCold::new(0.5);
        p.ensure_capacity(2);
        p.on_access(0, 1);
        p.on_score(0, 4.0);
        p.on_score(0, 0.0);
        assert!((p.rank(0) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn sieve_evicts_oldest_unvisited_first() {
        let mut p = SievePolicy::default();
        p.ensure_capacity(8);
        for id in 0..3u32 {
            p.on_access(id, id as u64 + 1); // insert 0,1,2 (all unvisited)
        }
        assert_eq!(p.victim(&mut |_| true), Some(0), "FIFO tail goes first");
        // touch 1 while resident: the visited bit protects it for one lap
        p.on_access(1, 9);
        assert_eq!(p.victim(&mut |_| true), Some(2));
        assert_eq!(p.victim(&mut |_| true), Some(1), "second lap claims 1");
        assert_eq!(p.victim(&mut |_| true), None, "drained");
    }

    #[test]
    fn sieve_hand_survives_eviction() {
        let mut p = SievePolicy::default();
        p.ensure_capacity(8);
        for id in 0..4u32 {
            p.on_access(id, id as u64 + 1);
        }
        // all visited: the first victim call clears tail-ward bits
        for id in 0..4u32 {
            p.on_access(id, 10 + id as u64);
        }
        assert_eq!(p.victim(&mut |_| true), Some(0));
        // a page inserted *after* the hand passed the tail region is newer
        // than the hand: the retained hand keeps sweeping old pages first
        p.on_access(7, 20);
        assert_eq!(p.victim(&mut |_| true), Some(1), "hand did not reset");
    }

    #[test]
    fn sieve_skips_non_evictable_and_reinsertion_resets_bit() {
        let mut p = SievePolicy::default();
        p.ensure_capacity(8);
        for id in 0..3u32 {
            p.on_access(id, id as u64 + 1);
        }
        assert_eq!(p.victim(&mut |id| id != 0), Some(1), "pinned 0 skipped");
        assert_eq!(p.victim(&mut |_| false), None, "all pinned");
        // evicted page re-enters at the head, unvisited again
        p.on_access(1, 9);
        p.on_remove(2);
        p.on_remove(0);
        assert_eq!(p.victim(&mut |_| true), Some(1));
        assert_eq!(p.victim(&mut |_| true), None);
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(EvictionPolicyKind::parse("lru"), Some(EvictionPolicyKind::Lru));
        assert_eq!(EvictionPolicyKind::parse("CLOCK"), Some(EvictionPolicyKind::Clock));
        assert_eq!(
            EvictionPolicyKind::parse("query-aware"),
            Some(EvictionPolicyKind::QueryAware)
        );
        assert_eq!(EvictionPolicyKind::parse("sieve"), Some(EvictionPolicyKind::Sieve));
        assert_eq!(EvictionPolicyKind::parse("bogus"), None);
        for k in EvictionPolicyKind::all() {
            assert_eq!(EvictionPolicyKind::parse(k.name()), Some(*k));
        }
        let names = EvictionPolicyKind::names();
        assert_eq!(names.len(), EvictionPolicyKind::all().len());
        assert!(names.contains(&"sieve"));
    }
}
