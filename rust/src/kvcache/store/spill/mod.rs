//! Disk-backed spill tier below the q8 cold tier: segment files plus a
//! buffer-manager-style staging/readahead layer.
//!
//! The classic disk-manager / buffer-manager split (simpledb lineage):
//!
//! * [`SegmentFile`](segment::SegmentFile) — fixed-slot files, free-slot
//!   bitmap, slot reuse on free. One slot holds one serialized KV page:
//!   q8-quantized K/V rows (per-row symmetric int8 + f32 scale) plus the
//!   page's bounding-box metadata, framed by a magic/filled/checksum
//!   header so corruption surfaces as a typed [`SpillError`], never a
//!   panic or silent garbage.
//! * [`SpillManager`] — the policy layer: a bounded write-back **staging
//!   buffer** (spilled pages accumulate in RAM and flush to slots in
//!   batches, so demotion bursts pay one batched write instead of N
//!   seeks), a **readahead cache** fed by the query-aware relevance
//!   scores (the pages the selection scores predict will be touched next
//!   are prefetched before `ensure_hot` faults on them), and the
//!   page → slot map.
//!
//! Spilling **fully frees pool memory**: the page's K/V rows are zeroed
//! in the pool slabs after encoding (a gather that skips the fault path
//! would read zeros — bugs are loud, not subtly stale). Bounding-box
//! metadata stays RAM-resident so Eq.-2 scoring keeps working while the
//! page is on disk; the slot carries a copy so a fault restores exactly
//! the boxes the scores were computed from.
//!
//! Determinism: all internal maps are `BTreeMap`s keyed by `PageId`, so
//! flush order, readahead candidate order and the resulting byte
//! counters are identical run-to-run for a fixed workload — the
//! `TimeModel::Modeled` event streams stay seed-deterministic with the
//! spill tier enabled.

pub mod segment;

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::kvcache::dtype::Slab;
use crate::kvcache::pool::{PageId, PagePool};

pub use segment::SegmentFile;

/// Slots per segment file; a full segment spawns `seg-<n>.kvseg` next to it.
const SEG_SLOTS: usize = 64;

/// Slot header: magic u32, filled u16, reserved u16, FNV-1a checksum u64.
const HEADER_BYTES: usize = 16;
const SLOT_MAGIC: u32 = 0x4B56_5350; // "KVSP"

static SPILL_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// A fresh process-unique spill directory under `TINYSERVE_SPILL_DIR`
/// (CI passes a tmpdir) or the system temp dir. Each call returns a new
/// path, so two engines in one process never share segment files.
pub fn default_spill_root() -> PathBuf {
    let base = std::env::var("TINYSERVE_SPILL_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| std::env::temp_dir());
    base.join(format!(
        "tinyserve-spill-{}-{}",
        std::process::id(),
        SPILL_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Typed spill-tier failure. Read-path corruption (bad magic, checksum
/// mismatch, truncation) is distinguishable from plain I/O so callers and
/// tests can assert on the exact failure class.
#[derive(Debug)]
pub enum SpillError {
    Io(std::io::Error),
    BadMagic { path: PathBuf, slot: u32, got: u32 },
    ChecksumMismatch { path: PathBuf, slot: u32 },
    Truncated { path: PathBuf, slot: u32 },
    SlotOutOfRange { slot: u32, n_slots: usize },
    /// fault on a page the tier does not hold (map desync — a logic bug)
    MissingPage(PageId),
    /// slot header's filled count disagrees with the pool's page shape
    ShapeMismatch { slot: u32, filled: usize, expect: usize },
}

impl std::fmt::Display for SpillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpillError::Io(e) => write!(f, "spill i/o error: {e}"),
            SpillError::BadMagic { path, slot, got } => write!(
                f,
                "bad slot magic {got:#010x} in {} slot {slot} (corrupted segment?)",
                path.display()
            ),
            SpillError::ChecksumMismatch { path, slot } => write!(
                f,
                "checksum mismatch in {} slot {slot} (corrupted segment)",
                path.display()
            ),
            SpillError::Truncated { path, slot } => write!(
                f,
                "segment {} truncated under slot {slot}",
                path.display()
            ),
            SpillError::SlotOutOfRange { slot, n_slots } => {
                write!(f, "slot {slot} out of range (segment holds {n_slots})")
            }
            SpillError::MissingPage(id) => {
                write!(f, "page {id} is not held by the spill tier")
            }
            SpillError::ShapeMismatch { slot, filled, expect } => write!(
                f,
                "slot {slot} holds {filled} filled rows, pool expects {expect}"
            ),
        }
    }
}

impl std::error::Error for SpillError {}

impl From<std::io::Error> for SpillError {
    fn from(e: std::io::Error) -> SpillError {
        SpillError::Io(e)
    }
}

/// Spill-tier sizing and behaviour knobs.
#[derive(Debug, Clone)]
pub struct SpillConfig {
    /// directory holding this manager's segment files (one per worker)
    pub dir: PathBuf,
    /// byte cap on spilled payloads (staged + on disk)
    pub budget_bytes: usize,
    /// pages prefetched per readahead tick (0 disables readahead)
    pub readahead_pages: usize,
    /// write-back staging buffer capacity in pages; a full buffer flushes
    /// as one batch
    pub staging_slots: usize,
}

impl SpillConfig {
    pub fn new(dir: PathBuf, budget_bytes: usize) -> SpillConfig {
        SpillConfig { dir, budget_bytes, readahead_pages: 0, staging_slots: 8 }
    }
}

/// Where a fault was served from (the store prices each differently).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSource {
    /// still in the write-back staging buffer — no disk read
    Staging,
    /// prefetched by readahead — the read was already paid
    Readahead,
    /// synchronous segment read
    Disk,
}

impl FaultSource {
    /// Stable wire name, used as the `src` field of `spill_fault` trace
    /// events.
    pub fn name(&self) -> &'static str {
        match self {
            FaultSource::Staging => "staging",
            FaultSource::Readahead => "readahead",
            FaultSource::Disk => "disk",
        }
    }
}

/// Fixed per-pool slot geometry (set on the first spill, invariant after).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SlotShape {
    n_layers: usize,
    d_kv: usize,
    page_size: usize,
}

impl SlotShape {
    fn of(pool: &PagePool) -> SlotShape {
        SlotShape { n_layers: pool.n_layers, d_kv: pool.d_kv, page_size: pool.page_size }
    }

    /// q8 rows (i8 data + f32 scale per row, K and V) + f32 bbox meta.
    fn payload_bytes(&self) -> usize {
        self.n_layers * self.page_size * 2 * (self.d_kv + 4)
            + self.n_layers * 2 * self.d_kv * 4
    }

    fn slot_bytes(&self) -> usize {
        HEADER_BYTES + self.payload_bytes()
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Encode one page into a slot-sized buffer: header + q8 rows + bboxes.
/// Int8 pools copy their raw (i8 data, scale) rows verbatim — the slot
/// layout is identical, but the round trip is bit-exact by construction
/// instead of by quantizer idempotency. Other dtypes quantize the
/// gathered f32 rows through a scratch `Slab::I8` — literally the same
/// per-row symmetric quantizer the cold tier uses, so the two can never
/// drift apart.
fn encode_page(pool: &PagePool, id: PageId, shape: SlotShape) -> Vec<u8> {
    let (l_n, d, s_n) = (shape.n_layers, shape.d_kv, shape.page_size);
    let mut buf = vec![0u8; shape.slot_bytes()];
    let mut off = HEADER_BYTES;
    let raw = pool.dtype() == crate::config::KvDtype::Int8;
    let mut scratch = Slab::new(crate::config::KvDtype::Int8, 1, d);
    let mut k = vec![0.0f32; s_n * d];
    let mut v = vec![0.0f32; s_n * d];
    for layer in 0..l_n {
        if raw {
            for s in 0..s_n {
                let ((kq, ks), (vq, vs)) =
                    pool.q8_rows_raw(id, layer, s).expect("int8 pool has raw rows");
                off = put_raw_row(&mut buf, off, kq, ks);
                off = put_raw_row(&mut buf, off, vq, vs);
            }
        } else {
            pool.gather_rows(id, layer, s_n, &mut k, &mut v);
            for s in 0..s_n {
                for row in [&k[s * d..(s + 1) * d], &v[s * d..(s + 1) * d]] {
                    scratch.store_row(0, d, row);
                    let (q, sc) = scratch.q8_row(0, d).expect("scratch is int8");
                    off = put_raw_row(&mut buf, off, q, sc);
                }
            }
        }
    }
    for layer in 0..l_n {
        for &x in pool.meta(id, layer) {
            buf[off..off + 4].copy_from_slice(&x.to_le_bytes());
            off += 4;
        }
    }
    debug_assert_eq!(off, shape.slot_bytes());
    let ck = fnv1a(&buf[HEADER_BYTES..]);
    buf[0..4].copy_from_slice(&SLOT_MAGIC.to_le_bytes());
    buf[4..6].copy_from_slice(&(pool.filled(id) as u16).to_le_bytes());
    buf[8..16].copy_from_slice(&ck.to_le_bytes());
    buf
}

fn put_raw_row(buf: &mut [u8], mut off: usize, q: &[i8], scale: f32) -> usize {
    for &b in q {
        buf[off] = b as u8;
        off += 1;
    }
    buf[off..off + 4].copy_from_slice(&scale.to_le_bytes());
    off + 4
}

/// Verify framing and restore a page from its slot buffer: dequantize the
/// q8 rows back into the pool slabs and reinstate the bounding boxes.
fn decode_page(
    pool: &mut PagePool,
    id: PageId,
    shape: SlotShape,
    slot: u32,
    path: &std::path::Path,
    buf: &[u8],
) -> Result<(), SpillError> {
    if buf.len() < shape.slot_bytes() {
        return Err(SpillError::Truncated { path: path.to_path_buf(), slot });
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != SLOT_MAGIC {
        return Err(SpillError::BadMagic { path: path.to_path_buf(), slot, got: magic });
    }
    let ck = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    if fnv1a(&buf[HEADER_BYTES..shape.slot_bytes()]) != ck {
        return Err(SpillError::ChecksumMismatch { path: path.to_path_buf(), slot });
    }
    let filled = u16::from_le_bytes(buf[4..6].try_into().unwrap()) as usize;
    if filled != shape.page_size {
        return Err(SpillError::ShapeMismatch {
            slot,
            filled,
            expect: shape.page_size,
        });
    }
    let (l_n, d, s_n) = (shape.n_layers, shape.d_kv, shape.page_size);
    let raw = pool.dtype() == crate::config::KvDtype::Int8;
    let mut off = HEADER_BYTES;
    let mut scratch = Slab::new(crate::config::KvDtype::Int8, 1, d);
    let mut k = vec![0.0f32; s_n * d];
    let mut v = vec![0.0f32; s_n * d];
    let mut kq = vec![0i8; d];
    let mut vq = vec![0i8; d];
    for layer in 0..l_n {
        if raw {
            for s in 0..s_n {
                let (next, ks) = get_raw_row(buf, off, &mut kq);
                let (next, vs) = get_raw_row(buf, next, &mut vq);
                off = next;
                pool.import_q8_row(id, layer, s, (&kq, ks), (&vq, vs));
            }
        } else {
            // dequantize through the scratch Slab — the cold tier's own
            // decode path, so spill and q8 demotion can never disagree
            for s in 0..s_n {
                let (next, ks) = get_raw_row(buf, off, &mut kq);
                let (next, vs) = get_raw_row(buf, next, &mut vq);
                off = next;
                scratch.store_q8_row(0, d, &kq, ks);
                scratch.load_rows(0, 1, d, &mut k[s * d..(s + 1) * d]);
                scratch.store_q8_row(0, d, &vq, vs);
                scratch.load_rows(0, 1, d, &mut v[s * d..(s + 1) * d]);
            }
            pool.import_rows(id, layer, s_n, &k, &v);
        }
    }
    let mut meta = vec![0.0f32; 2 * d];
    for layer in 0..l_n {
        for m in meta.iter_mut() {
            *m = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            off += 4;
        }
        pool.set_meta(id, layer, &meta);
    }
    Ok(())
}

fn get_raw_row(buf: &[u8], mut off: usize, q: &mut [i8]) -> (usize, f32) {
    for x in q.iter_mut() {
        *x = buf[off] as i8;
        off += 1;
    }
    let scale = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
    (off + 4, scale)
}

/// The buffer-manager half: staging buffer, readahead cache, page → slot
/// map, segment-file pool. Owned by one `PageStore` (one worker); see the
/// lock-ordering note in docs/pagestore_design.md.
pub struct SpillManager {
    cfg: SpillConfig,
    shape: Option<SlotShape>,
    segments: Vec<SegmentFile>,
    /// flushed pages: page -> (segment index, slot)
    map: BTreeMap<PageId, (u32, u32)>,
    /// write-back buffer: encoded slots awaiting the next batched flush
    staging: Vec<(PageId, Vec<u8>)>,
    /// readahead payload cache (page stays in `map`; the slot is freed
    /// only when the page actually faults back)
    cache: BTreeMap<PageId, Vec<u8>>,
    /// cache insertion order — overflow evicts the OLDEST prefetch, never
    /// the entry just read (may hold stale ids of pages that already
    /// faulted; they are skipped lazily)
    cache_fifo: VecDeque<PageId>,
    /// relevance scores of disk-resident pages (readahead signal)
    scores: BTreeMap<PageId, f32>,
    /// batched flushes performed (bench/observability)
    pub flushes: u64,
    /// failed flush attempts (payloads stay staged; the next flush
    /// retries) — the store folds this into its `spill_errors` counter
    pub write_errors: u64,
}

impl SpillManager {
    pub fn new(cfg: SpillConfig) -> Result<SpillManager, SpillError> {
        std::fs::create_dir_all(&cfg.dir)?;
        Ok(SpillManager {
            cfg,
            shape: None,
            segments: Vec::new(),
            map: BTreeMap::new(),
            staging: Vec::new(),
            cache: BTreeMap::new(),
            cache_fifo: VecDeque::new(),
            scores: BTreeMap::new(),
            flushes: 0,
            write_errors: 0,
        })
    }

    pub fn config(&self) -> &SpillConfig {
        &self.cfg
    }

    /// Resize the tier's byte budget at runtime (ops lever for host disk
    /// pressure). Shrinking never evicts already-spilled pages — it only
    /// stops new spills until faults drain the tier below the new cap.
    pub fn set_budget_bytes(&mut self, bytes: usize) {
        self.cfg.budget_bytes = bytes;
    }

    pub fn readahead_enabled(&self) -> bool {
        self.cfg.readahead_pages > 0
    }

    /// Pages currently held by the tier (staged or flushed).
    pub fn pages_on_tier(&self) -> usize {
        self.map.len() + self.staging.len()
    }

    /// Payload bytes currently committed to the tier.
    pub fn bytes_on_tier(&self) -> usize {
        match self.shape {
            Some(s) => self.pages_on_tier() * s.payload_bytes(),
            None => 0,
        }
    }

    /// Whole pages the tier can still accept under its byte budget.
    pub fn pages_free(&self, pool: &PagePool) -> usize {
        let payload = SlotShape::of(pool).payload_bytes();
        (self.cfg.budget_bytes.saturating_sub(self.bytes_on_tier())) / payload.max(1)
    }

    pub fn can_accept(&self, pool: &PagePool) -> bool {
        self.pages_free(pool) > 0
    }

    fn shape_for(&mut self, pool: &PagePool) -> SlotShape {
        let s = SlotShape::of(pool);
        match self.shape {
            Some(have) => {
                debug_assert_eq!(have, s, "one spill manager per pool shape");
                have
            }
            None => {
                self.shape = Some(s);
                s
            }
        }
    }

    /// Move a page onto the tier: encode, zero its pool rows, stage the
    /// slot. Returns the payload bytes committed. A full staging buffer
    /// triggers a batched flush; a flush failure keeps the payloads
    /// staged (nothing is lost — the fault path serves from staging and
    /// the next flush retries), counted in `write_errors`. Once staged
    /// the page **is** on the tier, so this cannot fail.
    pub fn spill(&mut self, pool: &mut PagePool, id: PageId) -> usize {
        debug_assert!(!self.holds(id), "double spill of page {id}");
        let shape = self.shape_for(pool);
        let buf = encode_page(pool, id, shape);
        pool.purge_rows(id);
        self.staging.push((id, buf));
        if self.staging.len() >= self.cfg.staging_slots.max(1) {
            let _ = self.flush();
        }
        shape.payload_bytes()
    }

    pub fn holds(&self, id: PageId) -> bool {
        self.map.contains_key(&id) || self.staging.iter().any(|(p, _)| *p == id)
    }

    /// Write every staged page to a segment slot (creating segments as
    /// needed). On error the unwritten tail stays staged and the failure
    /// is counted (`write_errors`). Payloads are written by reference and
    /// the staged prefix is drained once — no per-page buffer copies.
    pub fn flush(&mut self) -> Result<(), SpillError> {
        if self.staging.is_empty() {
            return Ok(());
        }
        let Some(shape) = self.shape else { return Ok(()) };
        // deterministic flush order: page id, not arrival order
        self.staging.sort_by_key(|(p, _)| *p);
        let mut written = 0usize;
        while written < self.staging.len() {
            let (seg_idx, slot) = match self.alloc_slot(shape) {
                Ok(a) => a,
                Err(e) => {
                    self.write_errors += 1;
                    self.staging.drain(..written);
                    return Err(e);
                }
            };
            let id = self.staging[written].0;
            let buf = &self.staging[written].1;
            if let Err(e) = self.segments[seg_idx as usize].write_slot(slot, buf) {
                self.segments[seg_idx as usize].free_slot(slot);
                self.write_errors += 1;
                self.staging.drain(..written);
                return Err(e);
            }
            self.map.insert(id, (seg_idx, slot));
            written += 1;
        }
        self.staging.clear();
        self.flushes += 1;
        Ok(())
    }

    fn alloc_slot(&mut self, shape: SlotShape) -> Result<(u32, u32), SpillError> {
        for (i, seg) in self.segments.iter_mut().enumerate() {
            if let Some(slot) = seg.alloc_slot() {
                return Ok((i as u32, slot));
            }
        }
        let idx = self.segments.len();
        let path = self.cfg.dir.join(format!("seg-{idx:03}.kvseg"));
        let mut seg = SegmentFile::create(&path, shape.slot_bytes(), SEG_SLOTS)?;
        let slot = seg.alloc_slot().expect("fresh segment has free slots");
        self.segments.push(seg);
        Ok((idx as u32, slot))
    }

    /// Fault a page back into the pool: restore its rows and bounding
    /// boxes, release its slot, and report where the payload came from.
    /// Returns the payload bytes moved.
    pub fn fault(
        &mut self,
        pool: &mut PagePool,
        id: PageId,
    ) -> Result<(usize, FaultSource), SpillError> {
        let shape = self.shape_for(pool);
        self.scores.remove(&id);
        if let Some(pos) = self.staging.iter().position(|(p, _)| *p == id) {
            let (_, buf) = self.staging.remove(pos);
            if let Err(e) = decode_page(pool, id, shape, 0, &self.cfg.dir, &buf) {
                // keep the payload on the tier so a retry (or drain via
                // `free`) still accounts for it
                self.staging.push((id, buf));
                return Err(e);
            }
            return Ok((shape.payload_bytes(), FaultSource::Staging));
        }
        if let Some(buf) = self.cache.remove(&id) {
            self.cache_fifo.retain(|p| *p != id);
            let (seg, slot) = self.map.remove(&id).ok_or(SpillError::MissingPage(id))?;
            let path = self.segments[seg as usize].path().to_path_buf();
            if let Err(e) = decode_page(pool, id, shape, slot, &path, &buf) {
                // a corrupted prefetch: reinstate the mapping (the slot
                // still holds the bytes — the synchronous path will
                // surface the same error on retry, and `free` can still
                // recycle the slot); drop the bad cache entry
                self.map.insert(id, (seg, slot));
                return Err(e);
            }
            self.segments[seg as usize].free_slot(slot);
            return Ok((shape.payload_bytes(), FaultSource::Readahead));
        }
        let (seg, slot) = self.map.remove(&id).ok_or(SpillError::MissingPage(id))?;
        let mut buf = Vec::new();
        let read = self.segments[seg as usize].read_slot(slot, &mut buf);
        if let Err(e) = read {
            // leave the mapping intact so a retry (or drain) still sees it
            self.map.insert(id, (seg, slot));
            return Err(e);
        }
        let path = self.segments[seg as usize].path().to_path_buf();
        match decode_page(pool, id, shape, slot, &path, &buf) {
            Ok(()) => {
                self.segments[seg as usize].free_slot(slot);
                Ok((shape.payload_bytes(), FaultSource::Disk))
            }
            Err(e) => {
                self.map.insert(id, (seg, slot));
                Err(e)
            }
        }
    }

    /// The page left residency entirely (freed back to the pool): drop it
    /// from every structure and recycle its slot.
    pub fn free(&mut self, id: PageId) {
        self.staging.retain(|(p, _)| *p != id);
        if self.cache.remove(&id).is_some() {
            self.cache_fifo.retain(|p| *p != id);
        }
        self.scores.remove(&id);
        if let Some((seg, slot)) = self.map.remove(&id) {
            self.segments[seg as usize].free_slot(slot);
        }
    }

    /// Relevance observation for a disk-resident page (readahead signal).
    pub fn note_score(&mut self, id: PageId, score: f32) {
        if self.map.contains_key(&id) || self.staging.iter().any(|(p, _)| *p == id) {
            self.scores.insert(id, score);
        }
    }

    /// Prefetch the top-scored flushed pages into the readahead cache.
    /// Returns the bytes read from disk (0 when readahead is off or
    /// nothing qualifies). The cache is bounded at twice the readahead
    /// width; overflow drops the oldest-prefetched entries — never this
    /// tick's reads (payloads stay on disk, so a dropped entry just
    /// degrades back to a synchronous read).
    pub fn prefetch(&mut self) -> Result<usize, SpillError> {
        if self.cfg.readahead_pages == 0 {
            return Ok(0);
        }
        let Some(shape) = self.shape else { return Ok(0) };
        // top-N by score among flushed, not-yet-cached pages; ties break
        // toward the lower page id (BTreeMap order keeps this stable)
        let mut cands: Vec<(PageId, f32)> = self
            .scores
            .iter()
            .filter(|(id, _)| self.map.contains_key(id) && !self.cache.contains_key(id))
            .map(|(&id, &s)| (id, s))
            .collect();
        cands.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        cands.truncate(self.cfg.readahead_pages);
        let mut bytes = 0usize;
        let mut buf = Vec::new();
        for (id, _) in cands {
            let &(seg, slot) = self.map.get(&id).expect("candidate is mapped");
            self.segments[seg as usize].read_slot(slot, &mut buf)?;
            self.cache.insert(id, buf.clone());
            self.cache_fifo.push_back(id);
            bytes += shape.payload_bytes();
        }
        // overflow evicts oldest-prefetched first (never this tick's
        // reads: the cap is 2x the per-tick insert count); evicted
        // payloads stay on disk, degrading to a synchronous read
        while self.cache.len() > 2 * self.cfg.readahead_pages {
            match self.cache_fifo.pop_front() {
                Some(old) => {
                    self.cache.remove(&old);
                }
                None => break,
            }
        }
        Ok(bytes)
    }

    /// Segment files currently backing the tier (tests, diagnostics).
    pub fn segment_paths(&self) -> Vec<PathBuf> {
        self.segments.iter().map(|s| s.path().to_path_buf()).collect()
    }
}

impl Drop for SpillManager {
    /// Best-effort cleanup: spill files are scratch state, never a
    /// database — remove our segments and the directory if emptied.
    fn drop(&mut self) {
        for seg in &self.segments {
            let _ = std::fs::remove_file(seg.path());
        }
        let _ = std::fs::remove_dir(&self.cfg.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    fn pool() -> PagePool {
        PagePool::new(2, 8, 4, KvDtype::F32)
    }

    fn fill_page(pool: &mut PagePool, id: PageId, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        for slot in 0..pool.page_size {
            for l in 0..pool.n_layers {
                let row: Vec<f32> =
                    (0..pool.d_kv).map(|_| rng.normal() as f32).collect();
                pool.write_token(id, slot, l, &row, &row);
            }
        }
    }

    fn manager(tag: &str, budget: usize) -> SpillManager {
        let dir = default_spill_root().join(tag);
        SpillManager::new(SpillConfig::new(dir, budget)).unwrap()
    }

    fn page_rows(pool: &PagePool, id: PageId) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        for l in 0..pool.n_layers {
            for s in 0..pool.page_size {
                out.push(pool.key_row(id, l, s));
            }
            out.push(pool.meta(id, l).to_vec());
        }
        out
    }

    #[test]
    fn spill_fault_roundtrips_q8_content_bit_exactly() {
        let mut p = pool();
        let mut m = manager("roundtrip", 1 << 20);
        let id = p.alloc();
        fill_page(&mut p, id, 11);
        // put the page in the q8 state the store spills from
        p.demote_page_in_place(id);
        let before = page_rows(&p, id);
        let bytes = m.spill(&mut p, id);
        assert!(bytes > 0);
        assert_eq!(m.pages_on_tier(), 1);
        // pool rows are physically freed (zeroed) while on the tier
        assert!(p.key_row(id, 0, 0).iter().all(|&x| x == 0.0));
        let (got, src) = m.fault(&mut p, id).unwrap();
        assert_eq!(got, bytes);
        assert_eq!(src, FaultSource::Staging, "unflushed page serves from staging");
        assert_eq!(page_rows(&p, id), before, "q8 payload + bbox round-trip");
        assert_eq!(m.pages_on_tier(), 0);
    }

    #[test]
    fn flush_then_fault_reads_from_disk() {
        let mut p = pool();
        let mut m = manager("disk", 1 << 20);
        let id = p.alloc();
        fill_page(&mut p, id, 3);
        p.demote_page_in_place(id);
        let before = page_rows(&p, id);
        m.spill(&mut p, id);
        m.flush().unwrap();
        assert_eq!(m.flushes, 1);
        let (_, src) = m.fault(&mut p, id).unwrap();
        assert_eq!(src, FaultSource::Disk);
        assert_eq!(page_rows(&p, id), before);
        // slot was recycled
        assert_eq!(m.segments[0].used_slots(), 0);
        p.release(id);
    }

    #[test]
    fn readahead_prefetch_serves_faults_from_cache() {
        let mut p = pool();
        let dir = default_spill_root().join("readahead");
        let mut cfg = SpillConfig::new(dir, 1 << 20);
        cfg.readahead_pages = 2;
        let mut m = SpillManager::new(cfg).unwrap();
        let ids: Vec<PageId> = (0..3).map(|_| p.alloc()).collect();
        for (i, &id) in ids.iter().enumerate() {
            fill_page(&mut p, id, 100 + i as u64);
            p.demote_page_in_place(id);
            m.spill(&mut p, id);
        }
        m.flush().unwrap();
        m.note_score(ids[0], 0.1);
        m.note_score(ids[1], 9.0);
        m.note_score(ids[2], 5.0);
        let bytes = m.prefetch().unwrap();
        assert!(bytes > 0, "two pages prefetched");
        let (_, src) = m.fault(&mut p, ids[1]).unwrap();
        assert_eq!(src, FaultSource::Readahead, "top-scored page was cached");
        let (_, src) = m.fault(&mut p, ids[0]).unwrap();
        assert_eq!(src, FaultSource::Disk, "low-scored page was not");
    }

    #[test]
    fn budget_bounds_accepted_pages() {
        let mut p = pool();
        let payload = SlotShape::of(&p).payload_bytes();
        let mut m = manager("budget", 2 * payload);
        assert_eq!(m.pages_free(&p), 2);
        for i in 0..2 {
            let id = p.alloc();
            fill_page(&mut p, id, i);
            p.demote_page_in_place(id);
            m.spill(&mut p, id);
        }
        assert!(!m.can_accept(&p), "tier is full at its byte budget");
        assert_eq!(m.bytes_on_tier(), 2 * payload);
    }

    #[test]
    fn corrupted_slot_is_a_checksum_error_not_a_panic() {
        use std::io::{Seek, SeekFrom, Write};
        let mut p = pool();
        let mut m = manager("corrupt", 1 << 20);
        let id = p.alloc();
        fill_page(&mut p, id, 5);
        p.demote_page_in_place(id);
        m.spill(&mut p, id);
        m.flush().unwrap();
        // flip one payload byte behind the manager's back
        let path = m.segment_paths()[0].clone();
        let mut f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(HEADER_BYTES as u64 + 5)).unwrap();
        f.write_all(&[0xAB]).unwrap();
        drop(f);
        match m.fault(&mut p, id) {
            Err(SpillError::ChecksumMismatch { .. }) => {}
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
        // the mapping survives the failed fault, so cleanup still drains it
        m.free(id);
        assert_eq!(m.pages_on_tier(), 0);
        p.release(id);
    }

    #[test]
    fn truncated_segment_is_a_typed_error_not_a_panic() {
        let mut p = pool();
        let mut m = manager("trunc", 1 << 20);
        let id = p.alloc();
        fill_page(&mut p, id, 6);
        p.demote_page_in_place(id);
        m.spill(&mut p, id);
        m.flush().unwrap();
        let path = m.segment_paths()[0].clone();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(8)
            .unwrap();
        match m.fault(&mut p, id) {
            Err(SpillError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        p.release(id);
    }

    #[test]
    fn free_recycles_slots_for_reuse() {
        let mut p = pool();
        let mut m = manager("recycle", 1 << 20);
        let a = p.alloc();
        fill_page(&mut p, a, 1);
        p.demote_page_in_place(a);
        m.spill(&mut p, a);
        m.flush().unwrap();
        m.free(a);
        assert_eq!(m.pages_on_tier(), 0);
        assert_eq!(m.segments[0].free_slots(), SEG_SLOTS);
        // the freed slot is reused by the next spill
        let b = p.alloc();
        fill_page(&mut p, b, 2);
        p.demote_page_in_place(b);
        m.spill(&mut p, b);
        m.flush().unwrap();
        assert_eq!(m.segments.len(), 1, "no new segment for a reused slot");
        p.release(a);
        p.release(b);
    }
}
