//! Fixed-slot segment files: the disk-manager half of the spill tier.
//!
//! A `SegmentFile` is a preallocated file of `n_slots` equal-sized slots
//! (one spilled KV page per slot), with an in-memory free-slot bitmap.
//! Slots are reused LIFO on free — the classic database disk-manager
//! shape (see the simpledb buffer-manager notes this subsystem is
//! modelled on), chosen over an append-only log because spilled pages
//! free in arbitrary order as sequences finish and the working set must
//! not leak disk space over a long serving run.
//!
//! The file layer knows nothing about the KV payload format: slots are
//! opaque byte blocks. Framing, checksums and (de)quantization live in
//! the [`SpillManager`](super::SpillManager) above.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::SpillError;

/// One fixed-slot spill file plus its free-slot bookkeeping.
pub struct SegmentFile {
    path: PathBuf,
    file: File,
    slot_bytes: usize,
    n_slots: usize,
    /// occupancy bitmap (true = slot holds a live page)
    used: Vec<bool>,
    /// free slot indices, reused LIFO
    free: Vec<u32>,
}

impl SegmentFile {
    /// Create (truncating) a segment of `n_slots` slots of `slot_bytes`
    /// each, preallocated to its full size so writes never grow the file.
    pub fn create(
        path: &Path,
        slot_bytes: usize,
        n_slots: usize,
    ) -> Result<SegmentFile, SpillError> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.set_len((slot_bytes * n_slots) as u64)?;
        Ok(SegmentFile {
            path: path.to_path_buf(),
            file,
            slot_bytes,
            n_slots,
            used: vec![false; n_slots],
            free: (0..n_slots as u32).rev().collect(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    pub fn used_slots(&self) -> usize {
        self.n_slots - self.free.len()
    }

    /// Claim a free slot (the caller writes it next). `None` when full.
    pub fn alloc_slot(&mut self) -> Option<u32> {
        let slot = self.free.pop()?;
        self.used[slot as usize] = true;
        Some(slot)
    }

    /// Return a slot to the free list (its bytes stay on disk but are
    /// dead; the next `alloc_slot`/`write_slot` pair overwrites them).
    pub fn free_slot(&mut self, slot: u32) {
        let s = slot as usize;
        debug_assert!(self.used[s], "freeing a free slot {slot}");
        if self.used[s] {
            self.used[s] = false;
            self.free.push(slot);
        }
    }

    pub fn write_slot(&mut self, slot: u32, buf: &[u8]) -> Result<(), SpillError> {
        debug_assert_eq!(buf.len(), self.slot_bytes, "slot write size mismatch");
        if slot as usize >= self.n_slots {
            return Err(SpillError::SlotOutOfRange { slot, n_slots: self.n_slots });
        }
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.slot_bytes as u64))?;
        self.file.write_all(buf)?;
        Ok(())
    }

    /// Read a slot into `buf` (resized to `slot_bytes`). A file shorter
    /// than the slot demands — external truncation, partial write — maps
    /// to the typed `Truncated` error instead of an opaque I/O failure.
    pub fn read_slot(&mut self, slot: u32, buf: &mut Vec<u8>) -> Result<(), SpillError> {
        if slot as usize >= self.n_slots {
            return Err(SpillError::SlotOutOfRange { slot, n_slots: self.n_slots });
        }
        buf.resize(self.slot_bytes, 0);
        self.file
            .seek(SeekFrom::Start(slot as u64 * self.slot_bytes as u64))?;
        match self.file.read_exact(buf) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                Err(SpillError::Truncated { path: self.path.clone(), slot })
            }
            Err(e) => Err(SpillError::Io(e)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        super::super::default_spill_root().join(format!("{tag}.kvseg"))
    }

    #[test]
    fn slots_roundtrip_and_reuse() {
        let path = tmp_path("roundtrip");
        let mut seg = SegmentFile::create(&path, 32, 4).unwrap();
        assert_eq!(seg.free_slots(), 4);
        let a = seg.alloc_slot().unwrap();
        let b = seg.alloc_slot().unwrap();
        assert_ne!(a, b);
        seg.write_slot(a, &[7u8; 32]).unwrap();
        seg.write_slot(b, &[9u8; 32]).unwrap();
        let mut buf = Vec::new();
        seg.read_slot(a, &mut buf).unwrap();
        assert_eq!(buf, vec![7u8; 32]);
        seg.read_slot(b, &mut buf).unwrap();
        assert_eq!(buf, vec![9u8; 32]);
        // free -> reuse gives the same slot back (LIFO)
        seg.free_slot(a);
        assert_eq!(seg.alloc_slot(), Some(a));
        assert_eq!(seg.used_slots(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exhaustion_returns_none() {
        let path = tmp_path("exhaust");
        let mut seg = SegmentFile::create(&path, 8, 2).unwrap();
        assert!(seg.alloc_slot().is_some());
        assert!(seg.alloc_slot().is_some());
        assert_eq!(seg.alloc_slot(), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_file_is_a_typed_error() {
        let path = tmp_path("truncated");
        let mut seg = SegmentFile::create(&path, 64, 2).unwrap();
        let s = seg.alloc_slot().unwrap();
        seg.write_slot(s, &[1u8; 64]).unwrap();
        // external truncation under the open handle
        OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(10)
            .unwrap();
        let mut buf = Vec::new();
        match seg.read_slot(s, &mut buf) {
            Err(SpillError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn out_of_range_slot_is_rejected() {
        let path = tmp_path("range");
        let mut seg = SegmentFile::create(&path, 8, 1).unwrap();
        let mut buf = Vec::new();
        assert!(matches!(
            seg.read_slot(5, &mut buf),
            Err(SpillError::SlotOutOfRange { .. })
        ));
        assert!(matches!(
            seg.write_slot(5, &[0u8; 8]),
            Err(SpillError::SlotOutOfRange { .. })
        ));
        let _ = std::fs::remove_file(&path);
    }
}
