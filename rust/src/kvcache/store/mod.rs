//! Memory-budgeted page store: wraps `PagePool` with a KV byte budget,
//! page pinning, pluggable replacement policies and a lower-precision cold
//! tier — the buffer-manager layer that turns the repo's "2x memory
//! savings" from a high-water-mark counter into an enforced invariant.
//!
//! Residency model: every in-use pool page is either **Hot** (stored at the
//! pool's configured KV dtype) or **Cold** (demoted in place to the q8
//! rate via `PagePool::demote_page_in_place`; byte accounting charges the
//! int8 rate). When an allocation or promotion would push
//! `bytes_in_use` over the budget, the active `EvictionPolicy` picks
//! victims to demote — never a pinned page (pages of currently-decoding
//! sequences), never a still-writable partial page, never a page already
//! cold. Cold pages selected by a sparsity policy are transparently
//! promoted before the gather, with a simulated spill cost charged through
//! the `hwmodel` device constants.
//!
//! The store is a sidecar over `PagePool`, not a wrapper type: pages can
//! still be allocated/freed behind its back (snapshot clones, session
//! clears); `sync` reconciles against pool refcounts before any budget
//! decision, so accounting is exact at every enforcement point.

pub mod policy;

pub use policy::{make_eviction_policy, EvictionPolicy, EvictionPolicyKind};

use crate::hwmodel::Device;

use super::pool::{PageId, PagePool};
use super::seq::SeqCache;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tier {
    Untracked,
    Hot,
    Cold,
}

#[derive(Debug, Clone, Copy)]
struct PageState {
    tier: Tier,
    pinned: bool,
}

impl Default for PageState {
    fn default() -> Self {
        PageState { tier: Tier::Untracked, pinned: false }
    }
}

/// Cumulative store counters (the engine diffs these per decode step).
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    /// selected page was already hot
    pub hits: u64,
    /// selected page was cold and had to be promoted
    pub misses: u64,
    pub demotions: u64,
    pub promotions: u64,
    /// simulated cold-tier transfer time (hwmodel-priced)
    pub spill_seconds: f64,
    /// enforcement passes that could not reach the budget (everything
    /// evictable already demoted)
    pub overflows: u64,
}

/// Byte-budgeted residency manager over a `PagePool`.
pub struct PageStore {
    budget_bytes: Option<usize>,
    policy: Box<dyn EvictionPolicy>,
    state: Vec<PageState>,
    pinned: Vec<PageId>,
    hot_pages: usize,
    cold_pages: usize,
    tick: u64,
    dev: Device,
    pub stats: StoreStats,
}

impl PageStore {
    pub fn new(budget_bytes: Option<usize>, kind: EvictionPolicyKind) -> PageStore {
        PageStore {
            budget_bytes,
            policy: make_eviction_policy(kind),
            state: Vec::new(),
            pinned: Vec::new(),
            hot_pages: 0,
            cold_pages: 0,
            tick: 0,
            dev: Device::default(),
            stats: StoreStats::default(),
        }
    }

    /// A store without a budget is a transparent pass-through: `alloc`
    /// falls back to `PagePool::grow` and no page is ever demoted.
    pub fn enabled(&self) -> bool {
        self.budget_bytes.is_some()
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    pub fn policy_kind(&self) -> EvictionPolicyKind {
        self.policy.kind()
    }

    /// Whether the engine should feed bounding-box relevance observations.
    pub fn wants_scores(&self) -> bool {
        self.enabled() && self.policy.kind() == EvictionPolicyKind::QueryAware
    }

    pub fn is_cold(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.tier == Tier::Cold)
            .unwrap_or(false)
    }

    pub fn is_hot(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.tier == Tier::Hot)
            .unwrap_or(false)
    }

    pub fn is_pinned(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.pinned)
            .unwrap_or(false)
    }

    /// (hot, cold) resident page counts as of the last sync.
    pub fn tier_counts(&self) -> (usize, usize) {
        (self.hot_pages, self.cold_pages)
    }

    /// KV bytes currently resident, cold pages charged at the q8 rate.
    /// Without a budget this is exactly `PagePool::bytes_in_use`.
    pub fn bytes_in_use(&self, pool: &PagePool) -> usize {
        if !self.enabled() {
            return pool.bytes_in_use();
        }
        self.hot_pages * pool.page_bytes() + self.cold_pages * pool.page_bytes_cold()
    }

    fn ensure_cap(&mut self, cap: usize) {
        if self.state.len() < cap {
            self.state.resize(cap, PageState::default());
            self.policy.ensure_capacity(cap);
        }
    }

    fn register_hot(&mut self, id: PageId) {
        let st = &mut self.state[id as usize];
        match st.tier {
            Tier::Untracked => self.hot_pages += 1,
            Tier::Cold => {
                self.cold_pages -= 1;
                self.hot_pages += 1;
            }
            Tier::Hot => {}
        }
        st.tier = Tier::Hot;
        self.tick += 1;
        self.policy.on_access(id, self.tick);
    }

    fn remove(&mut self, id: PageId) {
        let st = &mut self.state[id as usize];
        match st.tier {
            Tier::Hot => self.hot_pages -= 1,
            Tier::Cold => self.cold_pages -= 1,
            Tier::Untracked => return,
        }
        st.tier = Tier::Untracked;
        st.pinned = false;
        self.policy.on_remove(id);
    }

    /// Reconcile residency against pool refcounts: pages allocated behind
    /// the store's back (snapshot clones, prefill) become Hot; freed pages
    /// leave the replacement structures. O(cap_pages) — called once per
    /// enforcement point, not per token.
    pub fn sync(&mut self, pool: &PagePool) {
        if !self.enabled() {
            return;
        }
        self.ensure_cap(pool.cap_pages());
        for id in 0..pool.cap_pages() as u32 {
            let live = pool.refcount(id) > 0;
            match (live, self.state[id as usize].tier) {
                (true, Tier::Untracked) => self.register_hot(id),
                (false, Tier::Untracked) => {}
                (false, _) => self.remove(id),
                (true, _) => {}
            }
        }
    }

    /// Budget-aware allocation: demote victims until one more hot page
    /// fits, then allocate (falling back to pool growth when nothing is
    /// evictable — serving never fails on budget pressure, it overflows
    /// and records the fact).
    pub fn alloc(&mut self, pool: &mut PagePool) -> PageId {
        if !self.enabled() {
            return pool.alloc();
        }
        self.sync(pool);
        self.evict_until(pool, pool.page_bytes());
        let id = pool.alloc();
        self.ensure_cap(pool.cap_pages());
        self.register_hot(id);
        id
    }

    /// Pin a page for the duration of the current decode step: pinned
    /// pages are never demotion victims.
    pub fn pin(&mut self, id: PageId) {
        if !self.enabled() || (id as usize) >= self.state.len() {
            return;
        }
        let st = &mut self.state[id as usize];
        if !st.pinned {
            st.pinned = true;
            self.pinned.push(id);
        }
    }

    pub fn unpin_all(&mut self) {
        for id in self.pinned.drain(..) {
            self.state[id as usize].pinned = false;
        }
    }

    /// Clear one page's pin. The decode loop uses `unpin_all` at step end;
    /// this is the mid-flight path — cancellation or deadline expiry frees
    /// a sequence between steps, and its pages must stop being
    /// pin-protected before they can leave residency.
    pub fn unpin(&mut self, id: PageId) {
        if !self.enabled() || (id as usize) >= self.state.len() {
            return;
        }
        if self.state[id as usize].pinned {
            self.state[id as usize].pinned = false;
            self.pinned.retain(|&p| p != id);
        }
    }

    /// A sparsity policy selected this page for attention: count the
    /// residency hit/miss and transparently promote if cold (charging the
    /// simulated cold-tier transfer). Promotion may displace another page
    /// to stay inside the budget.
    pub fn ensure_hot(&mut self, pool: &mut PagePool, id: PageId) {
        if !self.enabled() {
            return;
        }
        self.ensure_cap(pool.cap_pages());
        match self.state[id as usize].tier {
            Tier::Hot => {
                self.stats.hits += 1;
                self.tick += 1;
                self.policy.on_access(id, self.tick);
            }
            Tier::Cold => {
                self.stats.misses += 1;
                self.stats.promotions += 1;
                self.state[id as usize].tier = Tier::Hot;
                self.cold_pages -= 1;
                self.hot_pages += 1;
                let bytes = pool.page_bytes_cold() + pool.page_bytes();
                self.stats.spill_seconds += self.spill_seconds(bytes);
                self.tick += 1;
                self.policy.on_access(id, self.tick);
                // displace someone else, never the page just promoted
                self.evict_until_excluding(pool, 0, Some(id));
            }
            Tier::Untracked => {
                // allocation raced past a sync point; adopt as hot
                self.register_hot(id);
                self.stats.hits += 1;
            }
        }
    }

    /// Feed a bounding-box relevance observation (query-aware policy).
    pub fn note_score(&mut self, id: PageId, score: f32) {
        if self.enabled() && (id as usize) < self.state.len() {
            self.policy.on_score(id, score);
        }
    }

    /// Demote victims until `bytes_in_use <= budget`. Called after every
    /// decode step (post-unpin) and inside alloc/promote.
    pub fn enforce_budget(&mut self, pool: &mut PagePool) {
        if !self.enabled() {
            return;
        }
        self.sync(pool);
        self.evict_until(pool, 0);
    }

    fn evict_until(&mut self, pool: &mut PagePool, headroom: usize) {
        self.evict_until_excluding(pool, headroom, None);
    }

    fn evict_until_excluding(
        &mut self,
        pool: &mut PagePool,
        headroom: usize,
        exclude: Option<PageId>,
    ) {
        let Some(budget) = self.budget_bytes else { return };
        loop {
            if self.bytes_in_use(pool) + headroom <= budget {
                return;
            }
            let victim = {
                let state = &self.state;
                let page_size = pool.page_size;
                let pool_ref = &*pool;
                self.policy.victim(&mut |id| {
                    Some(id) != exclude
                        && state
                            .get(id as usize)
                            .map(|s| s.tier == Tier::Hot && !s.pinned)
                            .unwrap_or(false)
                        && pool_ref.refcount(id) > 0
                        && pool_ref.filled(id) == page_size
                })
            };
            match victim {
                Some(id) => self.demote(pool, id),
                None => {
                    self.stats.overflows += 1;
                    return;
                }
            }
        }
    }

    fn demote(&mut self, pool: &mut PagePool, id: PageId) {
        debug_assert_eq!(self.state[id as usize].tier, Tier::Hot);
        debug_assert!(!self.state[id as usize].pinned, "demoting a pinned page");
        let moved = pool.demote_page_in_place(id);
        self.state[id as usize].tier = Tier::Cold;
        self.hot_pages -= 1;
        self.cold_pages += 1;
        self.stats.demotions += 1;
        self.stats.spill_seconds += self.spill_seconds(moved);
    }

    fn spill_seconds(&self, bytes: usize) -> f64 {
        self.dev.spill_seconds(bytes)
    }

    /// Coldest prunable table entry of a sequence (for the `PruneColdest`
    /// plugin action): lowest policy rank among non-sink entries, never the
    /// trailing write-head page. With the store disabled every rank ties
    /// and the first non-sink entry wins — the pre-store behaviour.
    pub fn coldest_index(&self, seq: &SeqCache, sink: usize) -> Option<usize> {
        let n = seq.pages.len();
        if n <= sink + 1 {
            return None;
        }
        (sink..n - 1).min_by(|&a, &b| {
            let ra = self.policy.rank(seq.pages[a].id);
            let rb = self.policy.rank(seq.pages[b].id);
            ra.partial_cmp(&rb).unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    fn pool() -> PagePool {
        // 2 layers, d=8, S=4, f32
        PagePool::new(2, 8, 4, KvDtype::F32)
    }

    fn fill_page(pool: &mut PagePool, id: PageId, val: f32) {
        for slot in 0..pool.page_size {
            for l in 0..pool.n_layers {
                let row = vec![val + slot as f32 * 0.25; pool.d_kv];
                pool.write_token(id, slot, l, &row, &row);
            }
        }
    }

    #[test]
    fn disabled_store_is_pass_through() {
        let mut p = pool();
        let mut s = PageStore::new(None, EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        assert!(!s.enabled());
        assert_eq!(s.bytes_in_use(&p), p.bytes_in_use());
        assert_eq!(s.stats.demotions, 0);
        p.release(a);
    }

    #[test]
    fn alloc_over_budget_demotes_instead_of_growing_bytes() {
        let mut p = pool();
        let budget = 3 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let mut live = Vec::new();
        for i in 0..6 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        assert!(s.bytes_in_use(&p) <= budget, "{} > {budget}", s.bytes_in_use(&p));
        assert!(s.stats.demotions >= 3);
        let (hot, cold) = s.tier_counts();
        assert_eq!(hot + cold, 6);
        for id in live {
            p.release(id);
        }
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
    }

    #[test]
    fn pinned_pages_survive_enforcement() {
        let mut p = pool();
        let budget = 2 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        s.pin(a);
        let mut others = Vec::new();
        for i in 0..4 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            others.push(id);
        }
        assert!(s.is_hot(a), "pinned page was demoted");
        s.unpin_all();
        s.enforce_budget(&mut p);
        assert!(s.bytes_in_use(&p) <= budget);
        p.release(a);
        for id in others {
            p.release(id);
        }
    }

    #[test]
    fn unpin_single_page_allows_demotion() {
        let mut p = pool();
        let budget = p.page_bytes(); // room for one hot page only
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        s.pin(a); // pin before the next alloc can demote it
        let b = s.alloc(&mut p);
        fill_page(&mut p, b, 2.0);
        s.pin(b);
        s.enforce_budget(&mut p);
        assert!(s.is_hot(a) && s.is_hot(b), "both pinned, neither demotes");
        // mid-flight release path: one page unpinned, the other stays safe
        s.unpin(a);
        s.enforce_budget(&mut p);
        assert!(s.is_cold(a), "unpinned page became demotable");
        assert!(s.is_hot(b), "still-pinned page survived");
        s.unpin(b);
        p.release(a);
        p.release(b);
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
    }

    #[test]
    fn promotion_counts_miss_and_restores_hot() {
        let mut p = pool();
        let budget = 2 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        for i in 0..3 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
        }
        s.enforce_budget(&mut p);
        assert!(s.is_cold(a), "LRU must have demoted the oldest page");
        s.ensure_hot(&mut p, a);
        assert!(s.is_hot(a));
        assert_eq!(s.stats.misses, 1);
        assert_eq!(s.stats.promotions, 1);
        assert!(s.stats.spill_seconds > 0.0);
        s.ensure_hot(&mut p, a);
        assert_eq!(s.stats.hits, 1);
    }

    #[test]
    fn partial_pages_are_never_demoted() {
        let mut p = pool();
        let budget = p.page_bytes(); // room for one page only
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Clock);
        let a = s.alloc(&mut p);
        // only one token written: page stays partial
        p.write_token(a, 0, 0, &[1.0; 8], &[1.0; 8]);
        p.write_token(a, 0, 1, &[1.0; 8], &[1.0; 8]);
        let b = s.alloc(&mut p);
        fill_page(&mut p, b, 2.0);
        s.enforce_budget(&mut p);
        assert!(s.is_hot(a), "partial page demoted");
        assert!(s.is_cold(b) || s.bytes_in_use(&p) <= budget);
    }

    #[test]
    fn sync_adopts_and_releases_foreign_pages() {
        let mut p = pool();
        let mut s = PageStore::new(Some(10 * p.page_bytes()), EvictionPolicyKind::Lru);
        let a = p.alloc(); // behind the store's back
        s.sync(&p);
        assert!(s.is_hot(a));
        p.release(a);
        s.sync(&p);
        assert!(!s.is_hot(a) && !s.is_cold(a));
        assert_eq!(s.tier_counts(), (0, 0));
    }

    #[test]
    fn overflow_recorded_when_nothing_evictable() {
        let mut p = pool();
        let budget = p.page_bytes() / 2; // below even one page
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::QueryAware);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        s.pin(a);
        s.enforce_budget(&mut p);
        assert!(s.stats.overflows > 0);
        assert!(s.is_hot(a));
        s.unpin_all();
        p.release(a);
    }

    #[test]
    fn coldest_index_defaults_to_first_non_sink() {
        let mut p = pool();
        let s = PageStore::new(None, EvictionPolicyKind::Lru);
        let mut seq = SeqCache::new();
        for i in 0..12 {
            let (page, slot) = seq.slot_for_next(&mut p);
            for l in 0..2 {
                p.write_token(page, slot, l, &[i as f32; 8], &[i as f32; 8]);
            }
            seq.commit_token();
        }
        // untracked pages all rank equal -> first non-sink index
        assert_eq!(s.coldest_index(&seq, 1), Some(1));
        assert_eq!(s.coldest_index(&seq, 5), None, "nothing prunable");
        seq.clear(&mut p);
    }
}
