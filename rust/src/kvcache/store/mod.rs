//! Memory-budgeted page store: wraps `PagePool` with a KV byte budget,
//! page pinning, pluggable replacement policies and a lower-precision cold
//! tier — the buffer-manager layer that turns the repo's "2x memory
//! savings" from a high-water-mark counter into an enforced invariant.
//!
//! Residency model: every in-use pool page is either **Hot** (stored at the
//! pool's configured KV dtype) or **Cold** (demoted in place to the q8
//! rate via `PagePool::demote_page_in_place`; byte accounting charges the
//! int8 rate). When an allocation or promotion would push
//! `bytes_in_use` over the budget, the active `EvictionPolicy` picks
//! victims to demote — never a pinned page (pages of currently-decoding
//! sequences), never a still-writable partial page, never a page already
//! cold. Cold pages selected by a sparsity policy are transparently
//! promoted before the gather, with a simulated spill cost charged through
//! the `hwmodel` device constants.
//!
//! The store is a sidecar over `PagePool`, not a wrapper type: pages can
//! still be allocated/freed behind its back (snapshot clones, session
//! clears); `sync` reconciles against pool refcounts before any budget
//! decision, so accounting is exact at every enforcement point.
//!
//! With a [`spill`] tier attached the residency machine grows a third
//! state: `Hot -> ColdQ8 -> Disk`. Budget enforcement cascades — demote
//! hot pages to q8 first, and once nothing hot is evictable, move the
//! oldest-demoted cold pages onto disk (their pool rows are zeroed; the
//! page charges zero RAM bytes). `ensure_hot` faults disk pages back —
//! read, dequantize, reinstate bounding boxes — priced through the
//! `hwmodel` disk-bandwidth constants so modeled event streams stay
//! seed-deterministic.
//!
//! The whole store stack is `Send` (the `EvictionPolicy` trait carries a
//! `Send` supertrait; the spill tier is owned files and maps): each
//! serving worker's store moves onto a scoped OS thread with its engine
//! when decode rounds run thread-parallel. The stack stays lock-free
//! because ownership is per-worker exclusive — see the lock-ordering
//! note in docs/pagestore_design.md.

pub mod policy;
pub mod spill;

pub use policy::{make_eviction_policy, EvictionPolicy, EvictionPolicyKind};
pub use spill::{
    default_spill_root, FaultSource, SpillConfig, SpillError, SpillManager,
};

use crate::hwmodel::Device;

use super::pool::{PageId, PagePool};
use super::seq::SeqCache;

/// One tier-transition the store performed, buffered per worker when
/// tracing is on and drained serially at the frontend's commit points
/// (worker order), so multi-threaded rounds serialize deterministically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StoreTraceEvent {
    /// hot page demoted in place to the q8 cold tier
    Demote { page: PageId },
    /// cold page moved onto the disk spill tier
    SpillOut { page: PageId },
    /// disk page faulted back into residency
    Fault { page: PageId, src: FaultSource },
    /// readahead tick prefetched this many payload bytes
    Readahead { bytes: u64 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Tier {
    Untracked,
    Hot,
    /// demoted in place to the q8 rate, still RAM-resident
    ColdQ8,
    /// payload on the spill tier; pool rows are zeroed, bboxes stay hot
    Disk,
}

#[derive(Debug, Clone, Copy)]
struct PageState {
    tier: Tier,
    pinned: bool,
}

impl Default for PageState {
    fn default() -> Self {
        PageState { tier: Tier::Untracked, pinned: false }
    }
}

/// Cumulative store counters (the engine diffs these per decode step).
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    /// selected page was already hot
    pub hits: u64,
    /// selected page was cold (q8 or disk) and had to be promoted
    pub misses: u64,
    pub demotions: u64,
    pub promotions: u64,
    /// simulated cold-tier transfer time (hwmodel-priced)
    pub spill_seconds: f64,
    /// enforcement passes that could not reach the budget (everything
    /// evictable already demoted/spilled)
    pub overflows: u64,
    // --- disk spill tier (zero without a spill manager) ---
    /// cold pages moved onto the disk tier
    pub spill_outs: u64,
    /// payload bytes written toward the disk tier
    pub spill_out_bytes: u64,
    /// disk pages faulted back into residency
    pub faults: u64,
    /// payload bytes read back from the disk tier
    pub spill_in_bytes: u64,
    /// faults served from the write-back staging buffer (no disk read)
    pub staging_hits: u64,
    /// faults served from the readahead cache (read already paid)
    pub readahead_hits: u64,
    /// bytes prefetched by readahead ticks
    pub readahead_bytes: u64,
    /// spill-tier I/O or corruption failures absorbed on the write path
    pub spill_errors: u64,
    /// simulated disk-tier transfer time (hwmodel-priced)
    pub disk_seconds: f64,
}

/// Byte-budgeted residency manager over a `PagePool`.
pub struct PageStore {
    budget_bytes: Option<usize>,
    policy: Box<dyn EvictionPolicy>,
    state: Vec<PageState>,
    pinned: Vec<PageId>,
    hot_pages: usize,
    cold_pages: usize,
    disk_pages: usize,
    /// store tick at demotion time: the q8→disk cascade spills the
    /// oldest-demoted cold page first (FIFO on demotion age)
    demoted_at: Vec<u64>,
    /// disk tier below q8 (None = the classic two-tier store)
    spill: Option<SpillManager>,
    tick: u64,
    dev: Device,
    pub stats: StoreStats,
    /// tier-transition event buffer; `None` = tracing off (the hot path's
    /// only cost is this option check)
    trace_buf: Option<Vec<StoreTraceEvent>>,
}

impl PageStore {
    pub fn new(budget_bytes: Option<usize>, kind: EvictionPolicyKind) -> PageStore {
        PageStore {
            budget_bytes,
            policy: make_eviction_policy(kind),
            state: Vec::new(),
            pinned: Vec::new(),
            hot_pages: 0,
            cold_pages: 0,
            disk_pages: 0,
            demoted_at: Vec::new(),
            spill: None,
            tick: 0,
            dev: Device::default(),
            stats: StoreStats::default(),
            trace_buf: None,
        }
    }

    /// Enable (or disable) tier-transition tracing. On enable the buffer
    /// starts empty; callers drain it with [`take_trace`](Self::take_trace)
    /// at their commit points.
    pub fn set_trace(&mut self, on: bool) {
        self.trace_buf = if on { Some(Vec::new()) } else { None };
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace_buf.is_some()
    }

    /// Drain the buffered tier-transition events (empty when tracing is
    /// off or nothing happened since the last drain).
    pub fn take_trace(&mut self) -> Vec<StoreTraceEvent> {
        match self.trace_buf.as_mut() {
            Some(buf) => std::mem::take(buf),
            None => Vec::new(),
        }
    }

    #[inline]
    fn trace(&mut self, ev: StoreTraceEvent) {
        if let Some(buf) = self.trace_buf.as_mut() {
            buf.push(ev);
        }
    }

    /// A store with a disk spill tier under the q8 cold tier. Creates the
    /// spill directory eagerly so misconfiguration fails at construction,
    /// not mid-serve.
    pub fn with_spill(
        budget_bytes: Option<usize>,
        kind: EvictionPolicyKind,
        spill_cfg: SpillConfig,
    ) -> anyhow::Result<PageStore> {
        let mut s = PageStore::new(budget_bytes, kind);
        s.spill = Some(SpillManager::new(spill_cfg)?);
        Ok(s)
    }

    pub fn spill_enabled(&self) -> bool {
        self.spill.is_some()
    }

    /// Whole pages the disk tier can still accept (0 without one).
    pub fn spill_free_pages(&self, pool: &PagePool) -> usize {
        self.spill.as_ref().map(|s| s.pages_free(pool)).unwrap_or(0)
    }

    /// Payload bytes currently held by the disk tier.
    pub fn spill_bytes(&self) -> usize {
        self.spill.as_ref().map(|s| s.bytes_on_tier()).unwrap_or(0)
    }

    /// Flush the spill staging buffer to segment files (tests, shutdown).
    pub fn flush_spill(&mut self) -> anyhow::Result<()> {
        if let Some(sp) = self.spill.as_mut() {
            sp.flush()?;
        }
        Ok(())
    }

    /// Resize the disk tier's byte budget at runtime (no-op without one).
    /// Shrinking never evicts already-spilled pages; it only stops new
    /// spills. The next `enforce_budget` sees the new cap.
    pub fn set_spill_budget_bytes(&mut self, bytes: usize) {
        if let Some(sp) = self.spill.as_mut() {
            sp.set_budget_bytes(bytes);
        }
    }

    /// A store without a budget is a transparent pass-through: `alloc`
    /// falls back to `PagePool::grow` and no page is ever demoted.
    pub fn enabled(&self) -> bool {
        self.budget_bytes.is_some()
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    pub fn policy_kind(&self) -> EvictionPolicyKind {
        self.policy.kind()
    }

    /// Whether the engine should feed bounding-box relevance observations
    /// (the query-aware eviction signal, and the disk tier's readahead
    /// predictor).
    pub fn wants_scores(&self) -> bool {
        self.enabled()
            && (self.policy.kind() == EvictionPolicyKind::QueryAware
                || self.readahead_enabled())
    }

    fn readahead_enabled(&self) -> bool {
        self.spill.as_ref().map(|s| s.readahead_enabled()).unwrap_or(false)
    }

    pub fn is_cold(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.tier == Tier::ColdQ8)
            .unwrap_or(false)
    }

    pub fn is_on_disk(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.tier == Tier::Disk)
            .unwrap_or(false)
    }

    pub fn is_hot(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.tier == Tier::Hot)
            .unwrap_or(false)
    }

    pub fn is_pinned(&self, id: PageId) -> bool {
        self.state
            .get(id as usize)
            .map(|s| s.pinned)
            .unwrap_or(false)
    }

    /// (hot, q8-cold) RAM-resident page counts as of the last sync.
    pub fn tier_counts(&self) -> (usize, usize) {
        (self.hot_pages, self.cold_pages)
    }

    /// (hot, q8-cold, disk) page counts as of the last sync.
    pub fn tier_residency(&self) -> (usize, usize, usize) {
        (self.hot_pages, self.cold_pages, self.disk_pages)
    }

    /// KV bytes currently RAM-resident: cold pages charge the q8 rate,
    /// disk pages charge nothing (their rows are zeroed in the pool; only
    /// the per-page bounding boxes stay hot, as metadata always does).
    /// Without a budget this is exactly `PagePool::bytes_in_use`.
    pub fn bytes_in_use(&self, pool: &PagePool) -> usize {
        if !self.enabled() {
            return pool.bytes_in_use();
        }
        self.hot_pages * pool.page_bytes() + self.cold_pages * pool.page_bytes_cold()
    }

    fn ensure_cap(&mut self, cap: usize) {
        if self.state.len() < cap {
            self.state.resize(cap, PageState::default());
            self.demoted_at.resize(cap, 0);
            self.policy.ensure_capacity(cap);
        }
    }

    fn register_hot(&mut self, id: PageId) {
        let st = &mut self.state[id as usize];
        match st.tier {
            Tier::Untracked => self.hot_pages += 1,
            Tier::ColdQ8 => {
                self.cold_pages -= 1;
                self.hot_pages += 1;
            }
            Tier::Disk => {
                // disk pages re-enter through `ensure_hot`'s fault path;
                // adopting one here means the caller bypassed it — keep the
                // accounting sound and drop the (now dead) spill payload
                debug_assert!(false, "page {id} adopted hot while on disk");
                self.disk_pages -= 1;
                self.hot_pages += 1;
                if let Some(sp) = self.spill.as_mut() {
                    sp.free(id);
                }
            }
            Tier::Hot => {}
        }
        self.state[id as usize].tier = Tier::Hot;
        self.tick += 1;
        self.policy.on_access(id, self.tick);
    }

    fn remove(&mut self, id: PageId) {
        let st = &mut self.state[id as usize];
        match st.tier {
            Tier::Hot => self.hot_pages -= 1,
            Tier::ColdQ8 => self.cold_pages -= 1,
            Tier::Disk => {
                self.disk_pages -= 1;
                if let Some(sp) = self.spill.as_mut() {
                    sp.free(id);
                }
            }
            Tier::Untracked => return,
        }
        self.state[id as usize].tier = Tier::Untracked;
        self.state[id as usize].pinned = false;
        self.policy.on_remove(id);
    }

    /// Reconcile residency against pool refcounts: pages allocated behind
    /// the store's back (snapshot clones, prefill) become Hot; freed pages
    /// leave the replacement structures. A page is tracked (and charged in
    /// `bytes_in_use`) **once per PageId** however many sequences, session
    /// snapshots or prefix-index entries share it — the refcount is fed to
    /// the policy as a sharer-count signal instead of inflating the byte
    /// accounting. O(cap_pages) — called once per enforcement point, not
    /// per token.
    pub fn sync(&mut self, pool: &PagePool) {
        if !self.enabled() {
            return;
        }
        self.ensure_cap(pool.cap_pages());
        for id in 0..pool.cap_pages() as u32 {
            let rc = pool.refcount(id);
            match (rc > 0, self.state[id as usize].tier) {
                (true, Tier::Untracked) => self.register_hot(id),
                (false, Tier::Untracked) => {}
                (false, _) => self.remove(id),
                (true, _) => {}
            }
            if rc > 0 {
                self.policy.on_sharers(id, rc);
            }
        }
    }

    /// Budget-aware allocation: demote victims until one more hot page
    /// fits, then allocate (falling back to pool growth when nothing is
    /// evictable — serving never fails on budget pressure, it overflows
    /// and records the fact).
    pub fn alloc(&mut self, pool: &mut PagePool) -> PageId {
        if !self.enabled() {
            return pool.alloc();
        }
        self.sync(pool);
        self.evict_until(pool, pool.page_bytes());
        let id = pool.alloc();
        self.ensure_cap(pool.cap_pages());
        self.register_hot(id);
        id
    }

    /// Pin a page for the duration of the current decode step: pinned
    /// pages are never demotion victims.
    pub fn pin(&mut self, id: PageId) {
        if !self.enabled() || (id as usize) >= self.state.len() {
            return;
        }
        let st = &mut self.state[id as usize];
        if !st.pinned {
            st.pinned = true;
            self.pinned.push(id);
        }
    }

    pub fn unpin_all(&mut self) {
        for id in self.pinned.drain(..) {
            self.state[id as usize].pinned = false;
        }
    }

    /// Clear one page's pin. The decode loop uses `unpin_all` at step end;
    /// this is the mid-flight path — cancellation or deadline expiry frees
    /// a sequence between steps, and its pages must stop being
    /// pin-protected before they can leave residency.
    pub fn unpin(&mut self, id: PageId) {
        if !self.enabled() || (id as usize) >= self.state.len() {
            return;
        }
        if self.state[id as usize].pinned {
            self.state[id as usize].pinned = false;
            self.pinned.retain(|&p| p != id);
        }
    }

    /// A sparsity policy selected this page for attention: count the
    /// residency hit/miss and transparently promote if cold (charging the
    /// simulated cold-tier transfer) or **fault** if on disk (read the
    /// segment slot, dequantize into the pool, reinstate bounding boxes,
    /// priced at disk bandwidth). Promotion may displace another page to
    /// stay inside the budget. Only the disk path can fail — a corrupted
    /// or truncated segment surfaces as a typed [`SpillError`].
    pub fn ensure_hot(&mut self, pool: &mut PagePool, id: PageId) -> anyhow::Result<()> {
        if !self.enabled() {
            return Ok(());
        }
        self.ensure_cap(pool.cap_pages());
        match self.state[id as usize].tier {
            Tier::Hot => {
                self.stats.hits += 1;
                self.tick += 1;
                self.policy.on_access(id, self.tick);
            }
            Tier::ColdQ8 => {
                self.stats.misses += 1;
                self.stats.promotions += 1;
                self.state[id as usize].tier = Tier::Hot;
                self.cold_pages -= 1;
                self.hot_pages += 1;
                let bytes = pool.page_bytes_cold() + pool.page_bytes();
                self.stats.spill_seconds += self.spill_seconds(bytes);
                self.tick += 1;
                self.policy.on_access(id, self.tick);
                // displace someone else, never the page just promoted
                self.evict_until_excluding(pool, 0, Some(id));
            }
            Tier::Disk => {
                let sp = self.spill.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("page {id} marked Disk without a spill tier")
                })?;
                let (bytes, src) = sp.fault(pool, id)?;
                self.stats.misses += 1;
                self.stats.promotions += 1;
                self.stats.faults += 1;
                self.stats.spill_in_bytes += bytes as u64;
                match src {
                    // still in the write-back buffer: no disk traffic
                    FaultSource::Staging => self.stats.staging_hits += 1,
                    // prefetched: the read was priced at the readahead tick
                    FaultSource::Readahead => self.stats.readahead_hits += 1,
                    FaultSource::Disk => {
                        self.stats.disk_seconds += self.dev.disk_seconds(bytes);
                    }
                }
                // the dequantized rows land at the hot rate: charge the
                // same q8→hot promotion the cold path pays
                self.stats.spill_seconds += self.spill_seconds(pool.page_bytes());
                self.trace(StoreTraceEvent::Fault { page: id, src });
                self.state[id as usize].tier = Tier::Hot;
                self.disk_pages -= 1;
                self.hot_pages += 1;
                self.tick += 1;
                self.policy.on_access(id, self.tick);
                self.evict_until_excluding(pool, 0, Some(id));
            }
            Tier::Untracked => {
                // allocation raced past a sync point; adopt as hot
                self.register_hot(id);
                self.stats.hits += 1;
            }
        }
        Ok(())
    }

    /// Fault a page back only if it lives on the disk tier (no-op for
    /// hot/cold pages — their bytes are RAM-resident and readable). The
    /// prefill session-resume path uses this before gathering.
    pub fn fault_if_spilled(
        &mut self,
        pool: &mut PagePool,
        id: PageId,
    ) -> anyhow::Result<()> {
        if self.is_on_disk(id) {
            self.ensure_hot(pool, id)?;
        }
        Ok(())
    }

    /// Feed a bounding-box relevance observation (query-aware policy and
    /// the disk tier's readahead predictor).
    pub fn note_score(&mut self, id: PageId, score: f32) {
        if self.enabled() && (id as usize) < self.state.len() {
            self.policy.on_score(id, score);
            if self.state[id as usize].tier == Tier::Disk {
                if let Some(sp) = self.spill.as_mut() {
                    sp.note_score(id, score);
                }
            }
        }
    }

    /// Prefetch the disk pages the current query scores highest into the
    /// readahead cache (one batched read, priced at disk bandwidth). The
    /// engine calls this once per decode step after feeding scores; a
    /// no-op without a spill tier or with readahead disabled. Read
    /// failures are absorbed (`spill_errors`) — readahead is a hint, and
    /// the synchronous fault path will surface a real corruption.
    pub fn readahead_tick(&mut self) {
        let Some(sp) = self.spill.as_mut() else { return };
        match sp.prefetch() {
            Ok(0) => {}
            Ok(bytes) => {
                self.stats.readahead_bytes += bytes as u64;
                self.stats.disk_seconds += self.dev.disk_seconds(bytes);
                self.trace(StoreTraceEvent::Readahead { bytes: bytes as u64 });
            }
            Err(_) => self.stats.spill_errors += 1,
        }
    }

    /// Preemption snapshot: push every eligible hot page of a paused
    /// sequence into the q8 cold tier through the normal demotion
    /// machinery (same pricing, same trace events), making its bytes
    /// reclaimable by whoever runs next — the budget cascade can then
    /// spill them onward to disk under pressure. Partially-filled and
    /// pinned pages stay hot (the demotion invariants exclude them; a
    /// trailing write-head page is small and still append-writable on
    /// resume). Returns the number of pages demoted. On resume the
    /// decode path's `ensure_hot` faults the pages back, so preemption
    /// is priced but bit-preserving for int8 pools and q8-lossy exactly
    /// once for f32/f16 pools — the same contract as budget demotion.
    pub fn demote_seq(&mut self, pool: &mut PagePool, seq: &SeqCache) -> usize {
        if !self.enabled() {
            return 0;
        }
        self.ensure_cap(pool.cap_pages());
        let mut n = 0;
        for e in &seq.pages {
            let id = e.id;
            let st = self.state[id as usize];
            if st.tier == Tier::Hot
                && !st.pinned
                && pool.refcount(id) > 0
                && pool.filled(id) == pool.page_size
            {
                self.demote(pool, id);
                n += 1;
            }
        }
        n
    }

    /// Demote victims until `bytes_in_use <= budget`. Called after every
    /// decode step (post-unpin) and inside alloc/promote.
    pub fn enforce_budget(&mut self, pool: &mut PagePool) {
        if !self.enabled() {
            return;
        }
        self.sync(pool);
        self.evict_until(pool, 0);
    }

    fn evict_until(&mut self, pool: &mut PagePool, headroom: usize) {
        self.evict_until_excluding(pool, headroom, None);
    }

    /// Budget cascade: demote hot pages to q8 while the policy still has
    /// victims; once nothing hot is evictable, spill the oldest-demoted
    /// cold pages to disk (fully freeing their pool bytes); only when both
    /// rungs are exhausted does the pass record an overflow.
    fn evict_until_excluding(
        &mut self,
        pool: &mut PagePool,
        headroom: usize,
        exclude: Option<PageId>,
    ) {
        let Some(budget) = self.budget_bytes else { return };
        loop {
            if self.bytes_in_use(pool) + headroom <= budget {
                return;
            }
            let victim = {
                let state = &self.state;
                let page_size = pool.page_size;
                let pool_ref = &*pool;
                self.policy.victim(&mut |id| {
                    Some(id) != exclude
                        && state
                            .get(id as usize)
                            .map(|s| s.tier == Tier::Hot && !s.pinned)
                            .unwrap_or(false)
                        && pool_ref.refcount(id) > 0
                        && pool_ref.filled(id) == page_size
                })
            };
            match victim {
                Some(id) => self.demote(pool, id),
                None => {
                    if !self.spill_one(pool, exclude) {
                        self.stats.overflows += 1;
                        return;
                    }
                }
            }
        }
    }

    fn demote(&mut self, pool: &mut PagePool, id: PageId) {
        debug_assert_eq!(self.state[id as usize].tier, Tier::Hot);
        debug_assert!(!self.state[id as usize].pinned, "demoting a pinned page");
        let moved = pool.demote_page_in_place(id);
        self.state[id as usize].tier = Tier::ColdQ8;
        self.hot_pages -= 1;
        self.cold_pages += 1;
        self.tick += 1;
        self.demoted_at[id as usize] = self.tick;
        self.stats.demotions += 1;
        self.stats.spill_seconds += self.spill_seconds(moved);
        self.trace(StoreTraceEvent::Demote { page: id });
    }

    /// The q8→disk rung of the cascade: move the oldest-demoted,
    /// unpinned cold page onto the spill tier. Returns false when there
    /// is no spill tier, it is at its byte budget, nothing qualifies, or
    /// the write path failed (recorded, never fatal — serving overflows
    /// instead of erroring on budget pressure).
    fn spill_one(&mut self, pool: &mut PagePool, exclude: Option<PageId>) -> bool {
        let can = match self.spill.as_ref() {
            Some(sp) => sp.can_accept(pool),
            None => false,
        };
        if !can {
            return false;
        }
        let mut best: Option<(PageId, u64)> = None;
        for i in 0..self.state.len() {
            let id = i as PageId;
            if Some(id) == exclude {
                continue;
            }
            let st = self.state[i];
            if st.tier != Tier::ColdQ8 || st.pinned || pool.refcount(id) == 0 {
                continue;
            }
            let t = self.demoted_at[i];
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((id, t));
            }
        }
        let Some((id, _)) = best else { return false };
        let (bytes, new_write_errors) = {
            let sp = self.spill.as_mut().expect("checked above");
            let before = sp.write_errors;
            let bytes = sp.spill(pool, id);
            (bytes, sp.write_errors - before)
        };
        self.state[id as usize].tier = Tier::Disk;
        self.cold_pages -= 1;
        self.disk_pages += 1;
        self.stats.spill_outs += 1;
        self.stats.spill_out_bytes += bytes as u64;
        self.stats.spill_errors += new_write_errors;
        self.stats.disk_seconds += self.dev.disk_seconds(bytes);
        self.trace(StoreTraceEvent::SpillOut { page: id });
        true
    }

    fn spill_seconds(&self, bytes: usize) -> f64 {
        self.dev.spill_seconds(bytes)
    }

    /// Coldest prunable table entry of a sequence (for the `PruneColdest`
    /// plugin action): lowest policy rank among non-sink entries, never the
    /// trailing write-head page. With the store disabled every rank ties
    /// and the first non-sink entry wins — the pre-store behaviour.
    pub fn coldest_index(&self, seq: &SeqCache, sink: usize) -> Option<usize> {
        let n = seq.pages.len();
        if n <= sink + 1 {
            return None;
        }
        (sink..n - 1).min_by(|&a, &b| {
            let ra = self.policy.rank(seq.pages[a].id);
            let rb = self.policy.rank(seq.pages[b].id);
            ra.partial_cmp(&rb).unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    fn pool() -> PagePool {
        // 2 layers, d=8, S=4, f32
        PagePool::new(2, 8, 4, KvDtype::F32)
    }

    fn fill_page(pool: &mut PagePool, id: PageId, val: f32) {
        for slot in 0..pool.page_size {
            for l in 0..pool.n_layers {
                let row = vec![val + slot as f32 * 0.25; pool.d_kv];
                pool.write_token(id, slot, l, &row, &row);
            }
        }
    }

    #[test]
    fn disabled_store_is_pass_through() {
        let mut p = pool();
        let mut s = PageStore::new(None, EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        assert!(!s.enabled());
        assert_eq!(s.bytes_in_use(&p), p.bytes_in_use());
        assert_eq!(s.stats.demotions, 0);
        p.release(a);
    }

    #[test]
    fn alloc_over_budget_demotes_instead_of_growing_bytes() {
        let mut p = pool();
        let budget = 3 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let mut live = Vec::new();
        for i in 0..6 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        assert!(s.bytes_in_use(&p) <= budget, "{} > {budget}", s.bytes_in_use(&p));
        assert!(s.stats.demotions >= 3);
        let (hot, cold) = s.tier_counts();
        assert_eq!(hot + cold, 6);
        for id in live {
            p.release(id);
        }
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
    }

    #[test]
    fn shared_prefix_page_counts_once_in_bytes_in_use() {
        let mut p = pool();
        let budget = 2 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let shared = s.alloc(&mut p);
        fill_page(&mut p, shared, 1.0);
        // Two more owners adopt the page (prefix index entry + a second
        // sequence's page table) — exactly what cross-request prefix
        // sharing does.
        p.retain(shared);
        p.retain(shared);
        s.sync(&p);
        assert_eq!(p.refcount(shared), 3);
        assert_eq!(
            s.bytes_in_use(&p),
            p.page_bytes(),
            "a 3-sharer page is charged once, not per owner"
        );
        // A private page alongside still fits the two-page budget: the
        // shared page does not phantom-fill the budget per sharer.
        let private = s.alloc(&mut p);
        fill_page(&mut p, private, 2.0);
        s.enforce_budget(&mut p);
        assert!(s.bytes_in_use(&p) <= budget);
        assert_eq!(s.bytes_in_use(&p), 2 * p.page_bytes());
        assert_eq!(s.stats.demotions, 0, "nothing over budget, nothing demoted");
        p.release(private);
        for _ in 0..3 {
            p.release(shared);
        }
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
    }

    #[test]
    fn sync_feeds_sharers_so_query_aware_demotes_private_first() {
        let mut p = pool();
        // Room for one hot page plus one cold page: exactly one demotion.
        let budget = p.page_bytes() + p.page_bytes_cold();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::QueryAwareCold);
        let shared = s.alloc(&mut p);
        fill_page(&mut p, shared, 1.0);
        let private = s.alloc(&mut p);
        fill_page(&mut p, private, 2.0);
        // The shared page looks *colder* by score, but carries two extra
        // sharers; the sharer signal must dominate the bbox score.
        s.note_score(shared, 0.01);
        s.note_score(private, 0.99);
        p.retain(shared);
        p.retain(shared);
        s.sync(&p);
        s.enforce_budget(&mut p);
        assert!(s.is_cold(private), "private page demotes first");
        assert!(!s.is_cold(shared), "3-sharer page stays hot despite cold score");
        p.release(private);
        for _ in 0..3 {
            p.release(shared);
        }
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
    }

    #[test]
    fn trace_buffer_records_tier_transitions_and_drains() {
        let mut p = pool();
        let budget = 2 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        assert!(!s.trace_enabled());
        s.set_trace(true);
        let mut live = Vec::new();
        for i in 0..4 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        let evs = s.take_trace();
        let demotes =
            evs.iter().filter(|e| matches!(e, StoreTraceEvent::Demote { .. })).count();
        assert_eq!(demotes as u64, s.stats.demotions, "one event per demotion");
        assert!(s.take_trace().is_empty(), "drain empties the buffer");
        // promotion back is a policy access, not a tier-transition event;
        // faults (disk tier) are covered by the spill battery
        let cold = *live.iter().find(|&&id| s.is_cold(id)).unwrap();
        s.ensure_hot(&mut p, cold).unwrap();
        let evs = s.take_trace();
        assert!(
            evs.iter().all(|e| matches!(e, StoreTraceEvent::Demote { .. })),
            "promotion may displace (demote) but emits no fault: {evs:?}"
        );
        s.set_trace(false);
        s.enforce_budget(&mut p);
        assert!(s.take_trace().is_empty(), "tracing off buffers nothing");
        for id in live {
            p.release(id);
        }
    }

    #[test]
    fn spill_and_fault_emit_trace_events() {
        let mut p = pool();
        let budget = p.page_bytes();
        let mut s = spill_store(budget, "trace-events");
        s.set_trace(true);
        let mut live = Vec::new();
        for i in 0..4 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        let evs = s.take_trace();
        let spills = evs
            .iter()
            .filter(|e| matches!(e, StoreTraceEvent::SpillOut { .. }))
            .count();
        assert_eq!(spills as u64, s.stats.spill_outs);
        let spilled = *live.iter().find(|&&id| s.is_on_disk(id)).unwrap();
        s.ensure_hot(&mut p, spilled).unwrap();
        let evs = s.take_trace();
        assert!(
            evs.iter().any(|e| matches!(
                e,
                StoreTraceEvent::Fault { page, .. } if *page == spilled
            )),
            "fault event names the faulted page: {evs:?}"
        );
        for id in live {
            p.release(id);
        }
    }

    #[test]
    fn pinned_pages_survive_enforcement() {
        let mut p = pool();
        let budget = 2 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        s.pin(a);
        let mut others = Vec::new();
        for i in 0..4 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            others.push(id);
        }
        assert!(s.is_hot(a), "pinned page was demoted");
        s.unpin_all();
        s.enforce_budget(&mut p);
        assert!(s.bytes_in_use(&p) <= budget);
        p.release(a);
        for id in others {
            p.release(id);
        }
    }

    #[test]
    fn unpin_single_page_allows_demotion() {
        let mut p = pool();
        let budget = p.page_bytes(); // room for one hot page only
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        s.pin(a); // pin before the next alloc can demote it
        let b = s.alloc(&mut p);
        fill_page(&mut p, b, 2.0);
        s.pin(b);
        s.enforce_budget(&mut p);
        assert!(s.is_hot(a) && s.is_hot(b), "both pinned, neither demotes");
        // mid-flight release path: one page unpinned, the other stays safe
        s.unpin(a);
        s.enforce_budget(&mut p);
        assert!(s.is_cold(a), "unpinned page became demotable");
        assert!(s.is_hot(b), "still-pinned page survived");
        s.unpin(b);
        p.release(a);
        p.release(b);
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
    }

    #[test]
    fn promotion_counts_miss_and_restores_hot() {
        let mut p = pool();
        let budget = 2 * p.page_bytes();
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Lru);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        for i in 0..3 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
        }
        s.enforce_budget(&mut p);
        assert!(s.is_cold(a), "LRU must have demoted the oldest page");
        s.ensure_hot(&mut p, a).unwrap();
        assert!(s.is_hot(a));
        assert_eq!(s.stats.misses, 1);
        assert_eq!(s.stats.promotions, 1);
        assert!(s.stats.spill_seconds > 0.0);
        s.ensure_hot(&mut p, a).unwrap();
        assert_eq!(s.stats.hits, 1);
    }

    #[test]
    fn partial_pages_are_never_demoted() {
        let mut p = pool();
        let budget = p.page_bytes(); // room for one page only
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::Clock);
        let a = s.alloc(&mut p);
        // only one token written: page stays partial
        p.write_token(a, 0, 0, &[1.0; 8], &[1.0; 8]);
        p.write_token(a, 0, 1, &[1.0; 8], &[1.0; 8]);
        let b = s.alloc(&mut p);
        fill_page(&mut p, b, 2.0);
        s.enforce_budget(&mut p);
        assert!(s.is_hot(a), "partial page demoted");
        assert!(s.is_cold(b) || s.bytes_in_use(&p) <= budget);
    }

    #[test]
    fn sync_adopts_and_releases_foreign_pages() {
        let mut p = pool();
        let mut s = PageStore::new(Some(10 * p.page_bytes()), EvictionPolicyKind::Lru);
        let a = p.alloc(); // behind the store's back
        s.sync(&p);
        assert!(s.is_hot(a));
        p.release(a);
        s.sync(&p);
        assert!(!s.is_hot(a) && !s.is_cold(a));
        assert_eq!(s.tier_counts(), (0, 0));
    }

    #[test]
    fn overflow_recorded_when_nothing_evictable() {
        let mut p = pool();
        let budget = p.page_bytes() / 2; // below even one page
        let mut s = PageStore::new(Some(budget), EvictionPolicyKind::QueryAware);
        let a = s.alloc(&mut p);
        fill_page(&mut p, a, 1.0);
        s.pin(a);
        s.enforce_budget(&mut p);
        assert!(s.stats.overflows > 0);
        assert!(s.is_hot(a));
        s.unpin_all();
        p.release(a);
    }

    #[test]
    fn coldest_index_defaults_to_first_non_sink() {
        let mut p = pool();
        let s = PageStore::new(None, EvictionPolicyKind::Lru);
        let mut seq = SeqCache::new();
        for i in 0..12 {
            let (page, slot) = seq.slot_for_next(&mut p);
            for l in 0..2 {
                p.write_token(page, slot, l, &[i as f32; 8], &[i as f32; 8]);
            }
            seq.commit_token();
        }
        // untracked pages all rank equal -> first non-sink index
        assert_eq!(s.coldest_index(&seq, 1), Some(1));
        assert_eq!(s.coldest_index(&seq, 5), None, "nothing prunable");
        seq.clear(&mut p);
    }

    fn spill_store(budget: usize, tag: &str) -> PageStore {
        PageStore::with_spill(
            Some(budget),
            EvictionPolicyKind::Lru,
            SpillConfig::new(default_spill_root().join(tag), 1 << 20),
        )
        .expect("spill store")
    }

    #[test]
    fn budget_cascade_demotes_then_spills_to_disk() {
        let mut p = pool();
        // budget holds exactly one hot page; cold pages overflow it too,
        // so the cascade must push them onto the disk tier
        let budget = p.page_bytes();
        let mut s = spill_store(budget, "cascade");
        let mut live = Vec::new();
        for i in 0..4 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        assert!(s.bytes_in_use(&p) <= budget, "cascade reached the budget");
        let (hot, cold, disk) = s.tier_residency();
        assert_eq!(hot + cold + disk, 4);
        assert!(disk > 0, "q8 alone cannot fit: pages must hit the disk tier");
        assert!(s.stats.spill_outs as usize == disk);
        assert!(s.stats.spill_out_bytes > 0);
        assert!(s.stats.disk_seconds > 0.0, "disk traffic is hwmodel-priced");
        assert_eq!(s.spill_bytes(), disk * (8 + 4) * 2 * 4 * 2 + disk * 2 * 2 * 8 * 4);
        // a spilled page's pool rows are physically zeroed
        let spilled = *live.iter().find(|&&id| s.is_on_disk(id)).unwrap();
        assert!(p.key_row(spilled, 0, 0).iter().all(|&x| x == 0.0));
        // fault it back: contents must match a pure q8 demotion round-trip
        s.ensure_hot(&mut p, spilled).unwrap();
        assert!(s.is_hot(spilled));
        assert_eq!(s.stats.faults, 1);
        assert!(s.stats.spill_in_bytes > 0);
        assert!(!p.key_row(spilled, 0, 0).iter().all(|&x| x == 0.0));
        for id in live {
            p.release(id);
        }
        s.sync(&p);
        assert_eq!(s.bytes_in_use(&p), 0);
        assert_eq!(s.spill_bytes(), 0, "released pages leave the disk tier");
    }

    #[test]
    fn spilled_pages_survive_release_and_realloc() {
        let mut p = pool();
        let budget = p.page_bytes();
        let mut s = spill_store(budget, "realloc");
        let mut live = Vec::new();
        for i in 0..3 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        let disk_before = s.tier_residency().2;
        assert!(disk_before > 0);
        // release a disk-resident page: its slot must recycle and the next
        // alloc of the same PageId must start clean (hot, zero fill)
        let victim = *live.iter().find(|&&id| s.is_on_disk(id)).unwrap();
        p.release(victim);
        s.sync(&p);
        assert_eq!(s.tier_residency().2, disk_before - 1);
        let fresh = s.alloc(&mut p);
        assert!(s.is_hot(fresh));
        for &id in live.iter().filter(|&&id| id != victim) {
            p.release(id);
        }
        p.release(fresh);
        s.sync(&p);
        assert_eq!(s.spill_bytes(), 0);
    }

    #[test]
    fn corrupted_segment_bubbles_typed_error_through_ensure_hot() {
        use std::io::{Seek, SeekFrom, Write};
        let mut p = pool();
        let budget = p.page_bytes();
        let dir = default_spill_root().join("store-corrupt");
        let mut s = PageStore::with_spill(
            Some(budget),
            EvictionPolicyKind::Lru,
            SpillConfig::new(dir.clone(), 1 << 20),
        )
        .unwrap();
        let mut live = Vec::new();
        for i in 0..3 {
            let id = s.alloc(&mut p);
            fill_page(&mut p, id, i as f32);
            live.push(id);
        }
        s.enforce_budget(&mut p);
        s.flush_spill().unwrap();
        let spilled = *live.iter().find(|&&id| s.is_on_disk(id)).unwrap();
        // corrupt the segment behind the store's back
        let seg = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| p.extension().map(|x| x == "kvseg").unwrap_or(false))
            .expect("segment file exists");
        let mut f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
        f.seek(SeekFrom::Start(20)).unwrap();
        f.write_all(&[0xEE, 0xEE, 0xEE]).unwrap();
        drop(f);
        let err = s.ensure_hot(&mut p, spilled).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("magic"),
            "typed corruption error, got: {err}"
        );
        for id in live {
            p.release(id);
        }
    }
}
