//! Paged KV pool: the paper's "structured memory layout via token grouping
//! into fixed-size pages" (§3.5), plus the per-page bounding-box metadata
//! that makes query-aware selection possible.
//!
//! One `PageId` covers all layers (vLLM-style): layer `l`'s keys/values for
//! a page live at the same page index in layer `l`'s slab. Pages are
//! refcounted so sessions can share immutable prefix pages (§4.4.2 session
//! management); only the *last, partially-filled* page of a sequence is
//! ever written, and sharing snapshots deep-copy it first.

use anyhow::Result;

use super::dtype::Slab;
use crate::config::KvDtype;

pub type PageId = u32;

const GROW_PAGES: usize = 256;

/// Global paged KV store for one model.
pub struct PagePool {
    pub page_size: usize, // S tokens per page
    pub d_kv: usize,      // channels per token (H * head_dim)
    pub n_layers: usize,
    dtype: KvDtype,
    /// per layer: K and V slabs, rows = cap_pages * page_size
    k: Vec<Slab>,
    v: Vec<Slab>,
    /// per layer, per page: [min(d_kv), max(d_kv)] f32 bounding boxes
    meta: Vec<Vec<f32>>,
    refcount: Vec<u32>,
    /// tokens filled in each page (frozen once == page_size)
    filled: Vec<u16>,
    free: Vec<PageId>,
    cap_pages: usize,
    /// high-water mark for stats
    pub peak_pages: usize,
    /// dtype-aware byte high-water mark (peak_pages hides dtype differences)
    bytes_peak: usize,
}

impl PagePool {
    pub fn new(n_layers: usize, d_kv: usize, page_size: usize, dtype: KvDtype) -> Self {
        PagePool {
            page_size,
            d_kv,
            n_layers,
            dtype,
            k: (0..n_layers).map(|_| Slab::new(dtype, 0, d_kv)).collect(),
            v: (0..n_layers).map(|_| Slab::new(dtype, 0, d_kv)).collect(),
            meta: vec![Vec::new(); n_layers],
            refcount: Vec::new(),
            filled: Vec::new(),
            free: Vec::new(),
            cap_pages: 0,
            peak_pages: 0,
            bytes_peak: 0,
        }
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    fn grow(&mut self) {
        let new_cap = self.cap_pages + GROW_PAGES;
        let rows = new_cap * self.page_size;
        for l in 0..self.n_layers {
            self.k[l].grow(rows, self.d_kv);
            self.v[l].grow(rows, self.d_kv);
            self.meta[l].resize(new_cap * 2 * self.d_kv, 0.0);
        }
        self.refcount.resize(new_cap, 0);
        self.filled.resize(new_cap, 0);
        for id in (self.cap_pages..new_cap).rev() {
            self.free.push(id as PageId);
        }
        self.cap_pages = new_cap;
    }

    pub fn alloc(&mut self) -> PageId {
        if self.free.is_empty() {
            self.grow();
        }
        let id = self.free.pop().expect("grow added pages");
        self.refcount[id as usize] = 1;
        self.filled[id as usize] = 0;
        self.peak_pages = self.peak_pages.max(self.pages_in_use());
        self.bytes_peak = self.bytes_peak.max(self.bytes_in_use());
        id
    }

    pub fn retain(&mut self, id: PageId) {
        self.refcount[id as usize] += 1;
    }

    pub fn release(&mut self, id: PageId) {
        let rc = &mut self.refcount[id as usize];
        assert!(*rc > 0, "double free of page {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
        }
    }

    pub fn refcount(&self, id: PageId) -> u32 {
        self.refcount[id as usize]
    }

    pub fn filled(&self, id: PageId) -> usize {
        self.filled[id as usize] as usize
    }

    pub fn pages_in_use(&self) -> usize {
        self.cap_pages - self.free.len()
    }

    pub fn cap_pages(&self) -> usize {
        self.cap_pages
    }

    /// Bytes of KV storage currently in use (both K and V, all layers).
    pub fn bytes_in_use(&self) -> usize {
        self.pages_in_use() * self.page_bytes()
    }

    /// Byte high-water mark across the pool's lifetime, at the configured
    /// KV dtype (the "unbounded footprint" the budgeted store is measured
    /// against).
    pub fn bytes_peak(&self) -> usize {
        self.bytes_peak
    }

    /// Bytes one page occupies at the pool dtype (K + V, all layers).
    pub fn page_bytes(&self) -> usize {
        self.k[0].bytes_per_row(self.d_kv) * 2 * self.page_size * self.n_layers
    }

    /// Bytes one page occupies after demotion to the q8 cold tier
    /// (per-row int8 data + one f32 scale, K + V, all layers).
    pub fn page_bytes_cold(&self) -> usize {
        (self.d_kv + 4) * 2 * self.page_size * self.n_layers
    }

    /// Append one token's K/V for one layer into `page` at `slot`.
    /// The caller (SeqCache) guarantees slot ordering; the fill counter
    /// advances when the *last* layer is written.
    pub fn write_token(
        &mut self,
        page: PageId,
        slot: usize,
        layer: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(self.refcount[page as usize], 1, "write to shared page");
        let row = page as usize * self.page_size + slot;
        self.k[layer].store_row(row, self.d_kv, k_row);
        self.v[layer].store_row(row, self.d_kv, v_row);
        // bounding-box metadata update (f32, from the unquantized key)
        let m = &mut self.meta[layer]
            [page as usize * 2 * self.d_kv..(page as usize + 1) * 2 * self.d_kv];
        let (mins, maxs) = m.split_at_mut(self.d_kv);
        if slot == 0 {
            mins.copy_from_slice(k_row);
            maxs.copy_from_slice(k_row);
        } else {
            for i in 0..self.d_kv {
                mins[i] = mins[i].min(k_row[i]);
                maxs[i] = maxs[i].max(k_row[i]);
            }
        }
        if layer == self.n_layers - 1 {
            self.filled[page as usize] = (slot + 1) as u16;
        }
    }

    /// Page metadata: `[min(d_kv) ++ max(d_kv)]` for (page, layer).
    pub fn meta(&self, page: PageId, layer: usize) -> &[f32] {
        &self.meta[layer]
            [page as usize * 2 * self.d_kv..(page as usize + 1) * 2 * self.d_kv]
    }

    /// Gather `n_slots` token rows of K and V into f32 staging buffers
    /// (the Algorithm-1 step-3 "sparse KV gather"). Returns bytes touched
    /// in storage (the measurable HBM-fetch analogue).
    pub fn gather_rows(
        &self,
        page: PageId,
        layer: usize,
        n_slots: usize,
        k_dst: &mut [f32],
        v_dst: &mut [f32],
    ) -> usize {
        let row = page as usize * self.page_size;
        self.k[layer].load_rows(row, n_slots, self.d_kv, k_dst);
        self.v[layer].load_rows(row, n_slots, self.d_kv, v_dst);
        2 * n_slots * self.k[layer].bytes_per_row(self.d_kv)
    }

    /// Dequantized single key row (oracle policy & tests).
    pub fn key_row(&self, page: PageId, layer: usize, slot: usize) -> Vec<f32> {
        self.k[layer].load_row_vec(page as usize * self.page_size + slot, self.d_kv)
    }

    /// Deep-copy a page's contents (all layers) into a fresh page.
    /// Used for copy-on-write of partially-filled pages at snapshot time.
    pub fn clone_page(&mut self, src: PageId) -> PageId {
        let dst = self.alloc();
        let n = self.filled[src as usize] as usize;
        let mut kbuf = vec![0.0f32; self.page_size * self.d_kv];
        let mut vbuf = vec![0.0f32; self.page_size * self.d_kv];
        for l in 0..self.n_layers {
            let row = src as usize * self.page_size;
            self.k[l].load_rows(row, n.max(1), self.d_kv, &mut kbuf);
            self.v[l].load_rows(row, n.max(1), self.d_kv, &mut vbuf);
            for s in 0..n {
                // store_row re-quantizes; acceptable (same precision class)
                let kr = kbuf[s * self.d_kv..(s + 1) * self.d_kv].to_vec();
                let vr = vbuf[s * self.d_kv..(s + 1) * self.d_kv].to_vec();
                let drow = dst as usize * self.page_size + s;
                self.k[l].store_row(drow, self.d_kv, &kr);
                self.v[l].store_row(drow, self.d_kv, &vr);
            }
            // copy metadata verbatim
            let src_off = src as usize * 2 * self.d_kv;
            let dst_off = dst as usize * 2 * self.d_kv;
            let (a, b) = if src_off < dst_off {
                let (lo, hi) = self.meta[l].split_at_mut(dst_off);
                (&lo[src_off..src_off + 2 * self.d_kv], &mut hi[..2 * self.d_kv])
            } else {
                let (lo, hi) = self.meta[l].split_at_mut(src_off);
                (&hi[..2 * self.d_kv], &mut lo[dst_off..dst_off + 2 * self.d_kv])
            };
            b.copy_from_slice(a);
        }
        self.filled[dst as usize] = self.filled[src as usize];
        dst
    }

    /// Exact (non-estimated) max q.k over a page — the Oracle policy's
    /// scoring function, and the quantity Eq. 2 upper-bounds.
    pub fn exact_page_score(&self, page: PageId, layer: usize, q: &[f32]) -> f32 {
        let n = self.filled[page as usize] as usize;
        let mut best = f32::NEG_INFINITY;
        let mut buf = vec![0.0f32; self.d_kv];
        for s in 0..n {
            self.k[layer].load_rows(
                page as usize * self.page_size + s,
                1,
                self.d_kv,
                &mut buf,
            );
            let dot: f32 = q.iter().zip(&buf).map(|(a, b)| a * b).sum();
            best = best.max(dot);
        }
        best
    }

    /// Cold-tier demotion: round-trip every filled K/V row of `page`
    /// through the per-token int8 quantizer (`kvcache::dtype` machinery)
    /// and store the result back at the pool dtype, then rebuild the
    /// page's bounding boxes from the quantized keys so Eq.-2 scores stay
    /// consistent with what a gather will actually read. The data loss is
    /// the q8 round-trip; the budgeted store charges the page at
    /// `page_bytes_cold` afterwards. Returns bytes rewritten (the
    /// spill-traffic analogue).
    ///
    /// Int8 pools are already at the q8 rate: re-quantizing their rows is
    /// the identity and the byte accounting gains nothing
    /// (`page_bytes_cold == page_bytes`), so demotion is a free no-op —
    /// values *and* bounding boxes stay bit-identical, which is what lets
    /// a budgeted int8 run decode token-identically to an unbounded one.
    pub fn demote_page_in_place(&mut self, page: PageId) -> usize {
        let n = self.filled[page as usize] as usize;
        let d = self.d_kv;
        if n == 0 || self.dtype == KvDtype::Int8 {
            return 0;
        }
        let mut scratch = Slab::new(crate::config::KvDtype::Int8, 1, d);
        let mut buf = vec![0.0f32; d];
        let mut q = vec![0.0f32; d];
        let mut bytes = 0usize;
        for l in 0..self.n_layers {
            for s in 0..n {
                let row = page as usize * self.page_size + s;
                // keys
                self.k[l].load_rows(row, 1, d, &mut buf);
                scratch.store_row(0, d, &buf);
                scratch.load_rows(0, 1, d, &mut q);
                self.k[l].store_row(row, d, &q);
                bytes += self.k[l].bytes_per_row(d) + d + 4;
                // bounding boxes follow the quantized keys
                {
                    let m = &mut self.meta[l]
                        [page as usize * 2 * d..(page as usize + 1) * 2 * d];
                    let (mins, maxs) = m.split_at_mut(d);
                    if s == 0 {
                        mins.copy_from_slice(&q);
                        maxs.copy_from_slice(&q);
                    } else {
                        for i in 0..d {
                            mins[i] = mins[i].min(q[i]);
                            maxs[i] = maxs[i].max(q[i]);
                        }
                    }
                }
                // values
                self.v[l].load_rows(row, 1, d, &mut buf);
                scratch.store_row(0, d, &buf);
                scratch.load_rows(0, 1, d, &mut q);
                self.v[l].store_row(row, d, &q);
                bytes += self.v[l].bytes_per_row(d) + d + 4;
            }
        }
        bytes
    }

    /// Disk-spill support: physically free a page's K/V rows (zero them at
    /// the pool dtype) while its id stays allocated. Bounding-box metadata
    /// is deliberately left resident — it is the scoring input and must
    /// keep working while the payload lives on disk. A gather that skips
    /// the fault path reads zeros, so a missed fault is loud, not subtly
    /// stale.
    pub fn purge_rows(&mut self, page: PageId) {
        let zeros = vec![0.0f32; self.d_kv];
        for l in 0..self.n_layers {
            for s in 0..self.page_size {
                let row = page as usize * self.page_size + s;
                self.k[l].store_row(row, self.d_kv, &zeros);
                self.v[l].store_row(row, self.d_kv, &zeros);
            }
        }
    }

    /// Disk-spill support: restore `n_rows` K/V rows of one layer from
    /// dequantized f32 data (stored back at the pool dtype). Unlike
    /// `write_token` this neither advances fill counters nor touches
    /// metadata or refcounts — the page is already fully accounted; only
    /// its payload was away.
    pub fn import_rows(
        &mut self,
        page: PageId,
        layer: usize,
        n_rows: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        debug_assert!(n_rows <= self.page_size);
        let d = self.d_kv;
        for s in 0..n_rows {
            let row = page as usize * self.page_size + s;
            self.k[layer].store_row(row, d, &k_rows[s * d..(s + 1) * d]);
            self.v[layer].store_row(row, d, &v_rows[s * d..(s + 1) * d]);
        }
    }

    /// Disk-spill support, int8 pools: raw (K, V) quantized rows for one
    /// slot — `((k_data, k_scale), (v_data, v_scale))`. `None` for f32 or
    /// f16 pools. The spill codec copies these bytes verbatim so an int8
    /// page round-trips the disk tier bit-exactly (re-quantization could
    /// drift the per-row scale by an ulp).
    #[allow(clippy::type_complexity)]
    pub fn q8_rows_raw(
        &self,
        page: PageId,
        layer: usize,
        slot: usize,
    ) -> Option<((&[i8], f32), (&[i8], f32))> {
        let row = page as usize * self.page_size + slot;
        let k = self.k[layer].q8_row(row, self.d_kv)?;
        let v = self.v[layer].q8_row(row, self.d_kv)?;
        Some((k, v))
    }

    /// Disk-spill support, int8 pools: restore one slot's raw quantized
    /// (K, V) rows. Returns false (and stores nothing) for other dtypes.
    pub fn import_q8_row(
        &mut self,
        page: PageId,
        layer: usize,
        slot: usize,
        k: (&[i8], f32),
        v: (&[i8], f32),
    ) -> bool {
        let row = page as usize * self.page_size + slot;
        self.k[layer].store_q8_row(row, self.d_kv, k.0, k.1)
            && self.v[layer].store_q8_row(row, self.d_kv, v.0, v.1)
    }

    /// Cross-pool porting support: stamp a page's fill counter directly.
    /// `import_rows`/`import_q8_row` deliberately leave fill counters
    /// untouched (spill faults restore payloads of already-accounted
    /// pages); the migration codec builds pages in a *different* pool,
    /// so it owns the accounting and stamps the fill once per page.
    pub fn set_filled(&mut self, page: PageId, n: usize) {
        debug_assert!(n <= self.page_size);
        self.filled[page as usize] = n as u16;
    }

    /// Disk-spill support: reinstate a page's `[min ++ max]` bounding box
    /// for one layer (the durable copy a spill slot carries).
    pub fn set_meta(&mut self, page: PageId, layer: usize, meta: &[f32]) {
        debug_assert_eq!(meta.len(), 2 * self.d_kv);
        self.meta[layer][page as usize * 2 * self.d_kv..(page as usize + 1) * 2 * self.d_kv]
            .copy_from_slice(meta);
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.free.len() <= self.cap_pages);
        let mut seen = vec![false; self.cap_pages];
        for &f in &self.free {
            anyhow::ensure!(!seen[f as usize], "page {f} twice in free list");
            seen[f as usize] = true;
            anyhow::ensure!(self.refcount[f as usize] == 0, "free page {f} has refs");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> PagePool {
        PagePool::new(2, 8, 4, KvDtype::F32)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = pool();
        let a = p.alloc();
        let b = p.alloc();
        assert_ne!(a, b);
        assert_eq!(p.pages_in_use(), 2);
        p.release(a);
        assert_eq!(p.pages_in_use(), 1);
        let c = p.alloc();
        assert_eq!(c, a, "freed page is reused");
        p.release(b);
        p.release(c);
        assert_eq!(p.pages_in_use(), 0);
        p.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = pool();
        let a = p.alloc();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn metadata_tracks_min_max() {
        let mut p = pool();
        let pg = p.alloc();
        let k1 = [1.0, -2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 5.0];
        let k2 = [0.0, -1.0, 4.0, -3.0, 0.0, 0.0, 0.0, 2.0];
        for l in 0..2 {
            p.write_token(pg, 0, l, &k1, &[0.0; 8]);
        }
        for l in 0..2 {
            p.write_token(pg, 1, l, &k2, &[0.0; 8]);
        }
        let m = p.meta(pg, 0);
        assert_eq!(m[0], 0.0); // min ch0
        assert_eq!(m[1], -2.0); // min ch1
        assert_eq!(m[3], -3.0); // min ch3
        assert_eq!(m[8], 1.0); // max ch0
        assert_eq!(m[10], 4.0); // max ch2
        assert_eq!(p.filled(pg), 2);
    }

    #[test]
    fn gather_roundtrip() {
        let mut p = pool();
        let pg = p.alloc();
        for s in 0..4 {
            let row: Vec<f32> = (0..8).map(|i| (s * 8 + i) as f32).collect();
            for l in 0..2 {
                p.write_token(pg, s, l, &row, &row);
            }
        }
        let mut k = vec![0.0; 4 * 8];
        let mut v = vec![0.0; 4 * 8];
        let bytes = p.gather_rows(pg, 1, 4, &mut k, &mut v);
        assert_eq!(bytes, 2 * 4 * 8 * 4);
        assert_eq!(k[0], 0.0);
        assert_eq!(k[31], 31.0);
        assert_eq!(v, k);
    }

    #[test]
    fn clone_page_copies_contents() {
        let mut p = pool();
        let a = p.alloc();
        let row = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        for l in 0..2 {
            p.write_token(a, 0, l, &row, &row);
        }
        let b = p.clone_page(a);
        assert_ne!(a, b);
        assert_eq!(p.key_row(b, 0, 0), row.to_vec());
        assert_eq!(p.meta(a, 1), p.meta(b, 1));
        assert_eq!(p.filled(b), 1);
    }

    #[test]
    fn exact_score_is_max_dot() {
        let mut p = pool();
        let pg = p.alloc();
        let k1 = [1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let k2 = [0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        for l in 0..2 {
            p.write_token(pg, 0, l, &k1, &[0.0; 8]);
            p.write_token(pg, 1, l, &k2, &[0.0; 8]);
        }
        let q = [1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(p.exact_page_score(pg, 0, &q), 2.0);
    }

    #[test]
    fn bytes_accounting_by_dtype() {
        for (dt, per_val) in [
            (KvDtype::F32, 4.0),
            (KvDtype::F16, 2.0),
        ] {
            let mut p = PagePool::new(1, 8, 4, dt);
            let _ = p.alloc();
            let expect = (4.0 * 8.0 * per_val * 2.0) as usize; // S*d*K&V
            assert_eq!(p.bytes_in_use(), expect, "{dt:?}");
            assert_eq!(p.page_bytes(), expect);
            assert_eq!(p.bytes_peak(), expect);
        }
    }

    #[test]
    fn bytes_peak_tracks_high_water() {
        let mut p = pool();
        let a = p.alloc();
        let b = p.alloc();
        let peak = p.bytes_in_use();
        p.release(a);
        p.release(b);
        assert_eq!(p.bytes_in_use(), 0);
        assert_eq!(p.bytes_peak(), peak);
        let _ = p.alloc();
        assert_eq!(p.bytes_peak(), peak, "reuse below peak leaves it");
    }

    #[test]
    fn cold_page_bytes_are_smaller() {
        let p = pool(); // f32
        assert!(p.page_bytes_cold() < p.page_bytes());
        // int8 pools gain nothing from demotion
        let p8 = PagePool::new(2, 8, 4, KvDtype::Int8);
        assert_eq!(p8.page_bytes_cold(), p8.page_bytes());
    }

    #[test]
    fn demote_roundtrips_within_q8_tolerance() {
        let mut p = pool();
        let pg = p.alloc();
        let mut rng = crate::util::rng::Rng::new(17);
        let mut rows = Vec::new();
        for s in 0..4 {
            let row: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            for l in 0..2 {
                p.write_token(pg, s, l, &row, &row);
            }
            rows.push(row);
        }
        let bytes = p.demote_page_in_place(pg);
        assert!(bytes > 0);
        for (s, row) in rows.iter().enumerate() {
            let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let got = p.key_row(pg, 1, s);
            for (a, b) in row.iter().zip(&got) {
                assert!(
                    (a - b).abs() <= amax / 100.0,
                    "slot {s}: {a} vs {b} (amax {amax})"
                );
            }
        }
        // bounding boxes still bound the (quantized) keys
        let m = p.meta(pg, 0).to_vec();
        for s in 0..4 {
            let k = p.key_row(pg, 0, s);
            for i in 0..8 {
                assert!(m[i] - 1e-6 <= k[i] && k[i] <= m[8 + i] + 1e-6);
            }
        }
    }

    #[test]
    fn demote_empty_page_is_noop() {
        let mut p = pool();
        let pg = p.alloc();
        assert_eq!(p.demote_page_in_place(pg), 0);
    }

    #[test]
    fn demote_int8_pool_is_identity() {
        let mut p = PagePool::new(1, 8, 4, KvDtype::Int8);
        let pg = p.alloc();
        let row = [0.3, -1.2, 0.9, 2.0, -0.5, 0.0, 1.1, -2.2];
        for s in 0..4 {
            p.write_token(pg, s, 0, &row, &row);
        }
        let before: Vec<Vec<f32>> = (0..4).map(|s| p.key_row(pg, 0, s)).collect();
        let meta_before = p.meta(pg, 0).to_vec();
        assert_eq!(p.demote_page_in_place(pg), 0, "int8 demotion moves nothing");
        let after: Vec<Vec<f32>> = (0..4).map(|s| p.key_row(pg, 0, s)).collect();
        assert_eq!(before, after);
        assert_eq!(meta_before, p.meta(pg, 0).to_vec());
    }

    #[test]
    fn purge_then_import_restores_rows_and_meta() {
        let mut p = pool();
        let pg = p.alloc();
        for s in 0..4 {
            let row: Vec<f32> = (0..8).map(|i| (s * 8 + i) as f32 * 0.25).collect();
            for l in 0..2 {
                p.write_token(pg, s, l, &row, &row);
            }
        }
        let rows: Vec<Vec<f32>> = (0..4).map(|s| p.key_row(pg, 1, s)).collect();
        let meta = p.meta(pg, 1).to_vec();
        p.purge_rows(pg);
        assert!(p.key_row(pg, 1, 2).iter().all(|&x| x == 0.0), "rows freed");
        assert_eq!(p.meta(pg, 1).to_vec(), meta, "bboxes stay resident");
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        for l in 0..2 {
            p.import_rows(pg, l, 4, &flat, &flat);
            p.set_meta(pg, l, &meta);
        }
        for (s, row) in rows.iter().enumerate() {
            assert_eq!(&p.key_row(pg, 1, s), row, "import restores slot {s}");
        }
        assert_eq!(p.meta(pg, 1).to_vec(), meta);
        assert_eq!(p.filled(pg), 4, "fill counter untouched by purge/import");
    }
}
