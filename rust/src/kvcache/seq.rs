//! Per-sequence page table over the shared `PagePool`, plus snapshots for
//! cross-request session reuse (paper §4.4.2).

use super::pool::{PageId, PagePool};

/// One entry in a sequence's page table. `base_pos` is the absolute token
//  position of the page's first slot — kept explicitly because eviction
//  (StreamingLLM & friends) can drop interior pages while ALiBi distances
//  must stay anchored to true positions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PageEntry {
    pub id: PageId,
    pub base_pos: usize,
}

/// A sequence's view of the cache.
#[derive(Debug, Default, Clone)]
pub struct SeqCache {
    pub pages: Vec<PageEntry>,
    /// total tokens ever appended (absolute next position)
    pub pos: usize,
    /// tokens currently resident (pos minus evicted)
    pub resident: usize,
}

impl SeqCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Tokens in a given table entry (full page unless it's the last one).
    pub fn entry_len(&self, idx: usize, pool: &PagePool) -> usize {
        pool.filled(self.pages[idx].id)
    }

    fn needs_new_page(&self, pool: &PagePool) -> bool {
        match self.pages.last() {
            None => true,
            Some(e) => self.pos - e.base_pos >= pool.page_size,
        }
    }

    /// Copy-on-write primitive for a partially-filled page: deep-copy the
    /// entry's page (rows *and* bounding-box metadata, carried verbatim by
    /// `PagePool::clone_page`) into a private page at the same `base_pos`.
    /// The single copy path shared by `snapshot`, `restore_prefix` and the
    /// COW-append guard below — bbox handling cannot drift between them.
    fn clone_partial_page(e: PageEntry, pool: &mut PagePool) -> PageEntry {
        PageEntry { id: pool.clone_page(e.id), base_pos: e.base_pos }
    }

    /// Append-side COW guard: if the page about to be written is shared
    /// (prefix-cache adoption or a restored snapshot left a refcount > 1
    /// partial page in the table), privatize it first so `write_token`'s
    /// exclusive-writer invariant holds for every sharer.
    fn cow_last_page(&mut self, pool: &mut PagePool) {
        if let Some(&e) = self.pages.last() {
            if pool.refcount(e.id) > 1 {
                let ne = Self::clone_partial_page(e, pool);
                pool.release(e.id);
                *self.pages.last_mut().unwrap() = ne;
            }
        }
    }

    /// Begin writing token at `self.pos`: returns (page, slot), allocating
    /// a fresh page when the previous one is full (or was evicted) and
    /// privatizing a shared partial page (copy-on-write) before handing
    /// out a writable slot in it.
    pub fn slot_for_next(&mut self, pool: &mut PagePool) -> (PageId, usize) {
        if self.needs_new_page(pool) {
            let id = pool.alloc();
            self.pages.push(PageEntry { id, base_pos: self.pos });
        } else {
            self.cow_last_page(pool);
        }
        let e = *self.pages.last().unwrap();
        (e.id, self.pos - e.base_pos)
    }

    /// `slot_for_next`, but allocating through the budgeted `PageStore`
    /// (over-budget allocations demote cold pages instead of growing the
    /// pool's footprint). The decode hot path uses this variant.
    pub fn slot_for_next_budgeted(
        &mut self,
        pool: &mut PagePool,
        store: &mut super::store::PageStore,
    ) -> (PageId, usize) {
        if self.needs_new_page(pool) {
            let id = store.alloc(pool);
            self.pages.push(PageEntry { id, base_pos: self.pos });
        } else {
            self.cow_last_page(pool);
        }
        let e = *self.pages.last().unwrap();
        (e.id, self.pos - e.base_pos)
    }

    /// Called once per token after all layers are written.
    pub fn commit_token(&mut self) {
        self.pos += 1;
        self.resident += 1;
    }

    /// Evict the table entry at `idx` (frees the page when unshared).
    pub fn evict(&mut self, idx: usize, pool: &mut PagePool) {
        let e = self.pages.remove(idx);
        self.resident -= pool.filled(e.id);
        pool.release(e.id);
    }

    /// Drop everything (sequence finished).
    pub fn clear(&mut self, pool: &mut PagePool) {
        for e in self.pages.drain(..) {
            pool.release(e.id);
        }
        self.pos = 0;
        self.resident = 0;
    }

    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Snapshot for session storage: full pages are shared by refcount;
    /// the trailing partial page (still writable) is deep-copied so later
    /// appends can't corrupt the snapshot.
    pub fn snapshot(&self, pool: &mut PagePool) -> SeqCache {
        let mut pages = Vec::with_capacity(self.pages.len());
        for (i, e) in self.pages.iter().enumerate() {
            let last = i + 1 == self.pages.len();
            let partial = pool.filled(e.id) < pool.page_size;
            if last && partial {
                pages.push(Self::clone_partial_page(*e, pool));
            } else {
                pool.retain(e.id);
                pages.push(*e);
            }
        }
        SeqCache { pages, pos: self.pos, resident: self.resident }
    }

    /// Restore a snapshot into a live sequence. The snapshot itself stays
    /// valid (pages get another reference); the trailing partial page is
    /// deep-copied so the restored sequence can append.
    pub fn restore(snap: &SeqCache, pool: &mut PagePool) -> SeqCache {
        Self::restore_prefix(snap, pool, usize::MAX).0
    }

    /// Restore at most the first `max_tokens` tokens of a snapshot at page
    /// granularity (vLLM-style prefix caching): pages fully inside the
    /// usable prefix are shared; the first page crossing the limit is
    /// dropped (its tokens get re-prefilled). Returns (cache, tokens
    /// actually covered).
    pub fn restore_prefix(
        snap: &SeqCache,
        pool: &mut PagePool,
        max_tokens: usize,
    ) -> (SeqCache, usize) {
        let mut pages = Vec::new();
        let mut covered = 0usize;
        let n = snap.pages.len();
        for (i, e) in snap.pages.iter().enumerate() {
            let filled = pool.filled(e.id);
            // only a contiguous, fully-covered prefix is reusable
            if e.base_pos != covered || e.base_pos + filled > max_tokens {
                break;
            }
            let _ = (i, n);
            let partial = filled < pool.page_size;
            if partial {
                // a partial page is necessarily the last kept page; clone it
                // so the restored sequence can append into it
                pages.push(Self::clone_partial_page(*e, pool));
            } else {
                pool.retain(e.id);
                pages.push(*e);
            }
            covered = e.base_pos + filled;
        }
        (
            SeqCache { pages, pos: covered, resident: covered },
            covered,
        )
    }

    /// Port a sequence's pages into a *different* worker's pool/store
    /// (cross-worker session migration and work stealing). Source pages
    /// are faulted hot first (the source store prices any cold/disk
    /// promotion), then copied page-by-page into freshly allocated pages
    /// of the destination: int8 pools move raw quantized rows so the
    /// port is bit-exact; f32/f16 pools round-trip through f32 staging
    /// (same precision class, deterministic). Bounding boxes and fill
    /// counters are carried verbatim, `base_pos`/`pos`/`resident` are
    /// preserved, so the ported sequence decodes identically on the new
    /// worker. The source cache is left untouched — the caller releases
    /// it on its own pool once the move commits. Returns the ported
    /// cache plus payload bytes copied (for transit pricing).
    pub fn port_to(
        src: &SeqCache,
        src_pool: &mut PagePool,
        src_store: &mut super::store::PageStore,
        dst_pool: &mut PagePool,
        dst_store: &mut super::store::PageStore,
    ) -> anyhow::Result<(SeqCache, usize)> {
        let d = src_pool.d_kv;
        debug_assert_eq!(d, dst_pool.d_kv, "porting across model shapes");
        debug_assert_eq!(src_pool.page_size, dst_pool.page_size);
        debug_assert_eq!(src_pool.n_layers, dst_pool.n_layers);
        let mut pages = Vec::with_capacity(src.pages.len());
        let mut bytes = 0usize;
        let mut kbuf = vec![0.0f32; src_pool.page_size * d];
        let mut vbuf = vec![0.0f32; src_pool.page_size * d];
        for e in &src.pages {
            src_store.ensure_hot(src_pool, e.id)?;
            let dst = dst_store.alloc(dst_pool);
            let n = src_pool.filled(e.id);
            for l in 0..src_pool.n_layers {
                let mut raw = true;
                for s in 0..n {
                    match src_pool.q8_rows_raw(e.id, l, s) {
                        Some((k, v)) => {
                            dst_pool.import_q8_row(dst, l, s, k, v);
                            bytes += 2 * (d + 4);
                        }
                        None => {
                            raw = false;
                            break;
                        }
                    }
                }
                if !raw {
                    bytes += src_pool.gather_rows(e.id, l, n, &mut kbuf, &mut vbuf);
                    dst_pool.import_rows(dst, l, n, &kbuf, &vbuf);
                }
                let meta = src_pool.meta(e.id, l).to_vec();
                dst_pool.set_meta(dst, l, &meta);
            }
            dst_pool.set_filled(dst, n);
            pages.push(PageEntry { id: dst, base_pos: e.base_pos });
        }
        dst_store.sync(dst_pool);
        Ok((SeqCache { pages, pos: src.pos, resident: src.resident }, bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    fn setup() -> (PagePool, SeqCache) {
        (PagePool::new(1, 4, 4, KvDtype::F32), SeqCache::new())
    }

    fn push_token(seq: &mut SeqCache, pool: &mut PagePool, val: f32) {
        let (page, slot) = seq.slot_for_next(pool);
        pool.write_token(page, slot, 0, &[val; 4], &[val; 4]);
        seq.commit_token();
    }

    #[test]
    fn pages_fill_then_allocate() {
        let (mut pool, mut seq) = setup();
        for i in 0..10 {
            push_token(&mut seq, &mut pool, i as f32);
        }
        assert_eq!(seq.pos, 10);
        assert_eq!(seq.n_pages(), 3); // 4 + 4 + 2
        assert_eq!(pool.filled(seq.pages[0].id), 4);
        assert_eq!(pool.filled(seq.pages[2].id), 2);
        assert_eq!(seq.pages[1].base_pos, 4);
    }

    #[test]
    fn eviction_frees_and_keeps_positions() {
        let (mut pool, mut seq) = setup();
        for i in 0..12 {
            push_token(&mut seq, &mut pool, i as f32);
        }
        assert_eq!(pool.pages_in_use(), 3);
        seq.evict(1, &mut pool); // drop middle page
        assert_eq!(seq.n_pages(), 2);
        assert_eq!(seq.resident, 8);
        assert_eq!(seq.pages[1].base_pos, 8); // positions preserved
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn snapshot_shares_full_pages() {
        let (mut pool, mut seq) = setup();
        for i in 0..6 {
            push_token(&mut seq, &mut pool, i as f32);
        }
        let in_use_before = pool.pages_in_use();
        let snap = seq.snapshot(&mut pool);
        // full page shared (refcount 2), partial page copied (one extra page)
        assert_eq!(pool.pages_in_use(), in_use_before + 1);
        assert_eq!(pool.refcount(seq.pages[0].id), 2);
        assert_ne!(snap.pages[1].id, seq.pages[1].id);

        // appending to the live seq must not affect the snapshot
        push_token(&mut seq, &mut pool, 99.0);
        assert_eq!(pool.key_row(snap.pages[1].id, 0, 1), vec![5.0; 4]);
        assert_eq!(pool.filled(snap.pages[1].id), 2);
    }

    #[test]
    fn restore_enables_independent_append() {
        let (mut pool, mut seq) = setup();
        for i in 0..5 {
            push_token(&mut seq, &mut pool, i as f32);
        }
        let snap = seq.snapshot(&mut pool);
        let mut restored = SeqCache::restore(&snap, &mut pool);
        assert_eq!(restored.pos, 5);
        push_token(&mut restored, &mut pool, 50.0);
        push_token(&mut seq, &mut pool, 60.0);
        // each wrote its own copy of the partial page
        assert_eq!(pool.key_row(restored.pages[1].id, 0, 1), vec![50.0; 4]);
        assert_eq!(pool.key_row(seq.pages[1].id, 0, 1), vec![60.0; 4]);
        // snapshot still intact
        assert_eq!(pool.filled(snap.pages[1].id), 1);
        // cleanup is balanced
        restored.clear(&mut pool);
        seq.clear(&mut pool);
        let mut snap = snap;
        snap.clear(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
        pool.validate().unwrap();
    }

    #[test]
    fn cow_append_privatizes_shared_partial_page() {
        let (mut pool, mut seq) = setup();
        for i in 0..6 {
            push_token(&mut seq, &mut pool, i as f32);
        }
        // share the trailing partial page, as prefix adoption would
        let shared = seq.pages[1].id;
        pool.retain(shared);
        assert_eq!(pool.refcount(shared), 2);
        // the next append must copy-on-write, not mutate the shared page
        push_token(&mut seq, &mut pool, 99.0);
        let private = seq.pages[1].id;
        assert_ne!(private, shared, "append cloned the shared page");
        assert_eq!(seq.pages[1].base_pos, 4, "base_pos survives the COW copy");
        assert_eq!(pool.refcount(shared), 1, "seq dropped its shared ref");
        assert_eq!(pool.refcount(private), 1);
        // shared original is untouched; the private copy has the new token
        assert_eq!(pool.filled(shared), 2);
        assert_eq!(pool.filled(private), 3);
        assert_eq!(pool.key_row(private, 0, 2), vec![99.0; 4]);
        // balance: drop both refs, pool empties
        pool.release(shared);
        seq.clear(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
        pool.validate().unwrap();
    }

    #[test]
    fn clone_partial_page_copies_bboxes_bit_equal() {
        let mut pool = PagePool::new(2, 4, 4, KvDtype::F32);
        let mut seq = SeqCache::new();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..3 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let (page, slot) = seq.slot_for_next(&mut pool);
            for l in 0..2 {
                pool.write_token(page, slot, l, &row, &row);
            }
            seq.commit_token();
        }
        let src = seq.pages[0].id;
        // exercise every partial-page copy path off the one shared helper:
        // snapshot, restore, and the COW-append guard
        let snap = seq.snapshot(&mut pool);
        let restored = SeqCache::restore(&snap, &mut pool);
        pool.retain(src);
        push_token(&mut seq, &mut pool, 7.0); // COW-append clone, then write
        assert_ne!(seq.pages[0].id, src, "guard fired on the shared page");
        for copy in [snap.pages[0].id, restored.pages[0].id] {
            for l in 0..2 {
                let a: Vec<u32> =
                    pool.meta(src, l).iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> =
                    pool.meta(copy, l).iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "bboxes bit-equal after copy (layer {l})");
            }
        }
        pool.release(src);
    }

    #[test]
    fn port_to_copies_pages_across_pools() {
        use crate::kvcache::store::{EvictionPolicyKind, PageStore};
        let (mut src_pool, mut seq) = setup();
        let mut src_store = PageStore::new(None, EvictionPolicyKind::Lru);
        let mut dst_pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut dst_store = PageStore::new(None, EvictionPolicyKind::Lru);
        for i in 0..6 {
            push_token(&mut seq, &mut src_pool, i as f32);
        }
        let (mut ported, bytes) = SeqCache::port_to(
            &seq,
            &mut src_pool,
            &mut src_store,
            &mut dst_pool,
            &mut dst_store,
        )
        .unwrap();
        assert!(bytes > 0);
        assert_eq!(ported.pos, 6);
        assert_eq!(ported.resident, 6);
        assert_eq!(ported.n_pages(), seq.n_pages());
        for (pe, se) in ported.pages.iter().zip(&seq.pages) {
            assert_eq!(pe.base_pos, se.base_pos);
            assert_eq!(dst_pool.filled(pe.id), src_pool.filled(se.id));
            assert_eq!(dst_pool.meta(pe.id, 0), src_pool.meta(se.id, 0));
            for s in 0..dst_pool.filled(pe.id) {
                assert_eq!(
                    dst_pool.key_row(pe.id, 0, s),
                    src_pool.key_row(se.id, 0, s)
                );
            }
        }
        // ported sequence appends independently on the destination pool
        let (page, slot) = ported.slot_for_next(&mut dst_pool);
        dst_pool.write_token(page, slot, 0, &[9.0; 4], &[9.0; 4]);
        ported.commit_token();
        assert_eq!(ported.pos, 7);
        // source untouched; cleanup balances both pools
        assert_eq!(seq.pos, 6);
        seq.clear(&mut src_pool);
        ported.clear(&mut dst_pool);
        assert_eq!(src_pool.pages_in_use(), 0);
        assert_eq!(dst_pool.pages_in_use(), 0);
        src_pool.validate().unwrap();
        dst_pool.validate().unwrap();
    }

    #[test]
    fn port_to_is_bit_exact_for_int8_pools() {
        use crate::kvcache::store::{EvictionPolicyKind, PageStore};
        let mut src_pool = PagePool::new(2, 8, 4, KvDtype::Int8);
        let mut dst_pool = PagePool::new(2, 8, 4, KvDtype::Int8);
        let mut src_store = PageStore::new(None, EvictionPolicyKind::Lru);
        let mut dst_store = PageStore::new(None, EvictionPolicyKind::Lru);
        let mut seq = SeqCache::new();
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..7 {
            let row: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            let (page, slot) = seq.slot_for_next(&mut src_pool);
            for l in 0..2 {
                src_pool.write_token(page, slot, l, &row, &row);
            }
            seq.commit_token();
        }
        let (ported, _) = SeqCache::port_to(
            &seq,
            &mut src_pool,
            &mut src_store,
            &mut dst_pool,
            &mut dst_store,
        )
        .unwrap();
        for (pe, se) in ported.pages.iter().zip(&seq.pages) {
            for l in 0..2 {
                for s in 0..src_pool.filled(se.id) {
                    let (sk, sv) = src_pool.q8_rows_raw(se.id, l, s).unwrap();
                    let (dk, dv) = dst_pool.q8_rows_raw(pe.id, l, s).unwrap();
                    assert_eq!(sk.0, dk.0, "raw q8 key bytes move verbatim");
                    assert_eq!(sk.1, dk.1);
                    assert_eq!(sv.0, dv.0);
                    assert_eq!(sv.1, dv.1);
                }
            }
        }
    }
}
