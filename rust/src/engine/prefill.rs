//! Prompt ingest: chunked prefill through the `prefill` artifact (B=1),
//! writing the produced KV into the paged pool (+ bounding-box metadata).
//!
//! Convention: prefill processes `tokens[0..n-1]`, leaving the final prompt
//! token *pending* — the first `decode_step` consumes it and produces the
//! first generated token (so TTFT = queue + prefill + one decode step).

use std::time::Instant;

use anyhow::{Context as _, Result};

use super::{Engine, Sequence};
use crate::metrics::StepMetrics;
use crate::runtime::Input;

impl Engine {
    /// Chunked-artifact prefill for one sequence (prompt already in
    /// `seq.tokens`). No-op when fewer than 2 tokens are pending.
    pub fn prefill(&mut self, seq: &mut Sequence, m: &mut StepMetrics) -> Result<()> {
        let t0 = Instant::now();
        let n_pre = seq.tokens.len().saturating_sub(1 + seq.cache.pos);
        if n_pre == 0 {
            return Ok(());
        }
        let art = self
            .rt
            .info
            .find_artifact("prefill", 1, None)
            .context("no prefill artifact")?
            .clone();
        let c = art.chunk.context("prefill artifact missing chunk")?;
        let tp = art.ctx.context("prefill artifact missing ctx")?;
        anyhow::ensure!(
            seq.cache.pos + n_pre <= tp,
            "prompt ({} tokens) exceeds prefill context {tp}",
            seq.cache.pos + n_pre
        );
        let (l, d_kv) = (self.n_layer, self.d_kv);

        // host-staged full KV buffers [L, Tp, d_kv] (B = 1)
        let mut kbuf = vec![0.0f32; l * tp * d_kv];
        let mut vbuf = vec![0.0f32; l * tp * d_kv];
        // resuming a session: reload resident pages into the staging buffer
        if seq.cache.pos > 0 {
            let mut krow = vec![0.0f32; self.pool.page_size * d_kv];
            let mut vrow = vec![0.0f32; self.pool.page_size * d_kv];
            // snapshot pages may have been spilled to disk while the
            // session idled — fault them back before gathering, holding
            // pins across the whole resume (same discipline as the decode
            // batch: without pins, faulting page B could displace
            // already-faulted page A back to disk and the gather below
            // would read A's zeroed rows). Hot/cold pages are
            // RAM-resident and read as-is, so the classic two-tier path
            // stays bit-identical (no extra sync, no pins).
            if self.store.spill_enabled() {
                self.store.sync(&self.pool);
                for e in &seq.cache.pages {
                    self.store.pin(e.id);
                }
                for e in &seq.cache.pages {
                    self.store.fault_if_spilled(&mut self.pool, e.id)?;
                }
            }
            for e in &seq.cache.pages {
                let filled = self.pool.filled(e.id);
                for layer in 0..l {
                    self.pool.gather_rows(e.id, layer, filled, &mut krow, &mut vrow);
                    let off = layer * tp * d_kv + e.base_pos * d_kv;
                    kbuf[off..off + filled * d_kv]
                        .copy_from_slice(&krow[..filled * d_kv]);
                    vbuf[off..off + filled * d_kv]
                        .copy_from_slice(&vrow[..filled * d_kv]);
                }
            }
            if self.store.spill_enabled() {
                for e in &seq.cache.pages {
                    self.store.unpin(e.id);
                }
            }
        }

        let start = seq.cache.pos;
        let mut done = 0usize;
        let mut chunk_tokens = vec![0i32; c];
        while done < n_pre {
            let take = c.min(n_pre - done);
            let base = seq.cache.pos; // == start + done
            for j in 0..c {
                chunk_tokens[j] = if j < take {
                    seq.tokens[base + j]
                } else {
                    0
                };
            }
            let prior = [base as i32];
            let out = self.rt.run(
                &art,
                None,
                &[
                    Input::I32(&chunk_tokens, &[1, c]),
                    Input::I32(&prior, &[]),
                    Input::F32(&kbuf, &[l, 1, tp, self.n_head, self.head_dim]),
                    Input::F32(&vbuf, &[l, 1, tp, self.n_head, self.head_dim]),
                ],
            )?;
            let kc = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let vc = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            // write real tokens into the staging buffer and the paged pool
            for j in 0..take {
                let (page, slot) = seq.cache.slot_for_next(&mut self.pool);
                for layer in 0..l {
                    let src = layer * c * d_kv + j * d_kv;
                    let dst = layer * tp * d_kv + (base + j) * d_kv;
                    kbuf[dst..dst + d_kv].copy_from_slice(&kc[src..src + d_kv]);
                    vbuf[dst..dst + d_kv].copy_from_slice(&vc[src..src + d_kv]);
                    self.pool.write_token(
                        page,
                        slot,
                        layer,
                        &kc[src..src + d_kv],
                        &vc[src..src + d_kv],
                    );
                }
                seq.cache.commit_token();
            }
            done += take;
        }
        debug_assert_eq!(seq.cache.pos, start + n_pre);
        debug_assert_eq!(seq.pending(), 1);
        m.step_seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Decode-path prefill: absorbs the prompt one token at a time through
    /// `decode_step`. Slower (one full selection per token) but exercises
    /// the exact serving path — used by tests and the quickstart example,
    /// and as the fallback when no prefill artifact exists.
    pub fn prefill_stepwise(
        &mut self,
        seq: &mut Sequence,
        m: &mut StepMetrics,
    ) -> Result<()> {
        while seq.pending() > 1 {
            let mut batch = [&mut *seq];
            self.absorb_step(&mut batch, m)?;
        }
        Ok(())
    }
}
