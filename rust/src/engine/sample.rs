//! Token sampling over the logits executable's output.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    Greedy,
    /// softmax temperature sampling, optionally top-k truncated
    Temperature { t: f32, top_k: usize },
}

#[derive(Debug, Clone, Copy)]
pub struct SampleOut {
    pub token: i32,
    /// entropy of the (possibly tempered) output distribution, nats —
    /// consumed by the entropy early-exit plugin (paper §3.1(2)).
    pub entropy: f32,
    pub logprob: f32,
}

/// Sample one token from a logits row.
pub fn sample(logits: &[f32], mode: Sampling, rng: &mut Rng) -> SampleOut {
    match mode {
        Sampling::Greedy => {
            let (mut best, mut bi) = (f32::NEG_INFINITY, 0usize);
            for (i, &l) in logits.iter().enumerate() {
                if l > best {
                    best = l;
                    bi = i;
                }
            }
            let (h, lp) = entropy_and_logprob(logits, 1.0, bi);
            SampleOut { token: bi as i32, entropy: h, logprob: lp }
        }
        Sampling::Temperature { t, top_k } => {
            let t = t.max(1e-3);
            // top-k mask
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if top_k > 0 && top_k < logits.len() {
                idx = crate::sparsity::top_k_indices(logits, top_k);
            }
            let max = idx.iter().map(|&i| logits[i]).fold(f32::MIN, f32::max);
            let mut probs: Vec<(usize, f32)> = idx
                .iter()
                .map(|&i| (i, ((logits[i] - max) / t).exp()))
                .collect();
            let z: f32 = probs.iter().map(|(_, p)| p).sum();
            let mut u = rng.f32() * z;
            let mut chosen = probs.last().map(|(i, _)| *i).unwrap_or(0);
            for &(i, p) in &probs {
                if u <= p {
                    chosen = i;
                    break;
                }
                u -= p;
            }
            for p in probs.iter_mut() {
                p.1 /= z;
            }
            let h = -probs
                .iter()
                .map(|(_, p)| if *p > 0.0 { p * p.ln() } else { 0.0 })
                .sum::<f32>();
            let lp = probs
                .iter()
                .find(|(i, _)| *i == chosen)
                .map(|(_, p)| p.ln())
                .unwrap_or(f32::NEG_INFINITY);
            SampleOut { token: chosen as i32, entropy: h, logprob: lp }
        }
    }
}

/// Entropy of softmax(logits) and log-prob of `target`, single pass.
pub fn entropy_and_logprob(logits: &[f32], t: f32, target: usize) -> (f32, f32) {
    let max = logits.iter().fold(f32::MIN, |m, &x| m.max(x));
    let mut z = 0.0f64;
    let mut zl = 0.0f64; // sum p_i * logit_i (unnormalized accumulation)
    for &l in logits {
        let e = (((l - max) / t) as f64).exp();
        z += e;
        zl += e * ((l - max) / t) as f64;
    }
    let h = (z.ln() - zl / z) as f32;
    let lp = ((logits[target] - max) / t) as f64 - z.ln();
    (h, lp as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0, 5.0, 1.0, -2.0];
        let out = sample(&logits, Sampling::Greedy, &mut rng);
        assert_eq!(out.token, 1);
        assert!(out.logprob < 0.0);
    }

    #[test]
    fn entropy_of_uniform_is_log_n() {
        let logits = vec![1.0; 8];
        let (h, _) = entropy_and_logprob(&logits, 1.0, 0);
        assert!((h - (8f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn entropy_of_peaked_is_small() {
        let mut logits = vec![0.0; 8];
        logits[3] = 50.0;
        let (h, lp) = entropy_and_logprob(&logits, 1.0, 3);
        assert!(h < 1e-3, "{h}");
        assert!(lp > -1e-3, "{lp}");
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Rng::new(7);
        let logits = vec![0.0, 3.0];
        let mut counts = [0u32; 2];
        for _ in 0..2000 {
            let o = sample(&logits, Sampling::Temperature { t: 1.0, top_k: 0 }, &mut rng);
            counts[o.token as usize] += 1;
        }
        // p(1) = sigmoid(3) ~ 0.95
        let frac = counts[1] as f64 / 2000.0;
        assert!((frac - 0.95).abs() < 0.03, "{frac}");
    }

    #[test]
    fn top_k_truncates() {
        let mut rng = Rng::new(9);
        let logits = vec![1.0, 0.9, -10.0, -10.0];
        for _ in 0..100 {
            let o = sample(&logits, Sampling::Temperature { t: 2.0, top_k: 2 }, &mut rng);
            assert!(o.token < 2, "sampled outside top-k");
        }
    }
}
