//! Decode engine: the system-level realization of Algorithm 1.
//!
//! Per decode step, per layer:
//!   1. `qkv` executable produces the fresh query + new K/V;
//!   2. Rust appends K/V to the paged pool and updates bounding boxes;
//!   3. Rust scores pages (Eq. 2), applies the active policy, top-Ks;
//!   4. Rust gathers the selected pages into a contiguous budget buffer
//!      (the HBM page-fetch analogue — every byte is counted);
//!   5. the fused Pallas attention executable (`post`) runs over it.
//!
//! The engine is single-threaded *internally* (no locks on the hot path)
//! but the whole stack is `Send`: one engine per worker, and the
//! coordinator's round executor may move a worker's `&mut Engine` onto a
//! scoped OS thread for the decode step (`--threads N`). The coordinator
//! owns batching and concurrency above it; engines never share mutable
//! state with each other.

pub mod fused;
pub mod prefill;
pub mod sample;

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::kvcache::{PagePool, PageStore, SeqCache, StoreStats};
use crate::metrics::StepMetrics;
use crate::runtime::{ArtifactInfo, Input, Manifest, ModelRuntime};
use crate::sparsity::{make_policy, Policy, PolicyKind, SelectCtx};
use crate::trace::{AccessTier, AnalyticsRecorder};
use crate::util::rng::Rng;

pub use sample::{sample, SampleOut, Sampling};

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;

/// One in-flight sequence (prompt + generation state + policy instance).
pub struct Sequence {
    pub id: u64,
    pub cache: SeqCache,
    pub policy: Box<dyn Policy>,
    /// full token history; position `cache.pos` is the next to process
    pub tokens: Vec<i32>,
    pub generated: usize,
    pub max_new_tokens: usize,
    pub finished: bool,
    pub last_entropy: f32,
    /// per layer: base_pos of pages selected at the previous step
    last_selected: Vec<Vec<usize>>,
    /// sum of per-step logprobs of sampled tokens (ppl bookkeeping)
    pub sum_logprob: f64,
}

impl Sequence {
    pub fn new(id: u64, policy: PolicyKind, n_layers: usize) -> Sequence {
        Sequence {
            id,
            cache: SeqCache::new(),
            policy: make_policy(policy),
            tokens: Vec::new(),
            generated: 0,
            max_new_tokens: 0,
            finished: false,
            last_entropy: f32::NAN,
            last_selected: vec![Vec::new(); n_layers],
            sum_logprob: 0.0,
        }
    }

    /// Tokens still unprocessed (pending prefill/decode input).
    pub fn pending(&self) -> usize {
        self.tokens.len().saturating_sub(self.cache.pos)
    }

    pub fn generated_tokens(&self) -> &[i32] {
        &self.tokens[self.tokens.len() - self.generated..]
    }
}

/// The model-execution engine for one model and one (batch, budget) family.
pub struct Engine {
    pub rt: ModelRuntime,
    pub cfg: ServingConfig,
    pub pool: PagePool,
    /// budget/residency layer over `pool` (pass-through when unbounded)
    pub store: PageStore,
    /// (kind, batch) -> artifact; `post` keyed with the configured budget
    arts: BTreeMap<(String, usize), ArtifactInfo>,
    batch_variants: Vec<usize>,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub head_dim: usize,
    pub d_kv: usize,
    pub vocab: usize,
    // --- reusable staging buffers (sized at construction) ---
    hbuf: Vec<f32>,
    qbuf: Vec<f32>,
    kbuf: Vec<f32>,
    vbuf: Vec<f32>,
    stage_k: Vec<f32>,
    stage_v: Vec<f32>,
    mask: Vec<f32>,
    dist: Vec<f32>,
    logits_buf: Vec<f32>,
    sel_scratch: Vec<usize>,
    /// store counters already surfaced through StepMetrics: each decode
    /// step reports growth since the previous one, so demotions/spill from
    /// between-step work (prefill enforcement, admission) are charged to
    /// the next step instead of dropped
    stats_reported: StoreStats,
    /// optional cache analytics (attached when `--analytics-out` is set);
    /// boxed so disabled engines pay one pointer
    analytics: Option<Box<AnalyticsRecorder>>,
    /// audit bbox selection against the exact-attention oracle every N
    /// engine decode steps (0 = off)
    audit_every: usize,
    next_id: u64,
}

impl Engine {
    pub fn new(artifacts_dir: &Path, cfg: ServingConfig) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::from_manifest(&manifest, cfg)
    }

    pub fn from_manifest(manifest: &Manifest, cfg: ServingConfig) -> Result<Engine> {
        cfg.validate()?;
        let rt = ModelRuntime::from_manifest(manifest, &cfg.model)?;
        let info = rt.info.clone();
        let d_kv = info.n_head * info.head_dim;
        let pool = PagePool::new(info.n_layer, d_kv, cfg.page_size, cfg.kv_dtype);
        // single-engine path: the whole spill budget belongs to worker 0
        // (WorkerPool::build re-slices stores for multi-worker pools)
        let store = match cfg.spill_config(0, 1) {
            Some(sc) => PageStore::with_spill(cfg.kv_budget_bytes(), cfg.eviction, sc)?,
            None => PageStore::new(cfg.kv_budget_bytes(), cfg.eviction),
        };

        // resolve the decode-path artifact variants we will use
        let mut arts = BTreeMap::new();
        let mut batch_variants = Vec::new();
        for &b in info
            .batch_variants("qkv")
            .iter()
            .filter(|&&b| b <= cfg.max_batch)
        {
            let ok = info.find_artifact("post", b, Some(cfg.budget)).is_ok();
            if !ok {
                continue;
            }
            for kind in ["embed", "qkv", "logits"] {
                let a = info.find_artifact(kind, b, None)?.clone();
                arts.insert((kind.to_string(), b), a);
            }
            let a = info.find_artifact("post", b, Some(cfg.budget))?.clone();
            arts.insert(("post".to_string(), b), a);
            batch_variants.push(b);
        }
        anyhow::ensure!(
            !batch_variants.is_empty(),
            "no (batch<=({}), budget={}) artifact variants for model {}; \
             available budgets: {:?}",
            cfg.max_batch,
            cfg.budget,
            cfg.model,
            info.budget_variants()
        );
        let max_b = *batch_variants.last().unwrap();
        let t = cfg.budget;
        Ok(Engine {
            pool,
            store,
            d_model: info.d_model,
            n_layer: info.n_layer,
            n_head: info.n_head,
            head_dim: info.head_dim,
            d_kv,
            vocab: info.vocab,
            hbuf: vec![0.0; max_b * info.d_model],
            qbuf: vec![0.0; max_b * d_kv],
            kbuf: vec![0.0; max_b * d_kv],
            vbuf: vec![0.0; max_b * d_kv],
            stage_k: vec![0.0; max_b * t * d_kv],
            stage_v: vec![0.0; max_b * t * d_kv],
            mask: vec![0.0; max_b * t],
            dist: vec![0.0; max_b * t],
            logits_buf: vec![0.0; max_b * info.vocab],
            sel_scratch: Vec::new(),
            stats_reported: StoreStats::default(),
            analytics: None,
            audit_every: 0,
            arts,
            batch_variants,
            rt,
            cfg,
            next_id: 0,
        })
    }

    pub fn new_sequence(&mut self) -> Sequence {
        self.next_id += 1;
        Sequence::new(self.next_id, self.cfg.policy, self.n_layer)
    }

    pub fn new_sequence_with_policy(&mut self, kind: PolicyKind) -> Sequence {
        self.next_id += 1;
        Sequence::new(self.next_id, kind, self.n_layer)
    }

    /// Smallest compiled batch variant that fits `n` rows.
    pub fn pick_batch(&self, n: usize) -> usize {
        *self
            .batch_variants
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(self.batch_variants.last().unwrap())
    }

    pub fn max_batch(&self) -> usize {
        *self.batch_variants.last().unwrap()
    }

    fn art(&self, kind: &str, b: usize) -> &ArtifactInfo {
        &self.arts[&(kind.to_string(), b)]
    }

    /// Compile the decode executables up front.
    pub fn warmup(&self) -> Result<()> {
        for ((_, _), art) in self.arts.iter() {
            self.rt.executable(art)?;
        }
        Ok(())
    }

    /// Release a finished sequence's pages.
    pub fn release(&mut self, seq: &mut Sequence) {
        seq.cache.clear(&mut self.pool);
        self.store.sync(&self.pool);
    }

    /// Release a sequence aborted mid-flight (cancellation / deadline
    /// expiry): clears any pins the store still holds on its pages first —
    /// the decode loop unpins at step end, but an abort can land between
    /// pin and unpin — then frees them and re-syncs residency accounting
    /// so `bytes_in_use` drops immediately.
    pub fn release_mid_flight(&mut self, seq: &mut Sequence) {
        for e in seq.cache.pages.iter() {
            self.store.unpin(e.id);
        }
        self.release(seq);
    }

    /// Demote pages until the KV byte budget holds (no-op when unbounded).
    /// The coordinator calls this after prefill/snapshot bursts that
    /// allocate outside the decode path.
    pub fn enforce_kv_budget(&mut self) {
        self.store.enforce_budget(&mut self.pool);
    }

    /// Attach a cache-analytics recorder (`trace::analytics`). With
    /// `audit_every > 0`, every Nth engine decode step also scores every
    /// page with the exact-attention oracle and records the top-k overlap
    /// of the policy's selection per layer.
    pub fn enable_analytics(&mut self, audit_every: usize) {
        self.analytics = Some(Box::new(AnalyticsRecorder::new()));
        self.audit_every = audit_every;
    }

    pub fn analytics(&self) -> Option<&AnalyticsRecorder> {
        self.analytics.as_deref()
    }

    pub fn analytics_mut(&mut self) -> Option<&mut AnalyticsRecorder> {
        self.analytics.as_deref_mut()
    }

    /// Admission-control check: can a prompt of `prompt_tokens` be brought
    /// fully hot without exceeding the KV budget, assuming every currently
    /// resident page could be demoted to the cold rate — and, with a disk
    /// spill tier attached, that as many cold pages as the tier still has
    /// room for could leave RAM entirely? Unbounded engines always admit.
    pub fn kv_admission_ok(&mut self, prompt_tokens: usize) -> bool {
        let Some(budget) = self.store.budget_bytes() else { return true };
        self.store.sync(&self.pool);
        let (hot, cold) = self.store.tier_counts();
        let spillable = self.store.spill_free_pages(&self.pool).min(hot + cold);
        let floor = (hot + cold - spillable) * self.pool.page_bytes_cold();
        let need = prompt_tokens.div_ceil(self.cfg.page_size).max(1)
            * self.pool.page_bytes();
        floor + need <= budget
    }

    /// Evict the coldest prunable page of a sequence, as ranked by the
    /// store's eviction policy (the `PruneColdest` plugin action). Falls
    /// back to the oldest non-sink page when the store has no signal.
    pub fn prune_coldest(&mut self, seq: &mut Sequence) {
        let sink = self.cfg.sink_pages;
        if seq.cache.n_pages() <= sink + 1 {
            return;
        }
        let idx = self.store.coldest_index(&seq.cache, sink).unwrap_or(sink);
        seq.cache.evict(idx, &mut self.pool);
        self.store.sync(&self.pool);
    }

    /// One decode step over up to `max_batch` sequences. Each sequence must
    /// have a pending token (`seq.pending() > 0`). Samples the next token
    /// for every row, appends it, and returns the sampled tokens.
    pub fn decode_step(
        &mut self,
        seqs: &mut [&mut Sequence],
        sampling: Sampling,
        rng: &mut Rng,
        m: &mut StepMetrics,
    ) -> Result<Vec<SampleOut>> {
        let n = seqs.len();
        anyhow::ensure!(n > 0, "empty batch");
        let b = self.pick_batch(n);
        anyhow::ensure!(n <= b, "batch {n} exceeds compiled variant {b}");
        let t0 = Instant::now();
        let t = self.cfg.budget;
        let (d, d_kv, n_head, hd) = (self.d_model, self.d_kv, self.n_head, self.head_dim);

        // ---- embed ----
        let mut tokens = vec![0i32; b];
        for (i, s) in seqs.iter().enumerate() {
            anyhow::ensure!(s.pending() > 0, "sequence {} has no pending token", s.id);
            tokens[i] = s.tokens[s.cache.pos];
        }
        let emb = self.art("embed", b).clone();
        let out = self.rt.run(&emb, None, &[Input::I32(&tokens, &[b])])?;
        crate::runtime::literal_into(&out[0], &mut self.hbuf[..b * d])?;

        // ---- pin the batch's pages: decoding sequences are never victims ----
        let budgeted = self.store.enabled();
        if budgeted {
            self.store.sync(&self.pool);
            for s in seqs.iter() {
                for e in s.cache.pages.iter() {
                    self.store.pin(e.id);
                }
            }
        }

        // ---- allocate this token's slot in each row's page table ----
        // (over budget, the store demotes cold pages instead of growing)
        let mut slots = Vec::with_capacity(n);
        for s in seqs.iter_mut() {
            slots.push(s.cache.slot_for_next_budgeted(&mut self.pool, &mut self.store));
        }
        if budgeted {
            for &(page, _) in &slots {
                self.store.pin(page);
            }
        }

        let qkv_art = self.art("qkv", b).clone();
        let post_art = self.art("post", b).clone();

        // selection-quality audit cadence: every `audit_every`th engine
        // step (engine-local step counter, so the decision is independent
        // of executor kind/width)
        let audit_step = self.audit_every > 0
            && self
                .analytics
                .as_ref()
                .is_some_and(|a| a.step() % self.audit_every as u64 == 0);

        for layer in 0..self.n_layer {
            // ---- qkv ----
            let out = self.rt.run(
                &qkv_art,
                Some(layer),
                &[Input::F32(&self.hbuf[..b * d], &[b, d])],
            )?;
            crate::runtime::literal_into(&out[0], &mut self.qbuf[..b * d_kv])?;
            crate::runtime::literal_into(&out[1], &mut self.kbuf[..b * d_kv])?;
            crate::runtime::literal_into(&out[2], &mut self.vbuf[..b * d_kv])?;

            // ---- append K/V + metadata ----
            for (i, s) in seqs.iter_mut().enumerate() {
                let (page, slot) = slots[i];
                self.pool.write_token(
                    page,
                    slot,
                    layer,
                    &self.kbuf[i * d_kv..(i + 1) * d_kv],
                    &self.vbuf[i * d_kv..(i + 1) * d_kv],
                );
                let _ = s;
            }

            // ---- select + gather per row ----
            self.mask[..b * t].fill(-1e9);
            self.dist[..b * t].fill(0.0);
            for (i, s) in seqs.iter_mut().enumerate() {
                let ts = Instant::now();
                let seq_ref: &mut Sequence = s;
                let Sequence { cache, policy, last_entropy, last_selected, .. } =
                    seq_ref;
                // cold-tier signal: observe every page's bounding-box
                // relevance against the fresh query (first layer only —
                // one extra metadata pass per step, same cost class as the
                // selection scan itself)
                if layer == 0 && self.store.wants_scores() {
                    let q = &self.qbuf[i * d_kv..(i + 1) * d_kv];
                    for e in cache.pages.iter() {
                        self.store
                            .note_score(e.id, crate::sparsity::score_page(q, self.pool.meta(e.id, 0)));
                    }
                }
                let ctx = SelectCtx {
                    layer,
                    n_layers: self.n_layer,
                    q: &self.qbuf[i * d_kv..(i + 1) * d_kv],
                    pool: &self.pool,
                    seq: cache,
                    budget_pages: self.cfg.budget_pages(),
                    sink_pages: self.cfg.sink_pages,
                    recent_pages: self.cfg.recent_pages,
                    last_entropy: *last_entropy,
                };
                let sel = &mut self.sel_scratch;
                policy.select_into(&ctx, sel);
                m.score_seconds += ts.elapsed().as_secs_f64();
                m.pages_scanned += cache.n_pages();
                m.pages_selected += sel.len();

                // hit-rate bookkeeping on stable page identities
                let prev = &mut last_selected[layer];
                let mut cur: Vec<usize> =
                    sel.iter().map(|&x| cache.pages[x].base_pos).collect();
                m.pages_reused +=
                    cur.iter().filter(|bp| prev.binary_search(bp).is_ok()).count();
                cur.sort_unstable();
                std::mem::swap(prev, &mut cur);

                // cache analytics: record tier-at-access for every selected
                // page (before the promotion below rewrites it), plus the
                // optional exact-attention oracle audit
                if let Some(an) = self.analytics.as_deref_mut() {
                    for &tidx in sel.iter() {
                        let id = cache.pages[tidx].id;
                        let tier = if !budgeted || self.store.is_hot(id) {
                            AccessTier::Hot
                        } else if self.store.is_on_disk(id) {
                            AccessTier::Disk
                        } else {
                            AccessTier::Cold
                        };
                        an.on_access(id as u64, tier);
                    }
                    if audit_step && !sel.is_empty() {
                        let q = &self.qbuf[i * d_kv..(i + 1) * d_kv];
                        let oracle =
                            oracle_topk(q, cache, &self.pool, layer, sel.len());
                        let overlap = sel
                            .iter()
                            .filter(|&&tx| oracle.binary_search(&tx).is_ok())
                            .count();
                        an.on_audit(layer, sel.len(), overlap);
                    }
                }

                // residency: promote selected cold pages (and fault
                // disk-spilled ones) back before the gather — counts the
                // hit/miss and charges the simulated q8/disk transfers
                if budgeted {
                    for &tidx in sel.iter() {
                        self.store.ensure_hot(&mut self.pool, cache.pages[tidx].id)?;
                    }
                }

                // gather
                let tg = Instant::now();
                let cur_pos = cache.pos; // token being processed
                let mut row = 0usize; // tokens staged so far for this seq
                for &tidx in sel.iter() {
                    let e = cache.pages[tidx];
                    let is_last = tidx + 1 == cache.n_pages();
                    let n_slots = if is_last {
                        cur_pos - e.base_pos + 1
                    } else {
                        self.pool.filled(e.id)
                    };
                    if row + n_slots > t {
                        break; // budget full (policy bug guard)
                    }
                    let off = (i * t + row) * d_kv;
                    m.gather_bytes += self.pool.gather_rows(
                        e.id,
                        layer,
                        n_slots,
                        &mut self.stage_k[off..off + n_slots * d_kv],
                        &mut self.stage_v[off..off + n_slots * d_kv],
                    );
                    for sl in 0..n_slots {
                        let pos = e.base_pos + sl;
                        self.mask[i * t + row + sl] = 0.0;
                        self.dist[i * t + row + sl] = (cur_pos - pos) as f32;
                    }
                    row += n_slots;
                }
                m.gather_seconds += tg.elapsed().as_secs_f64();
            }

            // ---- score-driven readahead, once per decode step ----
            // every row's layer-0 scores are in by now; prefetch the disk
            // pages the current queries rank highest so later layers (and
            // the next step) fault from the cache instead of the segment
            if layer == 0 {
                self.store.readahead_tick();
            }

            // ---- fused attention + MLP ----
            let te = Instant::now();
            let out = self.rt.run(
                &post_art,
                Some(layer),
                &[
                    Input::F32(&self.hbuf[..b * d], &[b, d]),
                    Input::F32(&self.qbuf[..b * d_kv], &[b, n_head, hd]),
                    Input::F32(&self.stage_k[..b * t * d_kv], &[b, t, n_head, hd]),
                    Input::F32(&self.stage_v[..b * t * d_kv], &[b, t, n_head, hd]),
                    Input::F32(&self.mask[..b * t], &[b, t]),
                    Input::F32(&self.dist[..b * t], &[b, t]),
                ],
            )?;
            m.exec_seconds += te.elapsed().as_secs_f64();
            crate::runtime::literal_into(&out[0], &mut self.hbuf[..b * d])?;
            let mass = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let ent = out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;

            // ---- feedback to mass-driven policies + entropy tracking ----
            for (i, s) in seqs.iter_mut().enumerate() {
                if layer == self.n_layer - 1 {
                    s.last_entropy = ent[i];
                }
                if !s.policy.wants_feedback() {
                    continue;
                }
                // reconstruct the per-page mass from the staged layout
                let seq_ref: &Sequence = s;
                let cache = &seq_ref.cache;
                let mut fb: Vec<(usize, f32)> = Vec::new();
                let mut row = 0usize;
                // re-derive the selection from last_selected base positions
                let sel_bases = &seq_ref.last_selected[layer];
                for &bp in sel_bases {
                    if let Some(tidx) = cache.pages.iter().position(|e| e.base_pos == bp)
                    {
                        let is_last = tidx + 1 == cache.n_pages();
                        let n_slots = if is_last {
                            cache.pos - bp + 1
                        } else {
                            self.pool.filled(cache.pages[tidx].id)
                        };
                        if row + n_slots > t {
                            break;
                        }
                        let mslice = &mass[i * t + row..i * t + row + n_slots];
                        fb.push((bp, mslice.iter().sum()));
                        row += n_slots;
                    }
                }
                s.policy.feedback(layer, &fb);
            }
        }

        // ---- logits + sampling ----
        let log_art = self.art("logits", b).clone();
        let out = self.rt.run(
            &log_art,
            None,
            &[Input::F32(&self.hbuf[..b * d], &[b, d])],
        )?;
        crate::runtime::literal_into(&out[0], &mut self.logits_buf[..b * self.vocab])?;

        let mut sampled = Vec::with_capacity(n);
        let mut ent_sum = 0.0f32;
        for (i, s) in seqs.iter_mut().enumerate() {
            let row = &self.logits_buf[i * self.vocab..(i + 1) * self.vocab];
            let o = sample(row, sampling, rng);
            ent_sum += s.last_entropy.max(0.0);
            s.cache.commit_token();
            s.tokens.push(o.token);
            s.generated += 1;
            s.sum_logprob += o.logprob as f64;
            if o.token == EOS || s.generated >= s.max_new_tokens.max(1) {
                s.finished = true;
            }
            m.resident_tokens += s.cache.resident;
            sampled.push(o);
        }
        // ---- budget enforcement: bytes_in_use <= budget after every step ----
        if budgeted {
            self.store.unpin_all();
            self.store.enforce_budget(&mut self.pool);
        }
        self.collect_store_stats(m);
        let (hot, cold, disk) = self.store.tier_residency();
        if let Some(an) = self.analytics.as_deref_mut() {
            an.on_step_end(hot, cold, disk);
        }
        m.pages_hot = hot;
        m.pages_cold = cold;
        m.pages_disk = disk;
        m.kv_bytes_in_use = self.store.bytes_in_use(&self.pool);
        m.kv_budget_bytes = self.store.budget_bytes().unwrap_or(0);
        m.batch = n;
        m.entropy = ent_sum / n as f32;
        m.step_seconds += t0.elapsed().as_secs_f64();
        Ok(sampled)
    }

    /// Fold the store's stat counters accumulated since the last
    /// collection into `m` and mark them reported. Decode steps call this
    /// at step end; the coordinator calls it around out-of-band page
    /// movement (preemption snapshots, resume fault-in, cross-worker
    /// porting) so tier traffic is priced into virtual time exactly once.
    pub fn collect_store_stats(&mut self, m: &mut StepMetrics) {
        let st = self.store.stats.clone();
        let st0 = &self.stats_reported;
        m.store_hits += (st.hits - st0.hits) as usize;
        m.store_misses += (st.misses - st0.misses) as usize;
        m.demotions += (st.demotions - st0.demotions) as usize;
        m.promotions += (st.promotions - st0.promotions) as usize;
        m.spill_seconds += st.spill_seconds - st0.spill_seconds;
        m.spill_out_bytes += (st.spill_out_bytes - st0.spill_out_bytes) as usize;
        m.spill_in_bytes += (st.spill_in_bytes - st0.spill_in_bytes) as usize;
        m.disk_faults += (st.faults - st0.faults) as usize;
        m.readahead_hits += (st.readahead_hits - st0.readahead_hits) as usize;
        m.disk_seconds += st.disk_seconds - st0.disk_seconds;
        self.stats_reported = st;
    }

    /// Log-probability of `token` in batch row `row` under the logits of
    /// the most recent `decode_step` (perplexity evaluation).
    pub fn logprob_of(&self, row: usize, token: i32) -> f32 {
        let lg = &self.logits_buf[row * self.vocab..(row + 1) * self.vocab];
        sample::entropy_and_logprob(lg, 1.0, token as usize).1
    }

    /// Force-feed one known token (teacher forcing / decode-path prefill):
    /// identical to `decode_step` but ignores sampling and does not extend
    /// `tokens` (the pending token is consumed instead).
    pub fn absorb_step(
        &mut self,
        seqs: &mut [&mut Sequence],
        m: &mut StepMetrics,
    ) -> Result<Vec<f32>> {
        // run a decode step with greedy sampling but roll back the sampled
        // token when more prompt remains; returns per-row logprob-ready
        // logits max for tests.
        let mut rng = Rng::new(0);
        let outs = self.decode_step(seqs, Sampling::Greedy, &mut rng, m)?;
        let mut firsts = Vec::with_capacity(seqs.len());
        for (s, o) in seqs.iter_mut().zip(&outs) {
            // undo the speculative append if the prompt continues
            if s.pending() > 1 {
                s.tokens.pop();
                s.generated -= 1;
                s.finished = false;
            }
            firsts.push(o.entropy);
        }
        Ok(firsts)
    }

    /// Fill a sequence's cache with synthetic KV (latency benches where
    /// values don't matter — see DESIGN.md §2 long-context substitution).
    pub fn synthetic_fill(&mut self, seq: &mut Sequence, n_tokens: usize, rng: &mut Rng) {
        let d_kv = self.d_kv;
        let mut k = vec![0.0f32; d_kv];
        let mut v = vec![0.0f32; d_kv];
        for _ in 0..n_tokens {
            let (page, slot) = seq.cache.slot_for_next(&mut self.pool);
            for l in 0..self.n_layer {
                for x in k.iter_mut() {
                    *x = rng.normal() as f32 * 0.3;
                }
                for x in v.iter_mut() {
                    *x = rng.normal() as f32 * 0.3;
                }
                self.pool.write_token(page, slot, l, &k, &v);
            }
            seq.cache.commit_token();
            seq.tokens.push((rng.usize(255)) as i32);
        }
    }
}

/// Exact-attention oracle page ranking for the selection audit: score
/// every page by the max over its filled slots of `dot(q, k_slot)` and
/// return the indices (into `cache.pages`) of the top-`k`, sorted
/// ascending. Ties break toward earlier pages so the ranking is fully
/// deterministic. Cold pages are dequantized by `key_row`; disk-resident
/// slots read back as zeros — the audit deliberately charges the policy
/// for pages it let spill out of reach.
fn oracle_topk(
    q: &[f32],
    cache: &SeqCache,
    pool: &PagePool,
    layer: usize,
    k: usize,
) -> Vec<usize> {
    let mut scored: Vec<(usize, f32)> = Vec::with_capacity(cache.n_pages());
    for (tidx, e) in cache.pages.iter().enumerate() {
        let is_last = tidx + 1 == cache.n_pages();
        let n_slots = if is_last {
            cache.pos - e.base_pos + 1
        } else {
            pool.filled(e.id)
        };
        let mut best = f32::NEG_INFINITY;
        for sl in 0..n_slots {
            let krow = pool.key_row(e.id, layer, sl);
            let dot: f32 = q.iter().zip(krow.iter()).map(|(a, b)| a * b).sum();
            if dot > best {
                best = dot;
            }
        }
        scored.push((tidx, best));
    }
    scored.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    scored.truncate(k);
    let mut idx: Vec<usize> = scored.into_iter().map(|(i, _)| i).collect();
    idx.sort_unstable();
    idx
}
