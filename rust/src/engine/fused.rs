//! Fully-fused decode variant: the `decode_fused` artifact runs paper
//! Algorithm 1 *in-graph* — Pallas page scoring, top-K, gather and fused
//! attention inside one executable, with the KV cache and bounding-box
//! metadata round-tripping as whole tensors.
//!
//! This is the "Fused Kernel" ablation comparator for the Rust-orchestrated
//! path (`Engine::decode_step`). On CPU PJRT the tuple result forces a
//! host copy of the full cache every step, so the orchestrated path wins
//! here; on a real accelerator the cache would stay device-resident and
//! the trade-off inverts — see EXPERIMENTS.md §T2 notes.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::{ArtifactInfo, Input, Manifest, ModelRuntime};

pub struct FusedEngine {
    pub rt: ModelRuntime,
    art: ArtifactInfo,
    /// host mirrors of the device state [L, B, P*S, H, hd] / [L, B, P, 2, d]
    kcache: Vec<f32>,
    vcache: Vec<f32>,
    meta: Vec<f32>,
    pub n_pages: usize,
    pub k_pages: usize,
    pub page_size: usize,
    pub pos: usize,
    vocab: usize,
}

impl FusedEngine {
    pub fn new(artifacts_dir: &Path, model: &str) -> Result<FusedEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        Self::from_manifest(&manifest, model)
    }

    pub fn from_manifest(manifest: &Manifest, model: &str) -> Result<FusedEngine> {
        let rt = ModelRuntime::from_manifest(manifest, model)?;
        let art = rt
            .info
            .artifacts
            .iter()
            .find(|a| a.kind == "decode_fused")
            .context("model has no decode_fused artifact")?
            .clone();
        let p = art.n_pages.context("n_pages")?;
        let k = art.k_pages.context("k_pages")?;
        let s = art.page_size.context("page_size")?;
        let info = &rt.info;
        let (l, h, hd, d) = (info.n_layer, info.n_head, info.head_dim, info.d_model);
        let cache_len = l * p * s * h * hd;
        Ok(FusedEngine {
            kcache: vec![0.0; cache_len],
            vcache: vec![0.0; cache_len],
            meta: vec![0.0; l * p * 2 * d],
            n_pages: p,
            k_pages: k,
            page_size: s,
            pos: 0,
            vocab: info.vocab,
            art,
            rt,
        })
    }

    pub fn reset(&mut self) {
        self.kcache.fill(0.0);
        self.vcache.fill(0.0);
        self.meta.fill(0.0);
        self.pos = 0;
    }

    /// One fused decode step: feeds `token` at the current position and
    /// returns the next-token logits. Returns the selected page indices of
    /// the last layer as a byproduct (instrumentation parity with the
    /// orchestrated path).
    pub fn step(&mut self, token: i32) -> Result<(Vec<f32>, Vec<i32>)> {
        anyhow::ensure!(
            self.pos < self.n_pages * self.page_size,
            "fused cache full ({} tokens)",
            self.pos
        );
        let info = &self.rt.info;
        let (l, h, hd, d) = (info.n_layer, info.n_head, info.head_dim, info.d_model);
        let (p, s) = (self.n_pages, self.page_size);
        let out = self.rt.run(
            &self.art,
            None,
            &[
                Input::I32(&[token], &[1]),
                Input::I32(&[self.pos as i32], &[]),
                Input::F32(&self.kcache, &[l, 1, p * s, h, hd]),
                Input::F32(&self.vcache, &[l, 1, p * s, h, hd]),
                Input::F32(&self.meta, &[l, 1, p, 2, d]),
            ],
        )?;
        crate::runtime::literal_into(&out[0], &mut self.kcache)?;
        crate::runtime::literal_into(&out[1], &mut self.vcache)?;
        crate::runtime::literal_into(&out[2], &mut self.meta)?;
        let logits = out[3].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let sel_all = out[4].to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let sel_last = sel_all[sel_all.len() - self.k_pages..].to_vec();
        self.pos += 1;
        debug_assert_eq!(logits.len(), self.vocab);
        Ok((logits, sel_last))
    }

    /// Greedy generation helper (absorbs `prompt`, then generates).
    pub fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.reset();
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.step(t)?.0;
        }
        let mut out = Vec::new();
        for _ in 0..max_new {
            let next = argmax(&logits) as i32;
            if next == super::EOS {
                break;
            }
            out.push(next);
            logits = self.step(next)?.0;
        }
        Ok(out)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut bi = 0;
    let mut best = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best {
            best = x;
            bi = i;
        }
    }
    bi
}
