//! Experiment harness shared by `benches/` and `examples/paper_tables.rs`:
//! canned measurement routines for decode latency, task accuracy and
//! serving runs, so every table/figure regenerates through one code path.

use std::path::Path;

use anyhow::Result;

use crate::config::{KvDtype, ServingConfig};
use crate::engine::{Engine, Sampling};
use crate::kvcache::EvictionPolicyKind;
use crate::metrics::StepMetrics;
use crate::runtime::Manifest;
use crate::sparsity::PolicyKind;
use crate::util::rng::Rng;
use crate::util::stats::Samples;
use crate::workload::tasks::{self, Task};

/// Quick mode (env `TINYSERVE_BENCH_QUICK=1`): fewer steps/cases so the
/// full suite smoke-runs in minutes instead of hours.
pub fn quick() -> bool {
    std::env::var("TINYSERVE_BENCH_QUICK").ok().as_deref() == Some("1")
}

pub fn scale(n: usize) -> usize {
    if quick() {
        (n / 4).max(2)
    } else {
        n
    }
}

/// Smallest compiled decode budget that covers `ctx` tokens (fair budget
/// for FullCache — padding a 4096-token artifact to serve 512 tokens of
/// context would overstate every sparse policy's speedup).
pub fn fullcache_budget(info: &crate::runtime::ModelInfo, ctx: usize) -> usize {
    info.budget_variants()
        .into_iter()
        .find(|&b| b >= ctx)
        .unwrap_or_else(|| *info.budget_variants().last().unwrap())
}

#[derive(Debug, Clone)]
pub struct DecodeMeasurement {
    pub model: String,
    pub policy: PolicyKind,
    pub ctx: usize,
    pub budget: usize,
    pub batch: usize,
    pub ms_per_token: f64,
    pub ms_std: f64,
    pub tokens_per_s: f64,
    pub hit_rate: f64,
    pub gather_gb_per_s: f64,
    pub gather_bytes_per_step: f64,
    pub score_ms: f64,
    pub gather_ms: f64,
    pub exec_ms: f64,
    pub pool_bytes: usize,
    /// per-step traces (for Figures 6/7)
    pub trace_bytes: Vec<f64>,
    pub trace_hit: Vec<f64>,
}

/// Measure steady-state decode latency for (model, policy, ctx, budget):
/// fills the cache synthetically to `ctx`, then times `steps` decode steps.
pub fn measure_decode(
    manifest: &Manifest,
    model: &str,
    policy: PolicyKind,
    ctx: usize,
    budget: usize,
    batch: usize,
    steps: usize,
    kv_dtype: KvDtype,
) -> Result<DecodeMeasurement> {
    let cfg = ServingConfig {
        model: model.to_string(),
        policy,
        budget,
        max_batch: batch,
        kv_dtype,
        ..Default::default()
    };
    let mut engine = Engine::from_manifest(manifest, cfg)?;
    let mut rng = Rng::new(7);
    // build `batch` sequences with ctx resident tokens each
    let mut seqs: Vec<_> = (0..batch)
        .map(|_| {
            let mut s = engine.new_sequence_with_policy(policy);
            engine.synthetic_fill(&mut s, ctx.saturating_sub(1), &mut rng);
            s.tokens.push(1); // pending token
            s.max_new_tokens = usize::MAX / 2;
            s
        })
        .collect();
    engine.warmup()?;

    // warmup steps (compile + cache effects)
    for _ in 0..3.min(steps) {
        let mut m = StepMetrics::default();
        let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
        engine.decode_step(&mut refs, Sampling::Greedy, &mut rng, &mut m)?;
    }
    let mut lat = Samples::new();
    let mut agg = StepMetrics::default();
    let mut trace_bytes = Vec::new();
    let mut trace_hit = Vec::new();
    for _ in 0..steps {
        let mut m = StepMetrics::default();
        let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
        engine.decode_step(&mut refs, Sampling::Greedy, &mut rng, &mut m)?;
        lat.push(m.step_seconds / batch as f64);
        trace_bytes.push(m.gather_bytes as f64);
        trace_hit.push(m.hit_rate());
        agg.gather_bytes += m.gather_bytes;
        agg.pages_selected += m.pages_selected;
        agg.pages_reused += m.pages_reused;
        agg.score_seconds += m.score_seconds;
        agg.gather_seconds += m.gather_seconds;
        agg.exec_seconds += m.exec_seconds;
        agg.step_seconds += m.step_seconds;
    }
    let pool_bytes = engine.pool.bytes_in_use();
    for s in seqs.iter_mut() {
        engine.release(s);
    }
    let mean = lat.mean();
    Ok(DecodeMeasurement {
        model: model.to_string(),
        policy,
        ctx,
        budget,
        batch,
        ms_per_token: mean * 1e3,
        ms_std: lat.std() * 1e3,
        tokens_per_s: batch as f64 / (agg.step_seconds / steps as f64),
        hit_rate: agg.pages_reused as f64 / agg.pages_selected.max(1) as f64,
        gather_gb_per_s: agg.gather_bytes as f64 / agg.step_seconds.max(1e-12) / 1e9,
        gather_bytes_per_step: agg.gather_bytes as f64 / steps as f64,
        score_ms: agg.score_seconds / steps as f64 * 1e3,
        gather_ms: agg.gather_seconds / steps as f64 * 1e3,
        exec_ms: agg.exec_seconds / steps as f64 * 1e3,
        pool_bytes,
        trace_bytes,
        trace_hit,
    })
}

#[derive(Debug, Clone)]
pub struct AccuracyMeasurement {
    pub policy: PolicyKind,
    pub task: Task,
    pub exact: f64,
    pub char_acc: f64,
    pub n: usize,
    pub ms_per_token: f64,
    pub hit_rate: f64,
    /// top-k recall of bbox page selection vs the exact-attention oracle
    /// (`measure_accuracy_audited`); `None` when no audit ran
    pub selection_recall: Option<f64>,
}

/// Task accuracy for one policy on the trained model: real prefill + greedy
/// decode, exact-match on the known answer.
pub fn measure_accuracy(
    manifest: &Manifest,
    model: &str,
    policy: PolicyKind,
    task: Task,
    n_cases: usize,
    prompt_chars: usize,
    budget: usize,
    seed: u64,
) -> Result<AccuracyMeasurement> {
    measure_accuracy_audited(
        manifest,
        model,
        policy,
        task,
        n_cases,
        prompt_chars,
        budget,
        seed,
        0,
    )
}

/// `measure_accuracy` plus the selection-quality audit: every
/// `audit_every`-th decode step scores bbox selection against the
/// exact-attention oracle (0 = no audit, identical to `measure_accuracy`).
/// Kept separate because the oracle runs inside `decode_step` and would
/// otherwise pollute the latency columns of non-audited tables.
#[allow(clippy::too_many_arguments)]
pub fn measure_accuracy_audited(
    manifest: &Manifest,
    model: &str,
    policy: PolicyKind,
    task: Task,
    n_cases: usize,
    prompt_chars: usize,
    budget: usize,
    seed: u64,
    audit_every: usize,
) -> Result<AccuracyMeasurement> {
    let cfg = ServingConfig {
        model: model.to_string(),
        policy,
        budget,
        max_batch: 1,
        ..Default::default()
    };
    let mut engine = Engine::from_manifest(manifest, cfg)?;
    if audit_every > 0 {
        engine.enable_analytics(audit_every);
    }
    let mut rng = Rng::new(seed);
    let mut task_rng = Rng::new(seed ^ 0x5eed);
    let mut exact = 0usize;
    let mut char_acc = 0.0f64;
    let mut lat = Samples::new();
    let mut hits = 0.0f64;
    let mut hit_n = 0usize;
    for _ in 0..n_cases {
        let doc = tasks::make_doc(&mut task_rng, task, prompt_chars);
        let mut seq = engine.new_sequence_with_policy(policy);
        seq.tokens = tasks::encode_prompt(&doc.prompt);
        seq.max_new_tokens = doc.answer.len() + 4;
        let mut m = StepMetrics::default();
        engine.prefill(&mut seq, &mut m)?;
        while !seq.finished {
            let mut m = StepMetrics::default();
            let mut batch = [&mut seq];
            engine.decode_step(&mut batch, Sampling::Greedy, &mut rng, &mut m)?;
            lat.push(m.step_seconds);
            hits += m.hit_rate();
            hit_n += 1;
        }
        let gen = tasks::decode_ids(seq.generated_tokens());
        exact += tasks::answer_matches(&doc, &gen) as usize;
        char_acc += tasks::answer_char_accuracy(&doc, &gen);
        engine.release(&mut seq);
    }
    Ok(AccuracyMeasurement {
        policy,
        task,
        exact: exact as f64 / n_cases as f64,
        char_acc: char_acc / n_cases as f64,
        n: n_cases,
        ms_per_token: lat.mean() * 1e3,
        hit_rate: hits / hit_n.max(1) as f64,
        selection_recall: engine.analytics().and_then(|a| a.mean_recall()),
    })
}

/// One budgeted-store measurement case (Table 9 row). `Default` is the
/// classic two-tier sweep shape; set `spill_budget_bytes` (and optionally
/// `readahead_pages`) to exercise the three-tier cascade. The spill
/// directory is a process-unique temp slice (honouring
/// `TINYSERVE_SPILL_DIR`) cleaned up when the engine drops.
#[derive(Debug, Clone)]
pub struct EvictionCase {
    pub eviction: EvictionPolicyKind,
    /// None = unbounded baseline
    pub budget_bytes: Option<usize>,
    /// None = no disk tier (requires `budget_bytes` when set)
    pub spill_budget_bytes: Option<usize>,
    pub readahead_pages: usize,
    pub kv_dtype: KvDtype,
    pub n_cases: usize,
    pub prompt_chars: usize,
    pub budget_tokens: usize,
    pub seed: u64,
}

impl Default for EvictionCase {
    fn default() -> Self {
        EvictionCase {
            eviction: EvictionPolicyKind::QueryAware,
            budget_bytes: None,
            spill_budget_bytes: None,
            readahead_pages: 0,
            kv_dtype: KvDtype::F32,
            n_cases: 10,
            prompt_chars: 600,
            budget_tokens: 256,
            seed: 11,
        }
    }
}

/// One budgeted-store measurement (Table 9 row): task accuracy plus
/// residency behaviour under a KV byte budget and eviction policy.
#[derive(Debug, Clone)]
pub struct EvictionRun {
    pub eviction: EvictionPolicyKind,
    /// None = unbounded baseline
    pub budget_bytes: Option<usize>,
    pub accuracy: f64,
    pub residency_hit_rate: f64,
    pub demotions_per_token: f64,
    /// pool high-water mark at the hot rate (the unbounded footprint)
    pub bytes_peak_unbounded: usize,
    /// max post-step store bytes (cold pages at the q8 rate)
    pub max_bytes_in_use: usize,
    /// steps that ended above the budget (0 = invariant held)
    pub violations: u64,
    pub new_tokens: u64,
    // --- disk spill tier (zero without one) ---
    pub spill_out_bytes: u64,
    pub spill_in_bytes: u64,
    pub disk_faults: u64,
    pub readahead_hits: u64,
    /// max post-step disk-resident page count
    pub disk_pages_peak: usize,
    /// wall-clock of the measured run (perf-record trajectory input)
    pub run_seconds: f64,
}

/// Run the task-accuracy workload through the budgeted page store and
/// aggregate residency counters. With `budget_bytes = None` this doubles
/// as the unbounded baseline whose `bytes_peak_unbounded` anchors the
/// Table 9 budget sweep.
pub fn measure_eviction(
    manifest: &Manifest,
    model: &str,
    case: &EvictionCase,
) -> Result<EvictionRun> {
    let cfg = ServingConfig {
        model: model.to_string(),
        policy: PolicyKind::TinyServe,
        budget: case.budget_tokens,
        max_batch: 1,
        kv_dtype: case.kv_dtype,
        kv_budget_mb: case.budget_bytes.map(|b| b as f64 / 1e6),
        eviction: case.eviction,
        spill_budget_mb: case.spill_budget_bytes.map(|b| b as f64 / 1e6),
        readahead_pages: case.readahead_pages,
        ..Default::default()
    };
    let t_run = std::time::Instant::now();
    let mut engine = Engine::from_manifest(manifest, cfg)?;
    let mut rng = Rng::new(case.seed);
    let mut task_rng = Rng::new(case.seed ^ 0x5eed);
    let mut exact = 0usize;
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut demotions = 0u64;
    let mut new_tokens = 0u64;
    let mut max_bytes = 0usize;
    let mut violations = 0u64;
    let mut spill_out_bytes = 0u64;
    let mut spill_in_bytes = 0u64;
    let mut disk_faults = 0u64;
    let mut readahead_hits = 0u64;
    let mut disk_pages_peak = 0usize;
    for i in 0..case.n_cases {
        let task = Task::all()[i % Task::all().len()];
        let doc = tasks::make_doc(&mut task_rng, task, case.prompt_chars);
        let mut seq = engine.new_sequence();
        seq.tokens = tasks::encode_prompt(&doc.prompt);
        seq.max_new_tokens = doc.answer.len() + 4;
        let mut m = StepMetrics::default();
        engine.prefill(&mut seq, &mut m)?;
        engine.enforce_kv_budget();
        while !seq.finished {
            let mut m = StepMetrics::default();
            let mut batch = [&mut seq];
            engine.decode_step(&mut batch, Sampling::Greedy, &mut rng, &mut m)?;
            hits += m.store_hits as u64;
            misses += m.store_misses as u64;
            demotions += m.demotions as u64;
            new_tokens += 1;
            max_bytes = max_bytes.max(m.kv_bytes_in_use);
            if m.kv_budget_bytes > 0 && m.kv_bytes_in_use > m.kv_budget_bytes {
                violations += 1;
            }
            spill_out_bytes += m.spill_out_bytes as u64;
            spill_in_bytes += m.spill_in_bytes as u64;
            disk_faults += m.disk_faults as u64;
            readahead_hits += m.readahead_hits as u64;
            disk_pages_peak = disk_pages_peak.max(m.pages_disk);
        }
        let gen = tasks::decode_ids(seq.generated_tokens());
        exact += tasks::answer_matches(&doc, &gen) as usize;
        engine.release(&mut seq);
    }
    Ok(EvictionRun {
        eviction: case.eviction,
        budget_bytes: case.budget_bytes,
        accuracy: exact as f64 / case.n_cases.max(1) as f64,
        residency_hit_rate: if hits + misses > 0 {
            hits as f64 / (hits + misses) as f64
        } else {
            1.0
        },
        demotions_per_token: demotions as f64 / new_tokens.max(1) as f64,
        bytes_peak_unbounded: engine.pool.bytes_peak(),
        max_bytes_in_use: max_bytes,
        violations,
        new_tokens,
        spill_out_bytes,
        spill_in_bytes,
        disk_faults,
        readahead_hits,
        disk_pages_peak,
        run_seconds: t_run.elapsed().as_secs_f64(),
    })
}

/// One shared-prefix-cache serving measurement (Table 10 cell): a seeded
/// multi-tenant template workload served under `TimeModel::Modeled`, with
/// the prefix cache on (`prefix_cache_mb = Some(..)`) or off (`None`, the
/// baseline column). Modeled time prices the skipped prefill out of the
/// virtual clock, so TTFT deltas are deterministic from the seed.
#[derive(Debug, Clone)]
pub struct PrefixCase {
    pub n_requests: usize,
    pub n_tenants: usize,
    pub templates_per_tenant: usize,
    pub template_prob: f64,
    /// None = sharing off
    pub prefix_cache_mb: Option<f64>,
    pub prefix_min_pages: usize,
    pub seed: u64,
}

impl Default for PrefixCase {
    fn default() -> Self {
        PrefixCase {
            n_requests: 32,
            n_tenants: 4,
            templates_per_tenant: 2,
            template_prob: 0.6,
            prefix_cache_mb: Some(16.0),
            prefix_min_pages: 1,
            seed: 11,
        }
    }
}

/// One `measure_prefix` result (Table 10 row).
#[derive(Debug, Clone)]
pub struct PrefixRun {
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    /// total prompt tokens offered by the workload
    pub prompt_tokens: u64,
    /// prefill tokens skipped via shared-prefix adoption
    pub tokens_skipped: u64,
    /// KV bytes deduplicated by adoption (hot rate)
    pub bytes_deduped: u64,
    /// fraction of index lookups that adopted at least one page
    pub hit_rate: f64,
    pub pages_published: u64,
    pub pages_unpublished: u64,
    /// steps that ended above the KV byte budget (0 = invariant held)
    pub kv_budget_violations: u64,
    /// virtual wall-clock of the run (modeled seconds)
    pub wall_s: f64,
    pub accuracy: f64,
}

/// Serve a seeded multi-tenant template workload through the frontend and
/// aggregate the shared-prefix counters (Table 10).
pub fn measure_prefix(
    manifest: &Manifest,
    model: &str,
    case: &PrefixCase,
) -> Result<PrefixRun> {
    use crate::coordinator::{Frontend, ServeOptions, TimeModel};
    use crate::workload::{OpenLoopConfig, OpenLoopGen};

    let cfg = ServingConfig {
        model: model.to_string(),
        policy: PolicyKind::TinyServe,
        budget: 256,
        max_batch: 4,
        prefix_cache_mb: case.prefix_cache_mb,
        prefix_min_pages: case.prefix_min_pages,
        ..Default::default()
    };
    let mut engine = Engine::from_manifest(manifest, cfg)?;
    engine.warmup().ok();
    let trace = OpenLoopGen::new(OpenLoopConfig {
        n_requests: case.n_requests,
        rate_rps: 40.0,
        prompt_chars: (300, 700),
        new_tokens: (8, 24),
        // sessions off: prefix sharing, not the session store, must carry
        // the reuse (template requests arrive with `session = None`)
        session_reuse_prob: 0.0,
        n_sessions: 0,
        n_tenants: case.n_tenants,
        templates_per_tenant: case.templates_per_tenant,
        template_prob: case.template_prob,
        seed: case.seed,
        ..Default::default()
    })
    .collect_all();
    let prompt_tokens: u64 = trace.iter().map(|r| r.prompt.len() as u64).sum();
    let opts = ServeOptions {
        time_model: TimeModel::Modeled,
        seed: case.seed,
        ..Default::default()
    };
    let mut plugins = crate::plugins::Pipeline::new();
    let mut fe = Frontend::builder().options(opts).build(&mut engine, &mut plugins);
    for req in &trace {
        fe.submit(req.clone());
    }
    while fe.has_work() {
        fe.step()?;
    }
    let r = fe.into_report();
    Ok(PrefixRun {
        ttft_p50_ms: r.metrics.request_ttft.p50() * 1e3,
        ttft_p99_ms: r.metrics.request_ttft.p99() * 1e3,
        prompt_tokens,
        tokens_skipped: r.prefix_stats.tokens_skipped,
        bytes_deduped: r.prefix_stats.bytes_deduped,
        hit_rate: r.prefix_stats.hit_rate(),
        pages_published: r.prefix_stats.pages_published,
        pages_unpublished: r.prefix_stats.pages_unpublished,
        kv_budget_violations: r.metrics.budget_violations,
        wall_s: r.wall_s,
        accuracy: r.accuracy,
    })
}

/// Perplexity of the trained model on held-out task docs under a policy —
/// the Table 7 "PPL" column (teacher-forcing through the serving path).
pub fn measure_ppl(
    manifest: &Manifest,
    model: &str,
    policy: PolicyKind,
    page_size: usize,
    budget: usize,
    n_docs: usize,
    prompt_chars: usize,
) -> Result<f64> {
    let cfg = ServingConfig {
        model: model.to_string(),
        policy,
        page_size,
        budget,
        max_batch: 1,
        ..Default::default()
    };
    let mut engine = Engine::from_manifest(manifest, cfg)?;
    let mut task_rng = Rng::new(99);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for i in 0..n_docs {
        let task = Task::all()[i % Task::all().len()];
        let doc = tasks::make_doc(&mut task_rng, task, prompt_chars);
        // teacher-forced NLL of the answer continuation through the full
        // serving path (prefill + per-token decode under the policy)
        let mut m = StepMetrics::default();
        let mut rng = Rng::new(3);
        let mut seq = engine.new_sequence_with_policy(policy);
        seq.tokens = tasks::encode_prompt(&doc.prompt);
        seq.max_new_tokens = usize::MAX / 2;
        engine.prefill(&mut seq, &mut m)?;
        for &want in tasks::encode(&doc.answer).iter() {
            let mut batch = [&mut seq];
            engine.decode_step(&mut batch, Sampling::Greedy, &mut rng, &mut m)?;
            nll -= engine.logprob_of(0, want) as f64;
            count += 1;
            // teacher-force the true token for the next step
            *seq.tokens.last_mut().unwrap() = want;
            seq.finished = false;
        }
        engine.release(&mut seq);
    }
    Ok((nll / count.max(1) as f64).exp())
}
