//! Workload generation: Poisson request arrivals, task documents, the
//! multi-user trace used by the serving experiments (paper §4.4.1:
//! "512-2048 concurrent requests, Poisson arrivals, mean inter-arrival
//! 50ms, 100-500 generated tokens"), and the open-loop live generator
//! (`openloop`) that feeds the frontend against its virtual clock instead
//! of pre-materializing a `Vec<Request>`. `client` is the closed-loop
//! counterpart: N concurrent TCP connections driving the network front
//! door, each waiting for its previous request before thinking and
//! submitting the next.

pub mod client;
pub mod openloop;
pub mod tasks;

use crate::util::rng::Rng;
pub use client::{run_closed_loop, ClientConfig, ClientStats};
pub use openloop::{ArrivalProcess, LoadShape, OpenLoopConfig, OpenLoopGen};
pub use tasks::{make_doc, Doc, Task};

/// A live arrival stream the serving frontend pulls from between
/// scheduling rounds — the open-loop alternative to submitting a
/// pre-materialized trace. Implementations must yield requests in
/// non-decreasing `arrival_s` order and be deterministic from their seed.
pub trait RequestSource {
    /// Virtual time of the next arrival, or None when the source is
    /// exhausted. Must not advance the source.
    fn peek_arrival_s(&self) -> Option<f64>;

    /// Remove and return every request with `arrival_s <= now`, in
    /// arrival order.
    fn take_due(&mut self, now: f64) -> Vec<Request>;
}

/// SLO class of a request. Tiers order the scheduler end to end: the
/// batcher's EDF key leads with the tier rank, and (with preemption
/// enabled) a waiting higher-tier request may pause a running lower-tier
/// one at the commit seam. `Batch` is the default — single-tier traces
/// schedule identically to the pre-tier scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SloTier {
    /// human-in-the-loop: tightest TTFT target, preempts lower tiers
    Interactive,
    /// default tier for bulk serving traffic
    #[default]
    Batch,
    /// best-effort offline work: preempted first, loosest targets
    Background,
}

impl SloTier {
    /// Scheduling rank: lower = more urgent. Leads the EDF key.
    pub fn rank(&self) -> u8 {
        match self {
            SloTier::Interactive => 0,
            SloTier::Batch => 1,
            SloTier::Background => 2,
        }
    }

    /// Per-tier time-to-first-token target (seconds). The preemption
    /// policy fires when a queued request of this tier has waited half
    /// its target and only lower-tier work occupies the active set.
    pub fn ttft_target_s(&self) -> f64 {
        match self {
            SloTier::Interactive => 0.25,
            SloTier::Batch => 2.0,
            SloTier::Background => 10.0,
        }
    }

    /// Default SLO deadline for the tier, relative to arrival (ms).
    pub fn deadline_ms(&self) -> f64 {
        match self {
            SloTier::Interactive => 1_000.0,
            SloTier::Batch => 10_000.0,
            SloTier::Background => 60_000.0,
        }
    }

    pub fn parse(s: &str) -> Option<SloTier> {
        match s {
            "interactive" => Some(SloTier::Interactive),
            "batch" => Some(SloTier::Batch),
            "background" => Some(SloTier::Background),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SloTier::Interactive => "interactive",
            SloTier::Batch => "batch",
            SloTier::Background => "background",
        }
    }

    pub fn all() -> [SloTier; 3] {
        [SloTier::Interactive, SloTier::Batch, SloTier::Background]
    }

    pub fn names() -> Vec<&'static str> {
        vec!["interactive", "batch", "background"]
    }
}

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// seconds since trace start
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// None = fresh conversation; Some(id) = follow-up in a session
    pub session: Option<u64>,
    pub task: Option<Task>,
    pub answer: Option<String>,
    /// SLO deadline relative to arrival, in milliseconds. The frontend
    /// sheds the request at admission or aborts it mid-decode (releasing
    /// its KV pages) once the deadline elapses; None = no deadline.
    pub deadline_ms: Option<f64>,
    /// SLO class; `Batch` unless the workload or client says otherwise.
    pub tier: SloTier,
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    /// mean inter-arrival seconds (paper: 0.050)
    pub mean_interarrival_s: f64,
    pub prompt_chars: (usize, usize),
    pub new_tokens: (usize, usize),
    /// fraction of requests that continue an existing session
    pub session_reuse_prob: f64,
    /// number of distinct sessions (zipf-popular)
    pub n_sessions: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 64,
            mean_interarrival_s: 0.05,
            prompt_chars: (200, 800),
            new_tokens: (20, 60),
            session_reuse_prob: 0.3,
            n_sessions: 16,
            seed: 42,
        }
    }
}

/// Generate a full arrival trace (deterministic from the seed). Session
/// requests reuse a per-session shared context with per-request questions,
/// so consecutive requests of one session share a long prompt prefix —
/// the substrate for cross-request cache reuse measurements.
pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    let all = Task::all();
    // pre-build session contexts
    let sess_chars = (cfg.prompt_chars.0 + cfg.prompt_chars.1) / 2;
    let sessions: Vec<tasks::SessionDoc> = (0..cfg.n_sessions)
        .map(|_| tasks::kvrecall_session(&mut rng, sess_chars, 8))
        .collect();
    for id in 0..cfg.n_requests as u64 {
        t += rng.exponential(1.0 / cfg.mean_interarrival_s.max(1e-9));
        let session = if rng.bool(cfg.session_reuse_prob) && cfg.n_sessions > 0 {
            Some(rng.zipf(cfg.n_sessions, 1.1) as u64)
        } else {
            None
        };
        let (doc, task) = match session {
            Some(sid) => {
                let q = rng.usize(8);
                (sessions[sid as usize].question(q), Task::KvRecall)
            }
            None => {
                let task = *rng.choice(all);
                let chars = rng
                    .range(cfg.prompt_chars.0 as u64, cfg.prompt_chars.1 as u64 + 1)
                    as usize;
                (make_doc(&mut rng, task, chars), task)
            }
        };
        out.push(Request {
            id,
            arrival_s: t,
            prompt: tasks::encode_prompt(&doc.prompt),
            max_new_tokens: rng
                .range(cfg.new_tokens.0 as u64, cfg.new_tokens.1 as u64 + 1)
                as usize,
            session,
            task: Some(task),
            answer: Some(doc.answer),
            deadline_ms: None,
            tier: SloTier::default(),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a.len(), 64);
        assert_eq!(a[10].prompt, b[10].prompt);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn interarrival_mean_matches() {
        let cfg = TraceConfig {
            n_requests: 5000,
            mean_interarrival_s: 0.05,
            ..Default::default()
        };
        let t = generate_trace(&cfg);
        let total = t.last().unwrap().arrival_s;
        let mean = total / 5000.0;
        assert!((mean - 0.05).abs() < 0.005, "{mean}");
    }

    #[test]
    fn sessions_are_zipf_skewed() {
        let cfg = TraceConfig {
            n_requests: 2000,
            session_reuse_prob: 1.0,
            n_sessions: 10,
            ..Default::default()
        };
        let t = generate_trace(&cfg);
        let mut counts = vec![0usize; 10];
        for r in &t {
            counts[r.session.unwrap() as usize] += 1;
        }
        assert!(counts[0] > counts[9]);
    }

    #[test]
    fn tier_ranks_order_and_parse_roundtrips() {
        assert!(SloTier::Interactive.rank() < SloTier::Batch.rank());
        assert!(SloTier::Batch.rank() < SloTier::Background.rank());
        assert_eq!(SloTier::default(), SloTier::Batch);
        for t in SloTier::all() {
            assert_eq!(SloTier::parse(t.name()), Some(t));
            assert!(t.ttft_target_s() > 0.0 && t.deadline_ms() > 0.0);
        }
        assert_eq!(SloTier::parse("bogus"), None);
        assert!(
            SloTier::Interactive.ttft_target_s() < SloTier::Background.ttft_target_s()
        );
    }

    #[test]
    fn bounds_respected() {
        let cfg = TraceConfig::default();
        for r in generate_trace(&cfg) {
            assert!(r.max_new_tokens >= 20 && r.max_new_tokens <= 60);
            assert!(r.prompt.len() >= 150); // BOS + >=200 chars, some shrink
        }
    }
}
