//! Closed-loop network client generator for the TCP front door.
//!
//! Open-loop sources (`openloop`) push arrivals at the frontend on a
//! schedule regardless of completions; a *closed-loop* client is the
//! opposite discipline: each connection keeps at most one request in
//! flight, waits for its terminal event, thinks for an exponentially
//! distributed pause, then submits the next. `N` concurrent connections
//! give a classic interactive-user load where offered rate self-adjusts
//! to server speed — the natural workload for exercising admission
//! backpressure (a deferred submit is retried after the server's hint,
//! not silently queued).
//!
//! Prompts come from the same seeded task-document generator as every
//! other workload (`tasks::make_doc`), with each connection forking its
//! own RNG stream, so a `(seed, conns, requests_per_conn)` triple names
//! one reproducible request population. With a single connection and
//! zero think time the server's virtual clock makes the whole exchange
//! deterministic — CI byte-diffs a seeded loopback run's server trace on
//! exactly this setup.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::server::proto::{ClientMsg, ServerMsg, PROTO_SCHEMA};
use crate::util::rng::Rng;

use super::tasks::{self, Task};
use super::SloTier;

/// Closed-loop load shape: `conns` connections, each submitting
/// `requests_per_conn` seeded task documents one at a time.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// server address, e.g. `127.0.0.1:4460`
    pub addr: String,
    pub conns: usize,
    pub requests_per_conn: usize,
    /// approximate prompt length fed to the task generator
    pub prompt_chars: usize,
    pub max_new_tokens: usize,
    /// mean think time between a terminal event and the next submit
    /// (exponential; 0 disables thinking — required for determinism runs)
    pub think_ms: f64,
    pub seed: u64,
    /// per-request SLO passed through to the server (None = no deadline)
    pub deadline_ms: Option<f64>,
    /// SLO tier attached to every submit (None = omit the field; the
    /// server schedules it as `batch`, the wire default)
    pub tier: Option<SloTier>,
    /// give up on a request after this many `retry` bounces
    pub max_retries: usize,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            addr: "127.0.0.1:4460".into(),
            conns: 2,
            requests_per_conn: 4,
            prompt_chars: 400,
            max_new_tokens: 16,
            think_ms: 0.0,
            seed: 42,
            deadline_ms: None,
            tier: None,
            max_retries: 8,
        }
    }
}

/// Aggregated request outcomes across every connection.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ClientStats {
    pub submitted: u64,
    pub finished: u64,
    pub cancelled: u64,
    pub expired: u64,
    /// `retry` bounces honoured (defer backpressure)
    pub retried: u64,
    /// requests abandoned on a typed `overload` (or retry exhaustion)
    pub overloaded: u64,
    /// connections refused at accept (`max_conns` shed)
    pub conns_shed: u64,
    pub tokens: u64,
    /// protocol `error` lines received
    pub errors: u64,
}

impl ClientStats {
    fn merge(&mut self, o: &ClientStats) {
        self.submitted += o.submitted;
        self.finished += o.finished;
        self.cancelled += o.cancelled;
        self.expired += o.expired;
        self.retried += o.retried;
        self.overloaded += o.overloaded;
        self.conns_shed += o.conns_shed;
        self.tokens += o.tokens;
        self.errors += o.errors;
    }
}

/// Drive the full closed loop: one thread per connection, forked RNG
/// streams, merged stats. Fails on I/O errors or protocol violations —
/// typed backpressure (`retry`/`overload`) is an expected outcome, not an
/// error.
pub fn run_closed_loop(cfg: &ClientConfig) -> Result<ClientStats> {
    let mut rng = Rng::new(cfg.seed);
    let mut handles = Vec::new();
    for c in 0..cfg.conns.max(1) {
        let cfg = cfg.clone();
        let conn_rng = rng.fork(c as u64);
        handles.push(
            std::thread::Builder::new()
                .name(format!("tinyserve-client-{c}"))
                .spawn(move || run_conn(&cfg, conn_rng))
                .context("spawn client thread")?,
        );
    }
    let mut stats = ClientStats::default();
    let mut first_err = None;
    for h in handles {
        match h.join().expect("client thread panicked") {
            Ok(s) => stats.merge(&s),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

fn run_conn(cfg: &ClientConfig, mut rng: Rng) -> Result<ClientStats> {
    let mut stats = ClientStats::default();
    let mut stream =
        TcpStream::connect(&cfg.addr).with_context(|| format!("connect {}", cfg.addr))?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);

    match read_msg(&mut reader)? {
        Some(ServerMsg::Hello { schema }) if schema == PROTO_SCHEMA => {}
        Some(ServerMsg::Hello { schema }) => {
            bail!("server speaks schema {schema}, client speaks {PROTO_SCHEMA}")
        }
        other => bail!("expected hello, got {other:?}"),
    }
    // an over-cap server sheds right after hello: overload then close
    // (peek by submitting nothing yet would block, so the shed check rides
    // on the first request's read loop below)

    for r in 0..cfg.requests_per_conn {
        if cfg.think_ms > 0.0 && r > 0 {
            let pause = rng.exponential(1.0 / cfg.think_ms).min(cfg.think_ms * 10.0);
            std::thread::sleep(std::time::Duration::from_micros((pause * 1000.0) as u64));
        }
        let task = *rng.choice(Task::all());
        let doc = tasks::make_doc(&mut rng, task, cfg.prompt_chars);
        let submit = ClientMsg::Submit {
            id: r as u64,
            prompt: doc.prompt,
            max_new: cfg.max_new_tokens,
            session: None,
            deadline_ms: cfg.deadline_ms,
            tier: cfg.tier,
        };
        let mut attempts = 0usize;
        'request: loop {
            stream
                .write_all(format!("{}\n", submit.to_line()).as_bytes())
                .context("write submit")?;
            stats.submitted += 1;
            loop {
                let Some(msg) = read_msg(&mut reader)? else {
                    // shed at accept shows up here: the overload line may
                    // have raced the close, so a bare EOF also counts
                    stats.conns_shed += 1;
                    return Ok(stats);
                };
                match msg {
                    ServerMsg::Admitted { .. }
                    | ServerMsg::Deferred { .. }
                    // non-terminal scheduling notices: the request is
                    // paused/resumed server-side, tokens keep flowing after
                    | ServerMsg::Preempted { .. }
                    | ServerMsg::Resumed { .. } => {}
                    ServerMsg::Token { .. } => stats.tokens += 1,
                    ServerMsg::Finished { .. } => {
                        stats.finished += 1;
                        break 'request;
                    }
                    ServerMsg::Cancelled { .. } => {
                        stats.cancelled += 1;
                        break 'request;
                    }
                    ServerMsg::Expired { .. } => {
                        stats.expired += 1;
                        break 'request;
                    }
                    ServerMsg::Retry { retry_after_ms, .. } => {
                        attempts += 1;
                        if attempts > cfg.max_retries {
                            stats.overloaded += 1;
                            break 'request;
                        }
                        stats.retried += 1;
                        std::thread::sleep(std::time::Duration::from_micros(
                            (retry_after_ms * 1000.0) as u64,
                        ));
                        continue 'request;
                    }
                    ServerMsg::Overload { id: None, .. } => {
                        // connection-level shed (max_conns)
                        stats.conns_shed += 1;
                        return Ok(stats);
                    }
                    ServerMsg::Overload { .. } => {
                        stats.overloaded += 1;
                        break 'request;
                    }
                    ServerMsg::Error { reason } => {
                        stats.errors += 1;
                        bail!("protocol error from server: {reason}");
                    }
                    ServerMsg::Hello { .. } => bail!("unexpected second hello"),
                }
            }
        }
    }

    stream
        .write_all(format!("{}\n", ClientMsg::Close.to_line()).as_bytes())
        .context("write close")?;
    // drain to EOF so the server's graceful close is observed
    while read_msg(&mut reader)?.is_some() {}
    Ok(stats)
}

fn read_msg(reader: &mut BufReader<TcpStream>) -> Result<Option<ServerMsg>> {
    let mut line = String::new();
    let n = reader.read_line(&mut line).context("read server line")?;
    if n == 0 {
        return Ok(None);
    }
    ServerMsg::parse(line.trim_end())
        .map(Some)
        .map_err(|e| anyhow::anyhow!("bad server line {line:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::shed::AdmissionConfig;
    use crate::server::{MockBackend, Server, ServerConfig};

    fn serve_mock(
        cfg: ServerConfig,
    ) -> (String, std::thread::JoinHandle<(crate::server::ServerStats, MockBackend)>)
    {
        let server = Server::bind(cfg).expect("bind loopback");
        let addr = server.local_addr().expect("addr").to_string();
        let handle = std::thread::spawn(move || {
            let mut backend = MockBackend::new();
            let stats = server.run(&mut backend).expect("server run");
            (stats, backend)
        });
        (addr, handle)
    }

    #[test]
    fn closed_loop_finishes_every_request_against_a_mock_server() {
        let (addr, server) =
            serve_mock(ServerConfig { exit_when_idle: true, ..ServerConfig::default() });
        let cfg = ClientConfig {
            addr,
            conns: 2,
            requests_per_conn: 3,
            prompt_chars: 120,
            max_new_tokens: 4,
            ..ClientConfig::default()
        };
        let stats = run_closed_loop(&cfg).expect("client run");
        assert_eq!(stats.finished, 6, "{stats:?}");
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.tokens, 24, "4 tokens per request stream back");
        assert_eq!(stats.overloaded + stats.errors, 0, "{stats:?}");
        let (server_stats, backend) = server.join().unwrap();
        assert_eq!(server_stats.submitted, 6);
        assert_eq!(backend.kv_bytes_in_use(), 0);
    }

    #[test]
    fn same_seed_same_request_population() {
        // the prompt/task stream is a pure function of (seed, conn index,
        // request index) — independent of server timing
        let docs = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut conn_rng = rng.fork(0);
            let mut out = Vec::new();
            for _ in 0..4 {
                let task = *conn_rng.choice(Task::all());
                out.push(tasks::make_doc(&mut conn_rng, task, 200).prompt);
            }
            out
        };
        assert_eq!(docs(7), docs(7));
        assert_ne!(docs(7), docs(8));
    }
}
