//! Open-loop live workload generator: a seeded arrival *process* the
//! frontend polls against its virtual clock, instead of a pre-materialized
//! trace vector.
//!
//! Open-loop means arrivals do not wait for completions — exactly the
//! §4.4.1 serving regime ("Poisson arrivals, mean inter-arrival 50ms") and
//! the load model under which admission ordering (EDF) and dispatch
//! policy actually matter: when the server falls behind, the queue grows
//! and scheduling decides who pays.
//!
//! Two interarrival processes at a common offered rate:
//!  * `Poisson` — exponential interarrivals (CV = 1), the paper's default;
//!  * `Gamma { shape }` — gamma-distributed unit-mean interarrivals; shape
//!    < 1 is burstier than Poisson (CV = 1/sqrt(shape)), shape > 1
//!    smoother. The burstiness knob at a fixed rate.
//!
//! The offered rate itself is modulated by a `LoadShape` phase curve —
//! warm-up ramps, recurring bursts, or a diurnal sinusoid — so a single
//! seeded generator covers the workload shapes a real frontend sees over
//! a day. Generation is deterministic from the seed: two generators with
//! the same config yield bit-identical request streams (the determinism
//! battery and the CI double-run diff both pin this).

use crate::util::rng::Rng;

use super::tasks::{self, Task};
use super::{Request, RequestSource, SloTier};

/// Interarrival process at a fixed offered rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// exponential interarrivals (memoryless, CV = 1)
    Poisson,
    /// gamma(shape, 1/shape) unit-mean interarrivals scaled by the rate;
    /// shape < 1 => bursty (CV > 1), shape > 1 => smoother than Poisson
    Gamma { shape: f64 },
}

impl ArrivalProcess {
    pub fn parse(s: &str) -> Option<ArrivalProcess> {
        match s {
            "poisson" => Some(ArrivalProcess::Poisson),
            "gamma" => Some(ArrivalProcess::Gamma { shape: 0.35 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Gamma { .. } => "gamma",
        }
    }

    pub fn names() -> Vec<&'static str> {
        vec!["poisson", "gamma"]
    }
}

/// Rate modulation over virtual time (multiplies the base rate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LoadShape {
    /// constant offered rate
    Steady,
    /// linear warm-up from 10% to 100% of the base rate over `ramp_s`,
    /// then steady
    Ramp { ramp_s: f64 },
    /// recurring bursts: every `period_s`, the first `burst_s` run at
    /// `factor` times the base rate
    Bursts { period_s: f64, burst_s: f64, factor: f64 },
    /// sinusoidal day curve: rate * (1 + amplitude * sin(2 pi t / period)),
    /// floored at 5% of base
    Diurnal { period_s: f64, amplitude: f64 },
}

impl LoadShape {
    pub fn parse(s: &str) -> Option<LoadShape> {
        match s {
            "steady" => Some(LoadShape::Steady),
            "ramp" => Some(LoadShape::Ramp { ramp_s: 2.0 }),
            "burst" | "bursts" => {
                Some(LoadShape::Bursts { period_s: 2.0, burst_s: 0.4, factor: 4.0 })
            }
            "diurnal" => Some(LoadShape::Diurnal { period_s: 8.0, amplitude: 0.8 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LoadShape::Steady => "steady",
            LoadShape::Ramp { .. } => "ramp",
            LoadShape::Bursts { .. } => "burst",
            LoadShape::Diurnal { .. } => "diurnal",
        }
    }

    pub fn names() -> Vec<&'static str> {
        vec!["steady", "ramp", "burst", "diurnal"]
    }
}

/// Configuration of the open-loop generator. Prompt/session/task knobs
/// mirror `TraceConfig`; the arrival side replaces a fixed mean
/// interarrival with (rate, process, shape).
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// total requests to emit (the generator then reports exhausted)
    pub n_requests: usize,
    /// base offered rate, requests/second (paper: 20/s <=> 50ms mean)
    pub rate_rps: f64,
    pub process: ArrivalProcess,
    pub shape: LoadShape,
    pub prompt_chars: (usize, usize),
    pub new_tokens: (usize, usize),
    /// fraction of requests that continue an existing session
    pub session_reuse_prob: f64,
    /// number of distinct sessions (zipf-popular)
    pub n_sessions: usize,
    /// SLO attached to every `deadline_every`-th request (None = no SLOs)
    pub deadline_ms: Option<f64>,
    /// 1 = every request carries the SLO, 4 = every 4th, 0 treated as 1
    pub deadline_every: usize,
    /// fraction of requests in the interactive SLO tier (0.0 = tier mix
    /// off; with both tier knobs at zero the RNG stream is bit-identical
    /// to the pre-tier generator and every request is `SloTier::Batch`)
    pub tier_interactive: f64,
    /// fraction of requests in the background SLO tier
    pub tier_background: f64,
    /// multi-tenant template mix for the shared-prefix cache: number of
    /// tenants (zipf-popular, like sessions). 0 = template mix off — with
    /// all three template knobs zeroed the RNG stream is bit-identical to
    /// the pre-template generator.
    pub n_tenants: usize,
    /// distinct prompt templates per tenant (0 treated as 1)
    pub templates_per_tenant: usize,
    /// fraction of non-session requests drawn from a tenant template:
    /// shared template preamble + a paraphrased question tail. Template
    /// requests carry `session = None`, so only page-granular prefix
    /// sharing (never the session store) can reuse their KV.
    pub template_prob: f64,
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        OpenLoopConfig {
            n_requests: 64,
            rate_rps: 20.0,
            process: ArrivalProcess::Poisson,
            shape: LoadShape::Steady,
            prompt_chars: (200, 800),
            new_tokens: (20, 60),
            session_reuse_prob: 0.3,
            n_sessions: 16,
            deadline_ms: None,
            deadline_every: 1,
            tier_interactive: 0.0,
            tier_background: 0.0,
            n_tenants: 0,
            templates_per_tenant: 0,
            template_prob: 0.0,
            seed: 42,
        }
    }
}

/// Seeded open-loop request generator (see module docs). Implements
/// [`RequestSource`], so `Frontend::set_source` pulls arrivals from it
/// live; `collect_all` materializes the remainder as a trace for callers
/// that still want a `Vec<Request>`.
pub struct OpenLoopGen {
    cfg: OpenLoopConfig,
    rng: Rng,
    sessions: Vec<tasks::SessionDoc>,
    /// tenant prompt templates (n_tenants x templates_per_tenant, row per
    /// tenant); empty when the template mix is off
    templates: Vec<tasks::SessionDoc>,
    /// virtual time of the most recently generated arrival
    t: f64,
    emitted: u64,
    /// pre-generated next request (so peek is exact)
    next: Option<Request>,
}

impl OpenLoopGen {
    pub fn new(cfg: OpenLoopConfig) -> OpenLoopGen {
        let mut rng = Rng::new(cfg.seed);
        let sess_chars = (cfg.prompt_chars.0 + cfg.prompt_chars.1) / 2;
        let sessions: Vec<tasks::SessionDoc> = (0..cfg.n_sessions)
            .map(|_| tasks::kvrecall_session(&mut rng, sess_chars, 8))
            .collect();
        // templates are drawn only when the mix is on, so off-configs keep
        // the construction RNG stream (and every later draw) bit-identical
        let templates: Vec<tasks::SessionDoc> = if cfg.n_tenants > 0 {
            let per = cfg.templates_per_tenant.max(1);
            (0..cfg.n_tenants * per)
                .map(|_| tasks::kvrecall_session(&mut rng, sess_chars, 8))
                .collect()
        } else {
            Vec::new()
        };
        let mut g = OpenLoopGen {
            cfg,
            rng,
            sessions,
            templates,
            t: 0.0,
            emitted: 0,
            next: None,
        };
        g.next = g.gen_next();
        g
    }

    /// Offered rate at virtual time `t` (base rate through the phase
    /// curve).
    pub fn rate_at(&self, t: f64) -> f64 {
        let base = self.cfg.rate_rps;
        match self.cfg.shape {
            LoadShape::Steady => base,
            LoadShape::Ramp { ramp_s } => {
                if ramp_s <= 0.0 || t >= ramp_s {
                    base
                } else {
                    base * (0.1 + 0.9 * t / ramp_s)
                }
            }
            LoadShape::Bursts { period_s, burst_s, factor } => {
                if period_s <= 0.0 {
                    return base;
                }
                let phase = t % period_s;
                if phase < burst_s {
                    base * factor
                } else {
                    base
                }
            }
            LoadShape::Diurnal { period_s, amplitude } => {
                if period_s <= 0.0 {
                    return base;
                }
                let s = (2.0 * std::f64::consts::PI * t / period_s).sin();
                (base * (1.0 + amplitude * s)).max(base * 0.05)
            }
        }
    }

    /// How many requests the generator has handed out so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Materialize every remaining request as a trace (arrival order).
    pub fn collect_all(mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.pop() {
            out.push(r);
        }
        out
    }

    fn pop(&mut self) -> Option<Request> {
        let r = self.next.take()?;
        self.next = self.gen_next();
        Some(r)
    }

    fn gen_next(&mut self) -> Option<Request> {
        if self.emitted >= self.cfg.n_requests as u64 {
            return None;
        }
        // unit-mean interarrival draw, scaled by the instantaneous rate at
        // the previous arrival (piecewise-constant thinning approximation:
        // exact for Steady, and phase-accurate whenever interarrivals are
        // short against the phase period, which serving loads are)
        let unit = match self.cfg.process {
            ArrivalProcess::Poisson => self.rng.exponential(1.0),
            ArrivalProcess::Gamma { shape } => {
                let k = shape.max(1e-3);
                self.rng.gamma(k, 1.0 / k)
            }
        };
        let rate = self.rate_at(self.t).max(1e-9);
        self.t += unit / rate;
        let id = self.emitted;
        let session = if self.rng.bool(self.cfg.session_reuse_prob)
            && self.cfg.n_sessions > 0
        {
            Some(self.rng.zipf(self.cfg.n_sessions, 1.1) as u64)
        } else {
            None
        };
        let all = Task::all();
        let (doc, task) = match session {
            Some(sid) => {
                let q = self.rng.usize(8);
                (self.sessions[sid as usize].question(q), Task::KvRecall)
            }
            // template draw is short-circuited on `templates.is_empty()`
            // BEFORE any RNG is consumed, so zeroed template knobs keep
            // the historical stream bit-identical (same contract as the
            // tier knobs below)
            None if !self.templates.is_empty()
                && self.rng.bool(self.cfg.template_prob) =>
            {
                let per = self.cfg.templates_per_tenant.max(1);
                let tenant = self.rng.zipf(self.cfg.n_tenants, 1.1);
                let tpl = self.rng.usize(per);
                let q = self.rng.usize(8);
                (self.templates[tenant * per + tpl].question(q), Task::KvRecall)
            }
            None => {
                let task = *self.rng.choice(all);
                let chars = self.rng.range(
                    self.cfg.prompt_chars.0 as u64,
                    self.cfg.prompt_chars.1 as u64 + 1,
                ) as usize;
                (tasks::make_doc(&mut self.rng, task, chars), task)
            }
        };
        let every = self.cfg.deadline_every.max(1) as u64;
        let mut deadline_ms = match self.cfg.deadline_ms {
            Some(d) if id % every == 0 => Some(d),
            _ => None,
        };
        let max_new_tokens = self.rng.range(
            self.cfg.new_tokens.0 as u64,
            self.cfg.new_tokens.1 as u64 + 1,
        ) as usize;
        // tier draw comes last and only when the mix is configured, so
        // mix-off configs keep the historical RNG stream bit-identical
        let p_int = self.cfg.tier_interactive.clamp(0.0, 1.0);
        let p_bg = self.cfg.tier_background.clamp(0.0, 1.0);
        let tier = if p_int > 0.0 || p_bg > 0.0 {
            let u = self.rng.range(0, 1_000_000) as f64 / 1e6;
            let t = if u < p_int {
                SloTier::Interactive
            } else if u < p_int + p_bg {
                SloTier::Background
            } else {
                SloTier::Batch
            };
            // tiered requests carry their tier's default SLO unless the
            // deadline_every rule already attached an explicit one
            deadline_ms = deadline_ms.or(Some(t.deadline_ms()));
            t
        } else {
            SloTier::default()
        };
        self.emitted += 1;
        Some(Request {
            id,
            arrival_s: self.t,
            prompt: tasks::encode_prompt(&doc.prompt),
            max_new_tokens,
            session,
            task: Some(task),
            answer: Some(doc.answer),
            deadline_ms,
            tier,
        })
    }
}

impl RequestSource for OpenLoopGen {
    fn peek_arrival_s(&self) -> Option<f64> {
        self.next.as_ref().map(|r| r.arrival_s)
    }

    fn take_due(&mut self, now: f64) -> Vec<Request> {
        let mut out = Vec::new();
        while self
            .next
            .as_ref()
            .map(|r| r.arrival_s <= now)
            .unwrap_or(false)
        {
            out.push(self.pop().expect("peeked Some"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(r: &Request) -> String {
        format!(
            "{} @{:016x} p{} n{} s{:?} d{:?} t:{}",
            r.id,
            r.arrival_s.to_bits(),
            r.prompt.len(),
            r.max_new_tokens,
            r.session,
            r.deadline_ms.map(|d| d.to_bits()),
            r.tier.name()
        )
    }

    /// Same seed => bit-identical request streams; also the CI
    /// double-run determinism gate's always-available log writer (the
    /// serve-level event log needs artifacts; this one never skips).
    #[test]
    fn same_seed_same_stream() {
        let seed: u64 = std::env::var("PALLAS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(42);
        let cfg = OpenLoopConfig {
            n_requests: 200,
            process: ArrivalProcess::Gamma { shape: 0.4 },
            shape: LoadShape::Bursts { period_s: 1.0, burst_s: 0.25, factor: 5.0 },
            deadline_ms: Some(250.0),
            deadline_every: 4,
            tier_interactive: 0.3,
            tier_background: 0.2,
            seed,
            ..Default::default()
        };
        let a: Vec<String> =
            OpenLoopGen::new(cfg.clone()).collect_all().iter().map(sig).collect();
        let b: Vec<String> =
            OpenLoopGen::new(cfg).collect_all().iter().map(sig).collect();
        assert_eq!(a, b, "same seed must generate identical streams");
        assert_eq!(a.len(), 200);
        if let Ok(dir) = std::env::var("TINYSERVE_EVENT_LOG") {
            let _ = std::fs::create_dir_all(&dir);
            let _ = std::fs::write(
                std::path::Path::new(&dir).join("openloop_requests.log"),
                a.join("\n"),
            );
        }
    }

    #[test]
    fn take_due_respects_the_clock_and_order() {
        let cfg = OpenLoopConfig { n_requests: 50, rate_rps: 100.0, ..Default::default() };
        let mut g = OpenLoopGen::new(cfg);
        let first = g.peek_arrival_s().expect("has arrivals");
        assert!(g.take_due(first / 2.0).is_empty(), "nothing due before t0");
        let batch = g.take_due(0.2);
        assert!(!batch.is_empty());
        assert!(batch.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(batch.iter().all(|r| r.arrival_s <= 0.2));
        if let Some(t) = g.peek_arrival_s() {
            assert!(t > 0.2, "peek after take_due is in the future");
        }
        // drain to exhaustion
        let rest = g.take_due(f64::INFINITY);
        assert_eq!(rest.len() + batch.len(), 50);
        assert_eq!(g.peek_arrival_s(), None);
        assert!(g.take_due(f64::INFINITY).is_empty());
    }

    #[test]
    fn poisson_rate_is_approximately_offered() {
        let cfg = OpenLoopConfig {
            n_requests: 4000,
            rate_rps: 50.0,
            session_reuse_prob: 0.0,
            n_sessions: 0,
            ..Default::default()
        };
        let trace = OpenLoopGen::new(cfg).collect_all();
        let total = trace.last().unwrap().arrival_s;
        let rate = 4000.0 / total;
        assert!((rate - 50.0).abs() < 5.0, "observed rate {rate}");
    }

    #[test]
    fn gamma_is_burstier_than_poisson_at_same_rate() {
        let mk = |process| OpenLoopConfig {
            n_requests: 3000,
            rate_rps: 20.0,
            process,
            session_reuse_prob: 0.0,
            n_sessions: 0,
            ..Default::default()
        };
        let cv = |trace: &[Request]| {
            let gaps: Vec<f64> = trace
                .windows(2)
                .map(|w| w[1].arrival_s - w[0].arrival_s)
                .collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>()
                / gaps.len() as f64;
            v.sqrt() / m
        };
        let p = OpenLoopGen::new(mk(ArrivalProcess::Poisson)).collect_all();
        let g =
            OpenLoopGen::new(mk(ArrivalProcess::Gamma { shape: 0.3 })).collect_all();
        assert!(cv(&g) > cv(&p) * 1.3, "gamma CV {} vs poisson {}", cv(&g), cv(&p));
    }

    #[test]
    fn burst_phases_concentrate_arrivals() {
        let cfg = OpenLoopConfig {
            n_requests: 3000,
            rate_rps: 50.0,
            shape: LoadShape::Bursts { period_s: 2.0, burst_s: 0.5, factor: 6.0 },
            session_reuse_prob: 0.0,
            n_sessions: 0,
            ..Default::default()
        };
        let trace = OpenLoopGen::new(cfg).collect_all();
        let in_burst = trace
            .iter()
            .filter(|r| (r.arrival_s % 2.0) < 0.5)
            .count() as f64
            / trace.len() as f64;
        // burst windows are 25% of the time but at 6x rate: expect well
        // over half the arrivals inside them
        assert!(in_burst > 0.55, "burst share {in_burst}");
    }

    #[test]
    fn ramp_starts_slow() {
        let cfg = OpenLoopConfig {
            n_requests: 2000,
            rate_rps: 100.0,
            shape: LoadShape::Ramp { ramp_s: 4.0 },
            session_reuse_prob: 0.0,
            n_sessions: 0,
            ..Default::default()
        };
        let g = OpenLoopGen::new(cfg);
        assert!(g.rate_at(0.0) < 20.0);
        assert!((g.rate_at(10.0) - 100.0).abs() < 1e-9);
        let trace = g.collect_all();
        let first_s = trace.iter().filter(|r| r.arrival_s < 1.0).count();
        let late_s = trace
            .iter()
            .filter(|r| r.arrival_s >= 4.0 && r.arrival_s < 5.0)
            .count();
        assert!(
            late_s > first_s,
            "post-ramp second ({late_s}) must outpace the first ({first_s})"
        );
    }

    #[test]
    fn diurnal_rate_oscillates_with_floor() {
        let g = OpenLoopGen::new(OpenLoopConfig {
            shape: LoadShape::Diurnal { period_s: 8.0, amplitude: 0.9 },
            rate_rps: 40.0,
            ..Default::default()
        });
        assert!(g.rate_at(2.0) > 70.0, "peak of the sinusoid");
        assert!(g.rate_at(6.0) < 10.0, "trough of the sinusoid");
        assert!(g.rate_at(6.0) >= 40.0 * 0.05, "floored at 5%");
    }

    #[test]
    fn deadlines_attach_every_nth() {
        let cfg = OpenLoopConfig {
            n_requests: 40,
            deadline_ms: Some(100.0),
            deadline_every: 4,
            ..Default::default()
        };
        for r in OpenLoopGen::new(cfg).collect_all() {
            assert_eq!(r.deadline_ms.is_some(), r.id % 4 == 0, "id {}", r.id);
        }
    }

    #[test]
    fn tier_mix_off_is_all_batch_and_stream_identical() {
        let base = OpenLoopConfig { n_requests: 100, ..Default::default() };
        let off = OpenLoopConfig {
            tier_interactive: 0.0,
            tier_background: 0.0,
            ..base.clone()
        };
        let a: Vec<String> =
            OpenLoopGen::new(base).collect_all().iter().map(sig).collect();
        let b: Vec<String> =
            OpenLoopGen::new(off.clone()).collect_all().iter().map(sig).collect();
        assert_eq!(a, b, "zeroed tier knobs must not perturb the RNG stream");
        for r in OpenLoopGen::new(off).collect_all() {
            assert_eq!(r.tier, SloTier::Batch);
            assert!(r.deadline_ms.is_none(), "no implicit SLO without a mix");
        }
    }

    #[test]
    fn tier_mix_fractions_and_default_deadlines() {
        let cfg = OpenLoopConfig {
            n_requests: 3000,
            tier_interactive: 0.3,
            tier_background: 0.2,
            ..Default::default()
        };
        let trace = OpenLoopGen::new(cfg).collect_all();
        let frac = |t: SloTier| {
            trace.iter().filter(|r| r.tier == t).count() as f64 / trace.len() as f64
        };
        assert!((frac(SloTier::Interactive) - 0.3).abs() < 0.05);
        assert!((frac(SloTier::Background) - 0.2).abs() < 0.05);
        assert!((frac(SloTier::Batch) - 0.5).abs() < 0.05);
        for r in &trace {
            let d = r.deadline_ms.expect("tiered requests carry an SLO");
            assert_eq!(d, r.tier.deadline_ms());
        }
    }

    #[test]
    fn template_mix_off_is_stream_identical() {
        let base = OpenLoopConfig { n_requests: 100, ..Default::default() };
        let off = OpenLoopConfig {
            n_tenants: 0,
            templates_per_tenant: 0,
            template_prob: 0.0,
            ..base.clone()
        };
        let a: Vec<String> =
            OpenLoopGen::new(base).collect_all().iter().map(sig).collect();
        let b: Vec<String> =
            OpenLoopGen::new(off).collect_all().iter().map(sig).collect();
        assert_eq!(a, b, "zeroed template knobs must not perturb the RNG stream");
    }

    #[test]
    fn template_mix_repeats_shared_prompt_prefixes() {
        let cfg = OpenLoopConfig {
            n_requests: 300,
            session_reuse_prob: 0.0,
            n_sessions: 0,
            n_tenants: 3,
            templates_per_tenant: 2,
            template_prob: 0.7,
            ..Default::default()
        };
        let trace = OpenLoopGen::new(cfg).collect_all();
        assert!(
            trace.iter().all(|r| r.session.is_none()),
            "template requests never carry a session id"
        );
        // bucket by a 32-token prompt prefix: template requests share the
        // tenant preamble, organic ones are (near-)unique
        let mut groups: std::collections::HashMap<Vec<i32>, usize> =
            std::collections::HashMap::new();
        for r in &trace {
            if r.prompt.len() >= 32 {
                *groups.entry(r.prompt[..32].to_vec()).or_insert(0) += 1;
            }
        }
        let mut sizes: Vec<usize> = groups.values().copied().collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        // ~70% of 300 requests spread over 6 zipf-weighted templates: the
        // hottest template prefix must repeat many times
        assert!(
            sizes[0] >= 20,
            "hottest shared prefix repeats {} times",
            sizes[0]
        );
        let shared: usize = sizes.iter().filter(|&&s| s >= 2).sum();
        assert!(
            shared as f64 >= 0.5 * trace.len() as f64,
            "shared-prefix share {shared}/{}",
            trace.len()
        );
    }

    #[test]
    fn parse_roundtrips() {
        assert_eq!(ArrivalProcess::parse("poisson"), Some(ArrivalProcess::Poisson));
        assert!(matches!(
            ArrivalProcess::parse("gamma"),
            Some(ArrivalProcess::Gamma { .. })
        ));
        assert_eq!(ArrivalProcess::parse("bogus"), None);
        for n in LoadShape::names() {
            assert!(LoadShape::parse(n).is_some(), "{n}");
        }
        assert_eq!(LoadShape::parse("nope"), None);
    }
}
