//! Synthetic task generators — byte-for-byte mirrors of
//! python/compile/corpus.py (the trainer saw exactly these formats, so
//! serving-time accuracy is a true exact-match metric). If you change a
//! template here, change it there; python/tests/test_corpus.py and
//! rust tests pin the shared formats.

use crate::util::rng::Rng;

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;

pub const WORDS: &[&str] = &[
    "the", "time", "stone", "river", "cloud", "light", "garden", "music",
    "silver", "paper", "stream", "winter", "morning", "bridge", "copper",
    "forest", "mountain", "shadow", "window", "harbor", "meadow", "lantern",
    "valley", "ember", "willow", "raven", "cedar", "harvest", "north", "tide",
];

pub const NAMES: &[&str] = &[
    "alpha", "bravo", "charlie", "delta", "echo", "foxtrot", "golf", "hotel",
    "india", "juliet", "kilo", "lima", "mike", "november", "oscar", "papa",
    "quebec", "romeo", "sierra", "tango",
];

const CODE_ALPHABET: &[u8] = b"abcdefghjkmnpqrstuvwxyz23456789";

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Passkey,
    KvRecall,
    Repeat,
    RareToken,
    Alias,
}

impl Task {
    pub fn all() -> &'static [Task] {
        &[Task::Passkey, Task::KvRecall, Task::Repeat, Task::RareToken, Task::Alias]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::Passkey => "passkey",
            Task::KvRecall => "kvrecall",
            Task::Repeat => "repeat",
            Task::RareToken => "raretoken",
            Task::Alias => "alias",
        }
    }

    /// LongBench row this task stands in for (DESIGN.md §2 substitution).
    pub fn longbench_analogue(&self) -> &'static str {
        match self {
            Task::Passkey => "NarrativeQA",
            Task::KvRecall => "Qasper",
            Task::Repeat => "TriviaQA",
            Task::RareToken => "HotpotQA",
            Task::Alias => "GovReport",
        }
    }
}

/// A generated problem instance: prompt text and exact expected answer.
#[derive(Debug, Clone)]
pub struct Doc {
    pub prompt: String,
    pub answer: String,
}

pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

pub fn encode_prompt(text: &str) -> Vec<i32> {
    let mut v = vec![BOS];
    v.extend(encode(text));
    v
}

pub fn decode_ids(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| (0..256).contains(&i))
        .map(|&i| i as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

fn sentence(rng: &mut Rng) -> String {
    let n = rng.range(4, 9) as usize;
    let mut s = String::new();
    for i in 0..n {
        if i > 0 {
            s.push(' ');
        }
        s.push_str(WORDS[rng.usize(WORDS.len())]);
    }
    s.push_str(". ");
    s
}

pub fn filler(rng: &mut Rng, n_chars: usize) -> String {
    let mut out = String::new();
    while out.len() < n_chars {
        out.push_str(&sentence(rng));
    }
    out.truncate(n_chars);
    out
}

fn code(rng: &mut Rng, n: usize) -> String {
    (0..n).map(|_| char::from(b'0' + rng.usize(10) as u8)).collect()
}

fn word_code(rng: &mut Rng, n: usize) -> String {
    (0..n)
        .map(|_| CODE_ALPHABET[rng.usize(CODE_ALPHABET.len())] as char)
        .collect()
}

pub fn passkey_doc(rng: &mut Rng, target_chars: usize) -> Doc {
    let key = code(rng, 5);
    let head = format!("The pass key is {key}. Remember it. ");
    let tail = "What is the pass key? Answer: ";
    let mid = filler(rng, target_chars.saturating_sub(head.len() + tail.len()));
    Doc { prompt: format!("{head}{mid}{tail}"), answer: key }
}

pub fn kvrecall_doc(rng: &mut Rng, target_chars: usize, n_pairs: usize) -> Doc {
    let mut names: Vec<&str> = NAMES.to_vec();
    rng.shuffle(&mut names);
    let pairs: Vec<(String, String)> = (0..n_pairs)
        .map(|i| (names[i].to_string(), word_code(rng, 5)))
        .collect();
    let head: String = pairs
        .iter()
        .map(|(n, v)| format!("{n} holds {v}. "))
        .collect();
    let (qn, qv) = &pairs[rng.usize(n_pairs)];
    let tail = format!("Recall what {qn} holds: ");
    let mid = filler(rng, target_chars.saturating_sub(head.len() + tail.len()));
    Doc { prompt: format!("{head}{mid}{tail}"), answer: qv.clone() }
}

pub fn repeat_doc(rng: &mut Rng, target_chars: usize) -> Doc {
    let s = sentence(rng);
    let reps = (target_chars / s.len()).max(2);
    let text: String = s.repeat(reps);
    let cut = s.len() * (reps - 1) + s.len() / 2;
    Doc {
        prompt: text[..cut].to_string(),
        answer: text[cut..cut + s.len() / 2].to_string(),
    }
}

pub fn raretoken_doc(rng: &mut Rng, target_chars: usize) -> Doc {
    let rare = format!("zyx{}qj", word_code(rng, 3));
    let head = format!("The rare token is {rare}. ");
    let tail = "Repeat the rare token: ";
    let mid = filler(rng, target_chars.saturating_sub(head.len() + tail.len()));
    Doc { prompt: format!("{head}{mid}{tail}"), answer: rare }
}

pub fn alias_doc(rng: &mut Rng, target_chars: usize) -> Doc {
    let name = NAMES[rng.usize(NAMES.len())];
    let v1 = word_code(rng, 5);
    let v2 = word_code(rng, 5);
    let head = format!("{name} holds {v1}. ");
    let mid_len = (target_chars / 2).saturating_sub(head.len());
    let mid1 = filler(rng, mid_len);
    let over = format!("Correction: {name} now holds {v2}. ");
    let tail = format!("Recall what {name} holds: ");
    let mid2 = filler(
        rng,
        target_chars.saturating_sub(head.len() + mid_len + over.len() + tail.len()),
    );
    Doc { prompt: format!("{head}{mid1}{over}{mid2}{tail}"), answer: v2 }
}

/// Multi-turn session context: a kv-recall document body (no question) and
/// the bindings it contains. Each follow-up request appends one question —
/// so every request in a session shares a long common prefix, which is what
/// makes cross-request cache reuse (paper §4.4.2) measurable.
pub struct SessionDoc {
    pub context: String,
    pub pairs: Vec<(String, String)>,
}

pub fn kvrecall_session(rng: &mut Rng, target_chars: usize, n_pairs: usize) -> SessionDoc {
    let mut names: Vec<&str> = NAMES.to_vec();
    rng.shuffle(&mut names);
    let pairs: Vec<(String, String)> = (0..n_pairs)
        .map(|i| (names[i].to_string(), word_code(rng, 5)))
        .collect();
    let head: String = pairs
        .iter()
        .map(|(n, v)| format!("{n} holds {v}. "))
        .collect();
    let mid = filler(rng, target_chars.saturating_sub(head.len()));
    SessionDoc { context: format!("{head}{mid}"), pairs }
}

impl SessionDoc {
    /// One follow-up question about binding `i`, as a full-prompt Doc.
    pub fn question(&self, i: usize) -> Doc {
        let (n, v) = &self.pairs[i % self.pairs.len()];
        Doc {
            prompt: format!("{}Recall what {n} holds: ", self.context),
            answer: v.clone(),
        }
    }
}

pub fn make_doc(rng: &mut Rng, task: Task, target_chars: usize) -> Doc {
    match task {
        Task::Passkey => passkey_doc(rng, target_chars),
        Task::KvRecall => kvrecall_doc(rng, target_chars, 8),
        Task::Repeat => repeat_doc(rng, target_chars),
        Task::RareToken => raretoken_doc(rng, target_chars),
        Task::Alias => alias_doc(rng, target_chars),
    }
}

/// Exact-match score: does the generation start with the expected answer?
pub fn answer_matches(doc: &Doc, generated: &str) -> bool {
    generated.trim_start().starts_with(doc.answer.trim())
}

/// Character-level prefix accuracy in [0,1] (partial credit for the tables).
pub fn answer_char_accuracy(doc: &Doc, generated: &str) -> f64 {
    let want: Vec<char> = doc.answer.chars().collect();
    let got: Vec<char> = generated.trim_start().chars().take(want.len()).collect();
    if want.is_empty() {
        return 1.0;
    }
    let correct = want
        .iter()
        .zip(got.iter().chain(std::iter::repeat(&'\0')))
        .filter(|(a, b)| a == b)
        .count();
    correct as f64 / want.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passkey_answer_embedded() {
        let mut rng = Rng::new(1);
        let d = passkey_doc(&mut rng, 500);
        assert!(d.prompt.contains(&format!("The pass key is {}.", d.answer)));
        assert!(d.prompt.ends_with("Answer: "));
        assert!(d.prompt.len() >= 490 && d.prompt.len() <= 560);
        assert_eq!(d.answer.len(), 5);
        assert!(d.answer.chars().all(|c| c.is_ascii_digit()));
    }

    #[test]
    fn kvrecall_queries_existing_pair() {
        let mut rng = Rng::new(2);
        let d = kvrecall_doc(&mut rng, 600, 8);
        assert!(d.prompt.contains(&format!("holds {}. ", d.answer)));
    }

    #[test]
    fn repeat_answer_is_continuation() {
        let mut rng = Rng::new(3);
        let d = repeat_doc(&mut rng, 400);
        // prompt+answer is a prefix of the repeated sentence stream
        let full = format!("{}{}", d.prompt, d.answer);
        let first: &str = full.split(". ").next().unwrap();
        assert!(full.starts_with(first));
        assert!(!d.answer.is_empty());
    }

    #[test]
    fn alias_latest_binding_wins() {
        let mut rng = Rng::new(4);
        let d = alias_doc(&mut rng, 800);
        assert!(d.prompt.contains(&format!("now holds {}.", d.answer)));
    }

    #[test]
    fn encode_roundtrip() {
        let ids = encode("hi!");
        assert_eq!(ids, vec![104, 105, 33]);
        assert_eq!(decode_ids(&ids), "hi!");
        let p = encode_prompt("x");
        assert_eq!(p[0], BOS);
    }

    #[test]
    fn matching_metrics() {
        let d = Doc { prompt: String::new(), answer: "42".into() };
        assert!(answer_matches(&d, " 42 and more"));
        assert!(!answer_matches(&d, "41"));
        assert_eq!(answer_char_accuracy(&d, "42"), 1.0);
        assert_eq!(answer_char_accuracy(&d, "40"), 0.5);
        assert_eq!(answer_char_accuracy(&d, ""), 0.0);
    }

    #[test]
    fn deterministic_generation() {
        let d1 = passkey_doc(&mut Rng::new(7), 300);
        let d2 = passkey_doc(&mut Rng::new(7), 300);
        assert_eq!(d1.prompt, d2.prompt);
        assert_eq!(d1.answer, d2.answer);
    }

    #[test]
    fn all_tasks_fit_target_size() {
        let mut rng = Rng::new(11);
        for &t in Task::all() {
            let d = make_doc(&mut rng, t, 1000);
            assert!(
                d.prompt.len() >= 500 && d.prompt.len() <= 1200,
                "{}: {}",
                t.name(),
                d.prompt.len()
            );
        }
    }
}
