//! Worker pool: the frontend's N engine workers and the dispatch policy
//! that assigns admitted requests to them.
//!
//! The pre-pool frontend drove exactly one `Engine` and accounted
//! "workers" virtually through the router. A `WorkerPool` makes them real:
//! each slot is a full `Engine` — its own `PagePool`, its own `PageStore`
//! carrying an equal slice of the global `kv_budget_mb` — and the decode
//! pump steps every worker's batch per scheduling round, advancing the
//! virtual clock by the *slowest* worker (they overlap in real time) while
//! `busy` accumulates the sum.
//!
//! Budget-split rule: a global budget of B bytes over N workers gives each
//! worker `B / N` (integer division), so the sum of per-worker budgets —
//! and therefore the sum of per-worker `bytes_in_use` after enforcement —
//! never exceeds B. Each worker's `PageStore` enforces its slice
//! independently; there is no cross-worker page traffic (sessions pin to
//! the worker holding their snapshot pages).
//!
//! Dispatch policies:
//!  * `RoundRobin` — rotate through workers; oblivious but fair in count.
//!  * `LeastLoaded` — pick the worker with the fewest resident KV bytes;
//!    load-adaptive, so long prompts and bursts spread by footprint.
//!  * `SessionAffinity` — hash the session id to a stable worker (fresh
//!    requests fall back to least-loaded); maximizes cross-request prefix
//!    reuse because session snapshots live in one worker's pool.
//!
//! A pool can also borrow a caller-owned engine (`WorkerPool::single`),
//! which is how the single-engine `Frontend::build` path is expressed —
//! a one-slot pool is code-path-identical to the pre-pool frontend.
//!
//! Round execution: the frontend splits every decode round into a pure
//! *dispatch* phase (an immutable per-worker plan), a *step* phase, and a
//! serial *commit* phase. The step phase runs through a
//! [`RoundExecutor`]: `Sequential` steps each worker's batch in ascending
//! worker order on the pump thread; `Threaded` moves each worker's
//! exclusive `&mut Engine` (engine + `PageStore` slice + per-worker spill
//! directory) onto a scoped OS thread and joins; `Persistent` feeds the
//! same chunks to long-lived worker threads over channels (the
//! `util::threadpool` pattern), amortizing the per-round spawn/join cost
//! that `Threaded` pays on every decode round. Results are always merged
//! in ascending worker order, and every worker draws from its own forked
//! RNG stream, so all three executors are *byte-identical* under
//! `TimeModel::Modeled` — threading changes wall time, never the event
//! stream. Workers share no mutable state during the step phase (each
//! owns its full store → pool → spill stack; see the lock-ordering note
//! in docs/pagestore_design.md), which is what makes both threaded paths
//! safe without any cross-worker locking.

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::Engine;
use crate::kvcache::PageStore;
use crate::runtime::Manifest;

/// How admitted requests are assigned to pool workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchKind {
    RoundRobin,
    /// fewest resident KV bytes wins (ties: lowest worker index)
    LeastLoaded,
    /// sessions hash to a stable worker; session-free requests fall back
    /// to least-loaded
    SessionAffinity,
}

impl DispatchKind {
    pub fn parse(s: &str) -> Option<DispatchKind> {
        match s {
            "round-robin" | "rr" => Some(DispatchKind::RoundRobin),
            "least-loaded" | "ll" => Some(DispatchKind::LeastLoaded),
            "session-affinity" | "affinity" => Some(DispatchKind::SessionAffinity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchKind::RoundRobin => "round-robin",
            DispatchKind::LeastLoaded => "least-loaded",
            DispatchKind::SessionAffinity => "session-affinity",
        }
    }

    pub fn all() -> &'static [DispatchKind] {
        &[
            DispatchKind::RoundRobin,
            DispatchKind::LeastLoaded,
            DispatchKind::SessionAffinity,
        ]
    }

    pub fn names() -> Vec<&'static str> {
        Self::all().iter().map(|k| k.name()).collect()
    }
}

/// How the step phase of a decode round executes its per-worker batches
/// (`--threads` on the CLI; `ServeOptions::threads`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundExecutor {
    /// step workers one after another on the pump thread (threads = 1)
    Sequential,
    /// step workers on up to `threads` scoped OS threads, joining before
    /// the commit phase; results merge in fixed worker order, so event
    /// streams match `Sequential` byte-for-byte under modeled time
    Threaded { threads: usize },
    /// step workers on `threads` long-lived decode threads fed over
    /// channels (see [`PersistentExecutor`]); identical chunking and
    /// merge order to `Threaded`, without the per-round spawn/join
    Persistent { threads: usize },
}

impl RoundExecutor {
    /// Executor for a `--threads N` value: 1 is the sequential path.
    pub fn with_threads(threads: usize) -> RoundExecutor {
        if threads <= 1 {
            RoundExecutor::Sequential
        } else {
            RoundExecutor::Threaded { threads }
        }
    }

    pub fn threads(&self) -> usize {
        match self {
            RoundExecutor::Sequential => 1,
            RoundExecutor::Threaded { threads } => (*threads).max(1),
            RoundExecutor::Persistent { threads } => (*threads).max(1),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoundExecutor::Sequential => "sequential",
            RoundExecutor::Threaded { .. } => "threaded",
            RoundExecutor::Persistent { .. } => "persistent",
        }
    }
}

/// Which multi-threaded step-phase implementation `--threads N` selects
/// (`--executor` on the CLI; `ServeOptions::executor`). Orthogonal to the
/// thread count: either kind with `threads <= 1` is the sequential path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// scoped OS threads spawned and joined every decode round
    Scoped,
    /// long-lived decode threads fed work over channels (the default:
    /// same event streams, no per-round spawn/join overhead)
    Persistent,
}

impl ExecutorKind {
    pub fn parse(s: &str) -> Option<ExecutorKind> {
        match s {
            "scoped" => Some(ExecutorKind::Scoped),
            "persistent" => Some(ExecutorKind::Persistent),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecutorKind::Scoped => "scoped",
            ExecutorKind::Persistent => "persistent",
        }
    }

    pub fn names() -> Vec<&'static str> {
        vec![ExecutorKind::Scoped.name(), ExecutorKind::Persistent.name()]
    }

    /// The round executor this kind selects at a given thread count.
    pub fn executor(&self, threads: usize) -> RoundExecutor {
        if threads <= 1 {
            return RoundExecutor::Sequential;
        }
        match self {
            ExecutorKind::Scoped => RoundExecutor::Threaded { threads },
            ExecutorKind::Persistent => RoundExecutor::Persistent { threads },
        }
    }
}

/// Type-erased round job fed to a persistent decode thread. Lifetimes are
/// erased at the submission site (see the SAFETY note in
/// [`PersistentExecutor::run`]); the completion channel is what makes
/// that sound.
type RoundJob = Box<dyn FnOnce() + Send + 'static>;

/// Long-lived decode threads for [`RoundExecutor::Persistent`].
///
/// `Threaded` pays a spawn + join per decode round; at serving scale that
/// is thousands of rounds, each a few tens of microseconds of thread
/// setup. A `PersistentExecutor` spawns its threads once and feeds each
/// round's contiguous chunks over per-thread channels, blocking on a
/// completion channel before returning — the same join point as
/// `std::thread::scope`, amortized. Chunking, merge order, and panic
/// propagation are identical to the scoped path, so the event-stream
/// determinism contract is untouched.
pub struct PersistentExecutor {
    senders: Vec<std::sync::mpsc::Sender<RoundJob>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl PersistentExecutor {
    pub fn new(threads: usize) -> PersistentExecutor {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let (tx, rx) = std::sync::mpsc::channel::<RoundJob>();
            let handle = std::thread::Builder::new()
                .name(format!("tinyserve-decode-{i}"))
                .spawn(move || {
                    // jobs arrive wrapped in catch_unwind, so the loop
                    // only ever exits when the pool drops its sender
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawn persistent decode thread");
            senders.push(tx);
            handles.push(handle);
        }
        PersistentExecutor { senders, handles }
    }

    pub fn threads(&self) -> usize {
        self.senders.len()
    }

    /// Run one round's chunks on the persistent threads: same contract as
    /// [`execute_round`] — results in input order, panics propagate after
    /// every chunk has completed.
    pub fn run<T: Send, R: Send>(
        &self,
        work: Vec<(usize, T)>,
        f: &(impl Fn(usize, T) -> R + Sync),
    ) -> Vec<(usize, R)> {
        if work.len() <= 1 {
            return work.into_iter().map(|(w, t)| (w, f(w, t))).collect();
        }
        let threads = self.senders.len();
        let chunk = work.len().div_ceil(threads);
        let mut chunks: Vec<Vec<(usize, T)>> = Vec::new();
        let mut it = work.into_iter();
        loop {
            let c: Vec<(usize, T)> = it.by_ref().take(chunk).collect();
            if c.is_empty() {
                break;
            }
            chunks.push(c);
        }
        let n = chunks.len();
        // carries (chunk index, thread::Result<Vec<(usize, R)>>)
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let mut sent = 0usize;
        for (i, c) in chunks.into_iter().enumerate() {
            let tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    c.into_iter().map(|(w, t)| (w, f(w, t))).collect::<Vec<_>>()
                }));
                // a closed receiver means the caller already bailed; the
                // result has nowhere to go and the thread moves on
                let _ = tx.send((i, out));
            });
            // SAFETY: the job borrows `f` and the chunk payloads from this
            // stack frame, but the channel demands 'static. Erasing the
            // lifetime is sound because this function does not return (or
            // unwind) until every submitted job closure has been
            // *destroyed*: completions are counted on `done_rx` below, and
            // a recv error can only occur once all `done_tx` clones — one
            // per job, dropped when the job runs or is discarded — are
            // gone. This is the scoped-thread join, expressed over the
            // pool's long-lived channels.
            let job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, RoundJob>(job)
            };
            if self.senders[i].send(job).is_err() {
                // decode thread gone (only possible if it was killed out
                // from under us); drain what was sent, then fail loudly
                break;
            }
            sent += 1;
        }
        drop(done_tx);
        // element type: Option<thread::Result<Vec<(usize, R)>>>, inferred
        // from the recv below
        let mut slots = Vec::new();
        slots.resize_with(n, || None);
        for _ in 0..sent {
            match done_rx.recv() {
                Ok((i, res)) => slots[i] = Some(res),
                // all senders dropped: every outstanding job closure has
                // been destroyed, so unwinding below is borrow-safe
                Err(_) => break,
            }
        }
        let mut out = Vec::new();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        let mut missing = false;
        for s in slots {
            match s {
                Some(Ok(v)) => out.extend(v),
                Some(Err(e)) => {
                    if panic.is_none() {
                        panic = Some(e);
                    }
                }
                None => missing = true,
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
        assert!(!missing, "persistent decode thread died mid-round");
        out
    }
}

impl Drop for PersistentExecutor {
    fn drop(&mut self) {
        // closing the channels ends each thread's recv loop
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run one round's worth of per-worker work items through an executor.
///
/// `work` is `(worker index, payload)` in ascending worker order; `f`
/// runs once per item and must only touch state owned by (or moved in
/// with) that item — workers are independent by construction. The
/// returned vector is in the *input* order regardless of executor, which
/// is the determinism contract the commit phase relies on. `Threaded`
/// splits the items into at most `threads` contiguous chunks, one scoped
/// OS thread each; a panic on any thread propagates (no work is silently
/// dropped).
///
/// Separated from `WorkerPool` so the scheduling core is testable without
/// constructing engines (see the executor property tests).
pub fn execute_round<T: Send, R: Send>(
    exec: RoundExecutor,
    work: Vec<(usize, T)>,
    f: &(impl Fn(usize, T) -> R + Sync),
) -> Vec<(usize, R)> {
    execute_round_with(exec, None, work, f)
}

/// [`execute_round`] with an optional long-lived [`PersistentExecutor`].
/// A `Persistent` round uses `persistent` when supplied (the pool's
/// amortized path) and otherwise spins up a throwaway executor — correct,
/// but paying the spawn cost the variant exists to avoid.
pub fn execute_round_with<T: Send, R: Send>(
    exec: RoundExecutor,
    persistent: Option<&PersistentExecutor>,
    work: Vec<(usize, T)>,
    f: &(impl Fn(usize, T) -> R + Sync),
) -> Vec<(usize, R)> {
    let threads = exec.threads();
    if threads == 1 || work.len() <= 1 {
        return work.into_iter().map(|(w, t)| (w, f(w, t))).collect();
    }
    if let RoundExecutor::Persistent { .. } = exec {
        return match persistent {
            Some(p) => p.run(work, f),
            None => PersistentExecutor::new(threads).run(work, f),
        };
    }
    let chunk = work.len().div_ceil(threads);
    let mut chunks: Vec<Vec<(usize, T)>> = Vec::new();
    let mut it = work.into_iter();
    loop {
        let c: Vec<(usize, T)> = it.by_ref().take(chunk).collect();
        if c.is_empty() {
            break;
        }
        chunks.push(c);
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|c| {
                s.spawn(move || {
                    c.into_iter().map(|(w, t)| (w, f(w, t))).collect::<Vec<_>>()
                })
            })
            .collect();
        // join in spawn order: chunks are contiguous, so the flattened
        // result preserves the input order exactly
        handles
            .into_iter()
            .flat_map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    })
}

/// Stable session -> worker hash (one SplitMix64 step — the same mixer
/// the RNG seeds through, so nearby session ids land on distant workers).
pub fn affinity_hash(session: u64) -> u64 {
    let mut state = session;
    crate::util::rng::splitmix64(&mut state)
}

/// Pure dispatch decision over per-worker KV loads (bytes resident):
/// reads the rotation pointer without advancing it, so a candidate that
/// subsequently defers (worker full, KV pressure) does not drift the
/// round-robin rotation. Separated from the pool so the policy logic is
/// unit-testable without constructing engines.
pub fn peek_worker(
    kind: DispatchKind,
    session: Option<u64>,
    rr_next: usize,
    kv_loads: &[usize],
) -> usize {
    let n = kv_loads.len();
    debug_assert!(n > 0);
    if n == 1 {
        return 0;
    }
    let least = || {
        (0..n)
            .min_by_key(|&w| kv_loads[w])
            .expect("non-empty worker set")
    };
    match kind {
        DispatchKind::RoundRobin => rr_next % n,
        DispatchKind::LeastLoaded => least(),
        DispatchKind::SessionAffinity => match session {
            Some(s) => (affinity_hash(s) % n as u64) as usize,
            None => least(),
        },
    }
}

/// Committing variant of [`peek_worker`]: advances the round-robin
/// rotation past the returned worker (what a successful placement does).
pub fn select_worker(
    kind: DispatchKind,
    session: Option<u64>,
    rr_next: &mut usize,
    kv_loads: &[usize],
) -> usize {
    let w = peek_worker(kind, session, *rr_next, kv_loads);
    if kind == DispatchKind::RoundRobin && kv_loads.len() > 1 {
        *rr_next = (w + 1) % kv_loads.len();
    }
    w
}

/// Per-worker serving counters, reported in `ServeReport::worker_stats`.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    /// requests dispatched to and prefilled on this worker
    pub admitted: u64,
    /// requests that ran to completion here
    pub finished: u64,
    /// decode tokens produced by this worker
    pub new_tokens: u64,
    /// decode rounds in which this worker stepped a batch
    pub steps: u64,
    /// peak post-step resident KV bytes (cold pages at the q8 rate)
    pub kv_bytes_peak: usize,
    /// virtual seconds this worker spent computing (prefill + decode);
    /// divide by the run's wall time for utilization
    pub busy_s: f64,
    /// *measured* wall seconds this worker spent inside decode steps
    /// (step-phase time, real `Instant` reads — the phase-profiling
    /// signal, unlike the virtual `busy_s`)
    pub step_wall_s: f64,
}

impl WorkerStats {
    /// Fraction of the run's (virtual) wall time this worker was
    /// computing. Workers overlap, so per-worker utilization is the
    /// honest dispatch-skew signal the summed `busy_frac` hides: an idle
    /// worker shows up as a low number here while the pool-wide busy
    /// fraction still looks healthy.
    pub fn utilization(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.busy_s / wall_s
        } else {
            0.0
        }
    }
}

enum Slot<'a> {
    /// caller-owned engine (the classic single-engine frontend path)
    Borrowed(&'a mut Engine),
    /// pool-owned engine built by `WorkerPool::build`
    Owned(Box<Engine>),
}

impl<'a> Slot<'a> {
    fn get(&self) -> &Engine {
        match self {
            Slot::Borrowed(e) => e,
            Slot::Owned(e) => e,
        }
    }

    fn get_mut(&mut self) -> &mut Engine {
        match self {
            Slot::Borrowed(e) => e,
            Slot::Owned(e) => e,
        }
    }
}

/// N engine workers plus the dispatch state (see module docs).
pub struct WorkerPool<'a> {
    slots: Vec<Slot<'a>>,
    pub dispatch: DispatchKind,
    rr_next: usize,
    pub stats: Vec<WorkerStats>,
    /// long-lived decode threads, built lazily on the first
    /// `Persistent` round and reused (rebuilt only if the thread count
    /// changes); `None` until then, and always `None` on the
    /// sequential/scoped paths
    persistent: Option<PersistentExecutor>,
}

impl WorkerPool<'static> {
    /// Build `workers` owned engines from one manifest + serving config.
    /// A bounded `kv_budget_mb` is split `total_bytes / workers` per
    /// worker (integer division — the per-worker budgets can never sum
    /// past the global budget). The spill tier splits the same way, and
    /// every worker gets its own spill directory slice (`worker-<w>/`
    /// under `spill_dir`) so segment files are never shared.
    pub fn build(
        manifest: &Manifest,
        cfg: &ServingConfig,
        workers: usize,
        dispatch: DispatchKind,
    ) -> Result<WorkerPool<'static>> {
        anyhow::ensure!(workers > 0, "worker pool needs at least one worker");
        let per_worker_budget = cfg.kv_budget_bytes().map(|b| b / workers);
        // one spill root for the whole pool, resolved ONCE so the workers
        // land in sibling `worker-<w>/` slices of the same directory
        let spill_root = cfg.spill_root();
        let mut slots = Vec::with_capacity(workers);
        // the pool installs each worker's store below; strip the spill
        // fields from the per-engine config so `from_manifest` does not
        // create (and immediately discard) a whole-budget spill manager
        let mut engine_cfg = cfg.clone();
        engine_cfg.spill_budget_mb = None;
        engine_cfg.spill_dir = None;
        engine_cfg.readahead_pages = 0;
        for w in 0..workers {
            let mut engine = Engine::from_manifest(manifest, engine_cfg.clone())?;
            if let Some(b) = per_worker_budget {
                anyhow::ensure!(
                    b > 0,
                    "kv budget {:?} MB splits to zero bytes across {} workers",
                    cfg.kv_budget_mb,
                    workers
                );
                let spill_cfg = spill_root
                    .as_deref()
                    .and_then(|root| cfg.spill_config_in(root, w, workers));
                engine.store = match spill_cfg {
                    Some(sc) => PageStore::with_spill(Some(b), cfg.eviction, sc)?,
                    None => PageStore::new(Some(b), cfg.eviction),
                };
            }
            slots.push(Slot::Owned(Box::new(engine)));
        }
        Ok(WorkerPool {
            slots,
            dispatch,
            rr_next: 0,
            stats: vec![WorkerStats::default(); workers],
            persistent: None,
        })
    }
}

impl<'a> WorkerPool<'a> {
    /// One-slot pool borrowing a caller-owned engine. Dispatch is
    /// irrelevant with a single worker; `RoundRobin` is recorded.
    pub fn single(engine: &'a mut Engine) -> WorkerPool<'a> {
        WorkerPool {
            slots: vec![Slot::Borrowed(engine)],
            dispatch: DispatchKind::RoundRobin,
            rr_next: 0,
            stats: vec![WorkerStats::default()],
            persistent: None,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn engine(&self, w: usize) -> &Engine {
        self.slots[w].get()
    }

    pub fn engine_mut(&mut self, w: usize) -> &mut Engine {
        self.slots[w].get_mut()
    }

    /// Exclusive access to two distinct workers' engines at once — the
    /// cross-worker KV porting path (session migration / work stealing)
    /// reads pages out of one engine while allocating into the other.
    /// Panics if `a == b`.
    pub fn engine_pair_mut(&mut self, a: usize, b: usize) -> (&mut Engine, &mut Engine) {
        assert_ne!(a, b, "engine_pair_mut needs two distinct workers");
        let (lo, hi) = (a.min(b), a.max(b));
        let (left, right) = self.slots.split_at_mut(hi);
        let (el, eh) = (left[lo].get_mut(), right[0].get_mut());
        if a < b {
            (el, eh)
        } else {
            (eh, el)
        }
    }

    /// Compile every worker's decode executables up front.
    pub fn warmup(&self) -> Result<()> {
        for s in &self.slots {
            s.get().warmup()?;
        }
        Ok(())
    }

    /// Resident KV bytes on one worker (cold pages at the q8 rate).
    pub fn kv_bytes(&self, w: usize) -> usize {
        let e = self.slots[w].get();
        e.store.bytes_in_use(&e.pool)
    }

    /// Sum of resident KV bytes across workers.
    pub fn total_kv_bytes(&self) -> usize {
        (0..self.len()).map(|w| self.kv_bytes(w)).sum()
    }

    /// Sum of per-worker byte budgets (None when unbounded).
    pub fn total_budget_bytes(&self) -> Option<usize> {
        let mut total = 0usize;
        for s in &self.slots {
            total += s.get().store.budget_bytes()?;
        }
        Some(total)
    }

    /// Candidate worker for a request under the active dispatch policy.
    /// Does not advance the round-robin rotation — call
    /// [`note_admitted`](Self::note_admitted) once the placement sticks,
    /// so deferrals (worker full, KV pressure) cannot drift the rotation.
    pub fn dispatch_worker(&self, session: Option<u64>) -> usize {
        let loads: Vec<usize> = (0..self.len()).map(|w| self.kv_bytes(w)).collect();
        peek_worker(self.dispatch, session, self.rr_next, &loads)
    }

    /// A dispatch-policy placement on `w` succeeded: advance the
    /// round-robin rotation past it.
    pub fn note_admitted(&mut self, w: usize) {
        if self.dispatch == DispatchKind::RoundRobin && self.len() > 1 {
            self.rr_next = (w + 1) % self.len();
        }
    }

    /// Record a post-step residency observation for `worker_stats`.
    pub fn note_kv_peak(&mut self, w: usize) {
        let bytes = self.kv_bytes(w);
        let s = &mut self.stats[w];
        s.kv_bytes_peak = s.kv_bytes_peak.max(bytes);
    }

    /// Step phase of a decode round: run `f` once per `(worker, payload)`
    /// item with that worker's exclusive `&mut Engine`, through the given
    /// executor. Items must name distinct workers (each engine is handed
    /// out exactly once); results come back in input order — ascending
    /// worker order, as the frontend's dispatch phase builds them — so
    /// the commit phase merges identically under both executors.
    pub fn run_round<T: Send, R: Send>(
        &mut self,
        exec: RoundExecutor,
        work: Vec<(usize, T)>,
        f: impl Fn(usize, &mut Engine, T) -> R + Sync,
    ) -> Vec<(usize, R)> {
        if let RoundExecutor::Persistent { threads } = exec {
            let t = threads.max(1);
            if self.persistent.as_ref().map(|p| p.threads()) != Some(t) {
                self.persistent = Some(PersistentExecutor::new(t));
            }
        }
        let WorkerPool { slots, persistent, .. } = self;
        let mut engines: Vec<Option<&mut Engine>> =
            slots.iter_mut().map(|s| Some(s.get_mut())).collect();
        let work: Vec<(usize, (&mut Engine, T))> = work
            .into_iter()
            .map(|(w, t)| {
                let e = engines[w].take().expect("duplicate worker in round plan");
                (w, (e, t))
            })
            .collect();
        execute_round_with(exec, persistent.as_ref(), work, &|w, payload| {
            let (engine, t) = payload;
            f(w, engine, t)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut rr = 0usize;
        let loads = [0usize; 3];
        let seq: Vec<usize> = (0..7)
            .map(|_| select_worker(DispatchKind::RoundRobin, None, &mut rr, &loads))
            .collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_loaded_picks_min_bytes_with_stable_ties() {
        let mut rr = 0usize;
        let w = select_worker(
            DispatchKind::LeastLoaded,
            None,
            &mut rr,
            &[500, 100, 100, 900],
        );
        assert_eq!(w, 1, "min bytes, lowest index on tie");
        let w = select_worker(DispatchKind::LeastLoaded, Some(7), &mut rr, &[5, 0]);
        assert_eq!(w, 1, "session id is ignored by least-loaded");
    }

    #[test]
    fn session_affinity_is_stable_and_spreads() {
        let mut rr = 0usize;
        let loads = [0usize; 4];
        for sid in 0..32u64 {
            let a =
                select_worker(DispatchKind::SessionAffinity, Some(sid), &mut rr, &loads);
            let b =
                select_worker(DispatchKind::SessionAffinity, Some(sid), &mut rr, &loads);
            assert_eq!(a, b, "same session, same worker");
            assert!(a < 4);
        }
        // distinct sessions must not all collapse onto one worker
        let mut hit = [false; 4];
        for sid in 0..64u64 {
            hit[select_worker(DispatchKind::SessionAffinity, Some(sid), &mut rr, &loads)] =
                true;
        }
        assert!(hit.iter().all(|&h| h), "64 sessions cover 4 workers: {hit:?}");
        // session-free requests fall back to least-loaded
        let w = select_worker(
            DispatchKind::SessionAffinity,
            None,
            &mut rr,
            &[10, 3, 10, 10],
        );
        assert_eq!(w, 1);
    }

    #[test]
    fn single_worker_always_wins() {
        let mut rr = 5usize;
        for kind in DispatchKind::all() {
            assert_eq!(select_worker(*kind, Some(9), &mut rr, &[123]), 0);
            assert_eq!(select_worker(*kind, None, &mut rr, &[123]), 0);
        }
        assert_eq!(rr, 5, "one-worker pools never touch dispatch state");
    }

    #[test]
    fn dispatch_kind_parse_roundtrip() {
        for k in DispatchKind::all() {
            assert_eq!(DispatchKind::parse(k.name()), Some(*k));
        }
        assert_eq!(DispatchKind::parse("rr"), Some(DispatchKind::RoundRobin));
        assert_eq!(DispatchKind::parse("ll"), Some(DispatchKind::LeastLoaded));
        assert_eq!(DispatchKind::parse("bogus"), None);
        assert_eq!(DispatchKind::names().len(), 3);
    }

    #[test]
    fn round_executor_parse_points() {
        assert_eq!(RoundExecutor::with_threads(0), RoundExecutor::Sequential);
        assert_eq!(RoundExecutor::with_threads(1), RoundExecutor::Sequential);
        assert_eq!(
            RoundExecutor::with_threads(4),
            RoundExecutor::Threaded { threads: 4 }
        );
        assert_eq!(RoundExecutor::Sequential.threads(), 1);
        assert_eq!(RoundExecutor::Threaded { threads: 4 }.threads(), 4);
        assert_eq!(RoundExecutor::Persistent { threads: 4 }.threads(), 4);
        assert_eq!(RoundExecutor::Sequential.name(), "sequential");
        assert_eq!(RoundExecutor::Threaded { threads: 2 }.name(), "threaded");
        assert_eq!(RoundExecutor::Persistent { threads: 2 }.name(), "persistent");
    }

    #[test]
    fn executor_kind_parse_and_selection() {
        for k in [ExecutorKind::Scoped, ExecutorKind::Persistent] {
            assert_eq!(ExecutorKind::parse(k.name()), Some(k));
        }
        assert_eq!(ExecutorKind::parse("bogus"), None);
        assert_eq!(ExecutorKind::names(), vec!["scoped", "persistent"]);
        // threads <= 1 is the sequential path for either kind
        assert_eq!(ExecutorKind::Scoped.executor(1), RoundExecutor::Sequential);
        assert_eq!(ExecutorKind::Persistent.executor(0), RoundExecutor::Sequential);
        assert_eq!(
            ExecutorKind::Scoped.executor(4),
            RoundExecutor::Threaded { threads: 4 }
        );
        assert_eq!(
            ExecutorKind::Persistent.executor(4),
            RoundExecutor::Persistent { threads: 4 }
        );
    }

    #[test]
    fn execute_round_preserves_order_and_results_across_thread_counts() {
        // per-item stateful work (an owned RNG each) must come back in
        // input order with identical results no matter how many threads
        // the round is chunked over — the determinism contract
        let run = |exec: RoundExecutor| -> Vec<(usize, u64)> {
            let work: Vec<(usize, crate::util::rng::Rng)> = (0..7)
                .map(|w| (w, crate::util::rng::Rng::new(0xBEEF ^ w as u64)))
                .collect();
            execute_round(exec, work, &|w, mut rng: crate::util::rng::Rng| {
                let mut acc = w as u64;
                for _ in 0..50 {
                    acc = acc.wrapping_add(rng.next_u64());
                }
                acc
            })
        };
        let base = run(RoundExecutor::Sequential);
        let order: Vec<usize> = base.iter().map(|(w, _)| *w).collect();
        assert_eq!(order, (0..7).collect::<Vec<_>>());
        for threads in [2usize, 3, 7, 16] {
            assert_eq!(
                base,
                run(RoundExecutor::Threaded { threads }),
                "threaded({threads}) diverged from sequential"
            );
            assert_eq!(
                base,
                run(RoundExecutor::Persistent { threads }),
                "persistent({threads}) diverged from sequential"
            );
        }
    }

    #[test]
    fn persistent_executor_reuses_threads_across_rounds() {
        let exec = PersistentExecutor::new(3);
        assert_eq!(exec.threads(), 3);
        // many rounds through the same threads: results stay in input
        // order and match the inline computation every time
        for round in 0..50u64 {
            let work: Vec<(usize, u64)> = (0..7).map(|w| (w, round)).collect();
            let out = exec.run(work, &|w, r: u64| (w as u64).wrapping_mul(31) ^ r);
            let want: Vec<(usize, u64)> =
                (0..7).map(|w| (w, (w as u64).wrapping_mul(31) ^ round)).collect();
            assert_eq!(out, want, "round {round} diverged");
        }
    }

    #[test]
    fn persistent_executor_propagates_panics_after_the_round_completes() {
        let exec = PersistentExecutor::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let work: Vec<(usize, usize)> = (0..4).map(|w| (w, w)).collect();
            exec.run(work, &|w, _| {
                if w == 1 {
                    panic!("boom in worker 1");
                }
                w
            });
        }));
        assert!(caught.is_err(), "worker panic must propagate to the caller");
        // the executor survives a panicked round and keeps serving
        let out = exec.run(vec![(0, 1usize), (1, 2)], &|w, x| w + x);
        assert_eq!(out, vec![(0, 1), (1, 3)]);
    }

    #[test]
    fn execute_round_handles_empty_and_single_item_rounds() {
        let exec = RoundExecutor::Threaded { threads: 4 };
        let empty: Vec<(usize, ())> = Vec::new();
        let out = execute_round(exec, empty, &|_, ()| 1);
        assert!(out.is_empty());
        let out = execute_round(exec, vec![(3, 10)], &|w, x| w + x);
        assert_eq!(out, vec![(3, 13)]);
    }

    #[test]
    fn worker_stats_utilization() {
        let ws = WorkerStats { busy_s: 0.5, ..Default::default() };
        assert!((ws.utilization(2.0) - 0.25).abs() < 1e-12);
        assert_eq!(ws.utilization(0.0), 0.0, "zero wall never divides");
    }

    #[test]
    fn engine_stack_is_send_for_threaded_rounds() {
        // compile-time gate for the whole Send refactor: a threaded round
        // moves these across thread boundaries
        fn assert_send<T: Send>() {}
        assert_send::<Engine>();
        assert_send::<crate::engine::Sequence>();
        assert_send::<PageStore>();
        assert_send::<WorkerPool<'static>>();
        assert_send::<&mut Engine>();
    }

    #[test]
    fn budget_split_never_sums_past_total() {
        // the WorkerPool::build rule, checked directly on the arithmetic
        for total in [1usize, 1_000_000, 1_500_001, 7_777_777] {
            for n in 1usize..=8 {
                let per = total / n;
                assert!(per * n <= total, "split {per}x{n} > {total}");
            }
        }
    }
}
