//! Continuous batcher: deadline-aware (EDF) admission queue + active set,
//! with the paper's batch-timeout grouping (§4.13.1, 50ms default).
//!
//! Admission order is tiered earliest-deadline-first: the queue is kept
//! sorted by `(SLO tier rank, absolute deadline, arrival, request id)`,
//! so interactive requests pop before batch before background, deadline
//! carriers jump ahead of deadline-free ones within a tier, and the
//! tie-break chain makes the pop order total and stable. Requests without
//! deadlines sort at infinity — among themselves they pop in arrival
//! order, which is exactly the old FIFO behaviour, so single-tier
//! deadline-free traces schedule identically to the pre-EDF batcher.
//!
//! Pure state machine over virtual time — the server drives it with real
//! measured step durations, tests drive it with synthetic clocks.

use std::collections::VecDeque;

use crate::workload::SloTier;

/// A queued request the batcher schedules (engine-agnostic).
#[derive(Debug, Clone, PartialEq)]
pub struct QueuedItem {
    pub request_idx: usize,
    pub arrival_s: f64,
    pub prompt_len: usize,
    /// absolute SLO deadline on the virtual clock (arrival + deadline_ms);
    /// None sorts last (after every deadline-carrying request of the tier)
    pub deadline_s: Option<f64>,
    /// SLO class; leads the EDF key, so tiers never interleave
    pub tier: SloTier,
    /// true when this item is a preempted request waiting to resume (its
    /// KV snapshot is parked in the cold/spill tiers). Preempted items
    /// are scheduled like any other queued item but are *not* new intake:
    /// the admission gate's queue-depth count excludes them.
    pub preempted: bool,
}

impl QueuedItem {
    /// EDF sort key: tier rank, then deadline (None -> +inf), then
    /// arrival, then id. The trailing `request_idx` makes the order total
    /// — no two distinct items compare equal, so insertion position is
    /// unambiguous.
    fn edf_key(&self) -> (u8, f64, f64, usize) {
        (
            self.tier.rank(),
            self.deadline_s.unwrap_or(f64::INFINITY),
            self.arrival_s,
            self.request_idx,
        )
    }
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_active: usize,
    pub batch_timeout_s: f64,
    /// admit at most this many prefills per scheduling round (prefill is
    /// expensive; interleaving keeps decode latency bounded)
    pub prefill_per_round: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_active: 8, batch_timeout_s: 0.05, prefill_per_round: 2 }
    }
}

#[derive(Debug, Default)]
pub struct BatcherStats {
    pub admitted: u64,
    pub timeout_flushes: u64,
    pub full_flushes: u64,
    pub max_queue_depth: usize,
    /// admissions bounced back by KV-budget pressure (requeue_front)
    pub deferred: u64,
    /// queued items removed before admission (frontend cancellation)
    pub cancelled: u64,
    /// enqueues where a deadline let the item overtake at least one
    /// already-queued request (EDF reordering actually engaged)
    pub edf_jumps: u64,
    /// running requests paused and returned to the queue (preemption)
    pub preempted: u64,
}

/// Decision for one scheduling round.
#[derive(Debug, PartialEq)]
pub enum Round {
    /// admit these queued items (prefill them), then decode
    Admit(Vec<QueuedItem>),
    /// nothing to admit; decode the active set
    Decode,
    /// nothing runnable; sleep until this virtual time (next arrival or
    /// timeout expiry)
    Idle(f64),
}

pub struct Batcher {
    pub cfg: BatcherConfig,
    /// EDF-sorted: front = earliest deadline, then arrival, then id
    queue: VecDeque<QueuedItem>,
    active: usize,
    /// arrival time of the oldest queued item (timeout anchor). With EDF
    /// ordering the front of the queue is no longer the oldest arrival,
    /// so this is maintained as the min arrival over the queue.
    oldest_wait: Option<f64>,
    /// set by `requeue_front`: force one decode round before the next
    /// admission attempt, so deferral under budget pressure cannot spin
    hold_admissions: bool,
    pub stats: BatcherStats,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            queue: VecDeque::new(),
            active: 0,
            oldest_wait: None,
            hold_admissions: false,
            stats: BatcherStats::default(),
        }
    }

    /// Insert preserving EDF order. `<=` on the unique key keeps equal
    /// prefixes stable (impossible for distinct items, but harmless).
    /// `count_jump` is set only for fresh enqueues: a deadline-carrying
    /// item landing ahead of queued work there is a real EDF reordering,
    /// while a `requeue_front` re-insertion merely returns to its own
    /// position and must not inflate the stat.
    fn insert_sorted(&mut self, item: QueuedItem, count_jump: bool) {
        let key = item.edf_key();
        let pos = self.queue.partition_point(|q| q.edf_key() <= key);
        if count_jump && pos < self.queue.len() && item.deadline_s.is_some() {
            self.stats.edf_jumps += 1;
        }
        self.queue.insert(pos, item);
    }

    /// Recompute the timeout anchor (min arrival over the queue) after a
    /// pop or removal. O(n); admission queues are short.
    fn refresh_oldest(&mut self) {
        self.oldest_wait = self
            .queue
            .iter()
            .map(|i| i.arrival_s)
            .fold(None, |m: Option<f64>, t| Some(m.map_or(t, |m| m.min(t))));
    }

    /// Return an admitted-but-not-started item to the queue (the server
    /// defers admission under KV-budget pressure). Undoes the admission
    /// accounting and holds further admissions for one decode round so
    /// in-flight sequences can retire and free pages. The item re-enters
    /// at its EDF position — the front, unless a more urgent request
    /// arrived in the meantime.
    pub fn requeue_front(&mut self, item: QueuedItem) {
        self.active -= 1;
        self.stats.admitted -= 1;
        self.stats.deferred += 1;
        self.oldest_wait = Some(match self.oldest_wait {
            Some(t) => t.min(item.arrival_s),
            None => item.arrival_s,
        });
        self.insert_sorted(item, false);
        self.hold_admissions = true;
    }

    /// Return a *running* request to the queue (preemption): it gives up
    /// its active slot and re-enters at its EDF position, flagged
    /// `preempted` so a later `schedule` pop resumes it from its KV
    /// snapshot instead of prefilling. Unlike `requeue_front` this does
    /// not hold admissions — the whole point of preempting is to admit
    /// more urgent work on the very next round.
    pub fn requeue_preempted(&mut self, mut item: QueuedItem) {
        self.active -= 1;
        self.stats.admitted -= 1;
        self.stats.preempted += 1;
        item.preempted = true;
        self.oldest_wait = Some(match self.oldest_wait {
            Some(t) => t.min(item.arrival_s),
            None => item.arrival_s,
        });
        self.insert_sorted(item, false);
    }

    /// Head of the EDF queue (the next item `schedule` would pop).
    pub fn peek_head(&self) -> Option<&QueuedItem> {
        self.queue.front()
    }

    /// Queue length counting only fresh intake — preempted items waiting
    /// to resume already consumed prefill and hold KV snapshots, so the
    /// admission gate must not treat them as queued submissions.
    pub fn queued_new_len(&self) -> usize {
        self.queue.iter().filter(|i| !i.preempted).count()
    }

    /// Undo the accounting for an item `schedule` handed out that never
    /// started (shed past its deadline, or cancelled between pop and
    /// prefill): it no longer occupies an active slot and must not count
    /// as admitted.
    pub fn abort_admission(&mut self, n: usize) {
        self.active -= n;
        self.stats.admitted -= n as u64;
    }

    /// Remove a queued item by request index (cancellation before
    /// admission). The item never counted as admitted, so only the queue
    /// and the timeout anchor need fixing. Returns false when absent.
    pub fn remove(&mut self, request_idx: usize) -> bool {
        let before = self.queue.len();
        self.queue.retain(|i| i.request_idx != request_idx);
        if self.queue.len() == before {
            return false;
        }
        self.stats.cancelled += 1;
        self.refresh_oldest();
        true
    }

    pub fn enqueue(&mut self, item: QueuedItem) {
        self.oldest_wait = Some(match self.oldest_wait {
            Some(t) => t.min(item.arrival_s),
            None => item.arrival_s,
        });
        self.insert_sorted(item, true);
        self.stats.max_queue_depth = self.stats.max_queue_depth.max(self.queue.len());
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Fresh-intake queue depth per SLO tier, indexed by `SloTier::rank()`
    /// (interactive, batch, background). Preempted requeues are excluded
    /// like `queued_new_len` — the live `stats` op reports intake
    /// pressure, not load the preemptor created itself.
    pub fn queued_by_tier(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for i in self.queue.iter().filter(|i| !i.preempted) {
            out[(i.tier.rank() as usize).min(2)] += 1;
        }
        out
    }

    pub fn active(&self) -> usize {
        self.active
    }

    /// Every admission slot is taken — the condition under which the
    /// SLO preemptor considers evicting a lower-tier active.
    pub fn is_full(&self) -> bool {
        self.active >= self.cfg.max_active
    }

    pub fn on_finished(&mut self, n: usize) {
        self.active -= n;
    }

    /// Decide what to do at virtual time `now`. `next_arrival`: the next
    /// trace arrival after `now`, if any.
    pub fn schedule(&mut self, now: f64, next_arrival: Option<f64>) -> Round {
        if self.hold_admissions {
            self.hold_admissions = false;
            if self.active > 0 {
                return Round::Decode;
            }
        }
        let free = self.cfg.max_active.saturating_sub(self.active);
        if free > 0 && !self.queue.is_empty() {
            let timeout_hit = self
                .oldest_wait
                .map(|t| now - t >= self.cfg.batch_timeout_s)
                .unwrap_or(false);
            let batch_full = self.queue.len() >= free || self.active > 0;
            // admit when the queue can fill capacity, when we already have
            // active work (continuous batching: don't stall decodes), or
            // when the oldest request has waited out the batch timeout
            if batch_full || timeout_hit || next_arrival.is_none() {
                if timeout_hit && !batch_full {
                    self.stats.timeout_flushes += 1;
                } else {
                    self.stats.full_flushes += 1;
                }
                let n = free.min(self.cfg.prefill_per_round).min(self.queue.len());
                let items: Vec<QueuedItem> = self.queue.drain(..n).collect();
                self.active += items.len();
                self.stats.admitted += items.len() as u64;
                self.refresh_oldest();
                return Round::Admit(items);
            }
            // hold for more arrivals, bounded by the timeout
            let deadline = self.oldest_wait.unwrap() + self.cfg.batch_timeout_s;
            let wake = next_arrival.map(|a| a.min(deadline)).unwrap_or(deadline);
            if self.active > 0 {
                return Round::Decode;
            }
            return Round::Idle(wake.max(now + 1e-9));
        }
        if self.active > 0 {
            return Round::Decode;
        }
        match next_arrival {
            Some(a) => Round::Idle(a.max(now + 1e-9)),
            None => Round::Idle(f64::INFINITY),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(idx: usize, t: f64) -> QueuedItem {
        QueuedItem {
            request_idx: idx,
            arrival_s: t,
            prompt_len: 100,
            deadline_s: None,
            tier: SloTier::Batch,
            preempted: false,
        }
    }

    fn item_slo(idx: usize, t: f64, deadline: f64) -> QueuedItem {
        QueuedItem { deadline_s: Some(deadline), ..item(idx, t) }
    }

    fn item_tier(idx: usize, t: f64, tier: SloTier) -> QueuedItem {
        QueuedItem { tier, ..item(idx, t) }
    }

    #[test]
    fn idle_when_empty() {
        let mut b = Batcher::new(BatcherConfig::default());
        assert_eq!(b.schedule(0.0, Some(1.5)), Round::Idle(1.5));
        assert_eq!(b.schedule(0.0, None), Round::Idle(f64::INFINITY));
    }

    #[test]
    fn waits_for_timeout_then_flushes() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 8,
            batch_timeout_s: 0.05,
            prefill_per_round: 8,
        });
        b.enqueue(item(0, 0.0));
        // a single queued item with upcoming arrivals: hold
        match b.schedule(0.01, Some(0.02)) {
            Round::Idle(t) => assert!((t - 0.02).abs() < 1e-9),
            r => panic!("expected idle, got {r:?}"),
        }
        // timeout expired: admit
        match b.schedule(0.06, Some(0.1)) {
            Round::Admit(v) => assert_eq!(v.len(), 1),
            r => panic!("expected admit, got {r:?}"),
        }
        assert_eq!(b.stats.timeout_flushes, 1);
        assert_eq!(b.active(), 1);
    }

    #[test]
    fn admits_immediately_when_queue_fills_capacity() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 2,
            batch_timeout_s: 10.0,
            prefill_per_round: 2,
        });
        b.enqueue(item(0, 0.0));
        b.enqueue(item(1, 0.0));
        b.enqueue(item(2, 0.0));
        match b.schedule(0.001, Some(5.0)) {
            Round::Admit(v) => assert_eq!(v.len(), 2),
            r => panic!("{r:?}"),
        }
        assert_eq!(b.queue_len(), 1);
        // at capacity now: decode
        assert_eq!(b.schedule(0.002, Some(5.0)), Round::Decode);
        b.on_finished(2);
        assert_eq!(b.active(), 0);
    }

    #[test]
    fn continuous_batching_admits_alongside_active() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 4,
            batch_timeout_s: 10.0,
            prefill_per_round: 1,
        });
        b.enqueue(item(0, 0.0));
        b.enqueue(item(1, 0.0));
        let _ = b.schedule(0.0, None); // admit both? prefill_per_round=1
        assert_eq!(b.active(), 1);
        // active work present -> new arrivals admitted without timeout
        match b.schedule(0.001, Some(9.0)) {
            Round::Admit(v) => assert_eq!(v.len(), 1),
            r => panic!("{r:?}"),
        }
    }

    #[test]
    fn requeue_front_defers_then_readmits() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 4,
            batch_timeout_s: 0.0,
            prefill_per_round: 2,
        });
        b.enqueue(item(0, 0.0));
        b.enqueue(item(1, 0.0));
        let admitted = match b.schedule(0.1, None) {
            Round::Admit(v) => v,
            r => panic!("{r:?}"),
        };
        assert_eq!(admitted.len(), 2);
        // budget pressure: bounce the second one back
        b.requeue_front(admitted[1].clone());
        assert_eq!(b.active(), 1);
        assert_eq!(b.queue_len(), 1);
        assert_eq!(b.stats.deferred, 1);
        // one decode round is forced before the next admission attempt
        assert_eq!(b.schedule(0.2, None), Round::Decode);
        match b.schedule(0.3, None) {
            Round::Admit(v) => assert_eq!(v[0].request_idx, 1),
            r => panic!("{r:?}"),
        }
        assert_eq!(b.stats.admitted, 2);
    }

    #[test]
    fn abort_admission_undoes_accounting() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 4,
            batch_timeout_s: 0.0,
            prefill_per_round: 2,
        });
        b.enqueue(item(0, 0.0));
        b.enqueue(item(1, 0.0));
        match b.schedule(0.1, None) {
            Round::Admit(v) => assert_eq!(v.len(), 2),
            r => panic!("{r:?}"),
        }
        // one item is shed past its deadline before prefill starts
        b.abort_admission(1);
        assert_eq!(b.active(), 1);
        assert_eq!(b.stats.admitted, 1, "shed item must not count as admitted");
        b.on_finished(1);
        assert_eq!(b.active(), 0);
    }

    #[test]
    fn remove_drops_queued_item_and_fixes_timeout_anchor() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 4,
            batch_timeout_s: 0.05,
            prefill_per_round: 4,
        });
        b.enqueue(item(0, 0.0));
        b.enqueue(item(1, 0.02));
        assert!(b.remove(0));
        assert_eq!(b.queue_len(), 1);
        assert_eq!(b.stats.cancelled, 1);
        assert!(!b.remove(0), "already gone");
        // the timeout anchor moved to the surviving item's arrival: at
        // t=0.05 item 0's timeout would have expired, item 1's has not
        match b.schedule(0.05, Some(1.0)) {
            Round::Idle(t) => assert!((t - 0.07).abs() < 1e-9, "wake at {t}"),
            r => panic!("expected idle, got {r:?}"),
        }
        // removing the last item empties the queue entirely
        assert!(b.remove(1));
        assert_eq!(b.queue_len(), 0);
        assert_eq!(b.schedule(0.06, None), Round::Idle(f64::INFINITY));
    }

    #[test]
    fn respects_prefill_per_round() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 8,
            batch_timeout_s: 0.0,
            prefill_per_round: 2,
        });
        for i in 0..6 {
            b.enqueue(item(i, 0.0));
        }
        match b.schedule(0.1, None) {
            Round::Admit(v) => assert_eq!(v.len(), 2),
            r => panic!("{r:?}"),
        }
        assert_eq!(b.queue_len(), 4);
    }

    #[test]
    fn edf_orders_by_deadline_then_arrival_then_id() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 16,
            batch_timeout_s: 0.0,
            prefill_per_round: 16,
        });
        // enqueue in deliberately scrambled order
        b.enqueue(item(0, 0.00)); // no deadline, earliest arrival
        b.enqueue(item_slo(1, 0.03, 0.50)); // late deadline
        b.enqueue(item_slo(2, 0.04, 0.10)); // earliest deadline, latest arrival
        b.enqueue(item_slo(3, 0.01, 0.50)); // deadline ties with 1, earlier arrival
        b.enqueue(item(4, 0.02)); // no deadline, later arrival
        let order: Vec<usize> = match b.schedule(1.0, None) {
            Round::Admit(v) => v.into_iter().map(|i| i.request_idx).collect(),
            r => panic!("{r:?}"),
        };
        assert_eq!(order, vec![2, 3, 1, 0, 4]);
        assert!(b.stats.edf_jumps >= 2, "deadlines overtook queued items");
    }

    #[test]
    fn deadline_free_queue_stays_fifo() {
        // without deadlines the EDF key degenerates to (arrival, id):
        // identical to the old FIFO batcher
        let mut b = Batcher::new(BatcherConfig {
            max_active: 8,
            batch_timeout_s: 0.0,
            prefill_per_round: 8,
        });
        for i in 0..5 {
            b.enqueue(item(i, i as f64 * 0.01));
        }
        match b.schedule(1.0, None) {
            Round::Admit(v) => {
                let got: Vec<usize> = v.into_iter().map(|i| i.request_idx).collect();
                assert_eq!(got, vec![0, 1, 2, 3, 4]);
            }
            r => panic!("{r:?}"),
        }
        assert_eq!(b.stats.edf_jumps, 0, "no reordering without deadlines");
    }

    #[test]
    fn requeue_respects_edf_position() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 8,
            batch_timeout_s: 0.0,
            prefill_per_round: 1,
        });
        b.enqueue(item(0, 0.0));
        let out = match b.schedule(0.1, None) {
            Round::Admit(v) => v,
            r => panic!("{r:?}"),
        };
        b.enqueue(item_slo(1, 0.1, 0.2));
        b.requeue_front(out[0].clone());
        // hold: with no active work the hold flag falls through and pops
        match b.schedule(0.2, None) {
            Round::Admit(v) => {
                assert_eq!(v[0].request_idx, 1, "urgent arrival overtakes deferred");
            }
            Round::Decode => panic!("no active work to decode"),
            Round::Idle(_) => panic!("queue not empty"),
        }
        assert_eq!(
            b.stats.edf_jumps, 0,
            "requeue re-insertions are not EDF reorderings"
        );
    }

    #[test]
    fn tier_rank_leads_the_edf_key() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 16,
            batch_timeout_s: 0.0,
            prefill_per_round: 16,
        });
        // a background request with a tight deadline still sorts after a
        // deadline-free interactive one: tiers never interleave
        b.enqueue(item_tier(0, 0.0, SloTier::Background));
        let mut urgent_bg = item_tier(1, 0.01, SloTier::Background);
        urgent_bg.deadline_s = Some(0.05);
        b.enqueue(urgent_bg);
        b.enqueue(item_tier(2, 0.03, SloTier::Interactive));
        b.enqueue(item_tier(3, 0.02, SloTier::Batch));
        let order: Vec<usize> = match b.schedule(1.0, None) {
            Round::Admit(v) => v.into_iter().map(|i| i.request_idx).collect(),
            r => panic!("{r:?}"),
        };
        assert_eq!(order, vec![2, 3, 1, 0]);
    }

    #[test]
    fn requeue_preempted_keeps_position_and_accounting() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 4,
            batch_timeout_s: 0.0,
            prefill_per_round: 4,
        });
        b.enqueue(item_tier(0, 0.0, SloTier::Batch));
        let out = match b.schedule(0.1, None) {
            Round::Admit(v) => v,
            r => panic!("{r:?}"),
        };
        assert_eq!(b.active(), 1);
        b.enqueue(item_tier(1, 0.2, SloTier::Interactive));
        b.requeue_preempted(out[0].clone());
        assert_eq!(b.active(), 0);
        assert_eq!(b.stats.preempted, 1);
        assert_eq!(b.stats.admitted, 0, "preempted item no longer counts admitted");
        assert_eq!(b.queue_len(), 2);
        assert_eq!(
            b.queued_new_len(),
            1,
            "preempted items are not new intake for the admission gate"
        );
        let head = b.peek_head().expect("queue non-empty");
        assert_eq!(head.request_idx, 1, "interactive arrival pops first");
        // no admission hold: the next schedule round pops immediately,
        // interactive first, then the preempted item flagged for resume
        let order: Vec<(usize, bool)> = match b.schedule(0.3, None) {
            Round::Admit(v) => {
                v.into_iter().map(|i| (i.request_idx, i.preempted)).collect()
            }
            r => panic!("{r:?}"),
        };
        assert_eq!(order, vec![(1, false), (0, true)]);
    }

    #[test]
    fn requeued_slo_item_does_not_inflate_edf_jumps() {
        let mut b = Batcher::new(BatcherConfig {
            max_active: 8,
            batch_timeout_s: 0.0,
            prefill_per_round: 1,
        });
        b.enqueue(item_slo(0, 0.0, 0.5));
        b.enqueue(item(1, 0.0));
        assert_eq!(b.stats.edf_jumps, 0, "0 entered an empty queue, 1 sorts after");
        // pop the SLO item, bounce it back over the deadline-free one:
        // it returns to its own position — not a reordering
        let out = match b.schedule(0.1, None) {
            Round::Admit(v) => v,
            r => panic!("{r:?}"),
        };
        assert_eq!(out[0].request_idx, 0);
        b.requeue_front(out[0].clone());
        assert_eq!(b.stats.edf_jumps, 0, "requeue over queued work doesn't count");
        // a *fresh* urgent enqueue ahead of queued work does
        b.enqueue(item_slo(2, 0.2, 0.25));
        assert_eq!(b.stats.edf_jumps, 1);
    }
}
