//! The serving front: drives the engine over an arrival trace with
//! continuous batching, session reuse and plugins, under a virtual clock.
//!
//! Queueing is discrete-event (arrivals advance the clock; every compute
//! quantum advances it by its *measured* wall time), so P50/P99 latency
//! distributions are honest even though the box has one core and cannot
//! actually sleep out a 50ms Poisson gap per request.

use std::collections::HashMap;

use anyhow::Result;

use crate::engine::{Engine, Sampling, Sequence};
use crate::metrics::{RequestRecord, ServerMetrics, StepMetrics};
use crate::plugins::{Pipeline, PluginAction, StepView};
use crate::util::rng::Rng;
use crate::workload::{tasks, Request};

use super::batcher::{Batcher, BatcherConfig, BatcherStats, QueuedItem, Round};
use super::router::{Router, RouterStats};
use super::session::{SessionStats, SessionStore};

#[derive(Clone)]
pub struct ServeOptions {
    pub sampling: Sampling,
    /// virtual workers for routing/migration accounting (real compute is
    /// single-engine; Table 8 scales via hwmodel)
    pub n_workers: usize,
    pub max_sessions: usize,
    pub batcher: BatcherConfig,
    /// use the chunked prefill artifact (true) or the stepwise decode path
    pub artifact_prefill: bool,
    pub collect_traces: bool,
    pub seed: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            sampling: Sampling::Greedy,
            n_workers: 1,
            max_sessions: 32,
            batcher: BatcherConfig::default(),
            artifact_prefill: true,
            collect_traces: false,
            seed: 42,
        }
    }
}

#[derive(Debug)]
pub struct ServeReport {
    pub metrics: ServerMetrics,
    pub requests: Vec<RequestRecord>,
    pub session_stats: SessionStats,
    pub router_stats: RouterStats,
    pub batcher_stats: BatcherStats,
    /// exact-match accuracy over requests with a known answer
    pub accuracy: f64,
    pub char_accuracy: f64,
    /// per-task (name, exact-match, n)
    pub per_task: Vec<(String, f64, usize)>,
    /// virtual wall-clock of the run
    pub wall_s: f64,
    /// fraction of wall time the engine was executing
    pub busy_frac: f64,
}

struct Active {
    seq: Sequence,
    req_idx: usize,
    admitted_s: f64,
    prefill_s: f64,
    first_token_s: Option<f64>,
    reused_tokens: usize,
    worker: usize,
}

/// Run a full trace through the engine. The engine's serving config decides
/// policy/budget/page size; `opts` decides coordination behaviour.
pub fn serve_trace(
    engine: &mut Engine,
    trace: &[Request],
    opts: &ServeOptions,
    plugins: &mut Pipeline,
) -> Result<ServeReport> {
    let mut rng = Rng::new(opts.seed);
    let mut batcher = Batcher::new(BatcherConfig {
        max_active: opts.batcher.max_active.min(engine.cfg.max_active),
        ..opts.batcher.clone()
    });
    let mut sessions = SessionStore::new(opts.max_sessions);
    let mut router = Router::new(opts.n_workers);
    let mut metrics = ServerMetrics::new(opts.collect_traces);
    let mut records: Vec<RequestRecord> = Vec::new();
    let mut active: Vec<Active> = Vec::new();
    let mut per_task: HashMap<&'static str, (f64, f64, usize)> = HashMap::new();

    let mut now = 0.0f64;
    let mut busy = 0.0f64;
    let mut next = 0usize; // next trace index not yet enqueued
    let mut exact_hits = 0usize;
    let mut char_acc_sum = 0.0f64;
    let mut scored = 0usize;

    loop {
        // pull arrivals that have happened
        while next < trace.len() && trace[next].arrival_s <= now {
            batcher.enqueue(QueuedItem {
                request_idx: next,
                arrival_s: trace[next].arrival_s,
                prompt_len: trace[next].prompt.len(),
            });
            next += 1;
        }
        let next_arrival = trace.get(next).map(|r| r.arrival_s);
        let done = next >= trace.len() && batcher.queue_len() == 0 && active.is_empty();
        if done {
            break;
        }

        match batcher.schedule(now, next_arrival) {
            Round::Idle(t) => {
                if t.is_infinite() {
                    break;
                }
                now = now.max(t);
            }
            Round::Admit(items) => {
                let mut deferred: Vec<QueuedItem> = Vec::new();
                for item in items {
                    let req = &trace[item.request_idx];
                    // KV-budget admission control: shed idle session
                    // snapshots first; if the prompt still cannot fit, defer
                    // while in-flight work can retire and free pages. Once
                    // one item defers, later ones follow to keep FIFO order.
                    if !deferred.is_empty() {
                        deferred.push(item);
                        continue;
                    }
                    if !engine.kv_admission_ok(req.prompt.len()) {
                        while !engine.kv_admission_ok(req.prompt.len())
                            && sessions.evict_one_lru(&mut engine.pool, req.session)
                        {}
                    }
                    if !engine.kv_admission_ok(req.prompt.len()) && !active.is_empty() {
                        deferred.push(item);
                        continue;
                    }
                    let mut seq = engine.new_sequence();
                    seq.max_new_tokens = req.max_new_tokens;
                    // session reuse: restore the stored prompt prefix
                    let mut reused = 0usize;
                    let pinned = req.session.and_then(|s| sessions.worker_of(s));
                    let decision = router.route(pinned);
                    if let Some(sid) = req.session {
                        if let Some(from) = decision.migrate_from {
                            let _ = from;
                            let bytes =
                                sessions.migrate(sid, decision.worker, &engine.pool);
                            // migration transit at ~200 GB/s NVLink-class
                            now += bytes as f64 / 200e9;
                        }
                        if let Some((cache, n)) =
                            sessions.try_reuse(sid, &req.prompt, &mut engine.pool)
                        {
                            seq.cache = cache;
                            reused = n;
                        }
                    }
                    seq.tokens = req.prompt.clone();
                    // prefill the (remaining) prompt, measured
                    let mut m = StepMetrics::default();
                    let t0 = std::time::Instant::now();
                    if opts.artifact_prefill
                        && engine.rt.info.find_artifact("prefill", 1, None).is_ok()
                    {
                        engine.prefill(&mut seq, &mut m)?;
                    } else {
                        engine.prefill_stepwise(&mut seq, &mut m)?;
                    }
                    let dt = t0.elapsed().as_secs_f64();
                    now += dt;
                    busy += dt;
                    // snapshot the prompt prefix for future session turns
                    if let Some(sid) = req.session {
                        sessions.store(
                            sid,
                            &seq.cache,
                            &req.prompt[..seq.cache.pos],
                            decision.worker,
                            &mut engine.pool,
                        );
                    }
                    // prefill/snapshot allocations bypass the decode path;
                    // demote back under the budget before decoding resumes
                    engine.enforce_kv_budget();
                    active.push(Active {
                        seq,
                        req_idx: item.request_idx,
                        admitted_s: item.arrival_s,
                        prefill_s: dt,
                        first_token_s: None,
                        reused_tokens: reused,
                        worker: decision.worker,
                    });
                }
                // front of the queue must stay FIFO: requeue in reverse
                for item in deferred.into_iter().rev() {
                    batcher.requeue_front(item);
                }
            }
            Round::Decode => {
                let b = engine.max_batch().min(active.len());
                let mut m = StepMetrics::default();
                let outs = {
                    let mut batch: Vec<&mut Active> =
                        active.iter_mut().take(b).collect();
                    let mut seqs: Vec<&mut Sequence> =
                        batch.iter_mut().map(|a| &mut a.seq).collect();
                    engine.decode_step(&mut seqs, opts.sampling, &mut rng, &mut m)?
                };
                // spill_seconds is the simulated cold-tier transfer cost of
                // the budgeted store (hwmodel-priced, not wall time)
                now += m.step_seconds + m.spill_seconds;
                busy += m.step_seconds + m.spill_seconds;
                metrics.on_step(&m);
                // plugins + first-token bookkeeping
                for (a, o) in active.iter_mut().take(b).zip(outs.iter()) {
                    if a.first_token_s.is_none() {
                        a.first_token_s = Some(now);
                    }
                    let action = if plugins.is_empty() {
                        PluginAction::Continue
                    } else {
                        plugins.on_step(&StepView {
                            seq: &a.seq,
                            sample: o,
                            attn_entropy: a.seq.last_entropy,
                            pool: &engine.pool,
                        })
                    };
                    match action {
                        PluginAction::Stop => a.seq.finished = true,
                        // routed through the page store: the eviction
                        // policy's rank picks the victim, not table order
                        PluginAction::PruneColdest => engine.prune_coldest(&mut a.seq),
                        PluginAction::Continue => {}
                    }
                }
                // retire finished sequences
                let mut i = 0;
                while i < active.len() {
                    if active[i].seq.finished {
                        let mut a = active.swap_remove(i);
                        let req = &trace[a.req_idx];
                        let gen = tasks::decode_ids(a.seq.generated_tokens());
                        if let Some(ans) = &req.answer {
                            let doc = tasks::Doc {
                                prompt: String::new(),
                                answer: ans.clone(),
                            };
                            let hit = tasks::answer_matches(&doc, &gen);
                            let ca = tasks::answer_char_accuracy(&doc, &gen);
                            exact_hits += hit as usize;
                            char_acc_sum += ca;
                            scored += 1;
                            if let Some(t) = req.task {
                                let e = per_task.entry(t.name()).or_insert((0.0, 0.0, 0));
                                e.0 += hit as u8 as f64;
                                e.1 += ca;
                                e.2 += 1;
                            }
                        }
                        let rec = RequestRecord {
                            id: req.id,
                            queue_seconds: a.admitted_s - req.arrival_s,
                            prefill_seconds: a.prefill_s,
                            ttft_seconds: a
                                .first_token_s
                                .map(|t| t - req.arrival_s)
                                .unwrap_or(0.0),
                            decode_seconds: now - a.admitted_s - a.prefill_s,
                            e2e_seconds: now - req.arrival_s,
                            prompt_tokens: req.prompt.len(),
                            new_tokens: a.seq.generated,
                            session_reused_tokens: a.reused_tokens,
                        };
                        metrics.on_request(&rec);
                        records.push(rec);
                        router.complete(a.worker);
                        batcher.on_finished(1);
                        engine.release(&mut a.seq);
                        plugins.reset();
                    } else {
                        i += 1;
                    }
                }
            }
        }
    }

    metrics.run_seconds = now;
    sessions.clear(&mut engine.pool);
    let mut per_task_out: Vec<(String, f64, usize)> = per_task
        .into_iter()
        .map(|(k, (hits, _ca, n))| (k.to_string(), hits / n.max(1) as f64, n))
        .collect();
    per_task_out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(ServeReport {
        accuracy: if scored > 0 { exact_hits as f64 / scored as f64 } else { f64::NAN },
        char_accuracy: if scored > 0 { char_acc_sum / scored as f64 } else { f64::NAN },
        per_task: per_task_out,
        session_stats: sessions.stats.clone(),
        router_stats: router.stats.clone(),
        batcher_stats: std::mem::take(&mut batcher.stats),
        metrics,
        requests: records,
        wall_s: now,
        busy_frac: if now > 0.0 { busy / now } else { 0.0 },
    })
}
