//! The serving front: `ServeOptions`/`ServeReport` definitions and the
//! deprecated `serve_trace` batch shim over the request-lifecycle
//! `Frontend` (see `coordinator::frontend`).
//!
//! Queueing is discrete-event (arrivals advance the clock; every compute
//! quantum advances it by its *measured* wall time), so P50/P99 latency
//! distributions are honest even though the box has one core and cannot
//! actually sleep out a 50ms Poisson gap per request.

use anyhow::Result;

use crate::engine::{Engine, Sampling};
use crate::metrics::{RequestRecord, ServerMetrics};
use crate::plugins::Pipeline;
use crate::workload::Request;

use super::batcher::{BatcherConfig, BatcherStats};
use super::frontend::Frontend;
use super::pool::WorkerStats;
use super::router::RouterStats;
use super::session::SessionStats;

/// How the frontend's discrete-event clock prices compute quanta.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeModel {
    /// advance by measured wall time of each prefill/decode call (honest
    /// latency percentiles on this box; run-to-run timing jitter)
    Measured,
    /// advance by hwmodel-priced durations — fully deterministic from the
    /// seed, so two identical runs produce bit-identical `ServeEvent`
    /// streams including timestamps (determinism tests, CI double-run
    /// diffs, golden serve reports)
    Modeled,
}

impl TimeModel {
    pub fn parse(s: &str) -> Option<TimeModel> {
        match s {
            "measured" => Some(TimeModel::Measured),
            "modeled" => Some(TimeModel::Modeled),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TimeModel::Measured => "measured",
            TimeModel::Modeled => "modeled",
        }
    }
}

#[derive(Clone)]
pub struct ServeOptions {
    pub sampling: Sampling,
    /// virtual workers for routing/migration accounting *within* each
    /// engine worker (real concurrency is the pool's worker count, set by
    /// building the frontend over a `WorkerPool`)
    pub n_workers: usize,
    pub max_sessions: usize,
    pub batcher: BatcherConfig,
    /// use the chunked prefill artifact (true) or the stepwise decode path
    pub artifact_prefill: bool,
    pub collect_traces: bool,
    /// virtual-clock pricing (measured wall time vs deterministic model)
    pub time_model: TimeModel,
    pub seed: u64,
    /// OS threads for the decode round's step phase (1 = sequential).
    /// Under `TimeModel::Modeled` the event stream is byte-identical for
    /// every value — threading buys wall-clock time, never different
    /// results (see the "Threading model" section of docs/serving_api.md).
    pub threads: usize,
    /// which multi-threaded step-phase implementation `threads > 1`
    /// selects: long-lived per-worker decode threads (`Persistent`, the
    /// default) or per-round scoped spawn/join (`Scoped`). Byte-identical
    /// event streams under `TimeModel::Modeled` either way (`--executor`).
    pub executor: super::pool::ExecutorKind,
    /// emit a metrics-registry JSONL snapshot every N committed decode
    /// rounds to the frontend's metrics sink (0 = off; `--metrics-every`)
    pub metrics_every: usize,
    /// record executor phase wall times (dispatch/step/commit + per-round
    /// worker skew) and attach a `PhaseProfile` to the report
    /// (`--profile`); wall-measured, so never part of deterministic output
    pub profile: bool,
    /// SLO-class preemption (`--preempt`): a starving higher-tier queue
    /// head may pause the lowest-tier active, snapshotting its KV pages
    /// into the cold/spill tiers for an exact resume
    pub preempt: bool,
    /// commit-seam work stealing (`--steal`): an idle pool worker ports
    /// one sequence from the most loaded worker's batch
    pub steal: bool,
    /// attach per-worker cache-analytics recorders (`--analytics-out`):
    /// snapshots drain to the frontend's analytics sink every
    /// `metrics_every` rounds (or only at shutdown when that is 0)
    pub analytics: bool,
    /// audit bbox selection against the exact-attention oracle every N
    /// engine decode steps (`--audit-selection N`; 0 = off, requires
    /// `analytics`)
    pub audit_every: usize,
    /// stall watchdog (`--stall-rounds N`): emit a `stalled` trace event +
    /// counter when an Active request makes no token progress for N
    /// consecutive committed rounds (0 = off)
    pub stall_rounds: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            sampling: Sampling::Greedy,
            n_workers: 1,
            max_sessions: 32,
            batcher: BatcherConfig::default(),
            artifact_prefill: true,
            collect_traces: false,
            time_model: TimeModel::Measured,
            seed: 42,
            threads: 1,
            executor: super::pool::ExecutorKind::Persistent,
            metrics_every: 0,
            profile: false,
            preempt: false,
            steal: false,
            analytics: false,
            audit_every: 0,
            stall_rounds: 0,
        }
    }
}

impl ServeOptions {
    /// The round executor the `threads` + `executor` knobs select.
    pub fn round_executor(&self) -> super::pool::RoundExecutor {
        self.executor.executor(self.threads)
    }
}

/// Per-worker cache-analytics summary attached to the serve report when
/// `ServeOptions::analytics` ran (see `trace::analytics`).
#[derive(Debug, Clone)]
pub struct AnalyticsSummary {
    pub worker: usize,
    /// page accesses recorded by the decode selection loop
    pub accesses: u64,
    /// fraction of accesses that found their page hot
    pub hit_rate: f64,
    /// selection-quality audit records (`--audit-selection N`)
    pub audit_records: u64,
    /// overall top-k recall of bbox selection vs the exact-attention
    /// oracle; `None` when no audit ran
    pub mean_recall: Option<f64>,
}

/// One worker's KV residency inside a [`LiveStats`] snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerKv {
    pub kv_bytes_in_use: u64,
    pub pages_hot: u64,
    pub pages_cold: u64,
    pub pages_disk: u64,
}

/// Live introspection snapshot of a running frontend: the payload behind
/// the wire-level `stats` op (proto schema 3). Every field is read off the
/// pump thread between rounds, so the numbers are mutually consistent.
/// Tier-indexed arrays follow `SloTier::rank()` order (interactive, batch,
/// background).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LiveStats {
    /// virtual clock at snapshot time
    pub t: f64,
    /// admission-queue depth per SLO tier (new intake, not preempted
    /// requeues)
    pub queued_by_tier: [u64; 3],
    pub active: u64,
    pub preempted: u64,
    pub deferred: u64,
    /// per-pool-worker KV residency
    pub workers: Vec<WorkerKv>,
    /// per-tier first tokens that met the tier's TTFT target
    pub ttft_attained: [u64; 3],
    /// per-tier first tokens observed
    pub ttft_total: [u64; 3],
    /// stall-watchdog firings so far
    pub stalled: u64,
}

#[derive(Debug)]
pub struct ServeReport {
    pub metrics: ServerMetrics,
    pub requests: Vec<RequestRecord>,
    pub session_stats: SessionStats,
    /// merged shared-prefix cache counters across workers (all zero when
    /// `--prefix-cache-mb` is off)
    pub prefix_stats: crate::kvcache::prefix::PrefixStats,
    pub router_stats: RouterStats,
    pub batcher_stats: BatcherStats,
    /// exact-match accuracy over requests with a known answer
    pub accuracy: f64,
    pub char_accuracy: f64,
    /// per-task (name, exact-match, n)
    pub per_task: Vec<(String, f64, usize)>,
    /// virtual wall-clock of the run
    pub wall_s: f64,
    /// fraction of wall time the engine was executing (sum of worker busy
    /// time over wall; > 1.0 means workers genuinely overlapped)
    pub busy_frac: f64,
    /// per-engine-worker counters (one entry per pool slot; single-engine
    /// frontends report exactly one)
    pub worker_stats: Vec<WorkerStats>,
    /// executor phase wall-time profile (`ServeOptions::profile`)
    pub profile: Option<crate::trace::PhaseProfile>,
    /// per-worker cache-analytics summary (`ServeOptions::analytics`);
    /// empty when analytics never ran
    pub analytics: Vec<AnalyticsSummary>,
}

/// Run a full trace through the engine: submit every request up front,
/// pump the frontend to completion, return the report. The engine's
/// serving config decides policy/budget/page size; `opts` decides
/// coordination behaviour.
///
/// Deprecated shim kept so trace-driven benches compile unchanged with
/// seed-identical metrics; live callers should drive a
/// [`Frontend`](super::frontend::Frontend) directly for streaming tokens,
/// cancellation and deadline-aware admission.
#[deprecated(
    note = "use coordinator::Frontend (submit/cancel/step/drain) for \
            per-request lifecycles; this shim only replays traces"
)]
pub fn serve_trace(
    engine: &mut Engine,
    trace: &[Request],
    opts: &ServeOptions,
    plugins: &mut Pipeline,
) -> Result<ServeReport> {
    let mut fe = Frontend::builder().options(opts.clone()).build(engine, plugins);
    for req in trace {
        fe.submit(req.clone());
    }
    // discard events per round instead of drain(): a trace replay has no
    // event consumer, so don't buffer O(total tokens) of them
    while fe.has_work() {
        fe.step()?;
    }
    Ok(fe.into_report())
}
