//! Request router over N (virtual) workers: session-affine least-loaded
//! assignment with migration when the pinned worker is overloaded.
//!
//! On this single-core box the workers are virtual (the cost model prices
//! real multi-GPU dispatch, Table 8); the routing *logic* — affinity,
//! load balance, migration trade-off — is the real, tested artifact.

#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub routed: u64,
    pub affinity_hits: u64,
    pub migrations_triggered: u64,
    pub rebalances: u64,
}

pub struct Router {
    loads: Vec<usize>,
    /// load imbalance factor that triggers migration away from the pinned
    /// worker: migrate when pinned load > factor * min load + 1
    pub imbalance_factor: f64,
    pub stats: RouterStats,
}

#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub struct RouteDecision {
    pub worker: usize,
    /// session pages must move from this worker first
    pub migrate_from: Option<usize>,
}

impl Router {
    pub fn new(n_workers: usize) -> Router {
        assert!(n_workers > 0);
        Router {
            loads: vec![0; n_workers],
            imbalance_factor: 2.0,
            stats: RouterStats::default(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.loads.len()
    }

    pub fn load(&self, w: usize) -> usize {
        self.loads[w]
    }

    fn least_loaded(&self) -> usize {
        (0..self.loads.len()).min_by_key(|&w| self.loads[w]).unwrap()
    }

    /// Route a request. `pinned`: worker holding the session's cache.
    pub fn route(&mut self, pinned: Option<usize>) -> RouteDecision {
        self.stats.routed += 1;
        let best = self.least_loaded();
        let d = match pinned {
            Some(p) => {
                let threshold =
                    (self.loads[best] as f64 * self.imbalance_factor) + 1.0;
                if (self.loads[p] as f64) <= threshold {
                    self.stats.affinity_hits += 1;
                    RouteDecision { worker: p, migrate_from: None }
                } else {
                    self.stats.migrations_triggered += 1;
                    RouteDecision { worker: best, migrate_from: Some(p) }
                }
            }
            None => RouteDecision { worker: best, migrate_from: None },
        };
        self.loads[d.worker] += 1;
        d
    }

    pub fn complete(&mut self, worker: usize) {
        debug_assert!(self.loads[worker] > 0);
        self.loads[worker] -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spreads_fresh_requests() {
        let mut r = Router::new(4);
        let workers: Vec<usize> = (0..8).map(|_| r.route(None).worker).collect();
        for w in 0..4 {
            assert_eq!(workers.iter().filter(|&&x| x == w).count(), 2);
        }
    }

    #[test]
    fn session_affinity_under_balance() {
        let mut r = Router::new(4);
        let d = r.route(Some(2));
        assert_eq!(d.worker, 2);
        assert_eq!(d.migrate_from, None);
        assert_eq!(r.stats.affinity_hits, 1);
    }

    #[test]
    fn migrates_away_from_overload() {
        let mut r = Router::new(2);
        for _ in 0..6 {
            let d = r.route(None);
            // manually pin everything on worker 0 to force imbalance
            if d.worker == 1 {
                r.complete(1);
                r.loads[0] += 1;
            }
        }
        assert!(r.load(0) >= 6);
        let d = r.route(Some(0));
        assert_eq!(d.worker, 1);
        assert_eq!(d.migrate_from, Some(0));
        assert_eq!(r.stats.migrations_triggered, 1);
    }

    #[test]
    fn complete_decrements() {
        let mut r = Router::new(2);
        let d = r.route(None);
        assert_eq!(r.load(d.worker), 1);
        r.complete(d.worker);
        assert_eq!(r.load(d.worker), 0);
    }
}
