//! L3 coordination: EDF continuous batcher, session manager, request
//! router, worker pool, and the request-lifecycle serving frontend (paper
//! §3.1 "Modular Scheduling Pipeline" + §4.4). `frontend::Frontend` is the
//! front door — submit/cancel/step/drain with typed `ServeEvent`s over one
//! borrowed engine or a `pool::WorkerPool` of N owned engine workers;
//! `server::serve_trace` remains as a deprecated batch shim over it.

pub mod batcher;
pub mod frontend;
pub mod pool;
pub mod router;
pub mod server;
pub mod session;

pub use batcher::{Batcher, BatcherConfig, Round};
pub use frontend::{
    event_log_header, Clock, Frontend, FrontendBuilder, Lifecycle,
    RequestHandle, ServeEvent, EVENT_LOG_SCHEMA,
};
pub use pool::{
    DispatchKind, ExecutorKind, PersistentExecutor, RoundExecutor, WorkerPool,
    WorkerStats,
};
pub use router::Router;
#[allow(deprecated)]
pub use server::serve_trace;
pub use server::{
    AnalyticsSummary, LiveStats, ServeOptions, ServeReport, TimeModel, WorkerKv,
};
pub use session::SessionStore;
