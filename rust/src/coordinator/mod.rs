//! L3 coordination: continuous batcher, session manager, request router and
//! the serving loop (paper §3.1 "Modular Scheduling Pipeline" + §4.4).

pub mod batcher;
pub mod router;
pub mod server;
pub mod session;

pub use batcher::{Batcher, BatcherConfig, Round};
pub use router::Router;
pub use server::{serve_trace, ServeOptions, ServeReport};
pub use session::SessionStore;
