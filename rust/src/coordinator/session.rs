//! Session manager: cross-request KV reuse (paper §4.4.2).
//!
//! After a request's prefill completes, its prompt-prefix cache can be
//! snapshotted under the session id. A follow-up whose prompt extends the
//! stored token prefix restores the snapshot and prefills only the suffix.
//! Snapshots share full pages with live sequences by refcount (see
//! `kvcache::seq`), so storage cost is one partial page per snapshot.

use std::collections::HashMap;

use crate::kvcache::{PagePool, SeqCache};

#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    pub stores: u64,
    pub hits: u64,
    pub misses: u64,
    pub reused_tokens: u64,
    pub evictions: u64,
    /// evictions forced by KV-budget pressure (admission path), a subset
    /// of `evictions`
    pub pressure_evictions: u64,
    /// simulated cross-worker migrations (router-driven)
    pub migrations: u64,
    pub migrated_bytes: u64,
}

impl SessionStats {
    /// Fold another worker's session counters into this one (the pooled
    /// frontend keeps one `SessionStore` per engine worker — snapshots
    /// hold pages of that worker's pool — and reports merged stats).
    pub fn merge(&mut self, o: &SessionStats) {
        self.stores += o.stores;
        self.hits += o.hits;
        self.misses += o.misses;
        self.reused_tokens += o.reused_tokens;
        self.evictions += o.evictions;
        self.pressure_evictions += o.pressure_evictions;
        self.migrations += o.migrations;
        self.migrated_bytes += o.migrated_bytes;
    }

    pub fn reuse_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Stored {
    cache: SeqCache,
    /// tokens covered by the snapshot (prompt prefix incl. BOS)
    tokens: Vec<i32>,
    last_used: u64,
    /// virtual worker currently holding the pages (router pinning)
    pub worker: usize,
}

/// LRU-bounded store of prompt-prefix snapshots.
pub struct SessionStore {
    map: HashMap<u64, Stored>,
    max_sessions: usize,
    clock: u64,
    pub stats: SessionStats,
}

impl SessionStore {
    pub fn new(max_sessions: usize) -> SessionStore {
        SessionStore {
            map: HashMap::new(),
            max_sessions,
            clock: 0,
            stats: SessionStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Whether a snapshot for this session id is resident (the pooled
    /// frontend routes a session's next turn to the store that holds it).
    pub fn contains(&self, id: u64) -> bool {
        self.map.contains_key(&id)
    }

    /// Store (or refresh) a session snapshot. `cache` is snapshotted;
    /// the previous snapshot for the id (if any) is released.
    pub fn store(
        &mut self,
        id: u64,
        cache: &SeqCache,
        tokens: &[i32],
        worker: usize,
        pool: &mut PagePool,
    ) {
        self.clock += 1;
        let snap = cache.snapshot(pool);
        if let Some(mut old) = self.map.remove(&id) {
            old.cache.clear(pool);
        }
        self.map.insert(
            id,
            Stored {
                cache: snap,
                tokens: tokens.to_vec(),
                last_used: self.clock,
                worker,
            },
        );
        self.stats.stores += 1;
        // LRU eviction
        while self.map.len() > self.max_sessions {
            let lru = *self
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k)
                .unwrap();
            if let Some(mut s) = self.map.remove(&lru) {
                s.cache.clear(pool);
            }
            self.stats.evictions += 1;
        }
    }

    /// Try to reuse a stored snapshot for a new prompt: the *longest common
    /// token prefix* is restored at page granularity (vLLM-style prefix
    /// caching), so follow-ups that share the session context but ask a
    /// different question still reuse the context pages. Returns the
    /// restored cache and the number of reused tokens; the engine prefills
    /// only the remainder. At least one prompt token is left pending.
    pub fn try_reuse(
        &mut self,
        id: u64,
        prompt: &[i32],
        pool: &mut PagePool,
    ) -> Option<(SeqCache, usize)> {
        self.clock += 1;
        let clock = self.clock;
        let min_reuse = pool.page_size; // not worth restoring below one page
        match self.map.get_mut(&id) {
            Some(s) => {
                let common = s
                    .tokens
                    .iter()
                    .zip(prompt.iter())
                    .take_while(|(a, b)| a == b)
                    .count()
                    .min(prompt.len().saturating_sub(1));
                if common < min_reuse {
                    self.stats.misses += 1;
                    return None;
                }
                s.last_used = clock;
                let (restored, covered) =
                    SeqCache::restore_prefix(&s.cache, pool, common);
                if covered == 0 {
                    self.stats.misses += 1;
                    return None;
                }
                self.stats.hits += 1;
                self.stats.reused_tokens += covered as u64;
                Some((restored, covered))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Retire the least-recently-used snapshot to relieve KV-budget
    /// pressure, releasing its pages. `except` protects the session the
    /// incoming request wants to reuse — shedding it would force a full
    /// re-prefill and make the pressure worse. Returns false when no
    /// sheddable snapshot is left.
    pub fn evict_one_lru(&mut self, pool: &mut PagePool, except: Option<u64>) -> bool {
        let lru = self
            .map
            .iter()
            .filter(|(&k, _)| Some(k) != except)
            .min_by_key(|(_, s)| s.last_used)
            .map(|(&k, _)| k);
        match lru {
            Some(id) => {
                if let Some(mut s) = self.map.remove(&id) {
                    s.cache.clear(pool);
                }
                self.stats.evictions += 1;
                self.stats.pressure_evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Which virtual worker holds the session's pages (for the router).
    pub fn worker_of(&self, id: u64) -> Option<usize> {
        self.map.get(&id).map(|s| s.worker)
    }

    /// Simulated migration of a session's pages to another worker:
    /// accounts bytes over the inter-GPU link (cost model consumes this).
    pub fn migrate(&mut self, id: u64, to_worker: usize, pool: &PagePool) -> usize {
        if let Some(s) = self.map.get_mut(&id) {
            if s.worker != to_worker {
                s.worker = to_worker;
                let bytes = s.cache.resident * pool.d_kv * 2 * 4 * pool.n_layers;
                self.stats.migrations += 1;
                self.stats.migrated_bytes += bytes as u64;
                return bytes;
            }
        }
        0
    }

    pub fn clear(&mut self, pool: &mut PagePool) {
        for (_, mut s) in self.map.drain() {
            s.cache.clear(pool);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KvDtype;

    fn fill(pool: &mut PagePool, n: usize) -> SeqCache {
        let mut seq = SeqCache::new();
        for i in 0..n {
            let (page, slot) = seq.slot_for_next(pool);
            pool.write_token(page, slot, 0, &[i as f32; 4], &[i as f32; 4]);
            seq.commit_token();
        }
        seq
    }

    #[test]
    fn prefix_hit_and_miss() {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(4);
        let seq = fill(&mut pool, 6);
        store.store(1, &seq, &[10, 11, 12, 13, 14, 15], 0, &mut pool);

        // extending prompt -> hit
        let (restored, reused) = store
            .try_reuse(1, &[10, 11, 12, 13, 14, 15, 16, 17], &mut pool)
            .expect("prefix hit");
        assert_eq!(reused, 6);
        assert_eq!(restored.pos, 6);

        // diverging prompt -> miss
        assert!(store.try_reuse(1, &[10, 99], &mut pool).is_none());
        // unknown session -> miss
        assert!(store.try_reuse(7, &[10], &mut pool).is_none());
        assert_eq!(store.stats.hits, 1);
        assert_eq!(store.stats.misses, 2);

        let mut restored = restored;
        restored.clear(&mut pool);
        let mut seq = seq;
        seq.clear(&mut pool);
        store.clear(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
        pool.validate().unwrap();
    }

    #[test]
    fn lru_eviction_releases_pages() {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(2);
        for id in 0..3u64 {
            let mut seq = fill(&mut pool, 4);
            store.store(id, &seq, &[id as i32; 4], 0, &mut pool);
            seq.clear(&mut pool);
        }
        assert_eq!(store.len(), 2);
        assert_eq!(store.stats.evictions, 1);
        assert!(store.try_reuse(0, &[0; 8], &mut pool).is_none(), "0 was LRU");
        store.clear(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn pressure_eviction_sheds_lru_first() {
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(8);
        for id in 0..3u64 {
            let mut seq = fill(&mut pool, 4);
            store.store(id, &seq, &[id as i32; 4], 0, &mut pool);
            seq.clear(&mut pool);
        }
        // refresh session 0 so 1 becomes LRU
        let (mut r, _) = store
            .try_reuse(0, &[0, 0, 0, 0, 9], &mut pool)
            .expect("refresh hit");
        r.clear(&mut pool);
        assert!(store.evict_one_lru(&mut pool, None));
        assert_eq!(store.len(), 2);
        assert_eq!(store.stats.pressure_evictions, 1);
        assert!(store.try_reuse(1, &[1; 5], &mut pool).is_none(), "1 was shed");
        // the incoming request's own session is protected
        assert!(store.evict_one_lru(&mut pool, Some(0)));
        let (mut r0, _) = store
            .try_reuse(0, &[0, 0, 0, 0, 7], &mut pool)
            .expect("protected session still reusable");
        r0.clear(&mut pool);
        assert!(store.evict_one_lru(&mut pool, None));
        assert!(!store.evict_one_lru(&mut pool, None), "store drained");
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn restore_after_original_freed() {
        // snapshot must stay valid after the live sequence is cleared
        let mut pool = PagePool::new(1, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(4);
        let mut seq = fill(&mut pool, 5);
        store.store(9, &seq, &[1, 2, 3, 4, 5], 0, &mut pool);
        seq.clear(&mut pool);
        let (mut r, reused) = store.try_reuse(9, &[1, 2, 3, 4, 5, 6], &mut pool).unwrap();
        assert_eq!(reused, 5);
        assert_eq!(pool.key_row(r.pages[0].id, 0, 2), vec![2.0; 4]);
        r.clear(&mut pool);
        store.clear(&mut pool);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn stats_merge_sums_every_counter() {
        let mut a = SessionStats {
            stores: 1,
            hits: 2,
            misses: 3,
            reused_tokens: 4,
            evictions: 5,
            pressure_evictions: 1,
            migrations: 6,
            migrated_bytes: 7,
        };
        a.merge(&a.clone());
        assert_eq!(a.stores, 2);
        assert_eq!(a.hits, 4);
        assert_eq!(a.misses, 6);
        assert_eq!(a.reused_tokens, 8);
        assert_eq!(a.evictions, 10);
        assert_eq!(a.pressure_evictions, 2);
        assert_eq!(a.migrations, 12);
        assert_eq!(a.migrated_bytes, 14);
    }

    #[test]
    fn migration_accounting() {
        let mut pool = PagePool::new(2, 4, 4, KvDtype::F32);
        let mut store = SessionStore::new(4);
        let mut seq = fill(&mut pool, 8);
        store.store(1, &seq, &[0; 8], 0, &mut pool);
        seq.clear(&mut pool);
        assert_eq!(store.worker_of(1), Some(0));
        let bytes = store.migrate(1, 2, &pool);
        assert!(bytes > 0);
        assert_eq!(store.worker_of(1), Some(2));
        assert_eq!(store.migrate(1, 2, &pool), 0, "already there");
        assert_eq!(store.stats.migrations, 1);
        store.clear(&mut pool);
    }
}
