//! Request-lifecycle serving frontend: the event-driven replacement for the
//! monolithic `serve_trace` batch call, pumping one *or many* engine
//! workers.
//!
//! A `Frontend` owns the discrete-event virtual `Clock` and the coordinator
//! stack (EDF batcher, router, per-worker session stores) over a
//! [`WorkerPool`](super::pool::WorkerPool) — either a borrowed single
//! engine (`build`) or N pool-owned engines (`build_pool`), each with its
//! own `PageStore` slice of the global KV budget. Callers drive it with
//! per-request operations instead of a pre-materialized trace:
//!
//! ```text
//! let mut fe = Frontend::builder().options(opts).build(&mut engine, &mut plugins);
//! let h = fe.submit(request);          // -> RequestHandle
//! while fe.has_work() {
//!     for ev in fe.step()? {           // typed ServeEvents
//!         match ev {
//!             ServeEvent::Token { id, tok, .. } => stream(id, tok),
//!             ServeEvent::Finished(rec) => done(rec),
//!             _ => {}
//!         }
//!     }
//!     if too_slow { fe.cancel(h.id); } // mid-stream cancellation
//! }
//! let report = fe.into_report();
//! ```
//!
//! Live workloads skip `submit` entirely: `set_source` attaches a
//! [`RequestSource`](crate::workload::RequestSource) (e.g.
//! `workload::openloop::OpenLoopGen`) and the pump pulls arrivals off it
//! against the virtual clock — open-loop serving instead of trace replay.
//!
//! Lifecycle: `Pending` (submitted, arrival in the virtual future) ->
//! `Queued` (in the batcher) -> possibly `Deferred` (admission bounced by
//! KV-budget pressure, still in the queue) -> `Active` (prefilled,
//! decoding) -> one of `Finished` / `Cancelled` / `DeadlineExpired`.
//! Cancellation and deadline expiry release the sequence's KV pages back
//! through the worker's `PageStore` mid-flight: pins are cleared, refcounts
//! drop, and `bytes_in_use` falls immediately — admission pressure relaxes
//! without waiting for the request to run to completion.
//!
//! Multi-worker rounds: admissions dispatch to a worker (round-robin /
//! least-loaded / session-affinity) and prefill serially on the pump.
//! Each decode round then runs in three phases:
//!
//! 1. **dispatch** (pure): build an immutable [`RoundPlan`] — which
//!    active-set indices step on which worker, in ascending worker order —
//!    from a read-only view of the frontend;
//! 2. **step** (parallel): execute the plan through the pool's
//!    [`RoundExecutor`](super::pool::RoundExecutor) — sequential on the
//!    pump thread, or each worker's `&mut Engine` + batch + forked RNG on
//!    a scoped OS thread (`--executor scoped`) or a long-lived persistent
//!    decode thread (`--executor persistent`, the default;
//!    `ServeOptions::threads`, `--threads`); workers share no mutable
//!    state during this phase;
//! 3. **commit** (serial): merge per-worker `StepMetrics` in fixed worker
//!    order, advance the clock by the *slowest* worker while `busy`
//!    accumulates the sum, emit token events, run plugins, retire
//!    finished sequences, and re-queue deferred work.
//!
//! Every worker samples from its own RNG stream (forked from the seed in
//! worker order at construction), so every executor produces
//! byte-identical event streams under `TimeModel::Modeled` — and the
//! serial commit phase is the architectural seam where preemption and
//! cross-worker session migration slot in later without touching the
//! parallel step.
//!
//! The deprecated `serve_trace` shim (`coordinator::server`) is exactly
//! "submit everything, drain, report", so trace-driven benches keep their
//! seed-identical behaviour while live callers get streaming, cancellation
//! and SLO-aware admission.

use std::collections::{HashMap, VecDeque};

use anyhow::Result;

use crate::engine::{Engine, SampleOut, Sequence};
use crate::hwmodel::{HwModel, Shape};
use crate::kvcache::store::StoreTraceEvent;
use crate::metrics::{RequestRecord, ServerMetrics, StepMetrics};
use crate::plugins::{Pipeline, PluginAction, StepView};
use crate::trace::{
    MetricsRegistry, PhaseProfile, RunHeader, SpanCtx, TraceEvent, TraceSink,
    Tracer,
};
use crate::util::rng::Rng;
use crate::kvcache::prefix::{PrefixIndex, PrefixStats};
use crate::kvcache::seq::SeqCache;
use crate::workload::{tasks, Request, RequestSource};

use super::batcher::{Batcher, BatcherConfig, QueuedItem, Round};
use super::pool::{WorkerPool, WorkerStats};
use super::router::Router;
use super::server::{
    AnalyticsSummary, LiveStats, ServeOptions, ServeReport, TimeModel, WorkerKv,
};
use super::session::{SessionStats, SessionStore};

/// Discrete-event virtual clock. Arrivals advance it to their timestamps;
/// every compute quantum (prefill, decode step, simulated spill/migration)
/// advances it by measured or modelled duration — so latency percentiles
/// are honest on a single-core box that cannot sleep out real gaps.
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now: f64,
}

impl Clock {
    pub fn new() -> Clock {
        Clock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by a duration (compute happened).
    pub fn advance(&mut self, dt: f64) {
        self.now += dt;
    }

    /// Jump forward to an absolute time (idle until an arrival/timeout).
    /// Never moves backwards.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// Opaque per-request handle returned by `Frontend::submit`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestHandle {
    pub id: u64,
}

/// Where a submitted request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// submitted; virtual arrival time not reached yet
    Pending,
    /// waiting in the batcher's admission queue
    Queued,
    /// admission bounced by KV-budget pressure; still queued, retried
    /// after a decode round — cancellable and deadline-sheddable like any
    /// queued request
    Deferred,
    /// prefilled and decoding
    Active,
    /// paused mid-decode by the SLO preemptor: KV pages snapshotted into
    /// the cold/spill tiers, decode state stashed, and the request back in
    /// the admission queue at its EDF position — resumes without prefill
    Preempted,
    Finished,
    Cancelled,
    /// shed or aborted because `deadline_ms` elapsed
    Expired,
}

impl Lifecycle {
    /// Terminal states never transition again (events fire exactly once).
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            Lifecycle::Finished | Lifecycle::Cancelled | Lifecycle::Expired
        )
    }
}

/// Typed event stream produced by the pump. Times are virtual seconds.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// request left the queue and its prompt is being prefilled
    Admitted { id: u64, t: f64 },
    /// admission bounced by KV-budget pressure; the request stays queued
    Deferred { id: u64, t: f64 },
    /// one decoded token surfaced (incremental streaming)
    Token { id: u64, tok: i32, t: f64 },
    /// request paused mid-decode to make room for a higher SLO tier; its
    /// KV snapshot waits in the cold/spill tiers and it is still queued
    Preempted { id: u64, t: f64 },
    /// preempted request faulted its snapshot hot and is decoding again
    Resumed { id: u64, t: f64 },
    /// request ran to completion; full timeline attached
    Finished(RequestRecord),
    /// request cancelled by the caller (any pre-terminal state)
    Cancelled { id: u64, t: f64 },
    /// request shed at admission or aborted mid-decode past its deadline
    DeadlineExpired { id: u64, t: f64 },
}

impl ServeEvent {
    /// The request this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            ServeEvent::Admitted { id, .. }
            | ServeEvent::Deferred { id, .. }
            | ServeEvent::Token { id, .. }
            | ServeEvent::Preempted { id, .. }
            | ServeEvent::Resumed { id, .. }
            | ServeEvent::Cancelled { id, .. }
            | ServeEvent::DeadlineExpired { id, .. } => *id,
            ServeEvent::Finished(rec) => rec.id,
        }
    }

    /// Compact deterministic wire form for event-log diffing. With
    /// `with_time` (sound under `TimeModel::Modeled`, where the clock is
    /// seed-deterministic) timestamps are included bit-exactly; without,
    /// only the kind/id/payload sequence is compared — the right signature
    /// under measured time, where wall durations jitter run to run.
    /// `Finished` carries no absolute clock reading, so its time field is
    /// the request's e2e *duration*, labelled `e2e@` to keep the log's
    /// `@` fields (absolute virtual instants) internally consistent.
    pub fn sig(&self, with_time: bool) -> String {
        let (kind, id, payload, tag, t) = match self {
            ServeEvent::Admitted { id, t } => ("A", *id, String::new(), "@", *t),
            ServeEvent::Deferred { id, t } => ("D", *id, String::new(), "@", *t),
            ServeEvent::Token { id, tok, t } => {
                ("T", *id, format!(" {tok}"), "@", *t)
            }
            ServeEvent::Preempted { id, t } => ("P", *id, String::new(), "@", *t),
            ServeEvent::Resumed { id, t } => ("R", *id, String::new(), "@", *t),
            ServeEvent::Cancelled { id, t } => ("C", *id, String::new(), "@", *t),
            ServeEvent::DeadlineExpired { id, t } => {
                ("X", *id, String::new(), "@", *t)
            }
            ServeEvent::Finished(r) => (
                "F",
                r.id,
                format!(" p{} n{}", r.prompt_tokens, r.new_tokens),
                "e2e@",
                r.e2e_seconds,
            ),
        };
        if with_time {
            format!("{kind} {id}{payload} {tag}{:016x}", t.to_bits())
        } else {
            format!("{kind} {id}{payload}")
        }
    }
}

/// Schema version of the serialized `TINYSERVE_EVENT_LOG` format (the
/// [`event_log_header`] line carries it). Bump on any `ServeEvent::sig`
/// format change so archived logs stay self-describing.
pub const EVENT_LOG_SCHEMA: u64 = 2;

/// Run-identifying first line for serialized event logs: schema version
/// plus the knobs that shaped the stream. The header itself is versioned,
/// so double-run determinism diffs stay byte-stable — identical
/// configurations produce identical headers, and a schema bump changes the
/// first line of every log loudly instead of silently. Cross-executor
/// diffs (`--threads 1` vs `--threads 4`) must skip this line: the body is
/// executor-independent by contract, the header records the executor.
pub fn event_log_header(
    seed: u64,
    threads: usize,
    workers: usize,
    policy: &str,
    budget_mb: Option<f64>,
) -> String {
    let budget = match budget_mb {
        Some(mb) => format!("{mb}mb"),
        None => "unbounded".to_string(),
    };
    format!(
        "# tinyserve-event-log v{EVENT_LOG_SCHEMA} seed={seed} \
         threads={threads} workers={workers} policy={policy} budget={budget}"
    )
}

/// Builder for `Frontend` (serving config lives in the engine; coordination
/// behaviour in `ServeOptions`).
#[derive(Default)]
pub struct FrontendBuilder {
    opts: ServeOptions,
    source: Option<Box<dyn RequestSource>>,
    tracer: Option<Tracer>,
    metrics_sink: Option<Box<dyn TraceSink>>,
    analytics_sink: Option<Box<dyn TraceSink>>,
}

impl FrontendBuilder {
    pub fn options(mut self, opts: ServeOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Attach a live request source (open-loop generator); the pump pulls
    /// arrivals from it against the virtual clock.
    pub fn source(mut self, src: Box<dyn RequestSource>) -> Self {
        self.source = Some(src);
        self
    }

    /// Attach a span tracer (`--trace-out`): the frontend emits the run
    /// header, turns on per-worker store tier-transition buffering, and
    /// streams one JSONL span event per lifecycle transition.
    pub fn tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = Some(tracer);
        self
    }

    /// Attach a metrics time-series sink (`--metrics-every` +
    /// `--metrics-out`): registry snapshots land here every N committed
    /// decode rounds.
    pub fn metrics_sink(mut self, sink: Box<dyn TraceSink>) -> Self {
        self.metrics_sink = Some(sink);
        self
    }

    /// Attach the cache-analytics sink (`--analytics-out`): per-worker
    /// `trace::analytics` snapshots drain here at the commit seam. Implies
    /// `ServeOptions::analytics` recorders on every engine.
    pub fn analytics_sink(mut self, sink: Box<dyn TraceSink>) -> Self {
        self.analytics_sink = Some(sink);
        self
    }

    /// Single borrowed engine: a one-slot pool, code-path-identical to the
    /// multi-worker frontend with `workers = 1`.
    pub fn build<'a>(
        self,
        engine: &'a mut Engine,
        plugins: &'a mut Pipeline,
    ) -> Frontend<'a> {
        let pool = WorkerPool::single(engine);
        self.build_pool(pool, plugins)
    }

    /// Frontend over an explicit worker pool (owned engines, dispatch
    /// policy and per-worker KV budget slices set at pool construction).
    pub fn build_pool<'a>(
        self,
        pool: WorkerPool<'a>,
        plugins: &'a mut Pipeline,
    ) -> Frontend<'a> {
        let mut fe = Frontend::new_with_pool(pool, self.opts, plugins);
        fe.source = self.source;
        if let Some(t) = self.tracer {
            fe.set_tracer(t);
        }
        if let Some(s) = self.metrics_sink {
            fe.set_metrics_sink(s);
        }
        if let Some(s) = self.analytics_sink {
            fe.set_analytics_sink(s);
        }
        fe
    }
}

/// Immutable output of a decode round's dispatch phase: per-worker
/// batches as `(worker, active-set indices in batch order)`, ascending by
/// worker. The step phase executes exactly this plan; the commit phase
/// consumes it to attribute results — neither re-decides membership, so
/// the three phases cannot disagree about who stepped where.
struct RoundPlan {
    batches: Vec<(usize, Vec<usize>)>,
}

/// One worker's step-phase output: its step metrics and sampled tokens.
type WorkerStepOut = (StepMetrics, Vec<SampleOut>);

struct Active {
    seq: Sequence,
    req_idx: usize,
    admitted_s: f64,
    prefill_s: f64,
    first_token_s: Option<f64>,
    reused_tokens: usize,
    /// virtual router worker (migration accounting within the engine)
    worker: usize,
    /// pool engine worker actually decoding this request
    engine_idx: usize,
    /// this request's own plugin pipeline, forked from the configured one
    /// at admission: per-request state (entropy streaks, repetition
    /// windows) never leaks across concurrent requests, survives
    /// preemption in the stash, and travels with the request when it is
    /// migrated or stolen across workers
    pipeline: Pipeline,
    /// committed rounds since this request last produced a token (stall
    /// watchdog input; survives preemption in the stash)
    rounds_since_progress: u64,
    /// the watchdog already fired for the current stall episode — the
    /// `stalled` event is edge-triggered, re-armed by the next token
    stall_flagged: bool,
}

/// The request-lifecycle serving frontend (see module docs).
pub struct Frontend<'a> {
    pool: WorkerPool<'a>,
    plugins: &'a mut Pipeline,
    opts: ServeOptions,
    clock: Clock,
    /// one sampling RNG per pool worker, forked from the seed in worker
    /// order at construction — each worker's draw sequence is independent
    /// of how (and on how many threads) the round executes
    worker_rngs: Vec<Rng>,
    batcher: Batcher,
    /// one session store per engine worker: snapshots hold pages of that
    /// worker's pool and cannot be restored across workers
    sessions: Vec<SessionStore>,
    /// one shared-prefix index per engine worker (empty when
    /// `--prefix-cache-mb` is off): published entries reference that
    /// worker's pool pages, so cross-worker adoption is structurally
    /// impossible, like session snapshots
    prefix: Vec<PrefixIndex>,
    router: Router,
    metrics: ServerMetrics,
    records: Vec<RequestRecord>,
    active: Vec<Active>,
    /// preemption stash: decode state of paused requests, keyed by
    /// `req_idx` lookup. Each entry's KV pages sit in its worker's
    /// cold/spill tiers; the matching `QueuedItem` (flagged `preempted`)
    /// waits in the batcher at its EDF position
    preempted: Vec<Active>,
    /// every submitted request, indexed by submission order
    reqs: Vec<Request>,
    state: Vec<Lifecycle>,
    id_to_idx: HashMap<u64, usize>,
    /// submitted-but-not-yet-arrived indices, ascending by arrival time
    /// (stable for ties, so trace order is preserved); in-order
    /// submission — the trace shim — inserts and drains at O(1)
    pending: VecDeque<usize>,
    /// live arrival source, polled by the pump against the virtual clock
    source: Option<Box<dyn RequestSource>>,
    /// span tracer (`Tracer::off()` unless a sink is attached); every hook
    /// is guarded by `enabled()`, so serving untraced pays one branch
    tracer: Tracer,
    /// metrics time-series sink (`--metrics-every`); snapshots emitted at
    /// decode-round commit points
    metrics_sink: Option<Box<dyn TraceSink>>,
    /// cache-analytics sink (`--analytics-out`); per-worker recorder
    /// snapshots drain here serially in worker order at the commit seam
    analytics_sink: Option<Box<dyn TraceSink>>,
    /// committed decode rounds so far (trace round ids, snapshot cadence)
    round_idx: u64,
    /// executor phase profile (`ServeOptions::profile`)
    profile: Option<PhaseProfile>,
    events: VecDeque<ServeEvent>,
    per_task: HashMap<&'static str, (f64, f64, usize)>,
    exact_hits: usize,
    char_acc_sum: f64,
    scored: usize,
}

impl<'a> Frontend<'a> {
    pub fn builder() -> FrontendBuilder {
        FrontendBuilder::default()
    }

    pub fn new(
        engine: &'a mut Engine,
        opts: ServeOptions,
        plugins: &'a mut Pipeline,
    ) -> Frontend<'a> {
        Frontend::new_with_pool(WorkerPool::single(engine), opts, plugins)
    }

    pub fn new_with_pool(
        mut pool: WorkerPool<'a>,
        opts: ServeOptions,
        plugins: &'a mut Pipeline,
    ) -> Frontend<'a> {
        let n = pool.len();
        // per-run accounting: `into_parts` hands the pool back for reuse,
        // so a fresh frontend must not inherit a previous run's worker
        // counters — `busy_frac` and `utilization` divide them by THIS
        // run's clock
        pool.stats = vec![WorkerStats::default(); n];
        // analytics recorders belong to the engines (the decode loop feeds
        // them), so attach them before the first round
        if opts.analytics {
            for w in 0..n {
                pool.engine_mut(w).enable_analytics(opts.audit_every);
            }
        }
        // the configured active cap is per worker: the global batcher cap
        // is min(opts cap, engine cap) * n, so pools actually scale their
        // admissible concurrency — a one-slot pool reduces to the classic
        // min(opts, engine cap)
        let per_worker_cap = (0..n)
            .map(|w| pool.engine(w).cfg.max_active)
            .min()
            .expect("non-empty pool");
        let batcher = Batcher::new(BatcherConfig {
            max_active: opts.batcher.max_active.min(per_worker_cap) * n,
            ..opts.batcher.clone()
        });
        let metrics = ServerMetrics::new(opts.collect_traces);
        let mut seed_rng = Rng::new(opts.seed);
        let worker_rngs = (0..n).map(|w| seed_rng.fork(w as u64)).collect();
        let sessions = (0..n).map(|_| SessionStore::new(opts.max_sessions)).collect();
        // shared-prefix indexes: each worker gets an equal slice of
        // --prefix-cache-mb, mirroring the KV-budget split (published
        // pages live in that worker's pool)
        let prefix: Vec<PrefixIndex> =
            match pool.engine(0).cfg.prefix_cache_bytes() {
                Some(total) => {
                    let min_pages = pool.engine(0).cfg.prefix_min_pages;
                    (0..n)
                        .map(|_| {
                            PrefixIndex::new(
                                Some((total / n.max(1)).max(1)),
                                min_pages,
                            )
                        })
                        .collect()
                }
                None => Vec::new(),
            };
        let router = Router::new(opts.n_workers);
        let profile = opts.profile.then(|| PhaseProfile::new(n));
        Frontend {
            pool,
            plugins,
            opts,
            clock: Clock::new(),
            worker_rngs,
            batcher,
            sessions,
            prefix,
            router,
            metrics,
            records: Vec::new(),
            active: Vec::new(),
            preempted: Vec::new(),
            reqs: Vec::new(),
            state: Vec::new(),
            id_to_idx: HashMap::new(),
            pending: VecDeque::new(),
            source: None,
            tracer: Tracer::off(),
            metrics_sink: None,
            analytics_sink: None,
            round_idx: 0,
            profile,
            events: VecDeque::new(),
            per_task: HashMap::new(),
            exact_hits: 0,
            char_acc_sum: 0.0,
            scored: 0,
        }
    }

    /// Attach (or replace) a live request source mid-run.
    pub fn set_source(&mut self, src: Box<dyn RequestSource>) {
        self.source = Some(src);
    }

    /// Attach a span tracer. An enabled tracer emits the run-header line
    /// immediately and turns on per-worker store tier-transition
    /// buffering (drained serially at prefill and commit points, so
    /// multi-threaded rounds serialize deterministically).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
        if self.tracer.enabled() {
            let header = self.run_header().to_line();
            self.tracer.emit_line(&header);
            for w in 0..self.pool.len() {
                self.pool.engine_mut(w).store.set_trace(true);
            }
        }
    }

    /// Attach the metrics time-series sink; the run header is its first
    /// line, so a snapshot stream is self-describing like a trace.
    pub fn set_metrics_sink(&mut self, mut sink: Box<dyn TraceSink>) {
        sink.emit(&self.run_header().to_line());
        self.metrics_sink = Some(sink);
    }

    /// Attach the cache-analytics sink (`--analytics-out`); like the
    /// metrics stream, the run header is its first line. Engines that do
    /// not already carry a recorder get one, so a sink attached without
    /// `ServeOptions::analytics` still produces a stream.
    pub fn set_analytics_sink(&mut self, mut sink: Box<dyn TraceSink>) {
        sink.emit(&self.run_header().to_line());
        for w in 0..self.pool.len() {
            if self.pool.engine(w).analytics().is_none() {
                self.pool.engine_mut(w).enable_analytics(self.opts.audit_every);
            }
        }
        self.analytics_sink = Some(sink);
    }

    /// Drain every worker's analytics recorder into the sink, serially in
    /// worker order — called only at commit seams (and shutdown), so the
    /// snapshot interleaving is identical however the step phase executed
    /// and the stream byte-diffs across executor kinds/widths.
    fn drain_analytics(&mut self) {
        if self.analytics_sink.is_none() {
            return;
        }
        let (round, t) = (self.round_idx, self.clock.now());
        let mut lines = Vec::new();
        for w in 0..self.pool.len() {
            if let Some(an) = self.pool.engine_mut(w).analytics_mut() {
                an.snapshot_into(w, round, t, &mut lines);
            }
        }
        if let Some(s) = self.analytics_sink.as_mut() {
            for l in &lines {
                s.emit(l);
            }
        }
    }

    /// Run-identifying header shared by the trace and metrics streams.
    /// Deliberately carries no thread count: under modeled time both
    /// streams are executor-independent, and CI diffs `--threads 1`
    /// output against `--threads 4` byte-for-byte.
    fn run_header(&self) -> RunHeader {
        let cfg = &self.pool.engine(0).cfg;
        let budget = self.pool.total_budget_bytes().unwrap_or(0) as u64;
        RunHeader {
            seed: self.opts.seed,
            workers: self.pool.len(),
            policy: cfg.policy.name().to_string(),
            eviction: cfg.eviction.name().to_string(),
            budget_bytes: budget,
            time: self.opts.time_model.name().to_string(),
        }
    }

    /// Serialize worker `w`'s buffered store tier-transitions into the
    /// trace, anchored to the enclosing span. Call order (worker order at
    /// commit, admission order at prefill) is fixed, so the interleaving
    /// is identical however the step phase executed.
    fn drain_store_trace(&mut self, w: usize, ctx: SpanCtx) {
        if !self.tracer.enabled() {
            return;
        }
        for ev in self.pool.engine_mut(w).store.take_trace() {
            let te = match ev {
                StoreTraceEvent::Demote { page } => {
                    TraceEvent::Demote { ctx, worker: w, page: page as u64 }
                }
                StoreTraceEvent::SpillOut { page } => {
                    TraceEvent::SpillOut { ctx, worker: w, page: page as u64 }
                }
                StoreTraceEvent::Fault { page, src } => TraceEvent::SpillFault {
                    ctx,
                    worker: w,
                    page: page as u64,
                    src: src.name(),
                },
                StoreTraceEvent::Readahead { bytes } => {
                    TraceEvent::Readahead { ctx, worker: w, bytes }
                }
            };
            self.tracer.emit(&te);
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Read-only view of the first pool worker's engine (single-engine
    /// introspection: `fe.engine().store.bytes_in_use(&fe.engine().pool)`).
    pub fn engine(&self) -> &Engine {
        self.pool.engine(0)
    }

    /// Read-only view of worker `w`'s engine.
    pub fn worker_engine(&self, w: usize) -> &Engine {
        self.pool.engine(w)
    }

    /// Number of engine workers in the pool.
    pub fn n_pool_workers(&self) -> usize {
        self.pool.len()
    }

    /// Resident KV bytes summed across all pool workers.
    pub fn kv_bytes_in_use(&self) -> usize {
        self.pool.total_kv_bytes()
    }

    /// Requests waiting for admission: the batcher queue plus submitted
    /// arrivals the pump has not pulled yet. The network front door's
    /// `--queue-depth` backpressure gate reads this before every submit,
    /// so the count covers *new intake only* — preempted requests back in
    /// the queue already paid for admission once and hold no unserved
    /// client submission; counting them would shed fresh submits for load
    /// the preemptor created itself.
    pub fn queued_len(&self) -> usize {
        self.batcher.queued_new_len() + self.pending.len()
    }

    /// Requests currently decoding.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Emit an externally-produced span event (the network front door's
    /// connection lifecycle) into the run's trace stream. A no-op without
    /// an attached tracer, like every internal hook.
    pub fn trace_event(&mut self, ev: &TraceEvent) {
        if self.tracer.enabled() {
            self.tracer.emit(ev);
        }
    }

    /// Run-level metrics accumulated so far.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Lifecycle state of a submitted request, if known.
    pub fn state_of(&self, id: u64) -> Option<Lifecycle> {
        self.id_to_idx.get(&id).map(|&i| self.state[i])
    }

    /// Anything left to pump? (pending arrivals — submitted or still in
    /// the live source — queued or active requests, or undelivered events)
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty()
            || self.batcher.queue_len() > 0
            || !self.active.is_empty()
            || !self.events.is_empty()
            || self
                .source
                .as_ref()
                .map(|s| s.peek_arrival_s().is_some())
                .unwrap_or(false)
    }

    /// Submit a request. Its `arrival_s` is interpreted on the frontend's
    /// virtual clock; times already in the past become eligible at the next
    /// `step`. Re-submitting an id replaces the handle mapping (last wins).
    pub fn submit(&mut self, req: Request) -> RequestHandle {
        let idx = self.reqs.len();
        let id = req.id;
        let arrival = req.arrival_s;
        self.reqs.push(req);
        self.state.push(Lifecycle::Pending);
        self.id_to_idx.insert(id, idx);
        // binary-search insert, `<=` so equal arrivals keep submit order;
        // in-order submission lands at the back in O(log n)
        let pos = {
            let reqs = &self.reqs;
            self.pending.partition_point(|&p| reqs[p].arrival_s <= arrival)
        };
        self.pending.insert(pos, idx);
        RequestHandle { id }
    }

    /// Cancel a request in any pre-terminal state. Queued and deferred
    /// requests leave the admission queue immediately; active ones abort
    /// mid-decode and their KV pages return to the worker's pool (pins
    /// cleared, `bytes_in_use` drops). Returns false for unknown ids and
    /// already-terminal requests.
    pub fn cancel(&mut self, id: u64) -> bool {
        let Some(&idx) = self.id_to_idx.get(&id) else {
            return false;
        };
        let now = self.clock.now();
        match self.state[idx] {
            Lifecycle::Pending => {
                self.pending.retain(|&p| p != idx);
            }
            // a Deferred or Preempted request is physically back in the
            // batcher queue (requeued at its EDF position), so it cancels
            // exactly like a Queued one — it must emit Cancelled, never
            // silently vanish; a preempted one additionally releases its
            // stashed KV snapshot
            Lifecycle::Queued | Lifecycle::Deferred | Lifecycle::Preempted => {
                self.batcher.remove(idx);
                self.drop_preempted(idx);
            }
            Lifecycle::Active => {
                let Some(pos) = self.active.iter().position(|a| a.req_idx == idx)
                else {
                    return false;
                };
                self.abort_active(pos);
            }
            Lifecycle::Finished | Lifecycle::Cancelled | Lifecycle::Expired => {
                return false;
            }
        }
        self.state[idx] = Lifecycle::Cancelled;
        self.metrics.on_cancelled();
        self.events.push_back(ServeEvent::Cancelled { id, t: now });
        if self.tracer.enabled() {
            self.tracer.emit(&TraceEvent::Cancelled { id, t: now });
        }
        true
    }

    /// One scheduling round of the event pump: pull due arrivals (from
    /// `submit`ted requests and the live source), ask the batcher for a
    /// decision, run it (admit/prefill, decode across all workers, or
    /// idle-jump the clock), and return the events produced. An empty vec
    /// with `has_work() == false` means the frontend is drained.
    pub fn step(&mut self) -> Result<Vec<ServeEvent>> {
        self.pump_round()?;
        Ok(self.events.drain(..).collect())
    }

    /// Pump until no work remains, returning every event in order.
    pub fn drain(&mut self) -> Result<Vec<ServeEvent>> {
        let mut out = Vec::new();
        loop {
            out.extend(self.events.drain(..));
            if !self.has_work() {
                return Ok(out);
            }
            self.pump_round()?;
        }
    }

    /// Consume the frontend into the run report (the `serve_trace` output
    /// shape). Clears surviving session snapshots back into their pools.
    pub fn into_report(self) -> ServeReport {
        self.into_parts().0
    }

    /// Like [`into_report`](Self::into_report), but also hands back the
    /// worker pool so callers can inspect (or reuse) the engines after the
    /// run — the owned-pool analogue of keeping your `&mut Engine`.
    pub fn into_parts(mut self) -> (ServeReport, WorkerPool<'a>) {
        self.metrics.run_seconds = self.clock.now();
        self.tracer.flush();
        if let Some(s) = self.metrics_sink.as_mut() {
            s.flush();
        }
        // final analytics drain: cumulative summaries plus any audit
        // records and residency entries still buffered since the last
        // cadence snapshot
        self.drain_analytics();
        if let Some(s) = self.analytics_sink.as_mut() {
            s.flush();
        }
        let analytics: Vec<AnalyticsSummary> = (0..self.pool.len())
            .filter_map(|w| {
                self.pool.engine(w).analytics().map(|an| AnalyticsSummary {
                    worker: w,
                    accesses: an.accesses(),
                    hit_rate: an.hit_rate(),
                    audit_records: an.audit_records(),
                    mean_recall: an.mean_recall(),
                })
            })
            .collect();
        // surviving preemption snapshots give their pages back before the
        // session stores clear, mirroring the cancel/expiry release path
        for mut a in std::mem::take(&mut self.preempted) {
            self.pool.engine_mut(a.engine_idx).release_mid_flight(&mut a.seq);
        }
        // prefix indexes release their page references before the session
        // stores clear, so teardown refcounts balance in either order
        let mut prefix_stats = PrefixStats::default();
        for w in 0..self.pool.len() {
            if let Some(px) = self.prefix.get_mut(w) {
                prefix_stats.merge(&px.stats);
                px.clear(&mut self.pool.engine_mut(w).pool);
            }
        }
        for w in 0..self.pool.len() {
            let pool = &mut self.pool;
            let sessions = &mut self.sessions;
            sessions[w].clear(&mut pool.engine_mut(w).pool);
        }
        let mut session_stats = SessionStats::default();
        for s in &self.sessions {
            session_stats.merge(&s.stats);
        }
        let mut per_task_out: Vec<(String, f64, usize)> = self
            .per_task
            .into_iter()
            .map(|(k, (hits, _ca, n))| (k.to_string(), hits / n.max(1) as f64, n))
            .collect();
        per_task_out.sort_by(|a, b| a.0.cmp(&b.0));
        let now = self.clock.now();
        // workers overlap, so total busy time is the sum of the per-worker
        // counters — the single source of busy accounting (utilization
        // divides the same counters by the same wall clock)
        let busy: f64 = self.pool.stats.iter().map(|s| s.busy_s).sum();
        let report = ServeReport {
            accuracy: if self.scored > 0 {
                self.exact_hits as f64 / self.scored as f64
            } else {
                f64::NAN
            },
            char_accuracy: if self.scored > 0 {
                self.char_acc_sum / self.scored as f64
            } else {
                f64::NAN
            },
            per_task: per_task_out,
            session_stats,
            prefix_stats,
            router_stats: self.router.stats.clone(),
            batcher_stats: std::mem::take(&mut self.batcher.stats),
            metrics: self.metrics,
            requests: self.records,
            wall_s: now,
            busy_frac: if now > 0.0 { busy / now } else { 0.0 },
            worker_stats: self.pool.stats.clone(),
            profile: self.profile,
            analytics,
        };
        (report, self.pool)
    }

    // ---- internal pump ----

    fn pump_round(&mut self) -> Result<()> {
        let now = self.clock.now();
        // pull live-source arrivals that have happened into the pending set
        let due = match self.source.as_mut() {
            Some(src) => src.take_due(now),
            None => Vec::new(),
        };
        for req in due {
            self.submit(req);
        }
        // pull arrivals that have happened
        while let Some(&idx) = self.pending.front() {
            if self.reqs[idx].arrival_s > now {
                break;
            }
            self.pending.pop_front();
            self.state[idx] = Lifecycle::Queued;
            if self.tracer.enabled() {
                self.tracer.emit(&TraceEvent::Queued {
                    id: self.reqs[idx].id,
                    t: self.reqs[idx].arrival_s,
                });
            }
            self.batcher.enqueue(QueuedItem {
                request_idx: idx,
                arrival_s: self.reqs[idx].arrival_s,
                prompt_len: self.reqs[idx].prompt.len(),
                deadline_s: self.reqs[idx]
                    .deadline_ms
                    .map(|d| self.reqs[idx].arrival_s + d / 1e3),
                tier: self.reqs[idx].tier,
                preempted: false,
            });
        }
        let mut next_arrival = self.pending.front().map(|&i| self.reqs[i].arrival_s);
        if let Some(t) = self.source.as_ref().and_then(|s| s.peek_arrival_s()) {
            next_arrival = Some(match next_arrival {
                Some(a) => a.min(t),
                None => t,
            });
        }
        if self.pending.is_empty()
            && self.batcher.queue_len() == 0
            && self.active.is_empty()
        {
            // only the live source has work left: idle-jump to its next
            // arrival so the pump makes progress
            if let Some(t) = next_arrival {
                self.clock.advance_to(t);
            }
            return Ok(());
        }
        // SLO preemption sits just before the scheduling decision: pausing
        // a low-tier active here frees its batcher slot, so the very next
        // `schedule` can admit the starving higher-tier head
        self.maybe_preempt();
        match self.batcher.schedule(now, next_arrival) {
            Round::Idle(t) => {
                if t.is_finite() {
                    self.clock.advance_to(t);
                }
            }
            Round::Admit(items) => self.admit_round(items)?,
            Round::Decode => self.decode_round()?,
        }
        Ok(())
    }

    /// Record an admission bounce: lifecycle, serve event, trace span.
    fn mark_deferred(&mut self, idx: usize) {
        self.state[idx] = Lifecycle::Deferred;
        let (id, t) = (self.reqs[idx].id, self.clock.now());
        self.events.push_back(ServeEvent::Deferred { id, t });
        if self.tracer.enabled() {
            self.tracer.emit(&TraceEvent::Deferred { id, t });
        }
    }

    /// True when `idx` carries a deadline that has already elapsed.
    fn deadline_passed(&self, idx: usize) -> bool {
        match self.reqs[idx].deadline_ms {
            Some(d) => self.clock.now() > self.reqs[idx].arrival_s + d / 1e3,
            None => false,
        }
    }

    /// Deterministic hwmodel price of prefilling `tokens` on this engine
    /// (TimeModel::Modeled): the chunked prefill artifact processes ~8
    /// prompt tokens per pass of the decode path.
    fn modeled_prefill_s(engine: &Engine, tokens: usize) -> f64 {
        let shape = Self::modeled_shape(engine, engine.cfg.max_batch, tokens.max(1));
        HwModel::a100().decode_token(&shape).total_s() * tokens.max(1) as f64 / 8.0
    }

    /// Deterministic hwmodel price of one decode step over `m.batch` rows.
    fn modeled_step_s(engine: &Engine, m: &StepMetrics) -> f64 {
        let ctx = m.resident_tokens / m.batch.max(1);
        let shape = Self::modeled_shape(engine, m.batch.max(1), ctx.max(1));
        HwModel::a100().decode_token(&shape).total_s()
    }

    fn modeled_shape(engine: &Engine, batch: usize, ctx: usize) -> Shape {
        Shape {
            d_model: engine.d_model,
            n_layer: engine.n_layer,
            n_params: engine.rt.info.n_params,
            ctx,
            page_size: engine.cfg.page_size,
            k_pages: engine.cfg.budget_pages(),
            kv_dtype: engine.cfg.kv_dtype,
            batch,
        }
    }

    fn admit_round(&mut self, items: Vec<QueuedItem>) -> Result<()> {
        let mut deferred: Vec<QueuedItem> = Vec::new();
        // deferral is a *per-worker* condition (that worker's KV pressure
        // or concurrency cap): once a worker bounces an item, every later
        // item dispatched to the same worker defers too — preserving the
        // EDF order within the worker — while items bound for other
        // workers still admit (no head-of-line blocking across workers).
        // A one-worker pool degenerates to the old global cascade.
        let mut blocked = vec![false; self.pool.len()];
        for item in items {
            let idx = item.request_idx;
            // authoritative state guard: a cancelled item normally leaves
            // the queue via Batcher::remove, but never trust stragglers.
            // A preemption-flagged item is legal in Preempted (stashed) or
            // Deferred (resume bounced once already) state; a fresh one in
            // Queued or Deferred.
            let state_ok = if item.preempted {
                matches!(
                    self.state[idx],
                    Lifecycle::Preempted | Lifecycle::Deferred
                )
            } else {
                matches!(self.state[idx], Lifecycle::Queued | Lifecycle::Deferred)
            };
            if !state_ok {
                self.batcher.abort_admission(1);
                continue;
            }
            // SLO-aware shedding: starting a request past its deadline
            // wastes prefill + decode on an answer nobody will take. A
            // preempted request shed here also frees its KV snapshot.
            if self.deadline_passed(idx) {
                self.batcher.abort_admission(1);
                self.drop_preempted(idx);
                self.state[idx] = Lifecycle::Expired;
                self.metrics.on_expired();
                let (id, t) = (self.reqs[idx].id, self.clock.now());
                self.events.push_back(ServeEvent::DeadlineExpired { id, t });
                if self.tracer.enabled() {
                    self.tracer.emit(&TraceEvent::Expired { id, t });
                }
                continue;
            }
            if item.preempted {
                match self.resume_preempted(item, &mut blocked)? {
                    None => {}
                    Some(bounced) => deferred.push(bounced),
                }
                continue;
            }
            let prompt_len = self.reqs[idx].prompt.len();
            let session = self.reqs[idx].session;
            // dispatch: a session whose snapshot is already resident on a
            // worker goes back to that worker regardless of policy —
            // snapshots hold that worker's pages and cannot be restored
            // elsewhere, so any other choice re-prefills the whole prompt
            // AND leaves an orphaned snapshot eating the holder's budget.
            // Everything else is the dispatch policy's call, re-decided on
            // every admission attempt so a deferred request can land on a
            // worker that has since freed pages.
            let holder = session.and_then(|s| {
                (0..self.pool.len()).find(|&w| self.sessions[w].contains(s))
            });
            let w = match holder {
                Some(h) => h,
                None => self.pool.dispatch_worker(session),
            };
            if blocked[w] {
                self.mark_deferred(idx);
                deferred.push(item);
                continue;
            }
            // per-worker concurrency cap: the global batcher admits up to
            // cap * n_workers, but a count-oblivious dispatch (affinity,
            // byte-based least-loaded) could pile them all onto one
            // engine; defer instead of exceeding that engine's max_active
            let worker_active =
                self.active.iter().filter(|a| a.engine_idx == w).count();
            if worker_active >= self.pool.engine(w).cfg.max_active {
                blocked[w] = true;
                self.mark_deferred(idx);
                deferred.push(item);
                continue;
            }
            // KV-budget admission control: shed the target worker's idle
            // session snapshots first; if the prompt still cannot fit,
            // defer while that worker's in-flight work can retire and
            // free pages
            if !self.pool.engine_mut(w).kv_admission_ok(prompt_len) {
                while !self.pool.engine_mut(w).kv_admission_ok(prompt_len)
                    && self.sessions[w]
                        .evict_one_lru(&mut self.pool.engine_mut(w).pool, session)
                {}
            }
            let worker_busy = self.active.iter().any(|a| a.engine_idx == w);
            if !self.pool.engine_mut(w).kv_admission_ok(prompt_len) && worker_busy {
                blocked[w] = true;
                self.mark_deferred(idx);
                deferred.push(item);
                continue;
            }
            // admission instant: queue_seconds measures arrival -> here;
            // decode_seconds starts after this plus the prefill
            let admitted_s = self.clock.now();
            let mut seq = self.pool.engine_mut(w).new_sequence();
            seq.max_new_tokens = self.reqs[idx].max_new_tokens;
            // session reuse: restore the stored prompt prefix
            let mut reused = 0usize;
            let pinned = session.and_then(|s| self.sessions[w].worker_of(s));
            let decision = self.router.route(pinned);
            if let Some(sid) = session {
                if decision.migrate_from.is_some() {
                    let bytes = self.sessions[w].migrate(
                        sid,
                        decision.worker,
                        &self.pool.engine(w).pool,
                    );
                    // migration transit at ~200 GB/s NVLink-class
                    self.clock.advance(bytes as f64 / 200e9);
                }
                if let Some((cache, n)) = self.sessions[w].try_reuse(
                    sid,
                    &self.reqs[idx].prompt,
                    &mut self.pool.engine_mut(w).pool,
                ) {
                    seq.cache = cache;
                    reused = n;
                }
            }
            // cross-request prefix adoption (session miss only): adopt the
            // longest published page chain by refcount bump. Only the
            // unmatched tail prefills below — `seq.pending()` shrinks with
            // the adopted position, so the modeled prefill price (and with
            // it TTFT) reflects the skipped compute.
            let mut adopted_tokens = 0usize;
            if reused == 0 && !self.prefix.is_empty() {
                if let Some((cache, n)) = self.prefix[w].adopt(
                    &self.reqs[idx].prompt,
                    &mut self.pool.engine_mut(w).pool,
                ) {
                    seq.cache = cache;
                    adopted_tokens = n;
                }
            }
            seq.tokens = self.reqs[idx].prompt.clone();
            self.events.push_back(ServeEvent::Admitted {
                id: self.reqs[idx].id,
                t: self.clock.now(),
            });
            if self.tracer.enabled() {
                self.tracer.emit(&TraceEvent::Admitted {
                    id: self.reqs[idx].id,
                    worker: w,
                    t: self.clock.now(),
                });
            }
            // prefill the (remaining) prompt, measured or modeled
            let to_prefill = seq.pending().saturating_sub(1);
            let mut m = StepMetrics::default();
            let t0 = std::time::Instant::now();
            if self.opts.artifact_prefill
                && self
                    .pool
                    .engine(w)
                    .rt
                    .info
                    .find_artifact("prefill", 1, None)
                    .is_ok()
            {
                self.pool.engine_mut(w).prefill(&mut seq, &mut m)?;
            } else {
                self.pool.engine_mut(w).prefill_stepwise(&mut seq, &mut m)?;
            }
            let dt = match self.opts.time_model {
                TimeModel::Measured => t0.elapsed().as_secs_f64(),
                TimeModel::Modeled => {
                    Self::modeled_prefill_s(self.pool.engine(w), to_prefill)
                }
            };
            let prefill_t0 = self.clock.now();
            self.clock.advance(dt);
            self.pool.stats[w].busy_s += dt;
            if adopted_tokens > 0 {
                let pages = adopted_tokens / self.pool.engine(w).cfg.page_size;
                let bytes = pages * self.pool.engine(w).pool.page_bytes();
                m.prefix_pages_adopted = pages;
                m.prefix_tokens_skipped = adopted_tokens;
                m.prefix_bytes_deduped = bytes;
                self.metrics.total_prefix_pages_adopted += pages as u64;
                self.metrics.total_prefix_tokens_skipped += adopted_tokens as u64;
                self.metrics.total_prefix_bytes_deduped += bytes as u64;
            }
            // publish this prompt's freshly-prefilled full pages for future
            // cross-request adoption (budget-bounded; LRU leaves unpublish)
            if !self.prefix.is_empty() {
                self.prefix[w].publish(
                    &self.reqs[idx].prompt,
                    &seq.cache,
                    &mut self.pool.engine_mut(w).pool,
                );
            }
            // snapshot the prompt prefix for future session turns
            if let Some(sid) = session {
                let covered = seq.cache.pos;
                let pool = &mut self.pool;
                self.sessions[w].store(
                    sid,
                    &seq.cache,
                    &self.reqs[idx].prompt[..covered],
                    decision.worker,
                    &mut pool.engine_mut(w).pool,
                );
            }
            // prefill/snapshot allocations bypass the decode path; demote
            // back under the budget before decoding resumes
            self.pool.engine_mut(w).enforce_kv_budget();
            self.pool.note_kv_peak(w);
            if self.tracer.enabled() {
                let id = self.reqs[idx].id;
                self.tracer.emit(&TraceEvent::Prefill {
                    id,
                    worker: w,
                    t0: prefill_t0,
                    t1: self.clock.now(),
                });
                // store activity during this admission (session eviction,
                // prefill allocation, budget enforcement) anchors to the
                // prefill span
                self.drain_store_trace(w, SpanCtx::Prefill { id });
            }
            self.pool.stats[w].admitted += 1;
            // rotation advances only for placements the dispatch policy
            // made (holder-routed sessions are not rotation decisions)
            if holder.is_none() {
                self.pool.note_admitted(w);
            }
            self.state[idx] = Lifecycle::Active;
            self.active.push(Active {
                seq,
                req_idx: idx,
                admitted_s,
                prefill_s: dt,
                first_token_s: None,
                reused_tokens: reused,
                worker: decision.worker,
                engine_idx: w,
                pipeline: self.plugins.fork(),
                rounds_since_progress: 0,
                stall_flagged: false,
            });
        }
        // deferred items go back to the batcher at their EDF positions
        for item in deferred.into_iter().rev() {
            self.batcher.requeue_front(item);
        }
        Ok(())
    }

    /// Resume a preempted request from its stashed decode state: fault its
    /// KV snapshot back to the hot tier on the worker that holds it — or,
    /// when that worker has no free slot, port the snapshot page-by-page
    /// to one that does (the snapshot is worker-portable, unlike live
    /// session state). No prefill runs; the sequence continues exactly
    /// where `preempt_active` paused it. Returns the item for requeueing
    /// when every candidate worker bounced it.
    fn resume_preempted(
        &mut self,
        item: QueuedItem,
        blocked: &mut [bool],
    ) -> Result<Option<QueuedItem>> {
        let idx = item.request_idx;
        let Some(spos) = self.preempted.iter().position(|p| p.req_idx == idx)
        else {
            // stash entry vanished (released by a racing terminal path):
            // the queue item is a straggler
            self.batcher.abort_admission(1);
            return Ok(None);
        };
        let home = self.preempted[spos].engine_idx;
        let resident = self.preempted[spos].seq.cache.resident;
        let slot_free = |fe: &Self, w: usize| {
            fe.active.iter().filter(|a| a.engine_idx == w).count()
                < fe.pool.engine(w).cfg.max_active
        };
        let mut target = None;
        if !blocked[home]
            && slot_free(self, home)
            && self.pool.engine_mut(home).kv_admission_ok(resident)
        {
            target = Some(home);
        } else {
            for w in 0..self.pool.len() {
                if w == home || blocked[w] || !slot_free(self, w) {
                    continue;
                }
                if self.pool.engine_mut(w).kv_admission_ok(resident) {
                    target = Some(w);
                    break;
                }
            }
        }
        let Some(w) = target else {
            self.mark_deferred(idx);
            return Ok(Some(item));
        };
        let mut a = self.preempted.swap_remove(spos);
        let id = self.reqs[idx].id;
        if w != home {
            // cross-worker migration: copy the snapshot into the target
            // pool (bit-exact for q8 pages), release the source copy, and
            // price the transit at the NVLink-class rate
            let (src, dst) = self.pool.engine_pair_mut(home, w);
            let (cache, bytes) = SeqCache::port_to(
                &a.seq.cache,
                &mut src.pool,
                &mut src.store,
                &mut dst.pool,
                &mut dst.store,
            )?;
            let mut old = std::mem::replace(&mut a.seq.cache, cache);
            for e in old.pages.iter() {
                src.store.unpin(e.id);
            }
            old.clear(&mut src.pool);
            src.store.sync(&src.pool);
            self.clock.advance(bytes as f64 / 200e9);
            a.engine_idx = w;
            self.metrics.on_migrated();
            if self.tracer.enabled() {
                self.tracer.emit(&TraceEvent::Migrated {
                    id,
                    from: home,
                    to: w,
                    bytes: bytes as u64,
                    t: self.clock.now(),
                });
            }
        }
        // fault the snapshot hot and price the tier traffic it moved
        let eng = self.pool.engine_mut(w);
        for e in a.seq.cache.pages.iter() {
            eng.store.ensure_hot(&mut eng.pool, e.id)?;
        }
        let mut m = StepMetrics::default();
        eng.collect_store_stats(&mut m);
        let dt = m.spill_seconds + m.disk_seconds;
        self.clock.advance(dt);
        self.pool.stats[w].busy_s += dt;
        self.pool.note_kv_peak(w);
        self.metrics.on_resumed();
        let t = self.clock.now();
        self.events.push_back(ServeEvent::Resumed { id, t });
        if self.tracer.enabled() {
            self.tracer.emit(&TraceEvent::Resumed { id, worker: w, t });
            self.drain_store_trace(w, SpanCtx::Round { round: self.round_idx });
        }
        self.state[idx] = Lifecycle::Active;
        self.active.push(a);
        Ok(None)
    }

    /// Release a stashed preemption snapshot's KV pages (cancellation or
    /// deadline expiry of a preempted request). No-op when `idx` holds no
    /// snapshot.
    fn drop_preempted(&mut self, idx: usize) {
        if let Some(pos) = self.preempted.iter().position(|p| p.req_idx == idx) {
            let mut a = self.preempted.swap_remove(pos);
            self.pool.engine_mut(a.engine_idx).release_mid_flight(&mut a.seq);
        }
    }

    /// Preemption check (gated by `ServeOptions::preempt`), run before
    /// every scheduling decision: when the batcher is slot-full and its
    /// head is a higher-SLO-tier request that has already waited out half
    /// its TTFT target, pause the lowest-tier latest-deadline active —
    /// snapshot its KV pages down the tier ladder, requeue it at its EDF
    /// position flagged `preempted`, and stash its decode state (sequence,
    /// plugin pipeline, timing) for an exact resume.
    fn maybe_preempt(&mut self) {
        if !self.opts.preempt || !self.batcher.is_full() {
            return;
        }
        let Some(head) = self.batcher.peek_head() else { return };
        // a preempted head resumes from its snapshot on the next free
        // slot; preempting again on its behalf would thrash
        if head.preempted {
            return;
        }
        let now = self.clock.now();
        if now - head.arrival_s < 0.5 * head.tier.ttft_target_s() {
            return;
        }
        let head_rank = head.tier.rank();
        let Some(pos) = self.lowest_priority_active(Some(head_rank), None)
        else {
            return;
        };
        self.preempt_active(pos);
    }

    /// The active-set position of the lowest-priority decoding request:
    /// highest tier rank first, then latest deadline (no deadline sorts
    /// last of all), then highest request id — a total, deterministic
    /// order. `rank_above` restricts to strictly lower tiers than the
    /// given rank (preemption never evicts its own tier); `on_worker`
    /// restricts to one engine's batch (work stealing).
    fn lowest_priority_active(
        &self,
        rank_above: Option<u8>,
        on_worker: Option<usize>,
    ) -> Option<usize> {
        let mut best: Option<(usize, (u8, f64, u64))> = None;
        for (i, a) in self.active.iter().enumerate() {
            if let Some(w) = on_worker {
                if a.engine_idx != w {
                    continue;
                }
            }
            let req = &self.reqs[a.req_idx];
            let rank = req.tier.rank();
            if let Some(r) = rank_above {
                if rank <= r {
                    continue;
                }
            }
            let deadline = req
                .deadline_ms
                .map(|d| req.arrival_s + d / 1e3)
                .unwrap_or(f64::INFINITY);
            let key = (rank, deadline, req.id);
            if best.as_ref().map(|(_, k)| key > *k).unwrap_or(true) {
                best = Some((i, key));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Pause the active at `pos`: unpin and demote its KV pages into the
    /// cold/spill tiers (a snapshot the budget can mostly reclaim), give
    /// back its router and batcher slots, requeue it `preempted` at its
    /// EDF position, and stash its decode state. The demotion traffic is
    /// hwmodel-priced into virtual time like any other tier movement.
    fn preempt_active(&mut self, pos: usize) {
        let mut a = self.active.swap_remove(pos);
        let idx = a.req_idx;
        let w = a.engine_idx;
        let eng = self.pool.engine_mut(w);
        for e in a.seq.cache.pages.iter() {
            eng.store.unpin(e.id);
        }
        eng.store.demote_seq(&mut eng.pool, &a.seq.cache);
        let mut m = StepMetrics::default();
        eng.collect_store_stats(&mut m);
        let dt = m.spill_seconds + m.disk_seconds;
        self.clock.advance(dt);
        self.pool.stats[w].busy_s += dt;
        self.router.complete(a.worker);
        let (id, arrival_s, prompt_len, deadline_s, tier) = {
            let req = &self.reqs[idx];
            (
                req.id,
                req.arrival_s,
                req.prompt.len(),
                req.deadline_ms.map(|d| req.arrival_s + d / 1e3),
                req.tier,
            )
        };
        self.batcher.requeue_preempted(QueuedItem {
            request_idx: idx,
            arrival_s,
            prompt_len,
            deadline_s,
            tier,
            preempted: true,
        });
        self.state[idx] = Lifecycle::Preempted;
        self.metrics.on_preempted();
        let t = self.clock.now();
        self.events.push_back(ServeEvent::Preempted { id, t });
        if self.tracer.enabled() {
            self.tracer.emit(&TraceEvent::Preempted { id, worker: w, t });
            self.drain_store_trace(w, SpanCtx::Round { round: self.round_idx });
        }
        self.preempted.push(a);
    }

    /// Work stealing at the commit seam (gated by `ServeOptions::steal`):
    /// when a worker sits idle while another holds at least two decoding
    /// requests, port the loaded worker's lowest-priority sequence across
    /// (page-by-page copy, bit-exact for q8 tiers) so the next round
    /// decodes on both engines. At most one steal per round keeps the
    /// event stream easy to reason about — and convergence is quick, the
    /// imbalance shrinks by two each time.
    fn maybe_steal(&mut self) -> Result<()> {
        if self.pool.len() < 2 {
            return Ok(());
        }
        let mut counts = vec![0usize; self.pool.len()];
        for a in &self.active {
            counts[a.engine_idx] += 1;
        }
        let Some(to) = (0..self.pool.len()).find(|&w| counts[w] == 0) else {
            return Ok(());
        };
        let Some(from) = counts
            .iter()
            .enumerate()
            .max_by_key(|&(w, &c)| (c, std::cmp::Reverse(w)))
            .filter(|&(_, &c)| c >= 2)
            .map(|(w, _)| w)
        else {
            return Ok(());
        };
        let Some(pos) = self.lowest_priority_active(None, Some(from)) else {
            return Ok(());
        };
        let resident = self.active[pos].seq.cache.resident;
        if !self.pool.engine_mut(to).kv_admission_ok(resident) {
            return Ok(());
        }
        let id = self.reqs[self.active[pos].req_idx].id;
        let (src, dst) = self.pool.engine_pair_mut(from, to);
        let (cache, bytes) = SeqCache::port_to(
            &self.active[pos].seq.cache,
            &mut src.pool,
            &mut src.store,
            &mut dst.pool,
            &mut dst.store,
        )?;
        let mut old = std::mem::replace(&mut self.active[pos].seq.cache, cache);
        for e in old.pages.iter() {
            src.store.unpin(e.id);
        }
        old.clear(&mut src.pool);
        src.store.sync(&src.pool);
        let dt = bytes as f64 / 200e9;
        self.clock.advance(dt);
        self.pool.stats[to].busy_s += dt;
        self.active[pos].engine_idx = to;
        self.metrics.on_stolen();
        if self.tracer.enabled() {
            let t = self.clock.now();
            self.tracer.emit(&TraceEvent::Stolen { id, from, to, t });
            self.drain_store_trace(from, SpanCtx::Round { round: self.round_idx });
            self.drain_store_trace(to, SpanCtx::Round { round: self.round_idx });
        }
        Ok(())
    }

    /// Tear down an active request that will not complete (cancellation
    /// or deadline expiry): drop it from the active set, give back its
    /// worker and batcher slot, and release its KV pages mid-flight. The
    /// caller records the terminal state, counter, and event.
    fn abort_active(&mut self, pos: usize) {
        let mut a = self.active.swap_remove(pos);
        self.router.complete(a.worker);
        self.batcher.on_finished(1);
        self.pool.engine_mut(a.engine_idx).release_mid_flight(&mut a.seq);
        // the aborted request's plugin state dies with its own forked
        // pipeline (dropped with `a`); resetting the shared template here
        // would wipe the *survivors'* streaks — the old cross-request
        // plugin-state leak
    }

    /// Abort active sequences whose deadline elapsed, releasing their KV
    /// pages mid-flight. Terminal-state transitions guarantee the
    /// `DeadlineExpired` event fires exactly once per request.
    fn expire_active(&mut self) {
        let now = self.clock.now();
        let mut i = 0;
        while i < self.active.len() {
            let idx = self.active[i].req_idx;
            if self.deadline_passed(idx) {
                self.abort_active(i);
                self.state[idx] = Lifecycle::Expired;
                self.metrics.on_expired();
                let id = self.reqs[idx].id;
                self.events.push_back(ServeEvent::DeadlineExpired { id, t: now });
                if self.tracer.enabled() {
                    self.tracer.emit(&TraceEvent::Expired { id, t: now });
                }
            } else {
                i += 1;
            }
        }
    }

    // ---- the three-phase decode round (see module docs) ----

    fn decode_round(&mut self) -> Result<()> {
        // deadlines are checked at round granularity: abort before burning
        // a decode step on sequences that already missed their SLO
        self.expire_active();
        if self.active.is_empty() {
            return Ok(());
        }
        let t_dispatch = std::time::Instant::now();
        let plan = self.plan_round();
        let dispatch_s = t_dispatch.elapsed().as_secs_f64();
        let stepped = self.step_round(&plan);
        self.commit_round(plan, stepped, dispatch_s)
    }

    /// Dispatch phase (pure): which active-set indices step on which
    /// worker this round, in ascending worker order, capped at each
    /// engine's compiled batch size. Built from an immutable view, so the
    /// plan is fixed before any engine state changes.
    fn plan_round(&self) -> RoundPlan {
        let mut batches = Vec::new();
        for w in 0..self.pool.len() {
            let cap = self.pool.engine(w).max_batch();
            let mut idxs: Vec<usize> = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| a.engine_idx == w)
                .map(|(i, _)| i)
                .collect();
            // fairness: a worker whose active set exceeds its compiled
            // batch width steps a window that rotates with the round
            // counter, not a fixed prefix — taking the first `cap` in
            // stable order starved everything behind the window until an
            // early request happened to retire
            if idxs.len() > cap {
                let r = self.round_idx as usize % idxs.len();
                idxs.rotate_left(r);
                idxs.truncate(cap);
            }
            if !idxs.is_empty() {
                batches.push((w, idxs));
            }
        }
        RoundPlan { batches }
    }

    /// Step phase: decode every planned worker batch through the round
    /// executor. Each item moves that worker's batch of `&mut Active` and
    /// its forked RNG onto the executor; with `threads > 1` the batches
    /// run on scoped OS threads against their own `&mut Engine`. No
    /// frontend state outside the batches is touched — the phase returns
    /// raw per-worker results (success or failure) for the serial commit
    /// to settle; failures are NOT short-circuited here, because sibling
    /// workers may already be running on other threads and their
    /// completed work must still be committed.
    fn step_round(&mut self, plan: &RoundPlan) -> Vec<(usize, Result<WorkerStepOut>)> {
        let sampling = self.opts.sampling;
        let exec = self.opts.round_executor();
        let mut actives: Vec<Option<&mut Active>> =
            self.active.iter_mut().map(Some).collect();
        let mut rngs: Vec<Option<&mut Rng>> =
            self.worker_rngs.iter_mut().map(Some).collect();
        let work: Vec<(usize, (Vec<&mut Active>, &mut Rng))> = plan
            .batches
            .iter()
            .map(|(w, idxs)| {
                let batch: Vec<&mut Active> = idxs
                    .iter()
                    .map(|&i| actives[i].take().expect("plan indices are unique"))
                    .collect();
                let rng = rngs[*w].take().expect("plan workers are unique");
                (*w, (batch, rng))
            })
            .collect();
        self.pool.run_round(exec, work, |_w, engine, payload| {
            let (mut batch, rng) = payload;
            let mut m = StepMetrics::default();
            let mut seqs: Vec<&mut Sequence> =
                batch.iter_mut().map(|a| &mut a.seq).collect();
            engine
                .decode_step(&mut seqs, sampling, rng, &mut m)
                .map(|outs| (m, outs))
        })
    }

    /// Commit phase (serial): price each worker's step, advance the clock
    /// by the *slowest* worker while `busy` accumulates the sum (workers
    /// overlap in real time), merge metrics in fixed worker order, then
    /// emit token events, run plugins and retire finished sequences —
    /// byte-identical regardless of how the step phase executed. A failed
    /// worker aborts the round with its error, but only *after* every
    /// successful worker's results are committed (first failure in worker
    /// order wins), so successful workers' sequences stay consistent with
    /// the metrics and event stream under both executors. The failed
    /// worker's batch keeps its (possibly partial) cache state but its
    /// pins are cleared; the error is fatal for those requests — callers
    /// cancel() them to release their pages.
    fn commit_round(
        &mut self,
        plan: RoundPlan,
        stepped: Vec<(usize, Result<WorkerStepOut>)>,
        dispatch_s: f64,
    ) -> Result<()> {
        let t_commit = std::time::Instant::now();
        let round_t0 = self.clock.now();
        let mut merged = StepMetrics::default();
        let mut round_dt = 0.0f64;
        let mut rounds: Vec<(usize, Vec<usize>, Vec<SampleOut>)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        // (worker, measured step wall) pairs for the phase profile
        let mut step_walls: Vec<(usize, f64)> = Vec::new();
        for ((w, idxs), (sw, res)) in plan.batches.into_iter().zip(stepped) {
            debug_assert_eq!(w, sw, "step results follow the plan order");
            let (m, outs) = match res {
                Ok(out) => out,
                Err(e) => {
                    // the failed step may have left its batch's pages
                    // pinned (decode_step unpins at step end, which an
                    // error skips): clear them so budget enforcement and
                    // teardown can never wedge on a dead pin. The batch's
                    // requests stay Active — the caller sees the error
                    // from step()/drain() and can cancel() them, which
                    // releases their pages as usual.
                    let eng = self.pool.engine_mut(w);
                    eng.store.unpin_all();
                    // whatever tier transitions the failed step performed
                    // still happened: drain them so they cannot leak into
                    // the next round's span
                    self.drain_store_trace(
                        w,
                        SpanCtx::Round { round: self.round_idx },
                    );
                    if first_err.is_none() {
                        first_err =
                            Some(e.context(format!("decode step on worker {w}")));
                    }
                    continue;
                }
            };
            // spill_seconds / disk_seconds are the simulated q8- and
            // disk-tier transfer costs of the budgeted store
            // (hwmodel-priced, not wall time; deterministic byte counts,
            // so Modeled event streams stay seed-stable with spill on)
            let tier_s = m.spill_seconds + m.disk_seconds;
            let dt_w = match self.opts.time_model {
                TimeModel::Measured => m.step_seconds + tier_s,
                TimeModel::Modeled => {
                    Self::modeled_step_s(self.pool.engine(w), &m) + tier_s
                }
            };
            self.pool.stats[w].busy_s += dt_w;
            self.pool.stats[w].step_wall_s += m.step_seconds;
            step_walls.push((w, m.step_seconds));
            round_dt = round_dt.max(dt_w);
            self.pool.stats[w].steps += 1;
            self.pool.stats[w].new_tokens += outs.len() as u64;
            self.pool.note_kv_peak(w);
            if self.tracer.enabled() {
                // this worker's slice of the round spans [round_t0,
                // round_t0 + its own virtual step price]; the clock itself
                // advances by the slowest worker below
                self.tracer.emit(&TraceEvent::Round {
                    round: self.round_idx,
                    worker: w,
                    ids: idxs
                        .iter()
                        .map(|&i| self.reqs[self.active[i].req_idx].id)
                        .collect(),
                    t0: round_t0,
                    t1: round_t0 + dt_w,
                });
                self.drain_store_trace(
                    w,
                    SpanCtx::Round { round: self.round_idx },
                );
            }
            merged.merge(&m);
            rounds.push((w, idxs, outs));
        }
        self.clock.advance(round_dt);
        // a round where every worker failed records no step (the old
        // sequential path bailed before on_step too)
        if !rounds.is_empty() {
            self.metrics.on_step(&merged);
            // the round's virtual duration over its tokens: the bucketed
            // deterministic per-token latency
            self.metrics.on_round_dt(round_dt, merged.batch);
        }
        let now = self.clock.now();
        // token events + plugins + first-token bookkeeping, in worker
        // order then batch order — deterministic
        for (w, idxs, outs) in &rounds {
            for (&i, o) in idxs.iter().zip(outs.iter()) {
                let a = &mut self.active[i];
                if a.first_token_s.is_none() {
                    a.first_token_s = Some(now);
                    let req = &self.reqs[a.req_idx];
                    self.metrics
                        .on_first_token(now - req.arrival_s, req.tier);
                }
                self.events.push_back(ServeEvent::Token {
                    id: self.reqs[a.req_idx].id,
                    tok: o.token,
                    t: now,
                });
                // each request steps its OWN forked pipeline: plugin state
                // (entropy streaks, repetition windows) is per-request by
                // contract, and the shared template would interleave every
                // concurrent request's tokens into one streak
                let Active { seq, pipeline, .. } = a;
                let action = if pipeline.is_empty() {
                    PluginAction::Continue
                } else {
                    pipeline.on_step(&StepView {
                        seq,
                        sample: o,
                        attn_entropy: seq.last_entropy,
                        pool: &self.pool.engine(*w).pool,
                    })
                };
                match action {
                    PluginAction::Stop => seq.finished = true,
                    // routed through the page store: the eviction policy's
                    // rank picks the victim, not table order
                    PluginAction::PruneColdest => {
                        self.pool.engine_mut(*w).prune_coldest(seq)
                    }
                    PluginAction::Continue => {}
                }
            }
        }
        // stall watchdog (`--stall-rounds N`): evaluated at every commit
        // over the whole active set in index order — a request outside
        // this round's batch window made no progress by definition. The
        // event is edge-triggered per episode; the next token re-arms it.
        if self.opts.stall_rounds > 0 {
            let mut progressed = vec![false; self.active.len()];
            for (_, idxs, outs) in &rounds {
                for (&i, _) in idxs.iter().zip(outs.iter()) {
                    progressed[i] = true;
                }
            }
            for (i, a) in self.active.iter_mut().enumerate() {
                if progressed[i] {
                    a.rounds_since_progress = 0;
                    a.stall_flagged = false;
                    continue;
                }
                a.rounds_since_progress += 1;
                if a.rounds_since_progress >= self.opts.stall_rounds as u64
                    && !a.stall_flagged
                {
                    a.stall_flagged = true;
                    self.metrics.on_stalled();
                    if self.tracer.enabled() {
                        self.tracer.emit(&TraceEvent::Stalled {
                            id: self.reqs[a.req_idx].id,
                            worker: a.engine_idx,
                            rounds: a.rounds_since_progress,
                            t: now,
                        });
                    }
                }
            }
        }
        // retire finished sequences
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].seq.finished {
                let mut a = self.active.swap_remove(i);
                let idx = a.req_idx;
                let gen = tasks::decode_ids(a.seq.generated_tokens());
                if let Some(ans) = self.reqs[idx].answer.clone() {
                    let doc = tasks::Doc { prompt: String::new(), answer: ans };
                    let hit = tasks::answer_matches(&doc, &gen);
                    let ca = tasks::answer_char_accuracy(&doc, &gen);
                    self.exact_hits += hit as usize;
                    self.char_acc_sum += ca;
                    self.scored += 1;
                    if let Some(t) = self.reqs[idx].task {
                        let e = self.per_task.entry(t.name()).or_insert((0.0, 0.0, 0));
                        e.0 += hit as u8 as f64;
                        e.1 += ca;
                        e.2 += 1;
                    }
                }
                let rec = RequestRecord {
                    id: self.reqs[idx].id,
                    tier: self.reqs[idx].tier,
                    queue_seconds: a.admitted_s - self.reqs[idx].arrival_s,
                    prefill_seconds: a.prefill_s,
                    ttft_seconds: a
                        .first_token_s
                        .map(|t| t - self.reqs[idx].arrival_s)
                        .unwrap_or(0.0),
                    decode_seconds: now - a.admitted_s - a.prefill_s,
                    e2e_seconds: now - self.reqs[idx].arrival_s,
                    prompt_tokens: self.reqs[idx].prompt.len(),
                    new_tokens: a.seq.generated,
                    session_reused_tokens: a.reused_tokens,
                };
                self.metrics.on_request(&rec);
                if self.tracer.enabled() {
                    self.tracer.emit(&TraceEvent::Finished { id: rec.id, t: now });
                }
                self.events.push_back(ServeEvent::Finished(rec.clone()));
                self.records.push(rec);
                self.state[idx] = Lifecycle::Finished;
                self.router.complete(a.worker);
                self.batcher.on_finished(1);
                self.pool.stats[a.engine_idx].finished += 1;
                self.pool.engine_mut(a.engine_idx).release(&mut a.seq);
                // the request's forked pipeline drops with `a`; the shared
                // template is never reset (see `abort_active`)
            } else {
                i += 1;
            }
        }
        // the commit seam is where cross-worker movement is legal: every
        // engine's step results are settled and no step thread is live
        if self.opts.steal && first_err.is_none() {
            self.maybe_steal()?;
        }
        self.round_idx += 1;
        // periodic metrics snapshot: a schema-versioned JSONL line every N
        // committed rounds (deterministic values only, so the stream
        // double-run-diffs like the trace)
        if self.opts.metrics_every > 0
            && self.metrics_sink.is_some()
            && self.round_idx % self.opts.metrics_every as u64 == 0
        {
            let line = self
                .metrics_registry()
                .snapshot_line(self.round_idx, self.clock.now());
            if let Some(s) = self.metrics_sink.as_mut() {
                s.emit(&line);
            }
        }
        // analytics snapshots ride the same cadence (a final drain at
        // shutdown covers `--metrics-every 0` runs)
        if self.analytics_sink.is_some()
            && self.opts.metrics_every > 0
            && self.round_idx % self.opts.metrics_every as u64 == 0
        {
            self.drain_analytics();
        }
        if self.profile.is_some() {
            let commit_s = t_commit.elapsed().as_secs_f64();
            let round = self.round_idx - 1;
            if self.tracer.enabled() {
                self.tracer.emit_line(&PhaseProfile::round_line(
                    round, dispatch_s, &step_walls, commit_s,
                ));
            }
            if let Some(p) = self.profile.as_mut() {
                p.on_round(dispatch_s, &step_walls, commit_s);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Publish the run's aggregation state into a fresh registry. Only
    /// modeled-deterministic values go in (virtual-clock prices, counters,
    /// virtual-time histograms) — wall-measured signals like
    /// `step_latency` or the phase profile are exported through the
    /// Prometheus dump and `--profile` table instead, never through the
    /// double-run-diffed JSONL stream.
    pub fn metrics_registry(&self) -> MetricsRegistry {
        let m = &self.metrics;
        let mut r = MetricsRegistry::new();
        r.counter("steps", m.total_steps);
        r.counter("new_tokens", m.total_new_tokens);
        r.counter("requests_finished", m.total_requests);
        r.counter("requests_cancelled", m.total_cancelled);
        r.counter("requests_expired", m.total_expired);
        r.counter("requests_preempted", m.total_preempted);
        r.counter("requests_resumed", m.total_resumed);
        r.counter("requests_migrated", m.total_migrated);
        r.counter("requests_stolen", m.total_stolen);
        r.counter("requests_stalled", m.total_stalled);
        r.counter("gather_bytes", m.total_gather_bytes);
        r.counter("demotions", m.total_demotions);
        r.counter("promotions", m.total_promotions);
        r.counter("spill_out_bytes", m.total_spill_out_bytes);
        r.counter("spill_in_bytes", m.total_spill_in_bytes);
        r.counter("disk_faults", m.total_disk_faults);
        r.counter("readahead_hits", m.total_readahead_hits);
        r.counter("prefix_pages_adopted", m.total_prefix_pages_adopted);
        r.counter("prefix_tokens_skipped", m.total_prefix_tokens_skipped);
        r.counter("prefix_bytes_deduped", m.total_prefix_bytes_deduped);
        r.counter("budget_violations", m.budget_violations);
        r.gauge("kv_bytes_in_use", self.pool.total_kv_bytes() as f64);
        r.gauge("kv_bytes_peak", m.kv_bytes_peak as f64);
        r.gauge("active_requests", self.active.len() as f64);
        r.gauge("queued_requests", self.batcher.queue_len() as f64);
        // burn-rate gauges: virtual-clock throughput, deterministic under
        // modeled time (wall-measured rates never enter this registry)
        let wall = self.clock.now();
        let rate = |v: u64| if wall > 0.0 { v as f64 / wall } else { 0.0 };
        r.gauge("token_burn_rate", rate(m.total_new_tokens));
        r.gauge("request_burn_rate", rate(m.total_requests));
        // per-SLO-tier TTFT-target attainment (fraction of first tokens
        // inside the tier's target; 0 before the tier's first token)
        for tier in crate::workload::SloTier::all() {
            let name = match tier.rank() {
                0 => "ttft_attainment_interactive",
                1 => "ttft_attainment_batch",
                _ => "ttft_attainment_background",
            };
            r.gauge(name, m.ttft_attainment(tier).unwrap_or(0.0));
        }
        r.histogram("ttft_seconds", &m.ttft_hist);
        r.histogram("token_latency_seconds", &m.token_lat_hist);
        r.help("steps", "committed decode rounds");
        r.help("kv_bytes_in_use", "resident KV bytes across pool workers");
        r.help("requests_stalled", "stall-watchdog firings (no token progress)");
        r.help(
            "prefix_tokens_skipped",
            "prompt tokens whose prefill was skipped via shared-prefix adoption",
        );
        r.help("token_burn_rate", "new tokens per virtual second");
        r.help("request_burn_rate", "finished requests per virtual second");
        r.help(
            "ttft_attainment_interactive",
            "fraction of interactive-tier first tokens inside the TTFT target",
        );
        r.help(
            "ttft_attainment_batch",
            "fraction of batch-tier first tokens inside the TTFT target",
        );
        r.help(
            "ttft_attainment_background",
            "fraction of background-tier first tokens inside the TTFT target",
        );
        r
    }

    /// Live introspection snapshot: the payload behind the wire-level
    /// `stats` op (proto schema 3). Taken between rounds on the pump
    /// thread, so queue depths, lifecycle counts, per-worker residency and
    /// attainment are mutually consistent. The network front door merges
    /// its own net_* shed counters on top.
    pub fn live_stats(&self) -> LiveStats {
        let workers = (0..self.pool.len())
            .map(|w| {
                let eng = self.pool.engine(w);
                let (hot, cold, disk) = eng.store.tier_residency();
                WorkerKv {
                    kv_bytes_in_use: eng.store.bytes_in_use(&eng.pool) as u64,
                    pages_hot: hot as u64,
                    pages_cold: cold as u64,
                    pages_disk: disk as u64,
                }
            })
            .collect();
        let deferred = self
            .state
            .iter()
            .filter(|s| matches!(s, Lifecycle::Deferred))
            .count() as u64;
        LiveStats {
            t: self.clock.now(),
            queued_by_tier: self.batcher.queued_by_tier(),
            active: self.active.len() as u64,
            preempted: self.preempted.len() as u64,
            deferred,
            workers,
            ttft_attained: self.metrics.ttft_attained,
            ttft_total: self.metrics.ttft_tier_total,
            stalled: self.metrics.total_stalled,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let mut c = Clock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(0.5);
        c.advance_to(0.25); // never backwards
        assert_eq!(c.now(), 0.5);
        c.advance_to(1.0);
        assert_eq!(c.now(), 1.0);
        c.advance(0.125);
        assert_eq!(c.now(), 1.125);
    }

    #[test]
    fn lifecycle_terminal_states() {
        assert!(!Lifecycle::Pending.is_terminal());
        assert!(!Lifecycle::Queued.is_terminal());
        assert!(!Lifecycle::Deferred.is_terminal());
        assert!(!Lifecycle::Active.is_terminal());
        assert!(!Lifecycle::Preempted.is_terminal());
        assert!(Lifecycle::Finished.is_terminal());
        assert!(Lifecycle::Cancelled.is_terminal());
        assert!(Lifecycle::Expired.is_terminal());
    }

    #[test]
    fn event_id_extraction() {
        assert_eq!(ServeEvent::Admitted { id: 7, t: 0.0 }.id(), 7);
        assert_eq!(ServeEvent::Token { id: 9, tok: 3, t: 0.1 }.id(), 9);
        assert_eq!(ServeEvent::Preempted { id: 6, t: 0.15 }.id(), 6);
        assert_eq!(ServeEvent::Resumed { id: 6, t: 0.18 }.id(), 6);
        assert_eq!(ServeEvent::Cancelled { id: 4, t: 0.2 }.id(), 4);
        assert_eq!(ServeEvent::DeadlineExpired { id: 5, t: 0.3 }.id(), 5);
        let rec = RequestRecord {
            id: 11,
            tier: crate::workload::SloTier::Batch,
            queue_seconds: 0.0,
            prefill_seconds: 0.0,
            ttft_seconds: 0.0,
            decode_seconds: 0.0,
            e2e_seconds: 0.0,
            prompt_tokens: 0,
            new_tokens: 0,
            session_reused_tokens: 0,
        };
        assert_eq!(ServeEvent::Finished(rec).id(), 11);
    }

    #[test]
    fn event_log_header_is_versioned_and_stable() {
        let h = event_log_header(42, 4, 2, "tinyserve", Some(256.0));
        assert_eq!(
            h,
            "# tinyserve-event-log v2 seed=42 threads=4 workers=2 \
             policy=tinyserve budget=256mb"
        );
        let h = event_log_header(7, 1, 1, "full", None);
        assert!(h.ends_with("budget=unbounded"));
        assert!(h.contains(&format!("v{EVENT_LOG_SCHEMA} ")));
    }

    #[test]
    fn event_sig_is_stable_and_time_optional() {
        let tok = ServeEvent::Token { id: 3, tok: 17, t: 0.25 };
        assert_eq!(tok.sig(false), "T 3 17");
        assert_eq!(tok.sig(true), format!("T 3 17 @{:016x}", 0.25f64.to_bits()));
        let rec = RequestRecord {
            id: 2,
            tier: crate::workload::SloTier::Batch,
            queue_seconds: 0.0,
            prefill_seconds: 0.0,
            ttft_seconds: 0.0,
            decode_seconds: 0.0,
            e2e_seconds: 1.5,
            prompt_tokens: 10,
            new_tokens: 4,
            session_reused_tokens: 0,
        };
        assert_eq!(ServeEvent::Finished(rec).sig(false), "F 2 p10 n4");
        assert_eq!(ServeEvent::Deferred { id: 1, t: 0.0 }.sig(false), "D 1");
        assert_eq!(ServeEvent::Preempted { id: 8, t: 0.5 }.sig(false), "P 8");
        assert_eq!(ServeEvent::Resumed { id: 8, t: 0.75 }.sig(false), "R 8");
    }
}
